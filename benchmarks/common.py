"""Shared benchmark helpers: timing, CSV emission, HLS/RTL-analog probes.

The paper's measurement split (DESIGN.md section 2):
  RTL side  = closed-form resource/cycle model of the Pallas kernel
              (hand-scheduled => predictable by construction)
  HLS side  = measured from the XLA-compiled reference: compile wall-clock
              (synthesis time), memory_analysis temp bytes (resource
              count), cost_analysis flops/bytes (work).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

# the paired interleaved A/B estimator is canonical in repro.core.autotune
# (the tuner must measure candidates the same way the CI gate re-measures
# the winners); re-exported here for the benchmark suite
from repro.core.autotune import paired_times as paired_times
from repro.kernels import ops, packing, ref


def time_call(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall-clock seconds per call (after warmup, block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))




def compile_probe(fn, *arg_shapes) -> dict:
    """Lower+compile with abstract args; returns times + memory analysis."""
    t0 = time.perf_counter()
    lowered = jax.jit(fn).lower(*arg_shapes)
    t_lower = time.perf_counter() - t0
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t1
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return {
        "lower_s": t_lower,
        "compile_s": t_compile,
        "total_s": t_lower + t_compile,
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "arg_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }


def make_operands(mode: str, m: int, n: int, k: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    if mode == "xnor":
        a = packing.pack_bits(jnp.asarray(rng.integers(0, 2, (m, k)), jnp.int32))
        w = packing.pack_bits(jnp.asarray(rng.integers(0, 2, (n, k)), jnp.int32))
    elif mode == "binary":
        a = jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(0, 2, (n, k)), jnp.int8)
    else:
        a = jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(-8, 8, (n, k)), jnp.int8)
    return a, w


def hls_ref_fn(mode: str, k: int):
    if mode == "xnor":
        return lambda a, w: ref.mvu_xnor_ref(a, w, k)
    if mode == "binary":
        return ref.mvu_binary_ref
    return ref.mvu_int_ref


def rtl_kernel_fn(mode: str, k: int, blocks: dict):
    def f(a, w):
        return ops.mvu(a, w, mode, k_bits=k if mode == "xnor" else None, **blocks)
    return f


def emit_json(record: dict, path: str | None = None) -> None:
    """Write one benchmark record as pretty JSON (the committed-baseline /
    regression-gate format; see scripts/check_bench_regression.py)."""
    if not path:
        return
    import json
    import os

    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        # default=float absorbs stray numpy scalars from cost analyses
        json.dump(record, f, indent=2, sort_keys=True, default=float)
        f.write("\n")


def emit(rows: list[dict], path: str | None = None) -> None:
    if not rows:
        return
    keys = list(rows[0].keys())
    lines = [",".join(keys)]
    for r in rows:
        lines.append(",".join(str(r.get(k, "")) for k in keys))
    text = "\n".join(lines)
    print(text)
    if path:
        import os

        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(text + "\n")
