"""Telemetry overhead benchmark: the zero-overhead-when-disabled contract.

Two arms of the SAME open-loop Poisson serving load (the serving_load
workload at 0.5x engine capacity), paired per round:

  off   ``tracer=None`` / ``drift=None`` -- the disabled path every
        component ships by default (one ``is not None`` test per site),
  on    a live :class:`repro.telemetry.Tracer` (request-lifecycle spans,
        async events, counters) plus a :class:`DriftMonitor` fed by every
        resolved batch.

The committed claim (``ceiling_only`` absolute gate):

  * ``tracing_overhead`` <= 0.05: enabling full telemetry costs at most 5%
    of completion throughput under the realistic (arrival-paced) load --
    the ratio is a median of per-round paired ratios, so one scheduler
    stall cannot own the number.

The per-event emit cost and the p99 impact are reported as informational
fields.  The "off" arm IS the zero-overhead measurement: it runs the
identical instrumented code with every tracer site disabled, so the gate
asserts the whole instrumented serving stack -- admission, dispatch,
harvest -- against itself, not against a de-instrumented build.

Usage:
    python -m benchmarks.telemetry_overhead [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.engine_throughput import nid_accelerator
from benchmarks.serving_load import poisson_arrivals, run_continuous
from repro.telemetry import DriftMonitor, Tracer

POLL_SLEEP_S = 2e-4


def emit_cost_us(n: int = 50000) -> float:
    """Microbenchmark: seconds -> microseconds per async-event emission."""
    tr = Tracer(capacity=n)
    t0 = time.perf_counter()
    for i in range(n):
        tr.begin_async("request", i, cat="request", tier="gold")
    return (time.perf_counter() - t0) / n * 1e6


def run(*, requests: int = 512, rounds: int = 5, seed: int = 0,
        load: float = 0.5,
        out: str | None = "experiments/bench/telemetry_overhead.json") -> dict:
    buckets = (1, 8, 32, 128)
    acc = nid_accelerator(seed, target="serving",
                          calibrate_batch=buckets[-1], calibrate_reps=3)
    rng = np.random.default_rng(seed + 1)
    xs = rng.integers(0, 4, (requests, 600)).astype(np.int32)

    cal = acc.calibration
    t_exec = cal["measured_s"]
    slo_s = max(8 * t_exec, 0.02)
    rate_hz = min(load * buckets[-1] / t_exec, 2000.0)
    arrivals = poisson_arrivals(requests, rate_hz, rng)

    off_runs, on_runs = [], []
    for _ in range(max(1, rounds)):
        off_runs.append(run_continuous(
            acc, xs, arrivals, buckets=buckets, slo_s=slo_s))
        tracer = Tracer(capacity=1 << 17)
        on_runs.append(run_continuous(
            acc, xs, arrivals, buckets=buckets, slo_s=slo_s, tracer=tracer))
        on_runs[-1]["trace_events"] = len(tracer)

    def med(vals):
        return float(np.median(vals))

    def pct(res, p):
        return float(np.percentile(res["lat_s"], p)) * 1e3

    overhead = med([off["samples_per_s"] / on["samples_per_s"] - 1.0
                    for off, on in zip(off_runs, on_runs)])
    record = {
        "config": "nid_mlp_600_64_64_64_1_2bit",
        "requests": requests,
        "rounds": int(rounds),
        "rate_hz": float(rate_hz),
        "load": float(load),
        "slo_ms": slo_s * 1e3,
        "buckets": list(buckets),
        # gated claim ---------------------------------------------------
        "ceiling_only": ["tracing_overhead"],
        "tracing_overhead": overhead,
        "max_tracing_overhead": 0.05,
        # informational -------------------------------------------------
        "emit_cost_us": emit_cost_us(),
        "trace_events_per_run": on_runs[0]["trace_events"],
        "off_samples_per_s": med([r["samples_per_s"] for r in off_runs]),
        "on_samples_per_s": med([r["samples_per_s"] for r in on_runs]),
        "p99_on_vs_off": med([pct(on, 99) / pct(off, 99)
                              for off, on in zip(off_runs, on_runs)]),
        "t_exec_s": t_exec,
        "s_per_cycle": cal["s_per_cycle"],
    }
    if out:
        out_dir = os.path.dirname(out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--rounds", type=int, default=5,
                    help="paired off/on rounds; the gated ratio is a median")
    ap.add_argument("--load", type=float, default=0.5,
                    help="fraction of one-replica capacity for the rate")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="small request count (CI)")
    ap.add_argument("--out", default="experiments/bench/telemetry_overhead.json")
    args = ap.parse_args()
    requests = min(args.requests, 256) if args.quick else args.requests
    rec = run(requests=requests, rounds=args.rounds, seed=args.seed,
              load=args.load, out=args.out)
    print(json.dumps(rec, indent=2))
    print(f"# telemetry overhead {rec['tracing_overhead']*100:.2f}% "
          f"(ceiling 5%); emit cost {rec['emit_cost_us']:.2f}us/event")


if __name__ == "__main__":
    main()
