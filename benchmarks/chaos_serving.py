"""Chaos benchmark: the hardened serving path vs the pre-hardening baseline
under the same deterministic fault plan.

The same open-loop Poisson load (80% gold / 20% best-effort tier) is driven
through two ``ContinuousBatcher`` arms over a 4-logical-replica pool on one
device, both injected with the identical committed :class:`FaultPlan`:

  hardened   the default ``FaultPolicy`` plus hedging: retries with
             deadline awareness, dispatch timeouts, integrity guard,
             canary-probe recovery, brownout tiering.
  baseline   ``FaultPolicy.disabled()`` -- the pre-hardening behavior
             (failed dispatches still resolve as shed; that fix is
             unconditional).

The fault plan mixes background rates (dispatch errors, output corruption,
stragglers) with three explicit events: a replica dies, a replica hangs
once, and a guaranteed output corruption.  The committed claims
(``scripts/check_bench_regression.py`` absolute gates):

  * ``corrupted_delivered`` == 0: the hardened arm never delivers a
    corrupted result (every delivered row bit-exact with the engine),
  * ``gold_completion_rate`` >= 0.99: gold-tier requests complete within
    their deadline despite the chaos,
  * ``baseline_failure_modes`` >= 1: the SAME plan demonstrably breaks the
    baseline (corrupted deliveries, stuck requests on the hung replica,
    and/or gold completion collapse) -- the A/B proof the hardening is
    load-bearing, not incidental.

The hardened arm always runs with telemetry wired (a
:class:`repro.telemetry.Tracer` plus a :class:`DriftMonitor`): the chaos
run doubles as the observability acceptance test.  Two more gated claims:

  * ``straggler_flagged`` >= 1: the scripted straggle on replica 1 is
    flagged by the drift monitor (``flagged_ever`` latches even though
    hedging hides the straggler's completion -- censored lower bounds),
  * ``trace_fault_annotations`` >= 3: retries / hedges / timeouts /
    corrupt batches / quarantines appear as instant events on the trace.

``--trace PATH`` additionally exports the Chrome trace-event JSON
(perfetto-viewable; CI uploads it as an artifact).

The record embeds the full fault-plan JSON: re-running with it reproduces
the identical fault schedule (draws are pure functions of
``(seed, replica, dispatch_index)``), which is what makes a chaos failure
on CI debuggable instead of a flake.

Usage:
    python -m benchmarks.chaos_serving [--quick] [--soak] [--out PATH]
                                       [--trace PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.engine_throughput import nid_accelerator
from repro.serving import (
    BEST_EFFORT,
    GOLD,
    ContinuousBatcher,
    FaultEvent,
    FaultPlan,
    FaultPolicy,
    ReplicaPool,
)
from repro.telemetry import DriftMonitor, Tracer

POLL_SLEEP_S = 2e-4
N_REPLICAS = 4


def build_fault_plan(seed: int, t_exec: float, *, soak: bool = False) -> FaultPlan:
    """Background chaos + four scripted catastrophes.  ``soak`` raises the
    background rates for the nightly long run."""
    scale = 2.0 if soak else 1.0
    return FaultPlan(
        seed=seed,
        rates={"error": 0.04 * scale, "corrupt": 0.05 * scale,
               "straggle": 0.04 * scale},
        straggle_delay_s=max(6.0 * t_exec, 0.02),
        events=[
            FaultEvent("corrupt", replica=0, at_dispatch=1),
            # the drift-monitor acceptance case: a scripted straggle well
            # past the drift band (8x the calibrated max-bucket time; the
            # dispatch timeout is 10x so it resolves, late) -- whether the
            # late completion is observed directly or hidden by a winning
            # hedge, replica 1 must end up in ``flagged_ever``
            FaultEvent("straggle", replica=1, at_dispatch=1,
                       delay_s=max(8.0 * t_exec, 0.03)),
            FaultEvent("hang", replica=2, at_dispatch=1),
            FaultEvent("die", replica=3, at_dispatch=2),
        ],
    )


def drive(batcher: ContinuousBatcher, xs, arrivals, tiers, *,
          horizon_s: float) -> dict:
    """Open-loop drive: submit each arrival on its own clock, poll
    continuously, stop when everything resolved or the horizon passes
    (the baseline's hung flight never resolves -- the horizon is what
    lets the un-hardened arm terminate at all)."""
    n = len(arrivals)
    rids: list[int] = []
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter()
        if i < n and now >= t0 + arrivals[i]:
            rids.append(batcher.submit(xs[i], tier=tiers[i]))
            i += 1
            batcher.poll()
            continue
        batcher.poll()
        if i >= n and (batcher.outstanding == 0 or now - t0 > horizon_s):
            break
        time.sleep(POLL_SLEEP_S)
    wall_s = time.perf_counter() - t0
    return {"rids": rids, "wall_s": wall_s,
            "snapshot": batcher.metrics.snapshot(),
            "health": batcher.pool.health_snapshot()}


def evaluate(run: dict, batcher: ContinuousBatcher, tiers, want) -> dict:
    """Per-arm outcome accounting against the golden engine outputs."""
    rids = run["rids"]
    delivered = corrupted = 0
    gold_total = gold_ok = 0
    stuck = 0
    for i, rid in enumerate(rids):
        r = batcher.results.get(rid)
        if tiers[i] == GOLD:
            gold_total += 1
        if r is None:
            stuck += 1  # never resolved: parked on a hung replica
            continue
        if r.out is None:
            continue  # shed (counted via availability)
        delivered += 1
        if not np.array_equal(r.out, want[i]):
            corrupted += 1
        elif tiers[i] == GOLD and not r.missed_deadline:
            gold_ok += 1
    return {
        "delivered": delivered,
        "corrupted_delivered": corrupted,
        "stuck_requests": stuck,
        "gold_completion_rate": gold_ok / gold_total if gold_total else 1.0,
        "availability": run["snapshot"]["availability"],
    }


FAULT_ANNOTATIONS = ("retry", "hedge", "timeout", "corrupt_batch",
                     "quarantine", "dispatch_failure")


def run(*, requests: int = 160, seed: int = 0, load: float = 0.25,
        soak: bool = False, trace: str | None = None,
        out: str | None = "experiments/bench/chaos_serving.json") -> dict:
    buckets = (1, 8, 32)
    acc = nid_accelerator(seed, target="serving",
                          calibrate_batch=buckets[-1], calibrate_reps=3)
    engine = acc.engine
    cal = acc.calibration
    t_exec = cal["measured_s"]  # one max-bucket engine call, this machine

    rng = np.random.default_rng(seed + 1)
    xs = rng.integers(0, 4, (requests, 600)).astype(np.int32)
    want = np.asarray(jax.block_until_ready(engine(jnp.asarray(xs))))
    rate_hz = min(load * buckets[-1] / t_exec, 2000.0)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, requests))
    tiers = [BEST_EFFORT if rng.uniform() < 0.2 else GOLD
             for _ in range(requests)]

    slo_s = max(40.0 * t_exec, 0.25)
    plan = build_fault_plan(seed + 2201, t_exec, soak=soak)
    horizon_s = float(arrivals[-1]) + max(80.0 * t_exec, 2.0)
    device = jax.local_devices()[0]

    hardened_policy = FaultPolicy(
        max_retries=4, retry_backoff_s=0.0,
        dispatch_timeout_s=max(10.0 * t_exec, 0.05),
        hedging=True, hedge_after_s=max(4.0 * t_exec, 0.02),
        probe_backoff_s=max(2.0 * t_exec, 0.01),
    )

    def make_batcher(policy: FaultPolicy, *, tracer=None,
                     drift=None) -> ContinuousBatcher:
        pool = ReplicaPool(engine, devices=[device] * N_REPLICAS,
                           faults=plan, policy=policy, tracer=tracer)
        return ContinuousBatcher(
            engine, batch_buckets=buckets, slo_s=slo_s, pool=pool,
            fault_policy=policy, cache=acc.cache,
            queue_capacity=max(256, requests),
            result_capacity=max(8192, 4 * requests),
            tracer=tracer, drift=drift).warmup()

    # the hardened arm carries full telemetry (the chaos run doubles as the
    # observability acceptance test); the baseline arm stays untraced
    tracer = Tracer(capacity=1 << 18,
                    meta={"benchmark": "chaos_serving", "seed": seed,
                          "fault_seed": plan.seed})
    drift = DriftMonitor()
    hardened = make_batcher(hardened_policy, tracer=tracer, drift=drift)
    h_run = drive(hardened, xs, arrivals, tiers, horizon_s=horizon_s)
    h = evaluate(h_run, hardened, tiers, want)

    flagged_ever = sorted(drift.flagged_ever())
    annotations = {name: 0 for name in FAULT_ANNOTATIONS}
    for ev in tracer.events():
        if ev["ph"] == "i" and ev["name"] in annotations:
            annotations[ev["name"]] += 1
    if trace:
        tracer.save(trace)

    baseline = make_batcher(FaultPolicy.disabled())
    b_run = drive(baseline, xs, arrivals, tiers, horizon_s=horizon_s)
    b = evaluate(b_run, baseline, tiers, want)

    baseline_failure_modes = sum([
        b["corrupted_delivered"] > 0,
        b["stuck_requests"] > 0,
        b["gold_completion_rate"] < 0.99,
    ])

    snap = h_run["snapshot"]
    record = {
        "config": "nid_mlp_600_64_64_64_1_2bit",
        "requests": requests,
        "replicas": N_REPLICAS,
        "buckets": list(buckets),
        "seed": seed,
        "soak": bool(soak),
        "rate_hz": float(rate_hz),
        "slo_ms": slo_s * 1e3,
        "gold_fraction": tiers.count(GOLD) / requests,
        # the committed chaos schedule: re-running with this plan replays
        # the identical fault at the identical (replica, dispatch) slots
        "fault_plan": plan.to_json(),
        # gated claims -------------------------------------------------
        "bit_exact": h["corrupted_delivered"] == 0 and h["delivered"] > 0,
        "ceiling_only": ["corrupted_delivered"],
        "corrupted_delivered": h["corrupted_delivered"],
        "max_corrupted_delivered": 0,
        "floor_only": ["gold_completion_rate", "baseline_failure_modes",
                       "straggler_flagged", "trace_fault_annotations"],
        "gold_completion_rate": h["gold_completion_rate"],
        "min_gold_completion_rate": 0.99,
        "baseline_failure_modes": baseline_failure_modes,
        "min_baseline_failure_modes": 1,
        # telemetry claims: the scripted straggle on replica 1 is flagged
        # by the drift monitor, and the fault machinery is visible on the
        # trace as instant annotations
        "straggler_flagged": int("replica:1" in flagged_ever),
        "min_straggler_flagged": 1,
        "trace_fault_annotations": sum(annotations.values()),
        "min_trace_fault_annotations": 3,
        # hardened-arm outcome ------------------------------------------
        "availability": h["availability"],
        "stuck_requests": h["stuck_requests"],
        "retries": snap["retries"],
        "hedges": snap["hedges"],
        "hedge_wins": snap["hedge_wins"],
        "timeouts": snap["timeouts"],
        "corrupt_batches_caught": snap["corrupt_batches"],
        "dispatch_failures": snap["dispatch_failures"],
        "quarantines": snap["quarantines"],
        "probes": snap["probes"],
        "recoveries": snap["recoveries"],
        "brownout_shed": snap["brownout_shed"],
        "p99_ms": snap["p99_ms"],
        "wall_s": h_run["wall_s"],
        # baseline arm under the SAME plan ------------------------------
        "baseline_corrupted_delivered": b["corrupted_delivered"],
        "baseline_stuck_requests": b["stuck_requests"],
        "baseline_gold_completion_rate": b["gold_completion_rate"],
        "baseline_availability": b["availability"],
        "baseline_wall_s": b_run["wall_s"],
        "t_exec_s": t_exec,
        "s_per_cycle": cal["s_per_cycle"],
        # telemetry detail (informational) ------------------------------
        "trace_annotations": annotations,
        "trace_events": len(tracer),
        "trace_dropped": tracer.dropped,
        "drift_flagged_ever": flagged_ever,
        "drift": drift.status(),
    }
    if out:
        out_dir = os.path.dirname(out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--load", type=float, default=0.25,
                    help="fraction of one-replica capacity for the rate")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="small request count (CI)")
    ap.add_argument("--soak", action="store_true",
                    help="nightly long run: more requests, higher fault rates")
    ap.add_argument("--out", default="experiments/bench/chaos_serving.json")
    ap.add_argument("--trace", default=None,
                    help="write the hardened arm's Chrome trace JSON here")
    args = ap.parse_args()
    requests = args.requests
    if requests is None:
        requests = 600 if args.soak else (128 if args.quick else 160)
    record = run(requests=requests, seed=args.seed, load=args.load,
                 soak=args.soak, trace=args.trace, out=args.out)
    pretty = {k: v for k, v in record.items() if k not in ("fault_plan", "drift")}
    print(json.dumps(pretty, indent=2))


if __name__ == "__main__":
    main()
