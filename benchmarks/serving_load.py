"""Serving-load benchmark: continuous batching vs the legacy submit/flush path.

Two front-ends over the same ``FusedEngine`` on the NID-MLP config, driven
by the same open-loop Poisson arrival schedule (requests arrive on their
own clock whether or not the server keeps up -- the tail-latency-honest
load model):

  server    the legacy ``EngineServer`` driven the only way a manual
            submit/flush API can be: flush on a fixed cadence.  The cadence
            is set to the SLO window -- flushing faster shrinks batches and
            costs throughput, flushing slower misses every deadline.
  serving   ``repro.serving.ContinuousBatcher``: bounded admission, flush
            on bucket-fill / pipeline-idle / deadline-slack, async
            least-loaded dispatch, resolution off the critical path.

The claim the record commits to: the continuous path is bit-exact with
direct engine calls, completes the open-loop load at >= 1.0x the legacy
throughput, and holds a strictly better p99 latency (``p99_vs_server`` < 1,
gated as a lower-is-better metric by scripts/check_bench_regression.py).
A closed-loop (fixed-concurrency) generator reports saturation throughput
for both paths as informational fields.

Usage:
    python -m benchmarks.serving_load [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.engine_throughput import nid_accelerator

POLL_SLEEP_S = 2e-4  # idle-loop tick for both drivers


def poisson_arrivals(n: int, rate_hz: float, rng) -> np.ndarray:
    """Open-loop Poisson process: cumulative arrival offsets in seconds."""
    return np.cumsum(rng.exponential(1.0 / rate_hz, n))


def _make_server(engine, buckets):
    from repro.launch.serve import EngineServer

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return EngineServer(engine, batch_buckets=buckets)


def run_engine_server(engine, xs, arrivals, *, buckets, flush_period_s):
    """Open-loop drive of the legacy server: submit on arrival, flush on
    the fixed cadence (its only possible policy)."""
    server = _make_server(engine, buckets)
    server._batcher.warmup()
    n = len(arrivals)
    done = []
    t0 = time.perf_counter()
    next_flush = t0 + flush_period_s
    i = 0
    # O(1) queue-depth probe: the shim's _pending property rebuilds the
    # full rid list per tick, which would tax only the legacy side's loop
    while i < n or server._batcher.queue.depth:
        now = time.perf_counter()
        if i < n and now >= t0 + arrivals[i]:
            server.submit(xs[i])
            i += 1
            continue
        if now >= next_flush:
            done.extend(server.flush())
            next_flush = now + flush_period_s
            continue
        wait = next_flush - now
        if i < n:
            wait = min(wait, t0 + arrivals[i] - now)
        if wait > 0:
            time.sleep(min(wait, POLL_SLEEP_S))
    lat = np.array([r.t_done - r.t_submit for r in done])
    t_last = max(r.t_done for r in done)
    outs = np.stack([r.out for r in sorted(done, key=lambda r: r.rid)])
    return {"lat_s": lat, "outs": outs, "samples_per_s": n / (t_last - t0),
            "stats": dict(server.stats)}


def run_continuous(acc, xs, arrivals, *, buckets, slo_s, tracer=None):
    """Open-loop drive of the serving subsystem: submit on arrival, poll
    continuously; the batcher decides every flush itself."""
    n = len(arrivals)
    batcher = acc.serve(batch_buckets=buckets, slo_s=slo_s,
                        result_capacity=max(8192, n), tracer=tracer)
    t0 = time.perf_counter()
    i = 0
    while i < n or batcher.outstanding:
        now = time.perf_counter()
        if i < n and now >= t0 + arrivals[i]:
            batcher.submit(xs[i])
            i += 1
            batcher.poll()
            continue
        batcher.poll()
        if i < n:
            wait = t0 + arrivals[i] - time.perf_counter()
            if wait > 0:
                time.sleep(min(wait, POLL_SLEEP_S))
        elif batcher.outstanding:
            time.sleep(POLL_SLEEP_S)
    batcher.drain()
    reqs = sorted(batcher.results.values(), key=lambda r: r.rid)
    lat = np.array([r.latency_s for r in reqs])
    t_last = max(r.t_done for r in reqs)
    outs = np.stack([r.out for r in reqs])
    return {"lat_s": lat, "outs": outs, "samples_per_s": n / (t_last - t0),
            "snapshot": batcher.metrics.snapshot()}


def run_closed_loop(acc, xs, *, buckets, total, continuous):
    """Fixed-concurrency (2 max-size bursts) saturation throughput."""
    cap = buckets[-1]
    n = len(xs)
    submitted = completed = 0
    if continuous:
        batcher = acc.serve(batch_buckets=buckets,
                            result_capacity=max(8192, total))
        t0 = time.perf_counter()
        while completed < total:
            while submitted < total and batcher.outstanding < 2 * cap:
                take = min(cap, total - submitted, n)
                batcher.submit_batch(xs[:take])
                submitted += take
            completed += len(batcher.poll())
        batcher.drain()
    else:
        server = _make_server(acc.engine, buckets)
        server._batcher.warmup()
        t0 = time.perf_counter()
        while completed < total:
            take = min(cap, total - submitted, n)
            server.submit_batch(xs[:take])
            submitted += take
            completed += len(server.flush())
    return total / (time.perf_counter() - t0)


def run(*, requests: int = 1024, rounds: int = 3, rate_hz: float | None = None,
        slo_ms: float | None = None, seed: int = 0, load: float = 0.5,
        closed_total: int | None = None, traced: bool = False,
        out: str | None = "experiments/bench/serving_load.json") -> dict:
    buckets = (1, 8, 32, 128)
    # the serving-target build calibrates the realized cycle time into the
    # accelerator's cache, so every batcher's flush budgets (and the
    # arrival rate / SLO below) are in this machine's wall-clock units
    acc = nid_accelerator(seed, target="serving",
                          calibrate_batch=buckets[-1], calibrate_reps=3)
    engine = acc.engine
    rng = np.random.default_rng(seed + 1)
    xs = rng.integers(0, 4, (requests, 600)).astype(np.int32)

    cal = acc.calibration
    t_exec = cal["measured_s"]  # one max-bucket engine call
    slo_s = (slo_ms / 1e3) if slo_ms is not None else max(8 * t_exec, 0.02)
    capacity_hz = buckets[-1] / t_exec
    rate_hz = rate_hz if rate_hz is not None else min(load * capacity_hz, 2000.0)
    arrivals = poisson_arrivals(requests, rate_hz, rng)

    # both drivers warm their bucket shape grids before their timed loops
    # (jax.block_until_ready keeps the reference run out of their timings)
    want = np.asarray(jax.block_until_ready(engine(jnp.asarray(xs))))

    # paired rounds, median ratios: one scheduler stall landing on either
    # side would otherwise own the p99 of a single round (the same
    # one-sided-noise reasoning as autotune.paired_times)
    # ``traced`` wires a live Tracer into the continuous arm's timed loop:
    # the gated speedup / p99 ratios then hold WITH telemetry enabled (the
    # dedicated overhead measurement is benchmarks.telemetry_overhead)
    tracer = None
    if traced:
        from repro.telemetry import Tracer

        tracer = Tracer(capacity=1 << 17,
                        meta={"benchmark": "serving_load", "seed": seed})
    server_runs, serving_runs = [], []
    for _ in range(max(1, rounds)):
        server_runs.append(run_engine_server(
            engine, xs, arrivals, buckets=buckets, flush_period_s=slo_s))
        serving_runs.append(run_continuous(
            acc, xs, arrivals, buckets=buckets, slo_s=slo_s, tracer=tracer))

    bit_exact = all(np.array_equal(sv["outs"], want)
                    and np.array_equal(se["outs"], want)
                    for sv, se in zip(serving_runs, server_runs))
    closed_total = closed_total if closed_total is not None else 4 * requests
    closed_serving = run_closed_loop(acc, xs, buckets=buckets,
                                     total=closed_total, continuous=True)
    closed_server = run_closed_loop(acc, xs, buckets=buckets,
                                    total=closed_total, continuous=False)

    def pct(res, p):
        return float(np.percentile(res["lat_s"], p)) * 1e3

    def med(vals):
        return float(np.median(vals))

    record = {
        "config": "nid_mlp_600_64_64_64_1_2bit",
        "requests": requests,
        "rounds": int(rounds),
        "rate_hz": float(rate_hz),
        "slo_ms": slo_s * 1e3,
        "buckets": list(buckets),
        "bit_exact": bit_exact,
        # open-loop completion throughput: median of per-round paired
        # machine-normalized ratios (gated)
        "speedup": med([sv["samples_per_s"] / se["samples_per_s"]
                        for sv, se in zip(serving_runs, server_runs)]),
        "min_speedup": 1.0,
        # tail latency: median of per-round paired p99 ratios,
        # lower-is-better (gated strictly below 1.0)
        "lower_is_better": ["p99_vs_server"],
        "p99_vs_server": med([pct(sv, 99) / pct(se, 99)
                              for sv, se in zip(serving_runs, server_runs)]),
        "max_p99_vs_server": 1.0,
        # absolute numbers (informational -- vary with the CI runner)
        "serving_p50_ms": med([pct(r, 50) for r in serving_runs]),
        "serving_p95_ms": med([pct(r, 95) for r in serving_runs]),
        "serving_p99_ms": med([pct(r, 99) for r in serving_runs]),
        "server_p50_ms": med([pct(r, 50) for r in server_runs]),
        "server_p99_ms": med([pct(r, 99) for r in server_runs]),
        "serving_samples_per_s": med([r["samples_per_s"] for r in serving_runs]),
        "server_samples_per_s": med([r["samples_per_s"] for r in server_runs]),
        "closed_loop_serving_samples_per_s": float(closed_serving),
        "closed_loop_server_samples_per_s": float(closed_server),
        "serving_deadline_miss_rate": med(
            [r["snapshot"]["deadline_misses"] / requests for r in serving_runs]),
        "server_deadline_miss_rate": med(
            [float(np.mean(r["lat_s"] > slo_s)) for r in server_runs]),
        "serving_padding_overhead": med(
            [r["snapshot"]["padding_overhead"] for r in serving_runs]),
        "server_flushes": server_runs[0]["stats"]["flushes"],
        "serving_flushes": serving_runs[0]["snapshot"]["flushes"],
        "s_per_cycle": cal["s_per_cycle"],
        "traced": bool(traced),
    }
    if tracer is not None:
        record["trace_events"] = len(tracer)
        record["trace_dropped"] = tracer.dropped
    if out:
        out_dir = os.path.dirname(out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--rounds", type=int, default=3,
                    help="paired A/B rounds; gated ratios are medians")
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate (req/s); default 0.5x engine capacity")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="latency SLO; default 8x one max-bucket engine call")
    ap.add_argument("--load", type=float, default=0.5,
                    help="fraction of engine capacity for the auto rate")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="small request count (CI smoke)")
    ap.add_argument("--traced", action="store_true",
                    help="run the continuous arm with a live Tracer wired in")
    ap.add_argument("--out", default="experiments/bench/serving_load.json")
    args = ap.parse_args()
    requests = args.requests
    closed_total = None
    if args.quick:
        requests, closed_total = min(requests, 256), 1024

    rec = run(requests=requests, rounds=args.rounds, rate_hz=args.rate,
              slo_ms=args.slo_ms, seed=args.seed, load=args.load,
              closed_total=closed_total, traced=args.traced, out=args.out)
    print(json.dumps(rec, indent=2))
    print(f"# serving p99 {rec['serving_p99_ms']:.2f}ms vs server p99 "
          f"{rec['server_p99_ms']:.2f}ms (ratio {rec['p99_vs_server']:.2f}); "
          f"open-loop throughput {rec['speedup']:.2f}x; "
          f"bit_exact={rec['bit_exact']}")


if __name__ == "__main__":
    main()
