"""Paper Fig 16: total synthesis time vs design size.

The paper's mechanism: HLS synthesizes the *whole generated design* and its
compile time grows superlinearly with design size, while RTL units are
modular (each MVU instance is the same hand-written module, synthesized
once per parameterization).  The TPU analog:

  HLS side = XLA compile of the full generated dataflow graph (a chain of
             L MVU layers lowered from the jnp reference) -- one monolithic
             compile whose time grows with L and with PE/SIMD-dependent
             shapes.
  RTL side = Pallas kernel compiles: one per distinct (mode, block-shape)
             parameterization, CACHED across instances -- adding layers
             with the same folding adds zero compile time.

Two sweeps feed the Fig 16 bars: (a) chain length L at fixed folding,
(b) PE/SIMD at fixed L=1.  The end-to-end caching result (cold autotune
sweep vs warm cache replay, the paper's ~10x out-of-context saving) lives
in the design-space explorer's record (``repro.explore`` ->
``experiments/explore/``); this benchmark isolates the compile-time
mechanism.  ``run_quick`` writes the JSON record the regression gate pairs
with the committed baseline.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import compile_probe, emit_json, rtl_kernel_fn
from repro.core.folding import Folding, to_tpu_blocks
from repro.kernels import ref


def _chain_fn(l: int, n: int):
    def f(a, ws):
        h = a
        for i in range(l):
            h = ref.mvu_int_ref(h, ws[i]).astype(jnp.int8)  # requantize analog
        return h
    return f


def run_chain(lengths=(1, 2, 4, 8, 16, 32), n=64) -> list[dict]:
    rows = []
    rtl_cache: dict = {}
    for l in lengths:
        # n != k would break chaining; use square layers (n == k) past layer 0
        hls = compile_probe(_chain_fn(l, n),
                            jax.ShapeDtypeStruct((128, n), jnp.int8),
                            jax.ShapeDtypeStruct((l, n, n), jnp.int8))
        # RTL: one kernel parameterization reused by every layer in the chain
        t0 = time.perf_counter()
        key = ("standard", 32, 32)
        if key not in rtl_cache:
            blocks = to_tpu_blocks(Folding(32, 32), "standard")
            rtl_cache[key] = compile_probe(
                rtl_kernel_fn("standard", n, blocks),
                jax.ShapeDtypeStruct((128, n), jnp.int8),
                jax.ShapeDtypeStruct((n, n), jnp.int8),
            )["total_s"]
        rtl_s = rtl_cache[key] + (time.perf_counter() - t0)
        rows.append({
            "sweep": "chain_length", "value": l,
            "hls_compile_s": round(hls["total_s"], 4),
            "rtl_compile_s": round(rtl_s, 4),
            "hls_over_rtl": round(hls["total_s"] / max(rtl_s, 1e-9), 2),
        })
    return rows


def run_folding(values=(2, 8, 32, 64), n=64, k=1024) -> list[dict]:
    """PE/SIMD sweep at one layer: each folding is a new RTL
    parameterization (compiled) but the same HLS reference shape."""
    rows = []
    for v in values:
        blocks = to_tpu_blocks(Folding(v, 64), "standard")
        a_s = jax.ShapeDtypeStruct((128, k), jnp.int8)
        w_s = jax.ShapeDtypeStruct((n, k), jnp.int8)
        hls = compile_probe(lambda a, w: ref.mvu_int_ref(a, w), a_s, w_s)
        rtl = compile_probe(rtl_kernel_fn("standard", k, blocks), a_s, w_s)
        rows.append({
            "sweep": "pe", "value": v,
            "hls_compile_s": round(hls["total_s"], 4),
            "rtl_compile_s": round(rtl["total_s"], 4),
        })
    return rows


def run(lengths=(1, 2, 4, 8, 16, 32), folding_values=(2, 8, 32, 64),
        quick: bool = False, out: str | None = None) -> dict:
    chain = run_chain(lengths)
    folding = run_folding(folding_values)
    first, last = chain[0], chain[-1]
    record = {
        "name": "synthesis_time",
        "quick": quick,
        "chain": chain,
        "folding": folding,
        # wall-clock shapes vary across runners, so these stay informational
        # (not gated); the mechanism claim -- modular RTL reuse beats the
        # monolithic compile at depth -- is what the figure renders
        "hls_growth": round(last["hls_compile_s"] /
                            max(first["hls_compile_s"], 1e-9), 2),
        "hls_over_rtl_at_depth": last["hls_over_rtl"],
        "summary": f"chain L={first['value']}..{last['value']}: "
                   f"hls {first['hls_compile_s']}s -> {last['hls_compile_s']}s, "
                   f"rtl flat {last['rtl_compile_s']}s "
                   f"({last['hls_over_rtl']}x at depth)",
    }
    emit_json(record, out)
    return record


def run_quick(out_dir: str | None = None) -> dict:
    out = f"{out_dir}/synthesis_time.json" if out_dir else None
    return run(lengths=(1, 4, 8), folding_values=(2, 32), quick=True, out=out)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/bench/synthesis_time.json")
    args = ap.parse_args()
    rec = (run(lengths=(1, 4, 8), folding_values=(2, 32), quick=True,
               out=args.out) if args.quick else run(out=args.out))
    print(f"# {rec['summary']}")


if __name__ == "__main__":
    main()
