"""Paper Fig 14: heat map of resource difference (HLS - RTL) over the
PE x SIMD grid, 4-bit inputs.  Positive = RTL uses fewer resources."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import compile_probe, emit, hls_ref_fn
from repro.core.folding import Folding
from repro.core.resource_model import mvu_resources


def run(pes=(2, 4, 8, 16, 32, 64), simds=(2, 4, 8, 16, 32, 64), out=None):
    # paper config 5/6 base: ifm_ch=64, kernel=4, ofm_ch=64, ifm_dim=8
    n = 64
    k = 4 * 4 * 64
    px = (8 - 4 + 1) ** 2
    rows = []
    for pe in pes:
        for simd in simds:
            fold = Folding(pe, simd)
            res = mvu_resources(n, k, fold, mode="standard", weight_bits=4,
                                act_bits=4, n_pixels=px, n_thresh=15)
            a_s = jax.ShapeDtypeStruct((128, k), jnp.int8)
            w_s = jax.ShapeDtypeStruct((n, k), jnp.int8)
            probe = compile_probe(hls_ref_fn("standard", k), a_s, w_s)
            rows.append({
                "PE": pe, "SIMD": simd,
                "rtl_lut_bytes": res.lut_bytes,
                "rtl_ff_bytes": res.ff_bytes,
                "hls_temp_bytes": probe["temp_bytes"],
                "delta_lut_bytes": probe["temp_bytes"] - res.lut_bytes,
                "rtl_cycles": res.cycles,
            })
    emit(rows, out)
    return rows


if __name__ == "__main__":
    run(out="experiments/bench/heatmap.csv")
