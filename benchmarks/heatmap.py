"""Paper Fig 14: heat map of resource difference (HLS - RTL) over the
PE x SIMD grid, 4-bit inputs.  Positive delta = the RTL analog (closed-form
Pallas resource model) uses fewer bytes than the measured XLA footprint.

The JSON record carries the full grid for ``scripts/make_experiments.py``
to render as the heatmap table/figure; ``run_quick`` writes the record the
regression gate pairs with the committed baseline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import compile_probe, emit_json, hls_ref_fn
from repro.core.folding import Folding
from repro.core.resource_model import mvu_resources

# paper config 5/6 base layer: ifm_ch=64, kernel=4, ofm_ch=64, ifm_dim=8
N = 64
K = 4 * 4 * 64
PX = (8 - 4 + 1) ** 2


def run(pes=(2, 4, 8, 16, 32, 64), simds=(2, 4, 8, 16, 32, 64),
        quick: bool = False, out: str | None = None) -> dict:
    cells = []
    # one XLA probe serves the whole grid: the reference shape is folding-
    # independent (that asymmetry -- RTL re-parameterizes, HLS recompiles
    # the same monolith -- is the paper's point)
    a_s = jax.ShapeDtypeStruct((128, K), jnp.int8)
    w_s = jax.ShapeDtypeStruct((N, K), jnp.int8)
    probe = compile_probe(hls_ref_fn("standard", K), a_s, w_s)
    for pe in pes:
        for simd in simds:
            res = mvu_resources(N, K, Folding(pe, simd), mode="standard",
                                weight_bits=4, act_bits=4, n_pixels=PX,
                                n_thresh=15)
            cells.append({
                "PE": pe, "SIMD": simd,
                "rtl_lut_bytes": res.lut_bytes,
                "rtl_ff_bytes": res.ff_bytes,
                "hls_temp_bytes": probe["temp_bytes"],
                "delta_lut_bytes": probe["temp_bytes"] - res.lut_bytes,
                "rtl_cycles": res.cycles,
            })
    record = {
        "name": "heatmap",
        "quick": quick,
        "shape": {"N": N, "K": K, "pixels": PX},
        "pes": list(pes), "simds": list(simds),
        "cells": cells,
        "summary": f"{len(cells)} cells, "
                   f"delta range [{min(c['delta_lut_bytes'] for c in cells)}, "
                   f"{max(c['delta_lut_bytes'] for c in cells)}] bytes",
    }
    emit_json(record, out)
    return record


def run_quick(out_dir: str | None = None) -> dict:
    out = f"{out_dir}/heatmap.json" if out_dir else None
    return run(pes=(2, 8, 32), simds=(2, 8, 32), quick=True, out=out)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/bench/heatmap.json")
    args = ap.parse_args()
    rec = (run(pes=(2, 8, 32), simds=(2, 8, 32), quick=True, out=args.out)
           if args.quick else run(out=args.out))
    print(f"# {rec['summary']}")


if __name__ == "__main__":
    main()
