"""Fused engine vs unfused interpreter throughput on the NID-MLP config.

Builds the paper's Table 6 MLP (600-64-64-64-1, 2-bit activations) with the
paper's PE/SIMD folding, *finalized but not streamlined* — so the graph
keeps its standalone batchnorm/quant_act nodes.  That graph runs two ways:

  unfused   ``dataflow.execute``: eager Python loop, one dispatch per node,
            float BN/quant epilogues between the MVU kernels
  fused     ``FusedEngine``: epilogues folded into the MVU threshold
            epilogue, whole chain jit-compiled once, microbatch streaming
            per the dataflow schedule (paper section 5.3 analog)

Emits one JSON record (default experiments/bench/engine_throughput.json)
with both timings, the speedup, and the stream plan.  ``--quick`` shrinks
the batch/reps for CI smoke runs.
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import paired_times
from repro.configs import nid_mlp
from repro.core import dataflow, lowering
from repro.core.engine import FusedEngine
from repro.core.ir import Graph, Node
from repro.core.mvu import MVUConfig


def build_nid_graph(seed: int = 0) -> Graph:
    """Table 6 MLP with random trained-like weights, lowered + finalized
    (NOT streamlined — bn/quant stay as standalone nodes) and folded with
    the paper's PE/SIMD choices."""
    rng = np.random.default_rng(seed)
    dims = [k for (k, _, _, _) in nid_mlp.LAYERS] + [nid_mlp.LAYERS[-1][1]]
    g: Graph = [Node("input", "in", {"shape": (dims[0],), "bits": nid_mlp.INPUT_BITS})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = (rng.normal(0, 1, (n, k)) / np.sqrt(k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(dims) - 2:
            g.append(Node("batchnorm", f"bn{i}", {}, {
                "gamma": jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32)),
                "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
                "mean": jnp.asarray(rng.normal(0, 1, n).astype(np.float32)),
                "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
            }))
            g.append(Node("quant_act", f"act{i}",
                          {"bits": nid_mlp.INPUT_BITS, "act_scale": 1.0}))
    lowered = lowering.lower_to_mvu(
        g, mode="standard", weight_bits=8, act_bits=nid_mlp.INPUT_BITS)
    fin = lowering.finalize(lowered)
    for node, fold in zip([n for n in fin if n.op == "mvu"], nid_mlp.foldings()):
        node.attrs["config"] = MVUConfig(
            **{**node.attrs["config"].__dict__, "folding": fold})
    return fin


def run(*, batch: int = 4096, reps: int = 5, seed: int = 0,
        out: str | None = "experiments/bench/engine_throughput.json") -> dict:
    graph = build_nid_graph(seed)
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(
        rng.integers(0, 2**nid_mlp.INPUT_BITS, (batch, 600)), jnp.int32)

    engine = FusedEngine(graph)
    plan = engine.plan(batch)

    want = np.asarray(dataflow.execute(graph, x))
    got = np.asarray(engine(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    t_unfused, t_fused, speedup = paired_times(
        lambda v: dataflow.execute(graph, v), engine, x, reps=reps)

    record = {
        "config": "nid_mlp_600_64_64_64_1_2bit",
        "batch": batch,
        "reps": reps,
        "unfused_us": t_unfused * 1e6,
        "fused_us": t_fused * 1e6,
        "speedup": speedup,
        "unfused_samples_per_s": batch / t_unfused,
        "fused_samples_per_s": batch / t_fused,
        "n_micro": plan.n_micro,
        "microbatch": plan.microbatch,
        "interval_cycles": plan.interval_cycles,
        "fifo_bound": plan.fifo_bound,
        "bottleneck": engine.schedule.bottleneck.name,
        "fused_nodes": sum(1 for n in engine.graph if n.attrs.get("fused")),
        "bit_exact": bool(np.array_equal(got, want)),
    }
    if out:
        out_dir = os.path.dirname(out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--quick", action="store_true",
                    help="small batch / few reps (CI smoke)")
    ap.add_argument("--out", default="experiments/bench/engine_throughput.json")
    args = ap.parse_args()
    if args.quick:
        # 5 reps + best-of timing: the regression gate needs a stable
        # estimator on loaded CI runners to hold a 20% threshold.
        args.batch, args.reps = min(args.batch, 512), 5

    rec = run(batch=args.batch, reps=args.reps, out=args.out)
    print(json.dumps(rec, indent=2))
    print(f"# fused {rec['fused_us']:.0f}us vs unfused {rec['unfused_us']:.0f}us "
          f"-> {rec['speedup']:.2f}x ({rec['fused_samples_per_s']:.0f} samples/s)")


if __name__ == "__main__":
    main()
