"""Fused engine vs unfused interpreter throughput on the NID-MLP config.

Builds the paper's Table 6 MLP (600-64-64-64-1, 2-bit activations) through
the ``repro.build`` step pipeline with the paper's PE/SIMD folding.  The
build keeps bn/quant as standalone nodes in the reference graph, so the
same :class:`~repro.build.accelerator.Accelerator` exposes both sides of
the comparison:

  unfused   ``acc.interpret``: eager per-node interpreter, one dispatch
            per node, float BN/quant epilogues between the MVU kernels
  fused     ``acc.engine``: epilogues folded into the MVU threshold
            epilogue, whole chain jit-compiled once, microbatch streaming
            per the dataflow schedule (paper section 5.3 analog)

Emits one JSON record (default experiments/bench/engine_throughput.json)
with both timings, the speedup, and the stream plan.  ``--quick`` shrinks
the batch/reps for CI smoke runs.
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import paired_times
from repro.build import Accelerator, build
from repro.configs import nid_mlp
from repro.core.ir import Graph


# the Table 6 chain definition moved to the config package so the
# explorer and examples can build it without importing benchmarks
def build_nid_graph(seed: int = 0) -> Graph:
    return nid_mlp.build_graph(seed)


def nid_accelerator(seed: int = 0, **overrides) -> Accelerator:
    """The NID-MLP dataflow build every benchmark/example shares: the
    paper's per-layer PE/SIMD folding, standard weight coding."""
    kw = dict(target="engine", mode="standard", weight_bits=8,
              act_bits=nid_mlp.INPUT_BITS, folding=nid_mlp.foldings(),
              name="nid_mlp")
    kw.update(overrides)
    return build(build_nid_graph(seed), **kw)


def run(*, batch: int = 4096, reps: int = 5, seed: int = 0,
        out: str | None = "experiments/bench/engine_throughput.json") -> dict:
    acc = nid_accelerator(seed)
    engine = acc.engine
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(
        rng.integers(0, 2**nid_mlp.INPUT_BITS, (batch, 600)), jnp.int32)

    plan = engine.plan(batch)

    want = np.asarray(acc.interpret(x))
    got = np.asarray(engine(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    t_unfused, t_fused, speedup = paired_times(
        lambda v: acc.interpret(v), engine, x, reps=reps)

    record = {
        "config": "nid_mlp_600_64_64_64_1_2bit",
        "batch": batch,
        "reps": reps,
        "unfused_us": t_unfused * 1e6,
        "fused_us": t_fused * 1e6,
        "speedup": speedup,
        "unfused_samples_per_s": batch / t_unfused,
        "fused_samples_per_s": batch / t_fused,
        "n_micro": plan.n_micro,
        "microbatch": plan.microbatch,
        "interval_cycles": plan.interval_cycles,
        "fifo_bound": plan.fifo_bound,
        "bottleneck": engine.schedule.bottleneck.name,
        "fused_nodes": sum(1 for n in engine.graph if n.attrs.get("fused")),
        "bit_exact": bool(np.array_equal(got, want)),
    }
    if out:
        out_dir = os.path.dirname(out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--quick", action="store_true",
                    help="small batch / few reps (CI smoke)")
    ap.add_argument("--out", default="experiments/bench/engine_throughput.json")
    args = ap.parse_args()
    if args.quick:
        # 5 reps + best-of timing: the regression gate needs a stable
        # estimator on loaded CI runners to hold a 20% threshold.
        args.batch, args.reps = min(args.batch, 512), 5

    rec = run(batch=args.batch, reps=args.reps, out=args.out)
    print(json.dumps(rec, indent=2))
    print(f"# fused {rec['fused_us']:.0f}us vs unfused {rec['unfused_us']:.0f}us "
          f"-> {rec['speedup']:.2f}x ({rec['fused_samples_per_s']:.0f} samples/s)")


if __name__ == "__main__":
    main()
