"""Paper Tables 6/7: the network-intrusion-detection MLP.

Builds the exact 4-layer / 2-bit MLP of Table 6 with the paper's PE/SIMD
folding, then reports per layer:

  * resource model (LUT/FF/BRAM analogs), weight-memory + input-buffer
    depths (Eq. 2),
  * execution cycles: our NF*SF model + the FINN pipeline depth of 5
    reproduces Table 7's 17/13/13 cycles exactly,
  * synthesis-time analogs (XLA ref vs Pallas kernel compile),
  * functional check: integer MVU inference on the synthetic UNSW-NB15
    stand-in reaches the accuracy of its float teacher.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import compile_probe, emit, hls_ref_fn, rtl_kernel_fn
from repro.configs import nid_mlp
from repro.core.folding import Folding, to_tpu_blocks
from repro.core.resource_model import mvu_resources

PIPELINE_DEPTH = 5  # FINN MVU register stages (input, simd, adder, acc, out)


def run(out=None):
    rows = []
    for i, (k, n, pe, simd) in enumerate(nid_mlp.LAYERS):
        fold = Folding(pe, simd)
        # paper PE/SIMD need not divide (layer0: 600/50=12 exact; 64/64=1)
        res = mvu_resources(n, k, fold, mode="standard",
                            weight_bits=nid_mlp.WEIGHT_BITS,
                            act_bits=nid_mlp.INPUT_BITS, n_pixels=1,
                            n_thresh=2**nid_mlp.INPUT_BITS - 1)
        cycles = fold.cycles(n, k, 1)
        a_s = jax.ShapeDtypeStruct((128, k), jnp.int8)
        w_s = jax.ShapeDtypeStruct((n, k), jnp.int8)
        hls = compile_probe(hls_ref_fn("standard", k), a_s, w_s)
        blocks = to_tpu_blocks(fold, "standard")
        rtl = compile_probe(rtl_kernel_fn("standard", k, blocks), a_s, w_s)
        rows.append({
            "layer": i, "K": k, "N": n, "PE": pe, "SIMD": simd,
            "exec_cycles_model": cycles + PIPELINE_DEPTH,
            "exec_cycles_paper_rtl": [17, 13, 13, 13][i],
            "wmem_depth": res.weight_mem_depth,
            "inbuf_depth": res.input_buffer_depth,
            "rtl_lut_bytes": res.lut_bytes,
            "rtl_ff_bytes": res.ff_bytes,
            "rtl_bram_bytes": res.bram_bytes,
            "hls_temp_bytes": hls["temp_bytes"],
            "hls_compile_s": round(hls["total_s"], 4),
            "rtl_compile_s": round(rtl["total_s"], 4),
        })
    emit(rows, out)
    return rows


def accuracy_check(n_train: int = 4096, n_test: int = 1024, steps: int = 300):
    """Train float MLP on synthetic NID data, streamline to 2-bit MVU graph
    through the ``repro.build`` pipeline (the QAT flow opts into the
    ``streamline`` step by name), compare integer-pipeline accuracy against
    the float model."""
    import repro.build as rbuild
    from repro.core import dataflow
    from repro.core.ir import Node
    from repro.data.nid import make_dataset

    x_train, y_train = make_dataset(n_train, seed=0)
    x_test, y_test = make_dataset(n_test, seed=1)

    dims = [600, 64, 64, 64, 1]
    key = jax.random.PRNGKey(0)
    ws = []
    for k, n in zip(dims[:-1], dims[1:]):
        key, sub = jax.random.split(key)
        ws.append(jax.random.normal(sub, (n, k)) * (1.0 / np.sqrt(k)))

    def fwd(ws, x):
        h = x.astype(jnp.float32)
        for i, w in enumerate(ws):
            h = h @ w.T
            if i < len(ws) - 1:
                h = jnp.clip(jnp.round(jnp.maximum(h, 0)), 0, 3)  # 2-bit act
        return h[..., 0]

    def loss(ws, x, y):
        logit = fwd(ws, x)
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    # straight-through trick: quantized forward, float backward
    def loss_ste(ws, x, y):
        h = x.astype(jnp.float32)
        for i, w in enumerate(ws):
            h = h @ w.T
            if i < len(ws) - 1:
                hq = jnp.clip(jnp.round(jnp.maximum(h, 0)), 0, 3)
                h = h + jax.lax.stop_gradient(hq - h)
        logit = h[..., 0]
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    step = jax.jit(lambda ws, x, y: jax.tree.map(
        lambda w, g: w - 0.03 * g, ws, jax.grad(loss_ste)(ws, x, y)))
    xb = jnp.asarray(x_train, jnp.float32)
    yb = jnp.asarray(y_train, jnp.float32)
    for _ in range(steps):
        ws = step(ws, xb, yb)

    float_acc = float(jnp.mean((fwd(ws, jnp.asarray(x_test, jnp.float32)) > 0)
                               == jnp.asarray(y_test)))

    # streamline into the integer MVU dataflow graph
    graph = [Node("input", "in", {"shape": (600,), "bits": 2})]
    for i, w in enumerate(ws):
        graph.append(Node("linear", f"fc{i}", {}, {"w": w}))
        if i < len(ws) - 1:
            n = w.shape[0]
            graph.append(Node("batchnorm", f"bn{i}", {}, {
                "gamma": jnp.ones((n,)), "beta": jnp.zeros((n,)),
                "mean": jnp.zeros((n,)), "var": jnp.ones((n,)) - 1e-5,
            }))
            graph.append(Node("quant_act", f"act{i}", {"bits": 2, "act_scale": 1.0}))
    # the streamlining flow: BN+quant fold into thresholds at lowering time
    # (on float weights), so "streamline" replaces the engine targets'
    # runtime fuse steps in the step list
    acc = rbuild.build(
        graph, target="interpret", mode="standard", weight_bits=8, act_bits=2,
        folding=nid_mlp.foldings(), name="nid_mlp_qat",
        steps=("validate", "lower", "streamline", "finalize", "fold",
               "dataflow"))
    stream = acc.graph
    out = acc.interpret(jnp.asarray(x_test, jnp.int32))
    # final layer emits raw int32 accumulator (no thresholds on the head);
    # the integer acc must be scaled by the head's weight scale for sign.
    mvu_nodes = [n for n in stream if n.op == "mvu"]
    scale = mvu_nodes[-1].params["mvu"].out_scale
    logits = out[..., 0] * (scale[0] if scale is not None else 1.0)
    int_acc = float(jnp.mean((logits > 0) == jnp.asarray(y_test)))
    sched = dataflow.schedule(stream)
    return {
        "float_acc": float_acc,
        "mvu_int_acc": int_acc,
        "pipeline_interval_cycles": sched.steady_state_interval,
        "pipeline_latency_cycles": sched.latency_cycles,
        "bottleneck": sched.bottleneck.name,
    }


def run_quick(out_dir: str | None = None) -> dict:
    """One JSON record: Table 7 cycle parity + the QAT accuracy check."""
    from benchmarks.common import emit_json

    rows = run(out=None)
    acc = accuracy_check(steps=200)
    claims = {
        "cycles_match_paper": all(
            r["exec_cycles_model"] == r["exec_cycles_paper_rtl"] for r in rows),
        "int_acc_tracks_float": acc["mvu_int_acc"] >= acc["float_acc"] - 0.05,
    }
    record = {
        "name": "nid_mlp",
        "layers": rows,
        "accuracy": acc,
        "claims": claims,
        "summary": f"cycles {'==' if claims['cycles_match_paper'] else '!='} "
                   f"paper; float={acc['float_acc']:.3f} "
                   f"int={acc['mvu_int_acc']:.3f}",
    }
    if not all(claims.values()):
        raise AssertionError(f"NID-MLP claims failed: {claims}")
    if out_dir:
        emit_json(record, f"{out_dir}/nid_mlp.json")
    return record


if __name__ == "__main__":
    print(run_quick(out_dir="experiments/bench"))
