"""Residual (skip-connection) MLP through the DAG build flow.

The chain IR could never express this workload; the DAG IR builds it
end-to-end: fan-out at the trunk activation, an elementwise-add join
(FINN's streaming elementwise-binary node), the branch-aware dataflow
schedule (skew FIFO at the join), and the fused engine -- held bit-exact
against the DAG reference interpreter.  The record claims:

  * ``bit_exact``: FusedEngine == dataflow.execute on the branched graph,
  * ``speedup`` >= 1.2x (``min_speedup``): the fused single-program engine
    must beat the per-node eager interpreter on the residual topology too
    (a conservative floor -- the measured margin is far larger; the chain
    benchmarks commit to 2x on deeper graphs),
  * the join's skew-FIFO depth and the branch labels, so a regression in
    the branch-balanced schedule shows up as a diff.

Discovered by ``benchmarks.run`` (exposes ``run_quick``); the committed
baseline lives at ``experiments/bench/residual_mlp.json``.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit_json, paired_times
from repro.build import Accelerator, build
from repro.configs import residual_mlp


def residual_accelerator(seed: int = 0, **overrides) -> Accelerator:
    """The skip-connection build every benchmark/example/test shares."""
    kw = dict(target="engine", mode="standard",
              weight_bits=residual_mlp.WEIGHT_BITS,
              act_bits=residual_mlp.INPUT_BITS,
              folding=residual_mlp.foldings(), name="residual_mlp")
    kw.update(overrides)
    return build(residual_mlp.build_graph(seed), **kw)


def run_quick(out_dir: str | None = None, *, batch: int = 512,
              reps: int = 3) -> dict:
    acc = residual_accelerator()
    engine = acc.engine
    k_in = residual_mlp.LAYERS[0][0]
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 2**residual_mlp.INPUT_BITS,
                                 (batch, k_in)), jnp.int32)

    want = np.asarray(acc.interpret(x))
    got = np.asarray(engine(x))
    bit_exact = bool(np.array_equal(got, want))

    t_int, t_eng, speedup = paired_times(
        lambda v: acc.interpret(v), engine, x, reps=reps)

    sched = engine.schedule
    joins = sched.summary().get("joins", [])
    record = {
        "name": "residual_mlp",
        "batch": batch,
        "reps": reps,
        "speedup": round(speedup, 3),
        "min_speedup": 1.2,
        "bit_exact": bit_exact,
        "interpreter_us": round(t_int * 1e6, 1),
        "engine_us": round(t_eng * 1e6, 1),
        "interval_cycles": sched.steady_state_interval,
        "bottleneck": sched.bottleneck.name,
        "critical_path_cycles": sched.latency_cycles,
        "joins": joins,
        "edges": acc.report.edges,
        "branches": sorted({n.branch for n in acc.report.nodes}),
        "summary": f"skip-connection DAG: engine {speedup:.2f}x vs DAG "
                   f"interpreter, bit_exact={bit_exact}, join skew FIFO "
                   f"depth {joins[0]['fifo_depth'] if joins else 0}",
    }
    if not bit_exact:
        raise AssertionError(
            "residual engine diverged from the DAG reference interpreter")
    if out_dir:
        emit_json(record, f"{out_dir}/residual_mlp.json")
    return record


if __name__ == "__main__":
    print(run_quick(out_dir="experiments/bench"))
