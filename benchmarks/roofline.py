"""Render EXPERIMENTS.md roofline/dry-run tables from experiments/dryrun/*.json."""

from __future__ import annotations

import glob
import json
import os


def load(dry_dir: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(recs: list[dict], mesh: str) -> str:
    lines = [
        "| arch | shape | compile s | args/dev | temp/dev | AR GB | AG GB | A2A GB | CP GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - | - | - | - |")
            continue
        c = r["collectives"]
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']} | "
            f"{fmt_bytes(m['argument_bytes'])} | {fmt_bytes(m['temp_bytes'])} | "
            f"{c['all-reduce']/1e9:.2f} | {c['all-gather']/1e9:.2f} | "
            f"{c['all-to-all']/1e9:.2f} | {c['collective-permute']/1e9:.2f} |"
        )
    return "\n".join(lines)


def roofline_table(recs: list[dict], mesh: str = "pod") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful ratio | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | SKIP | - | - | {r['skipped']} |")
            continue
        ro = r["roofline"]
        note = _bottleneck_note(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4g} | "
            f"{ro['memory_s']:.4g} | {ro['collective_s']:.4g} | {ro['dominant']} | "
            f"{r['model_flops']:.3g} | {r['useful_flops_ratio']:.2f} | {note} |"
        )
    return "\n".join(lines)


def _bottleneck_note(r: dict) -> str:
    ro = r["roofline"]
    d = ro["dominant"]
    if d == "compute":
        return "reduce recompute (remat policy) or cast more matmuls to int8 MVU"
    if d == "memory":
        if r["kind"] == "decode":
            return "quantize weights/KV (MVU w4/w8) to shrink the stream"
        return "sequence-shard remat activations (SP) / larger per-step tiles"
    return "overlap collectives with compute; shard experts over fewer axes"


def main():
    import sys

    dry_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(dry_dir)
    for mesh in ("pod", "multipod"):
        n_ok = sum(1 for r in recs if r.get("mesh") == mesh and not r.get("skipped"))
        print(f"\n## Dry-run ({mesh}, {dry_dir}): {n_ok} cells compiled\n")
        print(dryrun_table(recs, mesh))
    print(f"\n## Roofline (single pod, {dry_dir})\n")
    print(roofline_table(recs, "pod"))


if __name__ == "__main__":
    main()
