"""Paper Table 5: critical-path analog.

On FPGA the critical path bounds the clock; a TPU's clock is fixed, so the
direct analog is per-output latency under the folded schedule.  We report
ns per MVU output from the cycle model (RTL side, II=1 at the v5e clock)
and from XLA cost analysis at roofline speed (HLS side; note the XLA path
always runs the *unfolded* datapath, so absolute ratios reflect folding
discipline, not clock -- the paper-faithful claims validated here are the
STRUCTURAL ones of Table 5):

  C3a: IFM/OFM channel sweeps leave the per-step delay unchanged
       (control logic invariant) -> rtl min==max==mean across cfg1/cfg3.
  C3b: delay grows with PE/SIMD (array size) -> rtl mean grows across
       cfg5/cfg6.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import compile_probe, emit, hls_ref_fn
from repro.configs.paper_sweeps import CONFIGURATIONS, SIMD_TYPES, expand, mvu_shape
from repro.core.folding import Folding
from repro.core.resource_model import CLOCK_HZ, HBM_BW, PEAK_INT8_OPS
from repro.kernels import packing


def run(config_ids=(1, 3, 5, 6), out=None):
    rows = []
    m = 128
    for cid in config_ids:
        sweep = CONFIGURATIONS[cid]["sweep"]
        for st in SIMD_TYPES:
            rtl_ns, hls_ns, step_macs, depths = [], [], [], []
            for params, value in expand(cid):
                n, k, px = mvu_shape(params)
                pe = min(params["pe"], n)
                simd = min(params["simd"], k)
                while n % pe:
                    pe -= 1
                while k % simd:
                    simd -= 1
                fold = Folding(pe, simd)
                outputs = n * px
                rtl = fold.cycles(n, k, px) / CLOCK_HZ * 1e9 / outputs
                step_macs.append(pe * simd)  # datapath width: FPGA crit-path driver
                depths.append(int(np.ceil(np.log2(max(simd, 2)))))  # adder-tree levels

                if st == "xnor":
                    a_s = jax.ShapeDtypeStruct((m, packing.num_words(k)), jnp.uint32)
                    w_s = jax.ShapeDtypeStruct((n, packing.num_words(k)), jnp.uint32)
                else:
                    a_s = jax.ShapeDtypeStruct((m, k), jnp.int8)
                    w_s = jax.ShapeDtypeStruct((n, k), jnp.int8)
                probe = compile_probe(hls_ref_fn(st, k), a_s, w_s)
                t = max(probe["flops"] / PEAK_INT8_OPS, probe["bytes"] / HBM_BW)
                hls = t * 1e9 / (m * n)
                rtl_ns.append(rtl)
                hls_ns.append(hls)
            rows.append({
                "config": f"cfg{cid}:{sweep}",
                "simd_type": st,
                # C3a/C3b: per-step datapath width (crit-path driver on FPGA)
                "step_macs_min": min(step_macs),
                "step_macs_max": max(step_macs),
                "tree_depth_min": min(depths),
                "tree_depth_max": max(depths),
                "rtl_min_ns": round(min(rtl_ns), 4),
                "rtl_max_ns": round(max(rtl_ns), 4),
                "rtl_mean_ns": round(float(np.mean(rtl_ns)), 4),
                "hls_min_ns": round(min(hls_ns), 4),
                "hls_max_ns": round(max(hls_ns), 4),
                "hls_mean_ns": round(float(np.mean(hls_ns)), 4),
            })
    emit(rows, out)
    return rows


if __name__ == "__main__":
    run(out="experiments/bench/critical_path.csv")
