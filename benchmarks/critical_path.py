"""Paper Table 5: critical-path analog.

On FPGA the critical path bounds the clock; a TPU's clock is fixed, so the
direct analog is per-output latency under the folded schedule.  We report
ns per MVU output from the cycle model (RTL side, II=1 at the v5e clock)
and from XLA cost analysis at roofline speed (HLS side; the XLA path always
runs the *unfolded* datapath, so absolute ratios reflect folding
discipline, not clock).  The paper-faithful claims validated here -- and
checked into the record's ``claims`` -- are the STRUCTURAL ones of Table 5:

  C3a: IFM/OFM channel sweeps leave the per-step datapath unchanged
       (control logic invariant) -> step_macs min == max across cfg1/cfg3.
  C3b: delay grows with PE/SIMD (array size) -> per-step datapath width
       and adder-tree depth grow across cfg5/cfg6.

``run_quick`` writes the JSON record the regression gate pairs with the
committed baseline; the rows feed EXPERIMENTS.md's interval-sweep figure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import compile_probe, emit_json, hls_ref_fn
from repro.configs.paper_sweeps import CONFIGURATIONS, SIMD_TYPES, expand, mvu_shape
from repro.core.resource_model import CLOCK_HZ, HBM_BW, PEAK_INT8_OPS
from repro.explore import clamp_folding
from repro.kernels import packing


def _config_row(cid: int, st: str, probe: bool) -> dict:
    sweep = CONFIGURATIONS[cid]["sweep"]
    m = 128
    rtl_ns, hls_ns, step_macs, depths = [], [], [], []
    for params, _value in expand(cid):
        n, k, px = mvu_shape(params)
        fold = clamp_folding(n, k, params["pe"], params["simd"])
        outputs = n * px
        rtl_ns.append(fold.cycles(n, k, px) / CLOCK_HZ * 1e9 / outputs)
        step_macs.append(fold.pe * fold.simd)  # datapath width: crit-path driver
        depths.append(int(np.ceil(np.log2(max(fold.simd, 2)))))  # adder-tree levels

        if probe:
            if st == "xnor":
                a_s = jax.ShapeDtypeStruct((m, packing.num_words(k)), jnp.uint32)
                w_s = jax.ShapeDtypeStruct((n, packing.num_words(k)), jnp.uint32)
            else:
                a_s = jax.ShapeDtypeStruct((m, k), jnp.int8)
                w_s = jax.ShapeDtypeStruct((n, k), jnp.int8)
            p = compile_probe(hls_ref_fn(st, k), a_s, w_s)
            t = max(p["flops"] / PEAK_INT8_OPS, p["bytes"] / HBM_BW)
            hls_ns.append(t * 1e9 / (m * n))
    row = {
        "config": f"cfg{cid}:{sweep}",
        "simd_type": st,
        # C3a/C3b: per-step datapath width (crit-path driver on FPGA)
        "step_macs_min": min(step_macs),
        "step_macs_max": max(step_macs),
        "tree_depth_min": min(depths),
        "tree_depth_max": max(depths),
        "rtl_min_ns": round(min(rtl_ns), 4),
        "rtl_max_ns": round(max(rtl_ns), 4),
        "rtl_mean_ns": round(float(np.mean(rtl_ns)), 4),
    }
    if hls_ns:
        row.update(hls_min_ns=round(min(hls_ns), 4),
                   hls_max_ns=round(max(hls_ns), 4),
                   hls_mean_ns=round(float(np.mean(hls_ns)), 4))
    return row


def _claims(rows: list[dict]) -> dict:
    by_cfg = {}
    for r in rows:
        by_cfg.setdefault(r["config"].split(":")[0], []).append(r)
    claims = {}
    # C3a: channel sweeps (cfg1/cfg3) keep the datapath constant
    for cfg in ("cfg1", "cfg3"):
        if cfg in by_cfg:
            claims[f"{cfg}_step_invariant"] = all(
                r["step_macs_min"] == r["step_macs_max"] for r in by_cfg[cfg])
    # C3b: array sweeps (cfg5/cfg6) widen the datapath / deepen the tree
    for cfg in ("cfg5", "cfg6"):
        if cfg in by_cfg:
            claims[f"{cfg}_step_grows"] = all(
                r["step_macs_max"] > r["step_macs_min"] for r in by_cfg[cfg])
    return claims


def run(config_ids=(1, 3, 5, 6), simd_types=SIMD_TYPES, probe: bool = True,
        quick: bool = False, out: str | None = None) -> dict:
    rows = [_config_row(cid, st, probe)
            for cid in config_ids for st in simd_types]
    claims = _claims(rows)
    record = {
        "name": "critical_path",
        "quick": quick,
        "config_ids": list(config_ids),
        "rows": rows,
        "claims": claims,
        "summary": f"{len(rows)} rows, "
                   f"claims={'ok' if all(claims.values()) else 'FAIL'}",
    }
    if not all(claims.values()):
        raise AssertionError(f"critical-path structural claims failed: {claims}")
    emit_json(record, out)
    return record


def run_quick(out_dir: str | None = None) -> dict:
    out = f"{out_dir}/critical_path.json" if out_dir else None
    return run(config_ids=(1, 5), simd_types=("standard",), quick=True, out=out)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/bench/critical_path.json")
    args = ap.parse_args()
    rec = (run(config_ids=(1, 5), simd_types=("standard",), quick=True,
               out=args.out) if args.quick else run(out=args.out))
    print(f"# {rec['summary']}")


if __name__ == "__main__":
    main()
