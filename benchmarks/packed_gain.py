"""Bit-packed XNOR/popcount datapath vs the canonical MAC datapath.

Two ``repro.build`` runs over the SAME binarized (mode="xnor") NID-MLP
chain (paper Table 6 shapes, 1-bit weights and activations):

  packed     ``build(graph, tune="cache", pack="auto")``: the committed
             autotune cache routes every layer to the packed datapath --
             the blocked XNOR/popcount XLA path or the natively-packed
             Pallas kernel (paper Fig. 4a) -- and the ``pack_weights``
             lowering pass marks the nodes packed
  canonical  ``build(graph, backend="xla", tune="off", pack="never")``:
             the generic MAC datapath every packed kernel is verified
             against -- unpack the uint32 weight words to +/-1 rows and
             run a dense int matmul (``kernels.ref`` semantics)

Both engines must be bit-exact with the eager interpreter; the paired
interleaved timer reports the packed-over-canonical speedup.  The packed
datapath is memory-bandwidth-bound where the canonical one is
compute-bound, so the gain grows with N*K (the 600x64 input layer
dominates here).

The record also commits the storage side of the story: a binary-coded
(``mode="binary"``, {0,1} bitplanes x n-bit activations) build of the same
chain with ``pack="always"`` cuts HBM-resident weight bytes ~8x
(int8 rows -> uint32 bitplanes); ``weight_bytes_reduction`` is gated as an
absolute floor (``floor_only``) because it is a deterministic storage
ratio, not a timing.  ``packed_nodes`` gates that the committed cache
really selects the packed datapath (the autotuner chose it, nothing forced
it).

``--retune`` re-runs the empirical search (``tune="auto"``) into a fresh
cache and saves it so nightly CI exercises the packed axis of the search
space end to end.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import paired_times
from repro.build import build
from repro.configs import nid_mlp
from repro.core import autotune

MIN_SPEEDUP = 1.15  # committed floor for the packed-over-canonical gain
MIN_WEIGHT_BYTES_REDUCTION = 4.0  # binary bitplanes vs int8 rows (~8x here)
MIN_PACKED_NODES = 1  # the cache must route >= 1 node to the packed datapath


def binarized_accelerator(seed: int = 0, **overrides):
    """The Table 6 chain lowered with 1-bit XNOR weights/activations."""
    kw = dict(target="engine", mode="xnor", weight_bits=1, act_bits=1,
              folding=nid_mlp.foldings(), name="nid_mlp_xnor")
    kw.update(overrides)
    return build(nid_mlp.build_graph(seed), **kw)


def run(*, batch: int = 4096, reps: int = 5, seed: int = 0,
        retune: bool = False, cache_out: str | None = None,
        out: str | None = "experiments/bench/packed_gain.json") -> dict:
    if retune:
        cache = autotune.ScheduleCache()
        binarized_accelerator(seed, tune="auto", cache=cache)
        if cache_out:
            cache.save(cache_out)
            print(f"# saved {len(cache)} tuned entries -> {cache_out}")
    else:
        cache = autotune.default_cache()

    packed_acc = binarized_accelerator(seed, tune="cache", cache=cache)
    canonical_acc = binarized_accelerator(
        seed, backend="xla", tune="off", pack="never",
        name="nid_mlp_xnor_canonical")
    packed, canonical = packed_acc.engine, canonical_acc.engine

    x = autotune.synth_input(packed_acc.ref_graph, batch, seed=seed + 1)
    want = np.asarray(packed_acc.interpret(x))
    got_p = np.asarray(packed(x))
    got_c = np.asarray(canonical(x))
    np.testing.assert_array_equal(got_p, want)
    np.testing.assert_array_equal(got_c, want)

    t_canon, t_packed, speedup = paired_times(canonical, packed, x, reps=reps)

    packed_nodes = [
        n.name for n in packed.graph
        if n.op in ("mvu", "conv_mvu") and n.attrs["config"].packed]
    total_nodes = sum(1 for n in packed.graph if n.op in ("mvu", "conv_mvu"))

    # storage story: binary coding ({0,1} bitplanes) of the same chain --
    # the xnor variant stores packed words either way, so the byte cut is
    # measured where canonical storage really is int8 rows
    bin_packed = binarized_accelerator(
        seed, mode="binary", act_bits=4, tune="off", pack="always",
        name="nid_mlp_binary_packed")
    bin_canon = binarized_accelerator(
        seed, mode="binary", act_bits=4, tune="off", pack="never",
        name="nid_mlp_binary_canonical")
    xb = autotune.synth_input(bin_packed.ref_graph, min(batch, 256),
                              seed=seed + 2)
    bin_exact = bool(np.array_equal(np.asarray(bin_packed.engine(xb)),
                                    np.asarray(bin_canon.engine(xb))))
    w_packed = sum(n.weight_bytes for n in bin_packed.report.nodes)
    w_canon = sum(n.canonical_weight_bytes for n in bin_packed.report.nodes)
    reduction = w_canon / max(1, w_packed)

    record = {
        "config": "nid_mlp_xnor_600_64_64_64_1_1bit",
        "batch": batch,
        "reps": reps,
        "canonical_us": t_canon * 1e6,
        "packed_us": t_packed * 1e6,
        "speedup": speedup,
        "min_speedup": MIN_SPEEDUP,
        "canonical_samples_per_s": batch / t_canon,
        "packed_samples_per_s": batch / t_packed,
        "packed_nodes": len(packed_nodes),
        "packed_node_names": packed_nodes,
        "total_nodes": total_nodes,
        "packed_backends": sorted({
            n.attrs["config"].backend for n in packed.graph
            if n.op in ("mvu", "conv_mvu") and n.attrs["config"].packed}),
        "binary_weight_bytes_packed": w_packed,
        "binary_weight_bytes_canonical": w_canon,
        "weight_bytes_reduction": reduction,
        "min_weight_bytes_reduction": MIN_WEIGHT_BYTES_REDUCTION,
        "min_packed_nodes": MIN_PACKED_NODES,
        "floor_only": ["weight_bytes_reduction", "packed_nodes"],
        "cache_entries": len(cache),
        "bit_exact": bool(np.array_equal(got_p, want)
                          and np.array_equal(got_c, want)
                          and bin_exact),
    }
    if out:
        out_dir = os.path.dirname(out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--retune", action="store_true",
                    help="re-run the empirical search (packed axis included) "
                         "instead of using the committed cache")
    ap.add_argument("--cache-out", default=autotune.DEFAULT_CACHE_PATH,
                    help="where --retune saves the fresh cache")
    ap.add_argument("--quick", action="store_true",
                    help="small batch / few reps (CI smoke)")
    ap.add_argument("--out", default="experiments/bench/packed_gain.json")
    args = ap.parse_args()
    if args.quick:
        args.batch, args.reps = min(args.batch, 1024), 9

    rec = run(batch=args.batch, reps=args.reps, retune=args.retune,
              cache_out=args.cache_out, out=args.out)
    print(json.dumps(rec, indent=2))
    print(f"# packed {rec['packed_us']:.0f}us vs canonical "
          f"{rec['canonical_us']:.0f}us -> {rec['speedup']:.2f}x "
          f"({rec['packed_nodes']}/{rec['total_nodes']} nodes packed, "
          f"backends {rec['packed_backends']}, "
          f"weights {rec['weight_bytes_reduction']:.1f}x smaller)")


if __name__ == "__main__":
    main()
