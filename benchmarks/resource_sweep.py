"""Paper Figs 8-13 + Table 4 + Fig 15: resource utilization & latency vs
layer/implementation parameters, RTL(Pallas, closed-form) vs HLS(XLA,
measured).

Columns:
  rtl_lut/ff/bram_bytes : analytical model (DESIGN.md metric mapping)
  rtl_cycles            : folding cycle model (II=1)
  hls_temp/arg_bytes    : XLA memory_analysis of the compiled reference
  hls_compile_s         : XLA compile wall-clock (synthesis-time analog)
  hls_flops/bytes       : XLA cost_analysis
"""

from __future__ import annotations

from benchmarks.common import compile_probe, emit, hls_ref_fn
from repro.configs.paper_sweeps import (
    CONFIGURATIONS, LARGE_CONFIGS, SIMD_TYPES, expand, mvu_shape,
)
from repro.core.folding import Folding
from repro.core.resource_model import mvu_resources
from repro.kernels import packing

import jax
import jax.numpy as jnp


def _row(c: dict, simd_type: str, sweep: str, value) -> dict:
    n, k, px = mvu_shape(c)
    pe = min(c["pe"], n)
    simd = min(c["simd"], k)
    # legality: clamp to divisors (paper keeps PE|N, SIMD|K by construction)
    while n % pe:
        pe -= 1
    while k % simd:
        simd -= 1
    fold = Folding(pe, simd)
    wb = 1 if simd_type in ("xnor", "binary") else 4
    ab = 1 if simd_type == "xnor" else 4
    res = mvu_resources(n, k, fold, mode=simd_type, weight_bits=wb,
                        act_bits=ab, n_pixels=px, n_thresh=2**ab - 1)

    # HLS analog: compile the XLA reference at the MVU's working shape
    m = 128  # pixel tile fed per stream burst
    if simd_type == "xnor":
        a_s = jax.ShapeDtypeStruct((m, packing.num_words(k)), jnp.uint32)
        w_s = jax.ShapeDtypeStruct((n, packing.num_words(k)), jnp.uint32)
    else:
        a_s = jax.ShapeDtypeStruct((m, k), jnp.int8)
        w_s = jax.ShapeDtypeStruct((n, k), jnp.int8)
    probe = compile_probe(hls_ref_fn(simd_type, k), a_s, w_s)

    return {
        "sweep": sweep,
        "value": value,
        "simd_type": simd_type,
        "N": n, "K": k, "pixels": px, "PE": pe, "SIMD": simd,
        "rtl_lut_bytes": res.lut_bytes,
        "rtl_ff_bytes": res.ff_bytes,
        "rtl_bram_bytes": res.bram_bytes,
        "rtl_cycles": res.cycles,
        "rtl_wmem_depth": res.weight_mem_depth,
        "rtl_inbuf_depth": res.input_buffer_depth,
        "hls_temp_bytes": probe["temp_bytes"],
        "hls_arg_bytes": probe["arg_bytes"],
        "hls_compile_s": round(probe["total_s"], 4),
        "hls_flops": probe["flops"],
        "hls_bytes": probe["bytes"],
    }


def run(config_ids=(1, 3, 5, 6), simd_types=SIMD_TYPES, out=None) -> list[dict]:
    rows = []
    for cid in config_ids:
        sweep = CONFIGURATIONS[cid]["sweep"]
        for params, value in expand(cid):
            for st in simd_types:
                rows.append(_row(params, st, f"cfg{cid}:{sweep}", value))
    emit(rows, out)
    return rows


def run_large(out=None) -> list[dict]:
    """Table 3/4: large designs (PE=SIMD=16), increasing IFM channels."""
    rows = []
    for i, c in enumerate(LARGE_CONFIGS):
        rows.append(_row(c, "standard", "table3:ifm_ch", c["ifm_ch"]))
    emit(rows, out)
    return rows


if __name__ == "__main__":
    run(out="experiments/bench/resource_sweep.csv")
    run_large(out="experiments/bench/resource_large.csv")
