"""Paper Figs 8-13 + Table 4 + Fig 15: resource utilization vs layer /
implementation parameters, RTL(Pallas, closed-form) vs HLS(XLA, measured).

Three record sections, all rendered into EXPERIMENTS.md by
``scripts/make_experiments.py``:

  configs        one row per (Table 2 configuration value, SIMD type):
                 analytic LUT/FF/BRAM analogs + cycle model next to the
                 XLA compile probe of the reference at the same shape
  folding_curve  resources vs the PE*SIMD datapath product at one fixed
                 layer, realized through ``repro.explore``'s sweep grid --
                 the x-axis of the paper's Figs 8-13 resource curves
  large          Table 3/4's bigger designs (PE = SIMD = 16)

Structural claims checked into the record (``claims``): BRAM analog stays
flat under folding (weights don't move), the LUT analog grows with the
datapath, cycles shrink as folding widens.  ``run_quick`` writes the JSON
record the regression gate pairs with the committed baseline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import compile_probe, emit_json, hls_ref_fn
from repro.configs.paper_sweeps import (
    CONFIGURATIONS, LARGE_CONFIGS, SIMD_TYPES, expand, mvu_shape,
)
from repro.core.resource_model import mvu_resources
from repro.explore import LayerShape, clamp_folding, sweep_grid
from repro.kernels import packing


def _row(c: dict, simd_type: str, sweep: str, value, probe: bool = True) -> dict:
    n, k, px = mvu_shape(c)
    fold = clamp_folding(n, k, c["pe"], c["simd"])
    wb = 1 if simd_type in ("xnor", "binary") else 4
    ab = 1 if simd_type == "xnor" else 4
    res = mvu_resources(n, k, fold, mode=simd_type, weight_bits=wb,
                        act_bits=ab, n_pixels=px, n_thresh=2**ab - 1)
    row = {
        "sweep": sweep,
        "value": value,
        "simd_type": simd_type,
        "N": n, "K": k, "pixels": px, "PE": fold.pe, "SIMD": fold.simd,
        "rtl_lut_bytes": res.lut_bytes,
        "rtl_ff_bytes": res.ff_bytes,
        "rtl_bram_bytes": res.bram_bytes,
        "rtl_cycles": res.cycles,
        "rtl_wmem_depth": res.weight_mem_depth,
        "rtl_inbuf_depth": res.input_buffer_depth,
    }
    if probe:
        # HLS analog: compile the XLA reference at the MVU's working shape
        m = 128  # pixel tile fed per stream burst
        if simd_type == "xnor":
            a_s = jax.ShapeDtypeStruct((m, packing.num_words(k)), jnp.uint32)
            w_s = jax.ShapeDtypeStruct((n, packing.num_words(k)), jnp.uint32)
        else:
            a_s = jax.ShapeDtypeStruct((m, k), jnp.int8)
            w_s = jax.ShapeDtypeStruct((n, k), jnp.int8)
        p = compile_probe(hls_ref_fn(simd_type, k), a_s, w_s)
        row.update(hls_temp_bytes=p["temp_bytes"], hls_arg_bytes=p["arg_bytes"],
                   hls_compile_s=round(p["total_s"], 4),
                   hls_flops=p["flops"], hls_bytes=p["bytes"])
    return row


def folding_curve(n: int = 64, k: int = 1024, px: int = 25,
                  mode: str = "standard") -> list[dict]:
    """Resources vs PE*SIMD at one fixed layer, points realized by the
    explorer's sweep grid (same clamping the end-to-end sweep uses)."""
    shape = LayerShape("mvu", n, k, px)
    rows = []
    for pt in sweep_grid([shape]):
        fold = pt.foldings[0]
        res = mvu_resources(n, k, fold, mode=mode, weight_bits=4, act_bits=4,
                            n_pixels=px, n_thresh=15)
        rows.append({
            "point_id": pt.point_id, "PE": fold.pe, "SIMD": fold.simd,
            "pe_simd": fold.pe * fold.simd,
            "rtl_lut_bytes": res.lut_bytes, "rtl_ff_bytes": res.ff_bytes,
            "rtl_bram_bytes": res.bram_bytes, "rtl_cycles": res.cycles,
        })
    return rows


def _claims(curve: list[dict]) -> dict:
    lo = min(curve, key=lambda r: r["pe_simd"])
    hi = max(curve, key=lambda r: r["pe_simd"])
    return {
        # weights don't move under time-multiplexing: Fig 10/13's flat BRAM
        "bram_flat_under_folding": len(
            {r["rtl_bram_bytes"] for r in curve}) == 1,
        # the datapath (LUT analog) and state (FF analog) grow with PE*SIMD
        "lut_grows_with_datapath": hi["rtl_lut_bytes"] > lo["rtl_lut_bytes"],
        "ff_grows_with_datapath": hi["rtl_ff_bytes"] > lo["rtl_ff_bytes"],
        # cycles fall as the folding widens (Eq. 1: NF*SF shrink)
        "cycles_shrink_with_folding": hi["rtl_cycles"] < lo["rtl_cycles"],
    }


def run(config_ids=(1, 3, 5, 6), simd_types=SIMD_TYPES, probe: bool = True,
        quick: bool = False, out: str | None = None) -> dict:
    configs = []
    for cid in config_ids:
        sweep = CONFIGURATIONS[cid]["sweep"]
        for params, value in expand(cid):
            for st in simd_types:
                configs.append(_row(params, st, f"cfg{cid}:{sweep}", value,
                                    probe=probe))
    large = [_row(c, "standard", "table3:ifm_ch", c["ifm_ch"], probe=probe)
             for c in LARGE_CONFIGS]
    curve = folding_curve()
    claims = _claims(curve)
    record = {
        "name": "resource_sweep",
        "quick": quick,
        "config_ids": list(config_ids),
        "configs": configs,
        "large": large,
        "folding_curve": curve,
        "claims": claims,
        "summary": f"{len(configs)} config rows, "
                   f"{len(curve)}-point folding curve, "
                   f"claims={'ok' if all(claims.values()) else 'FAIL'}",
    }
    if not all(claims.values()):
        raise AssertionError(f"resource-sweep structural claims failed: {claims}")
    emit_json(record, out)
    return record


def run_quick(out_dir: str | None = None) -> dict:
    out = f"{out_dir}/resource_sweep.json" if out_dir else None
    return run(config_ids=(1, 5), simd_types=("xnor", "standard"),
               quick=True, out=out)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/bench/resource_sweep.json")
    args = ap.parse_args()
    if args.quick:
        rec = run(config_ids=(1, 5), simd_types=("xnor", "standard"),
                  quick=True, out=args.out)
    else:
        rec = run(out=args.out)
    print(f"# {rec['summary']}")


if __name__ == "__main__":
    main()
