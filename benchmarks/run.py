"""Benchmark orchestrator: discovers and runs every paper-figure benchmark.

Any module in ``benchmarks/`` exposing ``run_quick(out_dir=None) -> dict``
is discovered (``pkgutil``) and run; each returns a JSON-serializable
record with a ``summary`` line and, when ``--out-dir`` is given, writes
its record there (the fresh side of ``scripts/check_bench_regression.py``).

    python -m benchmarks.run                      # print-only smoke
    python -m benchmarks.run --out-dir /tmp/bench # CI: fresh gate records
    python -m benchmarks.run --only resource_sweep

The throughput benchmarks (engine/conv/autotune/serving) keep their own
CLIs -- they need --quick batch shaping -- and are NOT discovered here;
CI runs them as separate steps.
"""

from __future__ import annotations

import argparse
import importlib
import pkgutil
import time

import benchmarks


def discover() -> list:
    """Modules under ``benchmarks/`` exposing ``run_quick``, sorted by name."""
    mods = []
    for info in pkgutil.iter_modules(benchmarks.__path__):
        if info.name in ("run", "common"):
            continue
        mod = importlib.import_module(f"benchmarks.{info.name}")
        if hasattr(mod, "run_quick"):
            mods.append(mod)
    return sorted(mods, key=lambda m: m.__name__)


def main(argv: list[str] | None = None) -> list[dict]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None,
                    help="write each record as <out-dir>/<name>.json")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark module by name")
    args = ap.parse_args(argv)

    mods = discover()
    if args.only:
        mods = [m for m in mods if m.__name__.split(".")[-1] == args.only]
        if not mods:
            raise SystemExit(f"no benchmark module named {args.only!r} "
                             f"exposes run_quick()")
    t_all = time.time()
    records = []
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        t0 = time.time()
        rec = mod.run_quick(out_dir=args.out_dir)
        records.append(rec)
        print(f"[{name}] {time.time() - t0:.1f}s  {rec.get('summary', '')}",
              flush=True)
    print(f"# {len(records)} benchmarks in {time.time() - t_all:.1f}s",
          flush=True)
    return records


if __name__ == "__main__":
    main()
