"""Benchmark orchestrator -- one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus the per-table CSVs under
experiments/bench/).  Timings are CPU wall-clock of the XLA path; derived
columns carry the paper-metric analogs (see each module's docstring).
"""

from __future__ import annotations

import time

import jax
import numpy as np


def _kernel_microbench() -> list[tuple[str, float, str]]:
    """us/call of the three MVU datapaths, Pallas(interpret) vs XLA, small shape."""
    from benchmarks.common import hls_ref_fn, make_operands, rtl_kernel_fn, time_call

    rows = []
    m, n, k = 128, 64, 1024
    for mode in ("xnor", "binary", "standard"):
        a, w = make_operands(mode, m, n, k)
        blocks = dict(block_m=128, block_n=32, block_k=128, block_kw=8)
        if mode == "xnor":
            blocks.pop("block_k")
        else:
            blocks.pop("block_kw")
        f_rtl = jax.jit(rtl_kernel_fn(mode, k, blocks))
        f_hls = jax.jit(hls_ref_fn(mode, k))
        t_rtl = time_call(f_rtl, a, w)
        t_hls = time_call(f_hls, a, w)
        macs = m * n * k
        rows.append((f"kernel_{mode}_pallas_interpret", t_rtl * 1e6,
                     f"gmacs={macs/t_rtl/1e9:.2f}"))
        rows.append((f"kernel_{mode}_xla", t_hls * 1e6,
                     f"gmacs={macs/t_hls/1e9:.2f}"))
    return rows


def main() -> None:
    t_all = time.time()
    out: list[tuple[str, float, str]] = []

    print("name,us_per_call,derived")

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)
        out.append((name, us, derived))

    # kernel microbenchmarks (table-agnostic sanity row)
    for name, us, derived in _kernel_microbench():
        emit(name, us, derived)

    # Figs 8-13 + Fig 15 (resource sweeps)
    from benchmarks import resource_sweep

    t0 = time.time()
    rows = resource_sweep.run(config_ids=(1, 3, 5, 6),
                              out="experiments/bench/resource_sweep.csv")
    for r in rows[:0]:
        pass
    # headline: does RTL beat HLS for small designs & converge for large?
    small = [r for r in rows if r["PE"] * r["SIMD"] <= 16]
    large = [r for r in rows if r["PE"] * r["SIMD"] >= 1024]
    ratio_small = np.mean([r["hls_temp_bytes"] / max(r["rtl_lut_bytes"], 1) for r in small])
    ratio_large = np.mean([r["hls_temp_bytes"] / max(r["rtl_lut_bytes"], 1) for r in large])
    emit("fig8_13_resource_sweep", (time.time() - t0) * 1e6,
         f"hls/rtl_small={ratio_small:.2f};hls/rtl_large={ratio_large:.2f};rows={len(rows)}")

    t0 = time.time()
    rows = resource_sweep.run_large(out="experiments/bench/resource_large.csv")
    emit("table4_large_convergence", (time.time() - t0) * 1e6,
         ";".join(f"ifm{r['value']}:rtl={r['rtl_lut_bytes']}b" for r in rows))

    # Fig 14 heat map
    from benchmarks import heatmap

    t0 = time.time()
    rows = heatmap.run(pes=(2, 8, 32), simds=(2, 8, 32),
                       out="experiments/bench/heatmap.csv")
    emit("fig14_heatmap", (time.time() - t0) * 1e6, f"cells={len(rows)}")

    # Table 5 critical path
    from benchmarks import critical_path

    t0 = time.time()
    rows = critical_path.run(config_ids=(1, 5), out="experiments/bench/critical_path.csv")
    mean_ratio = np.mean([r["hls_mean_ns"] / max(r["rtl_mean_ns"], 1e-9) for r in rows])
    emit("table5_critical_path", (time.time() - t0) * 1e6,
         f"hls/rtl_mean_ns_ratio={mean_ratio:.2f}")

    # Fig 16 synthesis time: monolithic design-graph compile vs modular kernels
    from benchmarks import synthesis_time

    t0 = time.time()
    rows = synthesis_time.run_chain(out="experiments/bench/synthesis_time_chain.csv")
    first, last = rows[0], rows[-1]
    emit("fig16_synthesis_time_chain", (time.time() - t0) * 1e6,
         f"hls_L{first['value']}={first['hls_compile_s']}s;"
         f"hls_L{last['value']}={last['hls_compile_s']}s;"
         f"rtl_flat={last['rtl_compile_s']}s;hls/rtl_L{last['value']}={last['hls/rtl']}")
    t0 = time.time()
    synthesis_time.run_folding(out="experiments/bench/synthesis_time_folding.csv")
    emit("fig16_synthesis_time_folding", (time.time() - t0) * 1e6, "see csv")

    # Tables 6/7 NID MLP
    from benchmarks import nid_mlp

    t0 = time.time()
    rows = nid_mlp.run(out="experiments/bench/nid_mlp.csv")
    cyc = ";".join(
        f"L{r['layer']}:{r['exec_cycles_model']}v{r['exec_cycles_paper_rtl']}"
        for r in rows
    )
    emit("table7_nid_cycles", (time.time() - t0) * 1e6, cyc)

    t0 = time.time()
    acc = nid_mlp.accuracy_check(steps=200)
    emit("table7_nid_accuracy", (time.time() - t0) * 1e6,
         f"float={acc['float_acc']:.3f};mvu_int={acc['mvu_int_acc']:.3f};"
         f"interval={acc['pipeline_interval_cycles']}")

    # Roofline table (reads dry-run artifacts if present)
    import os

    from benchmarks import roofline

    dry = "experiments/dryrun_final" if os.path.isdir("experiments/dryrun_final") \
        else "experiments/dryrun"
    recs = roofline.load(dry)
    ok = sum(1 for r in recs if not r.get("skipped"))
    emit("roofline_cells_available", 0.0, f"dir={dry};compiled={ok};total={len(recs)}")

    print(f"# total {time.time()-t_all:.1f}s", flush=True)


if __name__ == "__main__":
    main()
