"""Cache-tuned FusedEngine vs the heuristic-default engine, end to end.

Two ``repro.build`` runs over the SAME raw chain:

  heuristic  ``build(graph, tune="off")``: every kernel schedule from the
             one-shot ``choose_folding`` + ``to_tpu_blocks`` defaults
  tuned      ``build(graph, tune="cache")``: per-node schedules from the
             committed autotune cache (``repro.configs.*.TUNED_SCHEDULES``)
             -- pure lookup, zero measurement at construction

Both must be bit-exact with the eager ``dataflow.execute`` interpreter; the
paired interleaved timer reports the tuned-over-heuristic speedup.  The
committed record (default ``experiments/bench/autotune_gain.json``) carries
``min_speedup`` so the CI regression gate holds this benchmark to its own
floor (1.15x) instead of the global fused-vs-interpreter 2x floor.

``--retune`` re-runs the empirical search (``tune="auto"`` + engine-level
microbatch tuning) into a fresh cache and saves it (default
``experiments/autotune/cache.json``; nightly CI uploads it as an artifact).
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import paired_times
from benchmarks.conv_throughput import cnv_accelerator
from benchmarks.engine_throughput import nid_accelerator
from repro.configs import cnv_bnn
from repro.core import autotune

MIN_SPEEDUP = 1.15  # the committed-gain floor the CI gate enforces


def build_accelerator(config: str, seed: int, **overrides):
    if config == "nid_mlp":
        return nid_accelerator(seed, **overrides), "nid_mlp_600_64_64_64_1_2bit"
    spec = cnv_bnn.QUICK
    acc = cnv_accelerator(spec, mode="xnor", seed=seed, **overrides)
    name = f"cnv_bnn_{spec.image}px_{'x'.join(map(str, spec.channels))}"
    return acc, name


def run(*, config: str = "nid_mlp", batch: int = 4096, reps: int = 5,
        seed: int = 0, retune: bool = False,
        cache_out: str | None = None,
        out: str | None = "experiments/bench/autotune_gain.json") -> dict:
    heur_acc, name = build_accelerator(config, seed)
    heuristic = heur_acc.engine
    x = autotune.synth_input(heur_acc.ref_graph, batch, seed=seed + 1)

    if retune:
        cache = autotune.ScheduleCache()
        # fill per-node entries by measuring, then search the microbatch tile
        build_accelerator(config, seed, tune="auto", cache=cache)
        autotune.tune_engine(heur_acc.graph, batch, cache=cache)
        if cache_out:
            cache.save(cache_out)
            print(f"# saved {len(cache)} tuned entries -> {cache_out}")
    else:
        cache = autotune.default_cache()

    tuned_acc, _ = build_accelerator(config, seed, tune="cache", cache=cache)
    tuned = tuned_acc.engine

    want = np.asarray(heur_acc.interpret(x))
    got_h = np.asarray(heuristic(x))
    got_t = np.asarray(tuned(x))
    np.testing.assert_allclose(got_h, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_t, want, rtol=1e-5, atol=1e-5)

    t_heur, t_tuned, speedup = paired_times(heuristic, tuned, x, reps=reps)

    tuned_nodes = sum(
        1 for n in tuned.graph
        if n.op in ("mvu", "conv_mvu") and n.attrs["config"].blocks is not None)
    total_nodes = sum(1 for n in tuned.graph if n.op in ("mvu", "conv_mvu"))
    record = {
        "config": name,
        "batch": batch,
        "reps": reps,
        "heuristic_us": t_heur * 1e6,
        "tuned_us": t_tuned * 1e6,
        "speedup": speedup,
        "min_speedup": MIN_SPEEDUP,
        "heuristic_samples_per_s": batch / t_heur,
        "tuned_samples_per_s": batch / t_tuned,
        "tuned_nodes": tuned_nodes,
        "total_nodes": total_nodes,
        "tuned_backends": sorted({
            n.attrs["config"].backend for n in tuned.graph
            if n.op in ("mvu", "conv_mvu")}),
        "microbatch_tile": tuned._tile,
        "cache_entries": len(cache),
        "bit_exact": bool(np.array_equal(got_t, want)
                          and np.array_equal(got_h, want)),
    }
    if out:
        out_dir = os.path.dirname(out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="nid_mlp", choices=("nid_mlp", "cnv"))
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--retune", action="store_true",
                    help="re-run the empirical search instead of using the "
                         "committed cache")
    ap.add_argument("--cache-out", default=autotune.DEFAULT_CACHE_PATH,
                    help="where --retune saves the fresh cache")
    ap.add_argument("--quick", action="store_true",
                    help="small batch / few reps (CI smoke)")
    ap.add_argument("--out", default="experiments/bench/autotune_gain.json")
    args = ap.parse_args()
    if args.quick:
        # tuned-vs-heuristic gaps are tighter than fused-vs-interpreter
        # ones, so the quick gate run spends more paired reps (median of 9
        # interleaved ratios) to hold the regression band on noisy runners
        args.batch, args.reps = min(args.batch, 1024), 9

    rec = run(config=args.config, batch=args.batch, reps=args.reps,
              retune=args.retune, cache_out=args.cache_out, out=args.out)
    print(json.dumps(rec, indent=2))
    print(f"# tuned {rec['tuned_us']:.0f}us vs heuristic "
          f"{rec['heuristic_us']:.0f}us -> {rec['speedup']:.2f}x "
          f"({rec['tuned_nodes']}/{rec['total_nodes']} nodes tuned, "
          f"backends {rec['tuned_backends']})")


if __name__ == "__main__":
    main()
