"""Fused streaming conv path vs the eager interpreter on the CNV topology.

One ``repro.build`` run of the CNV chain yields both executions (the
reference graph keeps standalone batchnorm/quant_act nodes, the unfused
form):

  unfused   ``acc.interpret``: one dispatch per node; every conv runs
            SWU-then-MVU with the full (B, OH*OW, Kd^2*C) im2col matrix
            materialized between them -- the buffering blow-up FINN's
            line-buffer SWU exists to avoid
  fused     ``acc.engine``: bn/quant folded into threshold epilogues,
            swu+mvu pairs collapsed into the line-buffer conv kernel
            (``kernels.swu_mvu``), whole chain one jit'd microbatch stream

Emits one JSON record (default experiments/bench/conv_throughput.json) with
both timings, the speedup, the bit-exactness flag, and the analytic
peak-activation-memory comparison (im2col bytes vs line-buffer resident
bytes at the worst conv layer).  ``--quick`` shrinks batch/reps for CI.
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import paired_times
from repro.build import Accelerator, build
from repro.configs import cnv_bnn
from repro.core import ir
from repro.core.engine import FusedEngine


def cnv_accelerator(spec=cnv_bnn.QUICK, *, mode: str = "xnor", seed: int = 0,
                    **overrides) -> Accelerator:
    """The CNV dataflow build (heuristic per-layer folding, as the
    committed baselines were measured)."""
    kw = dict(target="engine", mode=mode, weight_bits=spec.weight_bits,
              act_bits=spec.act_bits, folding="none",
              name=f"cnv_bnn_{spec.image}px")
    kw.update(overrides)
    return build(cnv_bnn.build_graph(spec, seed=seed), **kw)


def conv_memory_model(engine: FusedEngine, batch: int, microbatch: int) -> dict:
    """Analytic peak activation bytes at the worst conv layer.

    Interpreter: the SWU materializes the whole im2col matrix (int32 gather
    output) for the full batch before the MVU consumes it.  Fused kernel:
    one (H, W, C) int8 image tile plus one (rt*OW, K) int8 window tile per
    microbatch -- the line-buffer residency.
    """
    im2col = fused = 0
    for node, ins, out_shape in ir.io_shapes(engine.graph):
        if node.op != "conv_mvu":
            continue
        h, w, c = ins[0]
        oh, ow, _ = out_shape
        kd = node.attrs["kernel"]
        pad = node.attrs["pad"]
        k = kd * kd * c
        im2col = max(im2col, batch * oh * ow * k * 4)
        from repro.kernels.swu_mvu import conv_rows_per_tile

        cfg = node.attrs["config"]
        rt = conv_rows_per_tile(oh, ow, cfg.block_m)
        resident = (h + 2 * pad) * (w + 2 * pad) * c + rt * ow * k
        fused = max(fused, microbatch * resident)
    return {
        "im2col_peak_bytes": im2col,
        "fused_peak_bytes": fused,
        "peak_memory_ratio": (im2col / fused) if fused else 0.0,
    }


def run(*, batch: int = 256, reps: int = 5, seed: int = 0, mode: str = "xnor",
        spec=None, quick: bool = False,
        out: str | None = "experiments/bench/conv_throughput.json") -> dict:
    if spec is None:
        spec = cnv_bnn.QUICK if quick else cnv_bnn.FULL
    acc = cnv_accelerator(spec, mode=mode, seed=seed)
    engine = acc.engine
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(
        rng.integers(0, 2**spec.act_bits, (batch, spec.image, spec.image, 3)),
        jnp.int32)

    plan = engine.plan(batch)

    want = np.asarray(acc.interpret(x))
    got = np.asarray(engine(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    t_unfused, t_fused, speedup = paired_times(
        lambda v: acc.interpret(v), engine, x, reps=reps)

    n_conv = sum(1 for n in engine.graph if n.op == "conv_mvu")
    record = {
        "config": f"cnv_bnn_{spec.image}px_{'x'.join(map(str, spec.channels))}",
        "mode": mode,
        "batch": batch,
        "reps": reps,
        "unfused_us": t_unfused * 1e6,
        "fused_us": t_fused * 1e6,
        "speedup": speedup,
        "unfused_samples_per_s": batch / t_unfused,
        "fused_samples_per_s": batch / t_fused,
        "n_micro": plan.n_micro,
        "microbatch": plan.microbatch,
        "interval_cycles": plan.interval_cycles,
        "bottleneck": engine.schedule.bottleneck.name,
        "conv_stages": n_conv,
        "bit_exact": bool(np.array_equal(got, want)),
        **conv_memory_model(engine, batch, plan.microbatch),
    }
    if out:
        out_dir = os.path.dirname(out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--mode", default="xnor",
                    choices=("xnor", "binary", "standard"))
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized CNV + small batch / few reps")
    ap.add_argument("--out", default="experiments/bench/conv_throughput.json")
    args = ap.parse_args()
    if args.quick:
        # 5 reps + best-of timing for stability under the regression gate
        args.batch, args.reps = min(args.batch, 64), 5

    rec = run(batch=args.batch, reps=args.reps, mode=args.mode,
              quick=args.quick, out=args.out)
    print(json.dumps(rec, indent=2))
    print(f"# fused {rec['fused_us']:.0f}us vs unfused {rec['unfused_us']:.0f}us "
          f"-> {rec['speedup']:.2f}x, peak-mem ratio "
          f"{rec['peak_memory_ratio']:.1f}x at {rec['bottleneck']}")


if __name__ == "__main__":
    main()
