"""repro: FINN MVU reproduction on JAX/Pallas."""

import jax

# Sharding-invariant RNG: without this, jit(init, out_shardings=...) draws
# different parameters than eager init for tensors partitioned on a non-last
# axis (old threefry splits its counter per shard).  Partitionable threefry
# is the future jax default; opt in so sharded and single-device runs agree.
jax.config.update("jax_threefry_partitionable", True)
