"""The Accelerator facade: one object per built dataflow design.

Today's entry points are scattered: the eager interpreter lives in
``repro.core.dataflow``, the fused engine in ``repro.core.engine``, the
continuous batcher in ``repro.serving``, and the multi-device pipeline on
the engine itself.  ``Accelerator`` (the FINN "bitfile + driver" analog)
unifies them behind the build:

    acc = repro.build.build(graph, target="serving", ...)
    y   = acc.interpret(x)     # eager reference (bit-exact contract)
    y   = acc(x)               # fused streaming engine
    b   = acc.serve(batch_buckets=(1, 8, 32))   # continuous batcher
    run = acc.as_pipeline(mesh)                  # multi-device pipeline
    acc.report                  # the BuildReport (JSON-serializable)
"""

from __future__ import annotations

import os

from repro.build.config import BuildError
from repro.build.report import BuildReport
from repro.build.steps import BuildState
from repro.core import dataflow


class Accelerator:
    """A built dataflow design: interpreter + engine + serving, one handle.

    Constructed by :func:`repro.build.build`; never directly.  ``graph`` is
    the final (fused, tuned) chain, ``ref_graph`` the first executable
    snapshot the verification hooks pinned -- the unfused reference the
    benchmarks time the engine against.
    """

    def __init__(self, state: BuildState):
        self.config = state.cfg
        self.graph = state.graph
        self.ref_graph = state.ref_graph if state.ref_graph is not None else state.graph
        self.report: BuildReport = state.report
        self.cache = state.cache
        self.calibration = state.calibration
        # build-step Tracer when cfg.telemetry was set (None otherwise);
        # its summary is already embedded in report.telemetry
        self.tracer = state.tracer
        self._engine = state.engine
        if self.config.output_dir:
            self.save_report()

    # -------------------------------------------------------------- compute
    @property
    def engine(self):
        """The compiled :class:`~repro.core.engine.FusedEngine`."""
        if self._engine is None:
            raise BuildError(
                f"this build (target={self.config.target!r}) ran no 'engine' "
                "step; rebuild with target='engine'/'pipeline'/'serving' or "
                "a step list containing 'engine'")
        return self._engine

    def interpret(self, x):
        """Eager reference semantics (``dataflow.execute``): one dispatch
        per node on the unfused graph -- the behavioural model every
        verification hook compared against."""
        return dataflow.execute(self.ref_graph, x)

    def __call__(self, x):
        return self.engine(x) if self._engine is not None else self.interpret(x)

    def dispatch(self, x, *, params=None, tracer=None):
        """Non-blocking engine submit (see ``FusedEngine.dispatch``)."""
        return self.engine.dispatch(x, params=params, tracer=tracer)

    def profile(self, x, tracer, *, drift=None):
        """Traced per-node eager re-execution (``FusedEngine.profile``):
        bit-exact with ``acc(x)``, one span per node, optionally feeding a
        :class:`~repro.telemetry.DriftMonitor`."""
        return self.engine.profile(x, tracer, drift=drift)

    def drift_monitor(self, **kwargs):
        """A :class:`~repro.telemetry.DriftMonitor` primed with this
        build's per-stage predicted intervals (stage cycles x the
        *calibrated* cycle time).  Requires a ``target="serving"`` build
        (or any step list that ran ``calibrate``): against the nominal
        clock the measured/predicted ratios are meaningless -- see
        docs/observability.md."""
        from repro.telemetry import DriftMonitor

        s_per_cycle = (self.calibration or {}).get("s_per_cycle")
        if not s_per_cycle:
            raise BuildError(
                "drift_monitor() needs a calibrated cycle time; rebuild "
                "with target='serving' (the 'calibrate' step) so per-stage "
                "predictions reflect measured seconds, not the nominal clock")
        return DriftMonitor.from_schedule(
            self.schedule, float(s_per_cycle), **kwargs)

    @property
    def schedule(self):
        return (self._engine.schedule if self._engine is not None
                else dataflow.schedule(self.graph))

    def plan(self, batch: int):
        return self.engine.plan(batch)

    # -------------------------------------------------------------- serving
    def serve(self, *, warmup: bool = True, cache=None,
              fault_policy=None, faults=None, **kwargs):
        """A :class:`~repro.serving.batcher.ContinuousBatcher` over the
        engine.  The build's cache (holding the calibrated cycle time when
        the ``serving`` target ran) feeds the flush budgets unless an
        explicit ``cache`` overrides it; ``warmup`` precompiles every
        bucket shape on every replica before traffic arrives.

        ``fault_policy`` (a :class:`~repro.serving.health.FaultPolicy`)
        tunes the failure handling -- retries, dispatch timeouts, hedging,
        the integrity guard and brownout; the default policy is enabled
        with conservative settings and adds no overhead while replicas are
        healthy.  ``faults`` injects a deterministic
        :class:`~repro.serving.faults.FaultPlan` (chaos testing only).
        ``tracer=``/``drift=`` (forwarded to the batcher) wire telemetry:
        pair with :meth:`drift_monitor` for calibrated predictions."""
        from repro.serving import ContinuousBatcher

        batcher = ContinuousBatcher(
            self.engine, cache=cache if cache is not None else self.cache,
            fault_policy=fault_policy, faults=faults, **kwargs)
        return batcher.warmup() if warmup else batcher

    # ------------------------------------------------------------- pipeline
    def as_pipeline(self, mesh, *, axis: str = "stage", tracer=None):
        """Map the stage chain onto a device mesh (``FusedEngine.as_pipeline``)."""
        return self.engine.as_pipeline(mesh, axis=axis, tracer=tracer)

    # --------------------------------------------------------------- report
    def report_path(self) -> str:
        out_dir = self.config.output_dir or "."
        return os.path.join(out_dir, f"{self.config.name}_build_report.json")

    def save_report(self, path: str | None = None) -> str:
        """Serialize the BuildReport (default: ``<output_dir>/<name>_
        build_report.json``, next to the autotune cache artifacts)."""
        return self.report.save(path if path is not None else self.report_path())
