"""BuildReport: the software analog of the paper's resource/synthesis tables.

The paper reports LUT/FF/BRAM counts, cycle counts, and synthesis time per
design point (Tables 3-7); FINN's ``build_dataflow`` writes per-step
reports next to the build output.  ``BuildReport`` carries the same story
for one :func:`repro.build.build` run:

* per-step wall-clock + verification outcome + op histogram (the
  "synthesis time" table: where the build spends its time),
* per-node folding and resource-model estimates (the LUT/FF/BRAM-analog
  table: ``resource_model.mvu_resources`` per MVU/conv stage),
* the dataflow schedule summary with the predicted steady-state interval
  (nominal clock) next to the measured one when a calibrated cycle time is
  available (predicted vs measured, the paper's RTL-vs-HLS split),
* autotune accounting (cache hits / misses / engine microbatch tile).

Everything round-trips through JSON (``to_json`` / ``from_json`` /
``save`` / ``load``) so reports diff cleanly and can be committed next to
the autotune cache under ``experiments/``.
"""

from __future__ import annotations

import dataclasses
import json
import os


@dataclasses.dataclass
class StepRecord:
    """One executed build step."""

    name: str
    wall_s: float
    verified: bool | None  # None: nothing to verify after this step
    ops: dict[str, int]  # op histogram of the graph after the step
    note: str = ""


@dataclasses.dataclass
class NodeReport:
    """Per-MVU-stage folding + resource estimate (paper Tables 3/6/7)."""

    name: str
    op: str
    mode: str
    n: int
    k: int
    pe: int
    simd: int
    n_pixels: int
    cycles: int
    lut_bytes: int
    ff_bytes: int
    bram_bytes: int
    backend: str
    tuned: bool
    # DAG topology: the stage's input streams and which branch path of a
    # fan-out it sits on ("main" for the trunk).  Defaults keep reports
    # serialized before the DAG IR loadable.
    inputs: list = dataclasses.field(default_factory=list)
    branch: str = "main"
    # packed-datapath decision + HBM-resident weight bytes as stored vs
    # the canonical (unpacked) form.  Defaults (0 = unrecorded) keep
    # reports serialized before the packed datapath loadable.
    packed: bool = False
    weight_bytes: int = 0
    canonical_weight_bytes: int = 0


@dataclasses.dataclass
class BuildReport:
    """Everything one build run learned, JSON-serializable."""

    name: str
    target: str
    config: dict = dataclasses.field(default_factory=dict)
    steps: list[StepRecord] = dataclasses.field(default_factory=list)
    nodes: list[NodeReport] = dataclasses.field(default_factory=list)
    # serialized topology: every [producer, consumer] stream edge of the
    # final graph (chains serialize to the obvious path; fan-out/fan-in
    # graphs make the branch structure diffable)
    edges: list = dataclasses.field(default_factory=list)
    schedule: dict = dataclasses.field(default_factory=dict)
    tune: dict = dataclasses.field(default_factory=dict)
    # design-space exploration (repro.explore): when this build is one point
    # of a sweep, ``sweep`` identifies the point (grid coordinates + the
    # realized per-node foldings) and ``calibration`` carries the fitted
    # cycle time + per-node model-error records the explorer attributed to
    # this design.  Empty dicts for standalone builds.
    sweep: dict = dataclasses.field(default_factory=dict)
    calibration: dict = dataclasses.field(default_factory=dict)
    # build-step trace summary (``Tracer.summary()``) when the config ran
    # with ``telemetry=True``; empty otherwise (old reports load fine)
    telemetry: dict = dataclasses.field(default_factory=dict)
    predicted_interval_s: float | None = None
    measured_interval_s: float | None = None
    cycle_time_source: str = "nominal"  # "nominal" | "measured"
    total_wall_s: float = 0.0
    path: str | None = None

    # ------------------------------------------------------------- recording
    def record_step(self, name: str, wall_s: float, verified: bool | None,
                    ops: dict[str, int], note: str = "") -> StepRecord:
        rec = StepRecord(name, float(wall_s), verified, dict(ops), note)
        self.steps.append(rec)
        return rec

    @property
    def step_names(self) -> list[str]:
        return [s.name for s in self.steps]

    def summary(self) -> dict:
        """The one-line view examples print."""
        return {
            "name": self.name,
            "target": self.target,
            "steps": self.step_names,
            "verified_steps": sum(1 for s in self.steps if s.verified),
            "nodes": len(self.nodes),
            "interval_cycles": self.schedule.get("interval_cycles"),
            "bottleneck": self.schedule.get("bottleneck"),
            "predicted_interval_s": self.predicted_interval_s,
            "measured_interval_s": self.measured_interval_s,
            "tune": dict(self.tune),
            "total_wall_s": round(self.total_wall_s, 4),
            **({"sweep_point": self.sweep.get("point_id")} if self.sweep else {}),
        }

    # ----------------------------------------------------------------- (de)ser
    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("path")
        return d

    @classmethod
    def from_json(cls, d: dict) -> "BuildReport":
        d = dict(d)
        steps = [StepRecord(**s) for s in d.pop("steps", [])]
        nodes = [NodeReport(**n) for n in d.pop("nodes", [])]
        d.pop("path", None)
        rep = cls(**d)
        rep.steps = steps
        rep.nodes = nodes
        return rep

    def save(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        self.path = path
        return path

    @classmethod
    def load(cls, path: str) -> "BuildReport":
        with open(path) as f:
            rep = cls.from_json(json.load(f))
        rep.path = path
        return rep
