"""``repro.build``: the FINN-style step-pipeline compiler front-end.

The paper's lesson, operationalized: once the framework code-generates the
dataflow design, what matters is the *build flow* -- a declarative
pipeline of named transformation steps with per-step verification and a
resource/timing report, not another hand-sequenced chain of module calls.
FINN exposes this as ``build_dataflow`` over ``build_dataflow_steps``;
this package is the equivalent for our IR:

    import repro.build as build

    acc = build.build(
        graph,                      # raw chain: input/conv/linear/bn/quant
        target="engine",            # interpret | engine | pipeline | serving
        mode="standard", weight_bits=4, act_bits=2,
        folding="balance",          # or "none", or explicit [Folding, ...]
        tune="cache",               # committed autotune schedules
        output_dir="experiments/build",   # BuildReport JSON
    )
    y = acc(x)                      # fused streaming engine
    assert (y == acc.interpret(x)).all()   # verified per-step anyway
    batcher = acc.serve(batch_buckets=(1, 8, 32))
    print(acc.report.summary())

Custom steps splice into the default lists by name or callable::

    steps = build.default_steps("engine")
    steps.insert(steps.index("fold"), my_step)      # step(state) -> state
    acc = build.build(graph, steps=steps)

Every transform is verified bit-exact against the reference interpreter
on a probe batch (FINN's verification steps); a divergence raises
:class:`VerificationError` naming the offending step.  The
:class:`BuildReport` carries per-step wall-clock, per-node folding +
LUT/FF/BRAM-analog estimates, predicted-vs-measured cycle time, and
autotune cache accounting -- the software analog of the paper's resource
and synthesis-time tables.
"""

from __future__ import annotations

import dataclasses

from repro.build.accelerator import Accelerator
from repro.build.config import (
    BuildConfig,
    BuildError,
    VerificationError,
)
from repro.build.report import BuildReport, NodeReport, StepRecord
from repro.build.steps import (
    DEFAULT_STEPS,
    STEP_REGISTRY,
    BuildState,
    default_steps,
    register_step,
    run_pipeline,
)

__all__ = [
    "Accelerator",
    "BuildConfig",
    "BuildError",
    "BuildReport",
    "BuildState",
    "DEFAULT_STEPS",
    "NodeReport",
    "STEP_REGISTRY",
    "StepRecord",
    "VerificationError",
    "build",
    "default_steps",
    "register_step",
]


def build(graph_or_config, config: BuildConfig | None = None,
          **overrides) -> Accelerator:
    """Run the step pipeline and return the :class:`Accelerator`.

    ``graph_or_config`` is either a raw IR chain (then ``config`` /
    keyword overrides supply the recipe) or a :class:`BuildConfig` whose
    ``graph`` field carries the chain.  Keyword overrides are applied on
    top of the config in both forms, so the common call is simply
    ``build(graph, target="engine", mode="xnor", ...)``.
    """
    if isinstance(graph_or_config, BuildConfig):
        cfg = graph_or_config
        graph = cfg.graph
        if graph is None:
            raise BuildError(
                "build(config) needs config.graph; or call build(graph, config)")
    else:
        graph = graph_or_config
        cfg = config if config is not None else BuildConfig()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    state = run_pipeline(graph, cfg)
    return Accelerator(state)
