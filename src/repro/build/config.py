"""Build configuration + error types for the step-pipeline compiler.

``BuildConfig`` is the single declarative knob set for
:func:`repro.build.build` -- the FINN ``DataflowBuildConfig`` analog.  One
config names a *target* (which default step list runs), the lowering
parameters every step shares, the folding / autotune policy, and the
verification + report policy.  Everything here is plain data; the step
functions in :mod:`repro.build.steps` read it, never mutate it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from repro.core.folding import Folding

TARGETS = ("interpret", "engine", "pipeline", "serving")
TUNE_MODES = ("off", "cache", "auto")
VERIFY_MODES = ("all", "off")
# weight-packing policies (the pack_weights step):
#   auto   pack nodes whose tuned schedule selected the packed datapath
#   never  keep canonical weight storage everywhere
#   always force packed storage on every packable node (sweeps/benchmarks)
PACK_MODES = ("auto", "never", "always")

# folding policies (the ``folding`` field also accepts an explicit
# per-MVU-node list of Folding objects, applied in chain order)
FOLD_BALANCE = "balance"  # rate-balance all stages (lowering.apply_folding)
FOLD_NONE = "none"  # keep the per-layer heuristic defaults


class BuildError(ValueError):
    """A build step could not run (bad config, malformed graph, ...)."""


class VerificationError(BuildError):
    """A step's output diverged from the reference interpreter.

    The message always names the offending step -- FINN's verification
    steps fail the build the same way, pointing at the transform that
    broke numerical equivalence.  When the hook can localize the
    divergence by re-tracing the graph node-by-node, ``node`` holds the
    first divergent node's id and ``branch`` its branch path (which arm
    of a fan-out it sits on), and the message names both.
    """

    def __init__(self, step: str, detail: str, *,
                 node: str | None = None, branch: str | None = None):
        self.step = step
        self.node = node
        self.branch = branch
        super().__init__(f"verification failed after step {step!r}: {detail}")


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    """Declarative build recipe consumed by :func:`repro.build.build`.

    target: which Accelerator facing the build produces --
        ``interpret`` (eager reference only), ``engine`` (FusedEngine),
        ``pipeline`` (engine + multi-device ``as_pipeline``), ``serving``
        (engine + measured cycle-time calibration for the batcher).
    mode / weight_bits / act_bits / backend: lowering parameters
        (``lowering.lower_to_mvu``).
    folding: ``"balance"`` rate-balances every stage
        (``lowering.apply_folding`` with ``target_cycles``/``max_pe``/
        ``max_simd``), ``"none"`` keeps heuristic per-layer defaults, or an
        explicit sequence of :class:`Folding`, one per MVU node in chain
        order (the paper's Table 6 PE/SIMD choices).
    tune: autotune policy -- ``"off"``, ``"cache"`` (committed schedules,
        zero measurement) or ``"auto"`` (measure misses).  ``cache`` may
        hold a ScheduleCache; None means ``autotune.default_cache()``.
    pack: weight-packing policy for the ``pack_weights`` step --
        ``"auto"`` packs exactly the nodes whose tuned schedule selected
        the packed datapath, ``"never"`` keeps canonical storage,
        ``"always"`` forces packed storage on every packable node.
    verify: ``"all"`` re-runs a probe batch through the reference
        interpreter after every graph transform (FINN's verification
        steps) and checks bit-exactness; ``"off"`` skips.
    steps: override the target's default step list with names from the
        step registry and/or custom callables ``step(state) -> state``.
    name / output_dir: report identity; when ``output_dir`` is set the
        BuildReport is serialized to ``<output_dir>/<name>_build_report
        .json`` (next to the autotune cache under ``experiments/``).
    graph: optional -- lets ``build(config)`` be called with the config
        alone (``build(graph, config)`` wins when both are given).
    """

    target: str = "engine"
    # lowering
    mode: str = "standard"
    weight_bits: int = 4
    act_bits: int = 4
    backend: str = "pallas"
    # folding
    folding: Sequence[Folding] | str = FOLD_BALANCE
    target_cycles: int | None = None
    max_pe: int = 128
    max_simd: int = 128
    # autotune
    tune: str = "off"
    cache: Any = None  # ScheduleCache | None
    tune_kwargs: dict | None = None
    # weight packing (the pack_weights step)
    pack: str = "auto"
    # engine
    microbatches: int | None = None
    # serving calibration (target="serving")
    calibrate_batch: int = 32
    calibrate_reps: int = 3
    # verification + report
    verify: str = "all"
    # telemetry: trace every build step with a repro.telemetry.Tracer and
    # embed the span summary in the BuildReport (zero cost when False)
    telemetry: bool = False
    probe_batch: int = 8
    seed: int = 0
    steps: Sequence[Any] | None = None
    name: str = "build"
    output_dir: str | None = None
    graph: Any = None

    def __post_init__(self):
        if self.target not in TARGETS:
            raise BuildError(f"target must be one of {TARGETS}, got {self.target!r}")
        if self.tune not in TUNE_MODES:
            raise BuildError(f"tune must be one of {TUNE_MODES}, got {self.tune!r}")
        if self.verify not in VERIFY_MODES:
            raise BuildError(
                f"verify must be one of {VERIFY_MODES}, got {self.verify!r}")
        if self.pack not in PACK_MODES:
            raise BuildError(
                f"pack must be one of {PACK_MODES}, got {self.pack!r}")
        if isinstance(self.folding, str) and self.folding not in (
                FOLD_BALANCE, FOLD_NONE):
            raise BuildError(
                f"folding must be {FOLD_BALANCE!r}, {FOLD_NONE!r} or a "
                f"sequence of Folding, got {self.folding!r}")

    def snapshot(self) -> dict:
        """JSON-safe view of the config for the BuildReport (graph, cache
        and callables are identified, not serialized)."""
        d = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name in ("graph", "cache"):
                d[f.name] = None if v is None else type(v).__name__
            elif f.name == "steps":
                d[f.name] = None if v is None else [
                    s if isinstance(s, str) else getattr(s, "__name__", repr(s))
                    for s in v]
            elif f.name == "folding" and not isinstance(v, str):
                d[f.name] = [[fold.pe, fold.simd] for fold in v]
            else:
                d[f.name] = v
        return d
