"""The step pipeline: named build steps over the existing lowering passes.

FINN's ``build_dataflow`` runs a list of named transformation steps
(``build_dataflow_steps``) over the model, with optional verification
after each; this module is that machinery for our IR.  A *step* is any
callable ``step(state: BuildState)`` that mutates/returns the state (it
may also return a plain graph, which replaces ``state.graph``).  The
built-in steps wrap the module-level passes that every example used to
hand-sequence:

    validate        ir.validate_graph
    lower           lowering.lower_to_mvu
    streamline      lowering.streamline      (not in the defaults; the
                                              QAT flow opts in by name)
    finalize        lowering.finalize
    fold            lowering.apply_folding / explicit per-node Foldings
    fuse_epilogues  lowering.fuse_epilogues
    fuse_swu        lowering.fuse_swu
    tune            autotune.tune_graph      (cache hits/misses reported)
    pack_weights    lowering.pack_weights    (bit-packed weight storage)
    dataflow        dataflow.schedule -> report tables
    engine          core.engine.FusedEngine
    calibrate       serving.calibrate_cycle_time (serving target)

After every step that changed the graph, the verification hook re-runs a
probe batch through the reference interpreter (``dataflow.execute``) and
demands bit-exactness with the output captured at the first executable
graph -- FINN's per-transform verification, with
:class:`~repro.build.config.VerificationError` naming the failing step.
"""

from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Any, Callable

import numpy as np

from repro.build.config import (
    FOLD_BALANCE,
    FOLD_NONE,
    BuildConfig,
    BuildError,
    VerificationError,
)
from repro.build.report import BuildReport, NodeReport
from repro.core import dataflow, ir, lowering
from repro.core.ir import Graph
from repro.core.mvu import MVUConfig, MVULayer


# ------------------------------------------------------------------- state
@dataclasses.dataclass
class BuildState:
    """Everything a step may read or advance.

    ``graph`` is the working chain; ``ref_graph``/``probe_out`` pin the
    reference semantics the verification hook holds every later transform
    to.  Steps signal a graph rewrite via :meth:`mark_dirty` (the built-in
    steps do; custom steps that *return* a graph are marked automatically).
    """

    graph: Graph
    cfg: BuildConfig
    report: BuildReport
    cache: Any = None  # ScheduleCache once tune/calibrate need one
    engine: Any = None  # FusedEngine after the "engine" step
    calibration: dict | None = None  # cycle-time entry (serving target)
    tracer: Any = None  # repro.telemetry.Tracer when cfg.telemetry
    ref_graph: Graph | None = None
    probe: Any = None
    probe_out: np.ndarray | None = None
    _dirty: bool = False
    _engine_verified: bool = False

    def mark_dirty(self) -> None:
        self._dirty = True

    def require_cache(self):
        if self.cache is None:
            from repro.core import autotune

            self.cache = autotune.ScheduleCache()
        return self.cache


# ---------------------------------------------------------------- registry
STEP_REGISTRY: dict[str, Callable[[BuildState], Any]] = {}


def register_step(name: str):
    """Register ``fn`` under ``name`` so step lists can name it."""

    def deco(fn):
        STEP_REGISTRY[name] = fn
        fn.step_name = name
        return fn

    return deco


def step_name(step) -> str:
    if isinstance(step, str):
        return step
    return getattr(step, "step_name", getattr(step, "__name__", repr(step)))


def resolve_step(step) -> Callable[[BuildState], Any]:
    if callable(step):
        return step
    try:
        return STEP_REGISTRY[step]
    except KeyError:
        raise BuildError(
            f"unknown build step {step!r}; registered steps: "
            f"{sorted(STEP_REGISTRY)}") from None


# Default step lists per target -- the FINN ``default_build_dataflow_steps``
# analog.  ``interpret`` stops at the folded reference graph; the engine
# targets fuse + tune + compile; ``serving`` additionally measures the
# realized cycle time so batcher flush budgets are in wall-clock units.
_ENGINE_STEPS = ("validate", "lower", "finalize", "fold", "fuse_epilogues",
                 "fuse_swu", "tune", "pack_weights", "dataflow", "engine")
DEFAULT_STEPS: dict[str, tuple[str, ...]] = {
    "interpret": ("validate", "lower", "finalize", "fold", "pack_weights",
                  "dataflow"),
    "engine": _ENGINE_STEPS,
    "pipeline": _ENGINE_STEPS,
    "serving": _ENGINE_STEPS + ("calibrate",),
}


def default_steps(target: str) -> list[str]:
    """The default step-name list for one build target (copy; splice away)."""
    try:
        return list(DEFAULT_STEPS[target])
    except KeyError:
        raise BuildError(
            f"no default steps for target {target!r}; targets: "
            f"{sorted(DEFAULT_STEPS)}") from None


# ------------------------------------------------------------- built-ins
@register_step("validate")
def step_validate(state: BuildState) -> None:
    ir.validate_graph(state.graph)


@register_step("lower")
def step_lower(state: BuildState) -> None:
    cfg = state.cfg
    state.graph = lowering.lower_to_mvu(
        state.graph, mode=cfg.mode, weight_bits=cfg.weight_bits,
        act_bits=cfg.act_bits, backend=cfg.backend)
    state.mark_dirty()


@register_step("streamline")
def step_streamline(state: BuildState) -> None:
    state.graph = lowering.streamline(state.graph)
    state.mark_dirty()


@register_step("finalize")
def step_finalize(state: BuildState) -> None:
    state.graph = lowering.finalize(state.graph)
    state.mark_dirty()


@register_step("fold")
def step_fold(state: BuildState) -> None:
    cfg = state.cfg
    if isinstance(cfg.folding, str):
        if cfg.folding == FOLD_NONE:
            return
        assert cfg.folding == FOLD_BALANCE
        state.graph = lowering.apply_folding(
            state.graph, target_cycles=cfg.target_cycles,
            max_pe=cfg.max_pe, max_simd=cfg.max_simd)
        state.mark_dirty()
        return
    folds = list(cfg.folding)
    # explicit foldings apply in dataflow (topological) order -- identical
    # to list order for chains; toposorted nodes share their attrs dicts
    # with state.graph, so the in-place config rewrite reaches it
    mvu_nodes = [n for n in ir.toposort(state.graph)
                 if n.op in ("mvu", "conv_mvu")]
    if len(folds) != len(mvu_nodes):
        raise BuildError(
            f"folding override lists {len(folds)} entries but the lowered "
            f"graph has {len(mvu_nodes)} MVU stages")
    for node, fold in zip(mvu_nodes, folds):
        mcfg: MVUConfig = node.attrs["config"]
        node.attrs["config"] = MVUConfig(**{**mcfg.__dict__, "folding": fold})
    state.mark_dirty()


@register_step("fuse_epilogues")
def step_fuse_epilogues(state: BuildState) -> None:
    state.graph = lowering.fuse_epilogues(state.graph)
    state.mark_dirty()


@register_step("fuse_swu")
def step_fuse_swu(state: BuildState) -> None:
    state.graph = lowering.fuse_swu(state.graph)
    state.mark_dirty()


@register_step("tune")
def step_tune(state: BuildState) -> None:
    """Pin autotuned schedules; report cache hits/misses (autotune pass)."""
    cfg = state.cfg
    state.report.tune = {"mode": cfg.tune}
    if cfg.tune == "off":
        return
    from repro.core import autotune

    # run_pipeline seeds state.cache whenever cfg.tune != "off"; the cache
    # selection policy lives there alone
    kwargs = dict(cfg.tune_kwargs or {})
    keys = autotune.graph_node_keys(state.graph, device=kwargs.get("device"))
    hits = sum(1 for key in keys if key in state.cache)
    misses = len(keys) - hits
    state.graph = autotune.tune_graph(
        state.graph, cache=state.cache, mode=cfg.tune,
        allow_packed=cfg.pack != "never", **kwargs)
    state.report.tune.update(
        cache_hits=hits, cache_misses=misses, cache_entries=len(state.cache))
    state.mark_dirty()


@register_step("pack_weights")
def step_pack_weights(state: BuildState) -> None:
    """Bit-packed weight storage rewrite (``lowering.pack_weights``).

    ``pack="auto"`` packs exactly the nodes whose tuned schedule selected
    the packed datapath; ``"always"`` forces every packable node;
    ``"never"`` is a no-op.  The per-step verification hook then proves
    the rewrite bit-exact against the pinned reference for free.
    """
    cfg = state.cfg
    if cfg.pack == "never":
        return
    state.graph = lowering.pack_weights(
        state.graph, force=cfg.pack == "always")
    state.mark_dirty()


@register_step("dataflow")
def step_dataflow(state: BuildState) -> None:
    """Schedule + per-node resource tables into the report (no rewrite)."""
    sched = dataflow.schedule(state.graph)
    state.report.schedule = sched.summary() if sched.stages else {"stages": 0}
    state.report.edges = ir.edge_list(state.graph)
    branches = ir.branch_labels(state.graph)
    nodes: list[NodeReport] = []
    for node, _, out_shape in ir.io_shapes(state.graph):
        if node.op not in ("mvu", "conv_mvu"):
            continue
        mcfg: MVUConfig = node.attrs["config"]
        px = ir.n_pixels(out_shape)
        fold = mcfg.resolved_folding()
        res = MVULayer(mcfg).resources(n_pixels=px)
        nodes.append(NodeReport(
            name=node.name, op=node.op, mode=mcfg.mode,
            n=mcfg.out_features, k=mcfg.in_features,
            pe=fold.pe, simd=fold.simd, n_pixels=px, cycles=res.cycles,
            lut_bytes=res.lut_bytes, ff_bytes=res.ff_bytes,
            bram_bytes=res.bram_bytes, backend=mcfg.backend,
            tuned=mcfg.blocks is not None,
            inputs=list(node.inputs),
            branch=branches.get(node.name, "main"),
            packed=mcfg.packed,
            weight_bytes=res.weight_bytes,
            canonical_weight_bytes=res.canonical_weight_bytes))
    state.report.nodes = nodes
    if sched.stages:
        state.report.predicted_interval_s = (
            sched.steady_state_interval / dataflow.DEFAULT_CLOCK_HZ)
        measured = _measured_interval(state, sched)
        if measured is not None:
            state.report.measured_interval_s = measured
            state.report.cycle_time_source = "measured"


def _measured_interval(state: BuildState, sched) -> float | None:
    """Measured-cycle-time interval when the cache holds a calibration.

    The conversion itself stays in :func:`dataflow.interval_seconds` (the
    single owner of the cycles-to-seconds rule); this helper only decides
    whether a measurement exists at all.
    """
    if state.cache is None:
        return None
    from repro.core import autotune

    ent = state.cache.get(autotune.cycle_time_key())
    if ent is None or not ent.get("s_per_cycle"):
        return None
    return dataflow.interval_seconds(sched, cache=state.cache)


@register_step("engine")
def step_engine(state: BuildState) -> None:
    """Compile the fused streaming engine (tuned microbatch tile applies
    through the shared cache)."""
    from repro.core.engine import FusedEngine

    cfg = state.cfg
    state.engine = FusedEngine(
        state.graph, microbatches=cfg.microbatches, tune=cfg.tune,
        cache=state.cache, tune_kwargs=cfg.tune_kwargs)
    if cfg.tune != "off":
        state.report.tune["engine_tile"] = state.engine._tile


@register_step("calibrate")
def step_calibrate(state: BuildState) -> None:
    """Measure the realized seconds-per-cycle (the serving warmup path):
    recorded under ``autotune.cycle_time_key`` in the build's cache so
    every batcher constructed from this Accelerator budgets flushes in
    measured wall-clock units, not the nominal clock."""
    from repro.serving import calibrate_cycle_time

    if state.engine is None:
        raise BuildError("the 'calibrate' step needs the 'engine' step first")
    cfg = state.cfg
    state.calibration = calibrate_cycle_time(
        state.engine, batch=cfg.calibrate_batch, reps=cfg.calibrate_reps,
        cache=state.require_cache())
    sched = state.engine.schedule
    if sched.stages:
        state.report.measured_interval_s = dataflow.interval_seconds(
            sched, cache=state.cache)
        state.report.cycle_time_source = "measured"


# ------------------------------------------------------------ verification
def _localize_divergence(state: BuildState, graph: Graph) -> tuple:
    """Pin a probe-batch divergence to its first bad node and branch path.

    Re-traces ``graph`` and the pinned reference graph node-by-node
    (``dataflow.trace``) and walks the current graph in dataflow order
    comparing each node's stream against the reference activation it must
    reproduce -- fused nodes against the last epilogue node they absorbed
    (``attrs["fused"]``), conv_mvu nodes against their pre-``fuse_swu``
    MVU.  Returns ``(detail_suffix, node_name, branch)``; all empty when
    localization itself fails (the step-level error still raises).
    """
    try:
        ref_env = dataflow.trace(state.ref_graph, state.probe)
        got_env = dataflow.trace(graph, state.probe)
        branches = ir.branch_labels(graph)
    except Exception:
        return "", None, None
    for node in ir.toposort(graph):
        if node.op == "input":
            continue
        cands = []
        fused = node.attrs.get("fused")
        if fused:
            cands.append(fused[-1])
        cands.append(node.name)
        if ".conv_mvu" in node.name:
            cands.append(node.name.replace(".conv_mvu", ".mvu"))
        want = next((ref_env[c] for c in cands if c in ref_env), None)
        got = got_env.get(node.name)
        if want is None or got is None:
            continue
        want, got = np.asarray(want), np.asarray(got)
        if got.shape != want.shape or not np.array_equal(got, want):
            br = branches.get(node.name, "main")
            return (f"; first divergent node: {node.name!r} on branch "
                    f"{br!r}", node.name, br)
    return "", None, None


def _executable(graph: Graph) -> bool:
    """Can ``dataflow.execute`` run this graph? (no float conv/linear left,
    every MVU finalized)."""
    for n in graph:
        if n.op in ("conv", "linear"):
            return False
        if n.op in ("mvu", "conv_mvu") and "mvu" not in n.params:
            return False
    return True


def _op_histogram(graph: Graph) -> dict[str, int]:
    return dict(Counter(n.op for n in graph))


def verify_after(state: BuildState, name: str) -> bool | None:
    """The per-step verification hook (FINN's verification steps).

    Captures the reference interpreter output at the first executable
    graph; every later graph rewrite must reproduce it bit-exactly on the
    probe batch, and the compiled engine is held to the same reference.
    Returns True (verified), False is never returned -- a mismatch raises
    :class:`VerificationError` naming the step -- and None when there was
    nothing new to verify.
    """
    verified = None
    if state._dirty and _executable(state.graph):
        state._dirty = False
        if state.probe is None:
            from repro.core import autotune

            state.probe = autotune.synth_input(
                state.graph, state.cfg.probe_batch, seed=state.cfg.seed)
        if state.probe_out is None:
            # first executable graph: pin the reference semantics (and keep
            # this graph as the Accelerator's interpreter facing)
            state.ref_graph = state.graph
            state.probe_out = np.asarray(
                dataflow.execute(state.graph, state.probe))
            verified = True
        else:
            got = np.asarray(dataflow.execute(state.graph, state.probe))
            if got.shape != state.probe_out.shape or not np.array_equal(
                    got, state.probe_out):
                suffix, bad_node, branch = _localize_divergence(
                    state, state.graph)
                raise VerificationError(
                    name, "graph output diverged from the reference "
                    f"interpreter on a {state.cfg.probe_batch}-sample probe "
                    f"batch{suffix}", node=bad_node, branch=branch)
            verified = True
    if state.engine is not None and not state._engine_verified \
            and state.probe_out is not None:
        state._engine_verified = True
        got = np.asarray(state.engine(state.probe))
        if not np.array_equal(got, state.probe_out):
            # the engine shares the fused graph's params, so an eager
            # re-trace of engine.graph localizes the divergent stage
            suffix, bad_node, branch = _localize_divergence(
                state, state.engine.graph)
            raise VerificationError(
                name, "compiled engine diverged from the reference "
                f"interpreter on the probe batch{suffix}",
                node=bad_node, branch=branch)
        verified = True
    return verified


# ------------------------------------------------------------------ driver
def run_pipeline(graph: Graph, cfg: BuildConfig) -> BuildState:
    """Execute the config's step list over ``graph``; returns the final
    state (the :class:`~repro.build.accelerator.Accelerator` wraps it)."""
    report = BuildReport(name=cfg.name, target=cfg.target,
                         config=cfg.snapshot())
    state = BuildState(graph=list(graph), cfg=cfg, report=report)
    if cfg.tune != "off":
        from repro.core import autotune

        state.cache = cfg.cache if cfg.cache is not None else autotune.default_cache()
    elif cfg.cache is not None:
        state.cache = cfg.cache
    tracer = None
    if cfg.telemetry:
        from repro.telemetry import Tracer

        tracer = Tracer(meta={"build": cfg.name, "target": cfg.target})
    steps = cfg.steps if cfg.steps is not None else DEFAULT_STEPS[cfg.target]
    t_build = time.perf_counter()
    for step in steps:
        fn = resolve_step(step)
        name = step_name(step)
        sp = (tracer.span(f"step.{name}", cat="build").__enter__()
              if tracer is not None else None)
        t0 = time.perf_counter()
        try:
            out = fn(state)
        finally:
            if sp is not None:
                sp.__exit__(None, None, None)
        if isinstance(out, BuildState):
            state = out
        elif isinstance(out, list):  # a custom step returned a graph
            state.graph = out
            state.mark_dirty()
        wall = time.perf_counter() - t0
        verified = (verify_after(state, name)
                    if cfg.verify != "off" else None)
        report.record_step(name, wall, verified, _op_histogram(state.graph))
    report.total_wall_s = time.perf_counter() - t_build
    if tracer is not None:
        report.telemetry = tracer.summary()
        state.tracer = tracer
    if state.ref_graph is None and _executable(state.graph):
        state.ref_graph = state.graph
    return state
