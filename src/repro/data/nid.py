"""Synthetic UNSW-NB15-like dataset for the paper's NID use case (Sec 6.5).

The real dataset (49 flow features, binary attack label) is not available
offline; we generate a statistically similar stand-in: class-conditional
mixtures over 49 base features, expanded and quantized to the 600-wide
2-bit input vector the paper's MLP consumes (Table 6: layer 0 has 600 IFM
channels at 2-bit precision).
"""

from __future__ import annotations

import numpy as np

N_RAW = 49
N_INPUT = 600
BITS = 2


def _expand(raw: np.ndarray, rng: np.random.Generator, proj: np.ndarray) -> np.ndarray:
    """49 raw features -> 600 quantized (2-bit) features via random projection."""
    x = raw @ proj  # (B, 600)
    x = (x - x.mean(0, keepdims=True)) / (x.std(0, keepdims=True) + 1e-6)
    q = np.clip(np.round((x + 2.0) / 4.0 * (2**BITS - 1)), 0, 2**BITS - 1)
    return q.astype(np.int32)


def make_dataset(n: int, *, seed: int = 0, structure_seed: int = 1234):
    """Returns (x (n, 600) int 2-bit, y (n,) {0,1}).

    ``structure_seed`` fixes the class centers and feature projection (the
    "true network distribution"); ``seed`` varies only the sampled flows,
    so train/test splits share one distribution.
    """
    srng = np.random.default_rng(structure_seed)
    proj = srng.normal(0, 1.0, (N_RAW, N_INPUT)) / np.sqrt(N_RAW)
    centers = srng.normal(0, 1.0, (2, N_RAW))
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n)
    raw = centers[y] + rng.normal(0, 0.9, (n, N_RAW))
    # a few "protocol" features are strongly class-dependent (like UNSW's
    # service/state categoricals)
    raw[:, :6] += 2.5 * (2 * y[:, None] - 1)
    return _expand(raw, rng, proj), y.astype(np.int32)


def iterate(x, y, batch: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    while True:
        idx = rng.integers(0, n, batch)
        yield x[idx], y[idx]
