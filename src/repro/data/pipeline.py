"""Host data pipeline: deterministic synthetic LM stream.

Learnable structure: a fixed random permutation f over the vocabulary;
sequences follow tok[t+1] = f(tok[t]) with jump probability eps, so a
model can drive the loss well below ln(V) by learning f.  Sharded across
hosts by process index (each host materializes only its slice of the
global batch) and double-buffered ahead of the step (the dynamic analog of
FINN's stream backpressure lives here: the device never waits on the host
unless the host truly falls behind).
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        *,
        seed: int = 0,
        jump_prob: float = 0.1,
        process_index: int = 0,
        process_count: int = 1,
        prefetch: int = 2,
    ):
        assert global_batch % process_count == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // process_count
        self.rng = np.random.default_rng(seed + 1000 * process_index)
        self.perm = np.random.default_rng(seed).permutation(vocab_size)
        self.jump = jump_prob
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make_batch(self) -> dict:
        b, s = self.local_batch, self.seq
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = self.rng.integers(0, self.vocab, b)
        jumps = self.rng.random((b, s)) < self.jump
        randoms = self.rng.integers(0, self.vocab, (b, s))
        for t in range(s):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(jumps[:, t], randoms[:, t], nxt)
        return {"tokens": toks}

    def _worker(self):
        while not self._stop.is_set():
            batch = self._make_batch()
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
