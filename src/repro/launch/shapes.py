"""Assigned input-shape sets and the (arch x shape) cell matrix.

    train_4k     seq 4,096   global_batch 256   -> train_step
    prefill_32k  seq 32,768  global_batch 32    -> prefill (serve)
    decode_32k   seq 32,768  global_batch 128   -> decode_step (one token,
                                                   KV cache of seq_len)
    long_500k    seq 524,288 global_batch 1     -> decode_step; requires a
                                                   sub-quadratic arch

long_500k is SKIPPED for pure full-attention archs (yi-9b, command-r-plus,
nemotron-4, qwen2-vl, granite-moe, qwen3-moe, whisper) per the assignment;
it RUNS for h2o-danube (SWA), mamba2 (attn-free) and jamba (hybrid).
Skips are recorded in the dry-run table, justification in DESIGN.md
section Arch-applicability.
"""

from __future__ import annotations

import dataclasses

from repro.configs import ARCH_IDS, get_config


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable_shapes(arch: str) -> list[str]:
    cfg = get_config(arch)
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return names


def skip_reason(arch: str, shape: str) -> str | None:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return "pure full-attention arch (no sub-quadratic path); skip per assignment"
    return None


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in applicable_shapes(a)]


def all_cells_with_skips() -> list[tuple[str, str, str | None]]:
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            out.append((a, s, skip_reason(a, s)))
    return out
