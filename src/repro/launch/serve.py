"""Serving driver: sharded prefill + decode steps, batched request loop.

``shard_serve_fns`` builds the two jitted entry points the dry-run lowers
for the decode_* and long_* shapes; ``serve_loop`` is a host-scale batched
continuous-serving simulation (requests arrive, get batched, prefilled,
and decoded to completion) used by examples/serve_lm.py.

Long-context SP: with ``seq_over_model=True`` the KV cache's sequence dim
shards over "model" and GSPMD inserts the partial-softmax combine
(flash-decode style) -- used for the long_500k cells.

``EngineServer`` is the dataflow-graph counterpart: a request-coalescing,
shape-bucketed front-end over ``repro.core.engine.FusedEngine`` (used by the
NID example and benchmarks/engine_throughput.py).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import batch_pspec, cache_pspecs, param_shardings
from repro.models.model import Model


def shard_serve_fns(model: Model, mesh, batch: int, max_len: int,
                    *, seq_over_model: bool = False):
    """Returns (prefill_fn, decode_fn, params_sharding, state_sharding)."""
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = param_shardings(mesh, params_shape)
    state_shape = jax.eval_shape(lambda: model.init_decode_state(batch, max_len))
    s_shard = cache_pspecs(mesh, state_shape, seq_over_model=seq_over_model)
    tok_shard = jax.sharding.NamedSharding(mesh, batch_pspec(mesh))

    prefill = jax.jit(
        model.prefill,
        in_shardings=(p_shard, None, s_shard),
        out_shardings=(None, s_shard),
    )
    decode = jax.jit(
        model.decode_step,
        in_shardings=(
            p_shard,
            s_shard,
            jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(batch_pspec(mesh)[0])
            ),
        ),
        out_shardings=(None, s_shard),
        donate_argnums=(1,),
    )
    return prefill, decode, p_shard, s_shard


@dataclasses.dataclass
class EngineRequest:
    rid: int
    x: np.ndarray  # one sample, engine input shape minus the batch dim
    t_submit: float = 0.0
    t_done: float = 0.0
    out: np.ndarray | None = None


class EngineServer:
    """Batched serving front-end for ``repro.core.engine.FusedEngine``.

    Requests coalesce into padded shape buckets: a flush pads each pending
    group up to the smallest bucket batch that holds it, so the engine's jit
    cache sees only ``len(batch_buckets)`` executables no matter the traffic
    pattern (the serving analog of the dry-run's fixed shape grid).  Oversize
    groups split into max-bucket chunks.
    """

    def __init__(self, engine, *, batch_buckets: tuple[int, ...] = (1, 8, 32, 128)):
        if not batch_buckets or any(b <= 0 for b in batch_buckets):
            raise ValueError(f"need positive bucket sizes, got {batch_buckets}")
        self.engine = engine
        self.buckets = tuple(sorted(set(batch_buckets)))
        self._pending: list[EngineRequest] = []
        self._next_rid = 0
        self.stats = {"requests": 0, "flushes": 0, "padded_samples": 0}

    def submit(self, x: np.ndarray) -> int:
        """Queue one sample; returns its request id (resolved by flush)."""
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(EngineRequest(rid, np.asarray(x), time.perf_counter()))
        self.stats["requests"] += 1
        return rid

    def submit_batch(self, xs: np.ndarray) -> list[int]:
        """Queue a multi-sample request (leading batch dim); returns one rid
        per sample.  Requests larger than the biggest bucket are legal: flush
        splits the backlog across max-size bucket launches."""
        return [self.submit(x) for x in np.asarray(xs)]

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        # No bucket holds n samples.  Returning the max bucket here would
        # silently launch an unbucketed (n-sized) jit shape; oversized groups
        # must be split across max-size buckets by flush() instead.
        raise ValueError(
            f"group of {n} exceeds the largest bucket {self.buckets[-1]}; "
            "flush() must split it first"
        )

    def flush(self) -> list[EngineRequest]:
        """Coalesce pending requests, run the engine, scatter the results.

        Backlogs larger than the biggest bucket split into max-bucket chunks,
        so the engine only ever sees bucket-sized batches."""
        done: list[EngineRequest] = []
        while self._pending:
            group = self._pending[: self.buckets[-1]]
            self._pending = self._pending[len(group) :]
            bucket = self._bucket_for(len(group))
            xs = np.stack([r.x for r in group])
            if bucket > len(group):  # pad up to the bucket's batch shape
                pad = np.zeros((bucket - len(group),) + xs.shape[1:], xs.dtype)
                xs = np.concatenate([xs, pad])
                self.stats["padded_samples"] += bucket - len(group)
            ys = np.asarray(self.engine(jnp.asarray(xs)))
            t1 = time.perf_counter()
            for r, y in zip(group, ys):
                r.out, r.t_done = y, t1
            done.extend(group)
            self.stats["flushes"] += 1
        return done


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0


def serve_loop(model: Model, params, requests: list[Request], *,
               batch: int = 4, max_len: int = 256, greedy: bool = True):
    """Static-batched serving: groups requests into batches, prefills the
    (right-padded) prompts, then decodes all sequences in lockstep."""
    done: list[Request] = []
    for i in range(0, len(requests), batch):
        group = requests[i : i + batch]
        while len(group) < batch:
            group.append(Request(rid=-1, prompt=group[0].prompt, max_new=group[0].max_new))
        s = max(len(r.prompt) for r in group)
        toks = np.zeros((batch, s), np.int32)
        for j, r in enumerate(group):
            toks[j, : len(r.prompt)] = r.prompt  # left-aligned prompts
        state = model.init_decode_state(batch, max_len)
        t0 = time.perf_counter()
        logits, state = model.prefill(params, {"tokens": jnp.asarray(toks)}, state)
        nxt = jnp.argmax(logits, -1) if greedy else logits.argmax(-1)
        max_new = max(r.max_new for r in group)
        for _ in range(max_new):
            for j, r in enumerate(group):
                if r.rid >= 0 and len(r.out) < r.max_new:
                    r.out.append(int(nxt[j]))
            logits, state = model.decode_step(params, state, nxt)
            nxt = jnp.argmax(logits, -1)
        t1 = time.perf_counter()
        for r in group:
            if r.rid >= 0:
                r.t_done = t1 - t0
                done.append(r)
    return done
