"""Serving driver: sharded prefill + decode steps, batched request loop.

``shard_serve_fns`` builds the two jitted entry points the dry-run lowers
for the decode_* and long_* shapes; ``serve_loop`` is a host-scale batched
continuous-serving simulation (requests arrive, get batched, prefilled,
and decoded to completion) used by examples/serve_lm.py.

Long-context SP: with ``seq_over_model=True`` the KV cache's sequence dim
shards over "model" and GSPMD inserts the partial-softmax combine
(flash-decode style) -- used for the long_500k cells.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import batch_pspec, cache_pspecs, param_shardings
from repro.models.model import Model


def shard_serve_fns(model: Model, mesh, batch: int, max_len: int,
                    *, seq_over_model: bool = False):
    """Returns (prefill_fn, decode_fn, params_sharding, state_sharding)."""
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = param_shardings(mesh, params_shape)
    state_shape = jax.eval_shape(lambda: model.init_decode_state(batch, max_len))
    s_shard = cache_pspecs(mesh, state_shape, seq_over_model=seq_over_model)
    tok_shard = jax.sharding.NamedSharding(mesh, batch_pspec(mesh))

    prefill = jax.jit(
        model.prefill,
        in_shardings=(p_shard, None, s_shard),
        out_shardings=(None, s_shard),
    )
    decode = jax.jit(
        model.decode_step,
        in_shardings=(
            p_shard,
            s_shard,
            jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(batch_pspec(mesh)[0])
            ),
        ),
        out_shardings=(None, s_shard),
        donate_argnums=(1,),
    )
    return prefill, decode, p_shard, s_shard


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0


def serve_loop(model: Model, params, requests: list[Request], *,
               batch: int = 4, max_len: int = 256, greedy: bool = True):
    """Static-batched serving: groups requests into batches, prefills the
    (right-padded) prompts, then decodes all sequences in lockstep."""
    done: list[Request] = []
    for i in range(0, len(requests), batch):
        group = requests[i : i + batch]
        while len(group) < batch:
            group.append(Request(rid=-1, prompt=group[0].prompt, max_new=group[0].max_new))
        s = max(len(r.prompt) for r in group)
        toks = np.zeros((batch, s), np.int32)
        for j, r in enumerate(group):
            toks[j, : len(r.prompt)] = r.prompt  # left-aligned prompts
        state = model.init_decode_state(batch, max_len)
        t0 = time.perf_counter()
        logits, state = model.prefill(params, {"tokens": jnp.asarray(toks)}, state)
        nxt = jnp.argmax(logits, -1) if greedy else logits.argmax(-1)
        max_new = max(r.max_new for r in group)
        for _ in range(max_new):
            for j, r in enumerate(group):
                if r.rid >= 0 and len(r.out) < r.max_new:
                    r.out.append(int(nxt[j]))
            logits, state = model.decode_step(params, state, nxt)
            nxt = jnp.argmax(logits, -1)
        t1 = time.perf_counter()
        for r in group:
            if r.rid >= 0:
                r.t_done = t1 - t0
                done.append(r)
    return done
