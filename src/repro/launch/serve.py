"""Serving driver: sharded prefill + decode steps, batched request loop.

``shard_serve_fns`` builds the two jitted entry points the dry-run lowers
for the decode_* and long_* shapes; ``serve_loop`` is a host-scale batched
continuous-serving simulation (requests arrive, get batched, prefilled,
and decoded to completion) used by examples/serve_lm.py.

Long-context SP: with ``seq_over_model=True`` the KV cache's sequence dim
shards over "model" and GSPMD inserts the partial-softmax combine
(flash-decode style) -- used for the long_500k cells.

``EngineServer`` is the dataflow-graph counterpart: a request-coalescing,
shape-bucketed front-end over ``repro.core.engine.FusedEngine``.  It is now
a thin deprecated shim over ``repro.serving`` (bounded admission queue +
continuous batcher + replica pool); new code should use
``repro.serving.ContinuousBatcher`` directly.
"""

from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import batch_pspec, cache_pspecs, param_shardings
from repro.models.model import Model


def shard_serve_fns(model: Model, mesh, batch: int, max_len: int,
                    *, seq_over_model: bool = False):
    """Returns (prefill_fn, decode_fn, params_sharding, state_sharding)."""
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = param_shardings(mesh, params_shape)
    state_shape = jax.eval_shape(lambda: model.init_decode_state(batch, max_len))
    s_shard = cache_pspecs(mesh, state_shape, seq_over_model=seq_over_model)
    tok_shard = jax.sharding.NamedSharding(mesh, batch_pspec(mesh))

    prefill = jax.jit(
        model.prefill,
        in_shardings=(p_shard, None, s_shard),
        out_shardings=(None, s_shard),
    )
    decode = jax.jit(
        model.decode_step,
        in_shardings=(
            p_shard,
            s_shard,
            jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(batch_pspec(mesh)[0])
            ),
        ),
        out_shardings=(None, s_shard),
        donate_argnums=(1,),
    )
    return prefill, decode, p_shard, s_shard


# the shim warns once per process, not once per construction: a serving
# loop that builds servers in a loop should not flood the log
_ENGINE_SERVER_WARNED = False


def _warn_engine_server_deprecated() -> None:
    global _ENGINE_SERVER_WARNED
    if _ENGINE_SERVER_WARNED:
        return
    _ENGINE_SERVER_WARNED = True
    warnings.warn(
        "EngineServer is deprecated; build an Accelerator with "
        "repro.build.build(graph, target='serving') and use "
        "Accelerator.serve() / repro.serving.ContinuousBatcher",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class EngineRequest:
    rid: int
    x: np.ndarray | None  # legacy field; the shim no longer retains inputs
    t_submit: float = 0.0
    t_done: float = 0.0
    out: np.ndarray | None = None


class EngineServer:
    """DEPRECATED: thin shim over :mod:`repro.serving`.

    The original synchronous, manually-flushed server now delegates to the
    continuous-batching subsystem (bounded admission queue + batcher +
    replica pool) while keeping its submit/flush API and bucket semantics:
    a flush pads each pending group up to the smallest bucket batch that
    holds it, oversize backlogs split into max-bucket chunks, and samples
    are validated against the engine graph's input spec at ``submit`` (a
    malformed request fails there with a clear error, not inside the
    flush-time stack).  New code should build through
    ``repro.build.build(graph, target="serving")`` and use
    ``Accelerator.serve()`` / ``repro.serving.ContinuousBatcher``
    (SLO-aware flushing, async multi-replica dispatch, metrics).
    """

    def __init__(self, engine, *, batch_buckets: tuple[int, ...] = (1, 8, 32, 128)):
        if not batch_buckets or any(b <= 0 for b in batch_buckets):
            raise ValueError(f"need positive bucket sizes, got {batch_buckets}")
        _warn_engine_server_deprecated()
        from repro.serving import ContinuousBatcher

        self.engine = engine
        self.buckets = tuple(sorted(set(batch_buckets)))
        # manual-flush compatibility: no idle-greedy or deadline-triggered
        # launches, an effectively unbounded queue, flush() drives everything
        self._batcher = ContinuousBatcher(
            engine, batch_buckets=self.buckets, greedy_when_idle=False,
            queue_capacity=1 << 30)

    @property
    def stats(self) -> dict:
        c = self._batcher.metrics.counters
        return {"requests": c["requests"], "flushes": c["flushes"],
                "padded_samples": c["padded_samples"]}

    @property
    def _pending(self) -> list[int]:
        """Rids awaiting a flush (legacy probe; lives in the batcher queue)."""
        return self._batcher.queue.pending_rids()

    def submit(self, x: np.ndarray) -> int:
        """Queue one sample; returns its request id (resolved by flush)."""
        return self._batcher.submit(x)

    def submit_batch(self, xs: np.ndarray) -> list[int]:
        """Queue a multi-sample request (leading batch dim) as ONE block --
        no per-sample array copies -- returning one rid per sample.
        Requests larger than the biggest bucket are legal: flush splits the
        backlog across max-size bucket launches."""
        return self._batcher.submit_batch(xs)

    def _bucket_for(self, n: int) -> int:
        # No bucket holds an oversize n: returning the max bucket would
        # silently launch an unbucketed (n-sized) jit shape, so this raises
        # and flush() splits oversize backlogs across max-size buckets.
        return self._batcher.bucket_for(n)

    def flush(self) -> list[EngineRequest]:
        """Coalesce pending requests, run the engine, scatter the results.

        Backlogs larger than the biggest bucket split into max-bucket chunks,
        so the engine only ever sees bucket-sized batches.  Each launch is
        resolved and popped before the next starts (the legacy synchronous
        per-group execution), so the batcher's bounded result store never
        has to hold more than one bucket of a giant backlog."""
        b = self._batcher
        done: list[EngineRequest] = []
        while b.queue.depth:
            b._launch(min(b.queue.depth, b.buckets[-1]))
            for rid in sorted(b.harvest(block=True)):
                r = b.pop_result(rid)
                done.append(EngineRequest(rid, None, r.t_submit, r.t_done, r.out))
        done.sort(key=lambda r: r.rid)
        return done


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0


def serve_loop(model: Model, params, requests: list[Request], *,
               batch: int = 4, max_len: int = 256, greedy: bool = True):
    """Static-batched serving: groups requests into batches, prefills the
    (right-padded) prompts, then decodes all sequences in lockstep."""
    done: list[Request] = []
    for i in range(0, len(requests), batch):
        group = requests[i : i + batch]
        while len(group) < batch:
            group.append(Request(rid=-1, prompt=group[0].prompt, max_new=group[0].max_new))
        s = max(len(r.prompt) for r in group)
        toks = np.zeros((batch, s), np.int32)
        for j, r in enumerate(group):
            toks[j, : len(r.prompt)] = r.prompt  # left-aligned prompts
        state = model.init_decode_state(batch, max_len)
        t0 = time.perf_counter()
        logits, state = model.prefill(params, {"tokens": jnp.asarray(toks)}, state)
        nxt = jnp.argmax(logits, -1) if greedy else logits.argmax(-1)
        max_new = max(r.max_new for r in group)
        for _ in range(max_new):
            for j, r in enumerate(group):
                if r.rid >= 0 and len(r.out) < r.max_new:
                    r.out.append(int(nxt[j]))
            logits, state = model.decode_step(params, state, nxt)
            nxt = jnp.argmax(logits, -1)
        t1 = time.perf_counter()
        for r in group:
            if r.rid >= 0:
                r.t_done = t1 - t0
                done.append(r)
    return done
