import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with ShapeDtypeStruct inputs (no allocation):

  * compiled.memory_analysis()  -- per-device bytes (proves it fits)
  * compiled.cost_analysis()    -- per-device HLO FLOPs / bytes accessed
  * collective bytes parsed from compiled.as_text() (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),
    with while-body collectives multiplied by the layer-scan trip count
  * the three roofline terms (seconds) + dominant bottleneck

Results are written to experiments/dryrun/<arch>__<shape>__<mesh>.json;
benchmarks/roofline.py renders the EXPERIMENTS.md tables from them.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all            # every cell, both meshes
"""

import argparse
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _result_bytes(line: str) -> float:
    """Sum byte sizes of all typed shapes on the result side of an HLO line."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0.0
    rhs = lhs[1]
    # result type(s) precede the op name: e.g. "(bf16[8,128]{1,0}, u32[]) all-reduce("
    head = rhs.split("(", 1)[0] if not rhs.startswith("(") else rhs[: rhs.index(") ") + 1]
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str, scan_trips: int) -> dict:
    """Per-collective byte totals; while-body ops scaled by scan_trips.

    Byte model per chip: all-reduce moves ~2x its payload (ring), others
    ~1x the result payload.  Collectives inside while-loop bodies (the
    layer scans) execute once per trip.
    """
    # split into computations: "name { ... }"
    comp_bytes: dict[str, dict] = {}
    cur = None
    while_bodies: set[str] = set()
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?([\w.\-]+)\s*\([^)]*\)\s*->.*{$", s)
        if m or (s.startswith("ENTRY")):
            name = m.group(1) if m else "ENTRY"
            cur = name
            comp_bytes.setdefault(cur, {c: 0.0 for c in COLLECTIVES})
            continue
        if cur is None:
            continue
        for b in re.finditer(r"body=%?([\w.\-]+)", s):
            while_bodies.add(b.group(1))
        for c in COLLECTIVES:
            # match the op invocation, not tuple-element accesses
            if re.search(rf"\)?\s{c}[\.\(]|=\s*\(?[a-z0-9\[\],{{}} ]*\)?\s*{c}\(", s) or f" {c}(" in s:
                comp_bytes[cur][c] += _result_bytes(s)
                break

    out = {c: 0.0 for c in COLLECTIVES}
    for name, per in comp_bytes.items():
        mult = scan_trips if any(name.startswith(w) or w in name for w in while_bodies) else 1
        for c, v in per.items():
            out[c] += v * mult
    out["total_bytes"] = sum(
        (2.0 if c == "all-reduce" else 1.0) * v for c, v in out.items()
        if c in COLLECTIVES
    )
    return out


def scan_trip_count(cfg) -> int:
    if cfg.encdec:
        # encoder and decoder scans run with equal trip counts (whisper-tiny:
        # 4+4); the linear cost extrapolation treats one trip = one enc layer
        # + one dec layer.
        assert cfg.enc_layers == cfg.num_layers, "encdec extrapolation assumes equal depths"
        return cfg.num_layers
    if cfg.is_hybrid:
        return cfg.num_layers // cfg.attn_period
    return cfg.num_layers


def shallow_variant(cfg, trips: int):
    """Config with `trips` scan iterations, scan unrolled (no HLO while)."""
    p = cfg.attn_period if cfg.is_hybrid else 1
    kw = {"num_layers": trips * p, "scan_unroll": True}
    if cfg.encdec:
        kw["enc_layers"] = trips * p
    return cfg.replace(**kw)


HBM_PER_CHIP = 16e9  # v5e

_PROJ_NAMES = ("wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down")


def quantize_param_shapes(tree, backend: str):
    """Dense (in,out) projection shapes -> integer MVU deployment shapes
    ((out,in) int8 values + (out,) f32 scale), leading stack dims kept.
    Serving cells with a mvu_* linear backend lower the true integer
    datapath; memory analysis then reflects the quantized weight residency
    (the paper's lever on the decode memory term)."""

    def walk(node, name):
        if isinstance(node, dict):
            if (
                name in _PROJ_NAMES
                and set(node) == {"w"}
                and len(node["w"].shape) >= 2
            ):
                shape = node["w"].shape
                lead, (din, dout) = shape[:-2], shape[-2:]
                wdt = jnp.int4 if backend in ("mvu_w4a8", "mvu_w4a4") else jnp.int8
                return {
                    "values": jax.ShapeDtypeStruct((*lead, dout, din), wdt),
                    "scale": jax.ShapeDtypeStruct((*lead, dout), jnp.float32),
                }
            return {k: walk(v, k) for k, v in node.items()}
        return node

    return walk(tree, "")


# --------------------------------------------------------------------- cells
def build_cell(cfg, shape_name: str, mesh, *, fsdp: bool | None = None):
    """Returns (fn, example args (ShapeDtypeStructs), donate, in_shardings,
    cfg, accounting).

    fsdp=None -> automatic: enable ZeRO-3 2D weight sharding whenever the
    TP-only parameter (+optimizer, for train) footprint would exceed half
    the 16 GB v5e HBM (command-r-plus-104b, qwen3-moe-235b, jamba-398b).
    """
    from repro.distributed.sharding import (
        batch_shardings, cache_pspecs, param_shardings,
    )
    from repro.launch.shapes import SHAPES
    from repro.launch.train import make_train_step
    from repro.models.model import build
    from repro.optim import adamw

    from repro.distributed.sharding import bytes_per_device

    spec = SHAPES[shape_name]
    model = build(cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if cfg.linear_backend.startswith("mvu_") and spec.kind != "train":
        # serving with the paper's engine: integer-deployed projections
        params_shape = quantize_param_shapes(params_shape, cfg.linear_backend)
    if fsdp is None:
        tp_only = bytes_per_device(params_shape, param_shardings(mesh, params_shape), mesh)
        if spec.kind == "train":
            tp_only *= 5.0  # + fp32 grads/moments
        fsdp = tp_only > HBM_PER_CHIP / 2
    p_shard = param_shardings(mesh, params_shape, fsdp=fsdp)
    acct = {"params_dev": bytes_per_device(params_shape, p_shard, mesh),
            "state_dev": 0.0, "fsdp": bool(fsdp)}

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    if spec.kind == "train":
        b, s = spec.global_batch, spec.seq_len
        batch = {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
        if cfg.family == "vlm":
            batch["prefix_embeds"] = jax.ShapeDtypeStruct((b, 256, cfg.d_model), jnp.bfloat16)
        if cfg.encdec:
            batch["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        opt_shape = jax.eval_shape(adamw.init, params_shape)
        o_shard = {
            "mu": p_shard, "nu": p_shard,
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        b_shard = batch_shardings(mesh, batch)
        fn = make_train_step(model, adamw.AdamWConfig())
        return fn, (params_shape, opt_shape, batch), (0, 1), (p_shard, o_shard, b_shard), cfg, acct

    if spec.kind == "prefill":
        b, s = spec.global_batch, spec.seq_len
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["prefix_embeds"] = jax.ShapeDtypeStruct((b, 256, cfg.d_model), jnp.bfloat16)
        if cfg.encdec:
            batch["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        state_shape = jax.eval_shape(lambda: model.init_decode_state(b, s))
        s_shard = cache_pspecs(mesh, state_shape, seq_over_model=True)
        b_shard = batch_shardings(mesh, batch)
        acct["state_dev"] = bytes_per_device(state_shape, s_shard, mesh)
        return (
            model.prefill,
            (params_shape, batch, state_shape),
            (2,),
            (p_shard, b_shard, s_shard),
            cfg,
            acct,
        )

    # decode
    b, s = spec.global_batch, spec.seq_len
    state_shape = jax.eval_shape(lambda: model.init_decode_state(b, s))
    seq_sp = s >= 32768  # SP: shard long KV caches over "model"
    s_shard = cache_pspecs(mesh, state_shape, seq_over_model=seq_sp)
    if b >= dp_size:
        tok_shard = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(dp))
    else:
        tok_shard = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    tokens = jax.ShapeDtypeStruct((b,), jnp.int32)
    acct["state_dev"] = bytes_per_device(state_shape, s_shard, mesh)
    return (
        model.decode_step,
        (params_shape, state_shape, tokens),
        (1,),
        (p_shard, s_shard, tok_shard),
        cfg,
        acct,
    )


def _compile_cell(cfg, shape_name, mesh, fsdp=None):
    fn, args, donate, shardings, cfg, acct = build_cell(cfg, shape_name, mesh, fsdp=fsdp)
    t0 = time.time()
    from repro.launch.mesh import use_mesh

    with use_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
    return compiled, t_lower, t_compile, acct


def _cost_of(compiled) -> tuple[float, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    return float(cost.get("flops", 0.0)), float(cost.get("bytes accessed", 0.0))


def analytic_hbm_bytes(cfg, spec, mesh, *, params_dev: float, state_dev: float) -> float:
    """Fused-stream HBM traffic estimate per device per step (bytes).

    The CPU backend's "bytes accessed" counts every HLO operand with no
    fusion, overstating TPU HBM traffic by orders of magnitude; this model
    counts the irreducible streams a fused TPU program must move:

      train:   3x weight reads (fwd + remat-fwd + bwd) + param update r/w
               + fp32 grads r/w + fp32 moments r/w (2 moments)
               + remat-boundary activations (L x B_dev x S x d, w+r)
               + fp32 logits (w+r)
      prefill: 1x weight read + cache write + boundary activations
      decode:  1x weight read + full cache read + tiny writes
    """
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    b_dev = max(1, spec.global_batch // dp)
    s = spec.seq_len
    trips = scan_trip_count(cfg)
    d = cfg.d_model
    model_shards = mesh.shape.get("model", 1)

    if spec.kind == "train":
        n_param = cfg.param_count / model_shards  # elements per device (TP)
        acts = trips * b_dev * s * d * 2 * 2  # bf16 boundary saves, w+r
        logits = b_dev * s * cfg.vocab_size / model_shards * 4 * 2
        return (
            3 * params_dev  # bf16 weight streams
            + 2 * params_dev  # param read+write at update
            + 2 * n_param * 4  # fp32 grads w+r
            + 4 * n_param * 4  # two fp32 moments r+w
            + acts + logits
        )
    if spec.kind == "prefill":
        acts = trips * b_dev * s * d * 2 * 2
        return params_dev + state_dev + acts
    # decode: weights once + the whole cache read (+ small writes)
    return params_dev + state_dev * 1.05


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             quant: str | None = None, seq_sp: bool = False,
             fsdp: bool | None = None, naive_attn: bool = False,
             kv_quant: bool = False,
             save_dir: str = "experiments/dryrun",
             save_hlo: bool = False, tag_suffix: str = "") -> dict:
    from repro.configs import get_config
    from repro.core.resource_model import (
        HBM_BW, ICI_BW_PER_LINK, PEAK_BF16_FLOPS, roofline_terms,
    )
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES, skip_reason

    reason = skip_reason(arch, shape_name)
    tag = f"{arch}__{shape_name}__{mesh_name}" + (f"__{quant}" if quant else "") + tag_suffix
    if reason:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": reason}
        _save(save_dir, tag, rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = int(np.prod(list(mesh.shape.values())))
    cfg = get_config(arch)
    if quant:
        cfg = cfg.replace(linear_backend=quant)
    if seq_sp:
        cfg = cfg.replace(seq_sharded_acts=True)
    if naive_attn:
        cfg = cfg.replace(attn_q_chunk=0)
    if kv_quant:
        cfg = cfg.replace(kv_quant=True)

    # 1) full-depth compile: THE dry-run artifact (memory fit + lowering proof)
    compiled, t_lower, t_compile, acct = _compile_cell(cfg, shape_name, mesh, fsdp)
    fsdp_used = acct["fsdp"]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    trips = scan_trip_count(cfg)
    coll_while = parse_collective_bytes(hlo, trips)

    # 2) cost extrapolation: XLA's cost_analysis counts while bodies ONCE,
    # so compile shallow UNROLLED variants (1 and 2 scan trips) and use
    #   total = c1 + (trips - 1) * (c2 - c1)
    # which is exact for identical stacked layers (embed/head/optimizer are
    # depth-constant, per-layer work is the slope).  Collectives from the
    # unrolled HLO extrapolate the same way.
    c1, _, _, _ = _compile_cell(shallow_variant(cfg, 1), shape_name, mesh, fsdp_used)
    c2, _, _, _ = _compile_cell(shallow_variant(cfg, 2), shape_name, mesh, fsdp_used)
    f1, b1 = _cost_of(c1)
    f2, b2 = _cost_of(c2)
    coll1 = parse_collective_bytes(c1.as_text(), 1)
    coll2 = parse_collective_bytes(c2.as_text(), 1)
    # slopes clamped >= 0: XLA occasionally fuses the 2-trip variant more
    # aggressively than the 1-trip one, which would extrapolate negative.
    flops_dev = f1 + (trips - 1) * max(f2 - f1, 0.0)
    bytes_dev = b1 + (trips - 1) * max(b2 - b1, 0.0)
    coll = {
        k: coll1[k] + (trips - 1) * max(coll2[k] - coll1[k], 0.0)
        for k in coll1
    }

    spec = SHAPES[shape_name]
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    n_active = cfg.active_param_count
    mult = 6 if spec.kind == "train" else 2
    model_flops = mult * n_active * tokens

    roof_hlo = roofline_terms(
        flops_dev * chips, bytes_dev * chips, coll["total_bytes"], chips=chips
    )
    # fused-stream memory estimate (the CPU backend HLO byte count has no
    # fusion and overstates HBM traffic; see analytic_hbm_bytes docstring)
    bytes_analytic = analytic_hbm_bytes(cfg, spec, mesh,
                                        params_dev=acct["params_dev"],
                                        state_dev=acct["state_dev"])
    roof = roofline_terms(
        flops_dev * chips, bytes_analytic * chips, coll["total_bytes"], chips=chips
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "quant": quant,
        "fsdp": fsdp_used,
        "seq_sp": seq_sp,
        "naive_attn": naive_attn,
        "kv_quant": kv_quant,
        "chips": chips,
        "kind": spec.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None) if hasattr(mem, "peak_memory_in_bytes") else None,
        },
        "cost": {"flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
                 "trip1": {"flops": f1, "bytes": b1},
                 "trip2": {"flops": f2, "bytes": b2}},
        "collectives": coll,
        "collectives_whileparse": coll_while,
        "scan_trips": trips,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / max(flops_dev * chips, 1.0),
        "bytes_analytic_per_device": bytes_analytic,
        "accounting": acct,
        "roofline": roof,
        "roofline_hlo_bytes": roof_hlo,
        "hw": {"peak_flops": PEAK_BF16_FLOPS, "hbm_bw": HBM_BW,
               "link_bw": ICI_BW_PER_LINK},
    }
    _save(save_dir, tag, rec)
    if save_hlo:
        with open(os.path.join(save_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def _save(save_dir: str, tag: str, rec: dict):
    os.makedirs(save_dir, exist_ok=True)
    with open(os.path.join(save_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--quant", default=None)
    ap.add_argument("--seq-sp", action="store_true")
    ap.add_argument("--naive-attn", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--suffix", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-dir", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS
    from repro.launch.shapes import SHAPES

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                tag = f"{arch} x {shape} x {mesh_name}"
                try:
                    t0 = time.time()
                    fsdp = None if args.fsdp is None else (args.fsdp == "on")
                    rec = run_cell(arch, shape, mesh_name, quant=args.quant,
                                   seq_sp=args.seq_sp, fsdp=fsdp,
                                   naive_attn=args.naive_attn,
                                   kv_quant=args.kv_quant,
                                   save_dir=args.save_dir, save_hlo=args.save_hlo,
                                   tag_suffix=args.suffix)
                    if rec.get("skipped"):
                        print(f"[dryrun] SKIP {tag}: {rec['skipped']}", flush=True)
                    else:
                        r = rec["roofline"]
                        print(
                            f"[dryrun] OK   {tag}: compile {rec['compile_s']}s "
                            f"dominant={r['dominant']} bound={r['bound_s']:.4f}s "
                            f"mem/dev={rec['memory']['argument_bytes']}",
                            flush=True,
                        )
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[dryrun] FAIL {tag}: {e!r}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
