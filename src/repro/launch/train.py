"""Training driver: sharded train_step builder + a runnable host-scale loop.

``make_train_step``/``shard_train_step`` are the production path: the same
code lowers on the (16,16)/(2,16,16) meshes in the dry-run and runs on a
host mesh in tests/examples.  GSPMD inserts the DP gradient all-reduce (the
parameters are replicated over pod/data and the batch is sharded, so the
backward pass psums automatically); TP/EP collectives come from the
parameter PartitionSpecs in distributed/sharding.py.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.distributed.fault_tolerance import CheckpointManager, StepWatchdog
from repro.distributed.sharding import (
    batch_shardings,
    param_shardings,
)
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model, build
from repro.optim import adamw


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = adamw.update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def shard_train_step(model: Model, mesh, opt_cfg: adamw.AdamWConfig, batch_example):
    """Returns (jitted step, params_sharding, opt_sharding, batch_sharding)."""
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = param_shardings(mesh, params_shape)
    opt_shape = jax.eval_shape(adamw.init, params_shape)
    o_shard = {
        "mu": p_shard,
        "nu": p_shard,
        "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }
    b_shard = batch_shardings(mesh, jax.eval_shape(lambda: batch_example))
    step = jax.jit(
        make_train_step(model, opt_cfg),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1),
    )
    return step, p_shard, o_shard, b_shard


def init_sharded(model: Model, mesh, seed: int = 0):
    """Initialize params/opt state directly into their shardings."""
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(seed))
    p_shard = param_shardings(mesh, params_shape)
    params = jax.jit(model.init, out_shardings=p_shard)(jax.random.PRNGKey(seed))
    o_shard = {
        "mu": p_shard,
        "nu": p_shard,
        "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }
    opt_state = jax.jit(adamw.init, out_shardings=o_shard)(params)
    return params, opt_state, p_shard, o_shard


def train_loop(
    model: Model,
    mesh,
    *,
    steps: int = 100,
    batch_iter=None,
    opt_cfg: adamw.AdamWConfig | None = None,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    log_every: int = 10,
    resume: bool = True,
):
    opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=steps)
    example = next(batch_iter)
    example = jax.tree.map(jnp.asarray, example)
    step_fn, p_shard, o_shard, b_shard = shard_train_step(model, mesh, opt_cfg, example)
    params, opt_state, _, _ = init_sharded(model, mesh)

    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, every=ckpt_every)
        if resume:
            like = jax.eval_shape(lambda: {"params": params, "opt": opt_state})
            start, restored = mgr.resume_latest(
                like, {"params": p_shard, "opt": o_shard}
            )
            if restored is not None:
                params, opt_state = restored["params"], restored["opt"]
                print(f"[train] resumed from step {start}")

    watchdog = StepWatchdog()
    history = []
    batch = example
    for step in range(start + 1, steps + 1):
        with watchdog:
            batch_dev = jax.device_put(batch, b_shard)
            params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
            batch = jax.tree.map(jnp.asarray, next(batch_iter))  # overlap host fetch
            loss = float(metrics["loss"])
        history.append(loss)
        if step % log_every == 0 or step == steps:
            print(
                f"[train] step {step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} "
                f"med_step {watchdog.median*1e3:.0f}ms stragglers {watchdog.stragglers}"
            )
        if mgr:
            mgr.maybe_save(step, {"params": params, "opt": opt_state})
    if mgr:
        mgr.wait()
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="2x2")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    from repro.data.pipeline import SyntheticLM

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build(cfg)
    d0, d1 = (int(x) for x in args.mesh.split("x"))
    mesh = make_host_mesh((d0, d1))
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    t0 = time.time()
    _, _, hist = train_loop(
        model, mesh, steps=args.steps, batch_iter=iter(data), ckpt_dir=args.ckpt_dir
    )
    data.close()
    print(f"[train] {args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {hist[0]:.3f} -> {hist[-1]:.3f}")


if __name__ == "__main__":
    main()
