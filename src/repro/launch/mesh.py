"""Production meshes.

Single pod: (16, 16) ("data", "model") = 256 chips (TPU v5e pod).
Multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS *before* any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over host (CPU) devices for tests/examples."""
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager activating ``mesh``, across jax versions.

    ``jax.set_mesh`` only exists on newer jax; a ``Mesh`` has always been
    its own context manager, so fall back to entering it directly.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def data_axes(mesh) -> tuple[str, ...]:
    """Axes the batch is sharded over (pod folds into data-parallelism)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
