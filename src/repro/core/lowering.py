"""Graph transformation passes: FINN's lowering + streamlining, in JAX.

    lower_to_mvu:   conv -> [swu, mvu];  linear -> mvu
    streamline:     [mvu, batchnorm, quant_act] -> mvu(+thresholds)
    fuse_epilogues: same fold for finalized graphs (the runtime engine path)
    fuse_swu:       [swu, mvu] -> conv_mvu (line-buffer fused conv kernel)
    apply_folding:  attach rate-balanced Folding to every mvu/conv_mvu node
    apply_schedules: pin empirically tuned kernel schedules from the
                     autotune cache onto every mvu/conv_mvu node
    pack_weights:   rewrite packed-datapath nodes' weight storage into the
                    bit-packed form (uint32 bitplanes / uint8 2-bit lanes)

All passes are DAG-aware: patterns match along explicit dataflow edges
(producer -> sole-consumer paths), not list adjacency, so chains and
branched (fan-out/fan-in) graphs rewrite through the same code.  Every
pass returns a graph whose nodes carry explicit ``inputs`` edges.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import ir, swu as swu_mod
from repro.core.folding import balance_pipeline
from repro.core.ir import Graph, Node, validate_graph
from repro.core.mvu import MVUConfig, MVULayer
from repro.core.thresholds import bn_quant_thresholds, streamline_signs


def _reroute(graph: Graph, renames: dict[str, str]) -> Graph:
    """Repoint every input edge through ``renames`` (old producer name ->
    the name of the node that now yields its stream)."""
    if not renames:
        return graph
    out = Graph()
    for n in graph:
        ins = tuple(renames.get(s, s) for s in n.inputs)
        out.append(n if ins == n.inputs else dataclasses.replace(n, inputs=ins))
    return out


def _sole_consumer(cons: dict[str, list[Node]], name: str, op: str) -> Node | None:
    """The single consumer of ``name`` when it exists and has op ``op``."""
    cs = cons.get(name, ())
    if len(cs) == 1 and cs[0].op == op:
        return cs[0]
    return None


def lower_to_mvu(graph: Graph, *, mode: str = "standard",
                 weight_bits: int = 4, act_bits: int = 4,
                 backend: str = "pallas") -> Graph:
    """conv -> swu+mvu; linear -> mvu. Float weights stay attached (raw)."""
    validate_graph(graph)
    out = Graph()
    renames: dict[str, str] = {}
    for node in ir.as_graph(graph):
        if node.op == "conv":
            kd = node.attrs["kernel"]
            out.append(Node("swu", node.name + ".swu", dict(node.attrs),
                            inputs=node.inputs))
            wm = swu_mod.pack_conv_weights(node.params["w"])  # (N, K)
            cfg = MVUConfig(
                in_features=wm.shape[1], out_features=wm.shape[0],
                mode=mode, weight_bits=weight_bits, act_bits=act_bits,
                backend=backend,
            )
            out.append(Node("mvu", node.name + ".mvu",
                            {"config": cfg}, {"w_float": wm},
                            inputs=(node.name + ".swu",)))
            renames[node.name] = node.name + ".mvu"
        elif node.op == "linear":
            w = node.params["w"]
            cfg = MVUConfig(
                in_features=w.shape[1], out_features=w.shape[0],
                mode=mode, weight_bits=weight_bits, act_bits=act_bits,
                backend=backend,
            )
            out.append(Node("mvu", node.name + ".mvu", {"config": cfg},
                            {"w_float": w}, inputs=node.inputs))
            renames[node.name] = node.name + ".mvu"
        else:
            out.append(node)
    return _reroute(out, renames)


def streamline(graph: Graph) -> Graph:
    """Fold [mvu, batchnorm, quant_act] into mvu-with-thresholds (MVTU).

    Matched along edges: the batchnorm must be the MVU's sole consumer and
    the quant_act the batchnorm's sole consumer (a fork in between means
    some branch still needs the raw stream).  The quant_act's own fan-out
    is fine -- its consumers are rerouted to the fused node.
    """
    g = ir.as_graph(graph)
    cons = ir.consumer_map(g)
    drop: set[str] = set()
    fused: dict[str, Node] = {}
    renames: dict[str, str] = {}
    for node in g:
        if node.op != "mvu" or "w_float" not in node.params:
            continue
        bn = _sole_consumer(cons, node.name, "batchnorm")
        qa = bn and _sole_consumer(cons, bn.name, "quant_act")
        if qa is None:
            continue
        cfg: MVUConfig = node.attrs["config"]
        w_float = node.params["w_float"]
        bits = qa.attrs["bits"]
        # weight scale factors into BN: acc_int * (w_scale) feeds BN.
        params, qt = MVULayer.from_float(cfg, w_float)
        acc_scale = qt.scale.reshape(-1)  # (N,)
        t, flip = bn_quant_thresholds(
            bn.params["gamma"], bn.params["beta"],
            bn.params["mean"], bn.params["var"],
            bits=bits, acc_scale=1.0,
            act_scale=qa.attrs.get("act_scale", 1.0),
        )
        # thresholds computed against real acc = acc_int * acc_scale:
        t = t / acc_scale[:, None]
        # flip rows (negative gamma): negate quantized weight rows.
        wq = streamline_signs(qt.values.astype(jnp.int32), flip).astype(qt.values.dtype)
        qt2 = type(qt)(wq, qt.scale, qt.bits, qt.signed)
        params, _ = _params_from_qtensor(cfg, qt2, t)
        cfg2 = MVUConfig(**{**cfg.__dict__, "act_bits": bits})
        fused[node.name] = Node("mvu", node.name, {"config": cfg2},
                                {"mvu": params}, inputs=node.inputs)
        drop.update((bn.name, qa.name))
        renames[qa.name] = node.name
    out = Graph(fused.get(n.name, n) for n in g if n.name not in drop)
    return _reroute(out, renames)


def _params_from_qtensor(cfg: MVUConfig, qt, thresholds):
    from repro.core.mvu import MVUParams
    from repro.core.thresholds import integerize_thresholds
    from repro.kernels import packing

    if cfg.mode == "xnor":
        w = packing.pack_bits(packing.bipolar_to_bits(qt.values))
    elif cfg.mode == "binary":
        w = packing.bipolar_to_bits(qt.values).astype(jnp.int8)
    else:
        w = qt.values
    t = integerize_thresholds(thresholds)
    return MVUParams(weights=w, thresholds=t, out_scale=None), qt


def finalize(graph: Graph) -> Graph:
    """Quantize any mvu nodes still carrying float weights (no BN to fold)."""
    out = Graph()
    for node in ir.as_graph(graph):
        if node.op == "mvu" and "mvu" not in node.params:
            cfg: MVUConfig = node.attrs["config"]
            params, _ = MVULayer.from_float(cfg, node.params["w_float"])
            out.append(Node("mvu", node.name, dict(node.attrs), {"mvu": params},
                            inputs=node.inputs))
        else:
            out.append(node)
    return out


def _flip_weight_rows(weights: jnp.ndarray, flip: jnp.ndarray, cfg: MVUConfig):
    """Negate the (bipolar) value of flipped weight rows, per weight coding.

    standard: integer rows negate directly (widened so -(-2^(b-1)) is safe);
    binary:   {0,1}-coded +/-1 rows flip bits (1 - w);
    xnor:     packed rows unpack over the true K bits, flip, repack (pad
              bits stay zero, preserving the popcount correction).
    """
    from repro.kernels import packing

    if cfg.mode == "xnor":
        bits = packing.unpack_bits(weights, cfg.in_features)
        bits = jnp.where(flip[:, None], 1 - bits, bits)
        return packing.pack_bits(bits)
    if cfg.mode == "binary":
        return jnp.where(flip[:, None], 1 - weights, weights).astype(weights.dtype)
    w = streamline_signs(weights.astype(jnp.int32), flip)
    return w.astype(weights.dtype)


def fuse_epilogues(graph: Graph) -> Graph:
    """Fold batchnorm/quant_act successors of *finalized* MVU nodes into the
    kernel's multi-threshold epilogue.

    :func:`streamline` does this rewrite at lowering time on float weights;
    this pass is its runtime-engine analog for graphs that kept standalone
    ``batchnorm``/``quant_act`` nodes (the unfused interpreter path).  The
    dequant scale already attached to the MVU (``out_scale``) folds into the
    thresholds, so the fused node emits integer activation levels straight
    from the accumulator — no float epilogue nodes remain in the hot path.

    Handled patterns (the head MVU and anything else pass through); the
    epilogue nodes must sit on a sole-consumer path off the MVU, while the
    quant_act's own consumers (including residual fan-out) reroute to the
    fused node:
        mvu -> batchnorm -> quant_act   =>  mvu(+thresholds)
        mvu -> quant_act                =>  mvu(+thresholds)  (identity BN)
    """
    from repro.core.mvu import MVUParams

    g = ir.as_graph(graph)
    cons = ir.consumer_map(g)
    drop: set[str] = set()
    fused_nodes: dict[str, Node] = {}
    renames: dict[str, str] = {}
    for node in g:
        fusable = (
            node.op in ("mvu", "conv_mvu")
            and "mvu" in node.params
            and node.params["mvu"].thresholds is None
        )
        if not fusable:
            continue
        bn = _sole_consumer(cons, node.name, "batchnorm")
        qa = (_sole_consumer(cons, bn.name, "quant_act") if bn is not None
              else _sole_consumer(cons, node.name, "quant_act"))
        if qa is None:
            continue

        cfg: MVUConfig = node.attrs["config"]
        params: MVUParams = node.params["mvu"]
        n = cfg.out_features
        bits = qa.attrs["bits"]
        if bn is not None:
            gamma, beta = bn.params["gamma"], bn.params["beta"]
            mean, var = bn.params["mean"], bn.params["var"]
        else:
            # identity BN: var = 1 - eps so sqrt(var + eps) == 1 exactly and
            # the thresholds reduce to the bare quantizer boundaries.
            gamma = jnp.ones((n,), jnp.float32)
            beta = jnp.zeros((n,), jnp.float32)
            mean = jnp.zeros((n,), jnp.float32)
            var = jnp.ones((n,), jnp.float32) - 1e-5
        t, flip = bn_quant_thresholds(
            gamma, beta, mean, var,
            bits=bits, acc_scale=1.0,
            act_scale=qa.attrs.get("act_scale", 1.0),
        )
        # thresholds hold on the real accumulator; the kernel compares the
        # integer accumulator, so divide per-row by the dequant scale.
        scale = params.out_scale
        if scale is not None:
            t = t / scale.reshape(-1)[:, None]
        from repro.core.thresholds import integerize_thresholds

        w = _flip_weight_rows(params.weights, flip, cfg)
        fused_params = MVUParams(
            weights=w, thresholds=integerize_thresholds(t), out_scale=None
        )
        cfg2 = MVUConfig(**{**cfg.__dict__, "act_bits": bits})
        attrs = dict(node.attrs)
        attrs["config"] = cfg2
        attrs["fused"] = tuple(x.name for x in (bn, qa) if x is not None)
        fused_nodes[node.name] = Node(node.op, node.name, attrs,
                                      {"mvu": fused_params}, inputs=node.inputs)
        drop.update(x.name for x in (bn, qa) if x is not None)
        renames[qa.name] = node.name
    out = Graph(fused_nodes.get(n.name, n) for n in g if n.name not in drop)
    return _reroute(out, renames)


def fuse_swu(graph: Graph) -> Graph:
    """Collapse ``swu -> mvu`` edges into one ``conv_mvu`` node.

    The standalone SWU materializes the full (B, OH*OW, Kd^2*C) im2col
    matrix in HBM before the MVU consumes it; the fused node streams sliding
    windows through the line-buffer kernel (``kernels.swu_mvu``) instead --
    the runtime analog of FINN's SWU->MVU AXI stream, where the interleaved
    GEMM activation matrix never exists in memory.  Requires finalized MVU
    nodes (``params["mvu"]``) and an SWU with a single consumer; run after
    :func:`finalize` / :func:`fuse_epilogues`.
    """
    g = ir.as_graph(graph)
    cons = ir.consumer_map(g)
    drop: set[str] = set()
    fused: dict[str, Node] = {}
    renames: dict[str, str] = {}
    for node in g:
        if node.op != "swu":
            continue
        mvu = _sole_consumer(cons, node.name, "mvu")
        if mvu is None or "mvu" not in mvu.params:
            continue
        attrs = dict(mvu.attrs)
        attrs["kernel"] = node.attrs["kernel"]
        attrs["stride"] = node.attrs["stride"]
        attrs["pad"] = node.attrs["pad"]
        name = mvu.name.replace(".mvu", ".conv_mvu")
        fused[mvu.name] = Node("conv_mvu", name, attrs, mvu.params,
                               inputs=node.inputs)
        drop.add(node.name)
        renames[mvu.name] = name
    out = Graph(fused.get(n.name, n) for n in g if n.name not in drop)
    return _reroute(out, renames)


def apply_folding(graph: Graph, *, target_cycles: int | None = None,
                  max_pe: int = 128, max_simd: int = 128) -> Graph:
    """FINN folding pass: rate-balance all MVU stages (DESIGN.md section 4).

    Conv stages fold over the pixel dimension too: their cycle count is
    ``n_pixels * NF * SF`` (paper Eq. 1 with the SWU feeding one window per
    output pixel), so a conv layer with few channels but many pixels can
    still be the rate bottleneck.  MVU stages are visited in topological
    (dataflow) order; configs rewrite in place through the shared attrs
    dicts, so the caller's graph is updated.
    """
    shapes = []
    mvu_nodes = []
    for node, _, out_shape in ir.io_shapes(graph):
        if node.op in ("mvu", "conv_mvu"):
            cfg: MVUConfig = node.attrs["config"]
            shapes.append((cfg.out_features, cfg.in_features,
                           ir.n_pixels(out_shape)))
            mvu_nodes.append(node)
    folds = balance_pipeline(shapes, slowest_cycles=target_cycles,
                             max_pe=max_pe, max_simd=max_simd)
    for node, f in zip(mvu_nodes, folds):
        cfg = node.attrs["config"]
        node.attrs["config"] = MVUConfig(**{**cfg.__dict__, "folding": f})
    return graph


def apply_schedules(graph: Graph, *, cache=None, mode: str = "cache",
                    device: str | None = None) -> Graph:
    """Empirical-schedule pass: the autotuned counterpart of ``apply_folding``.

    Rewrites every finalized mvu/conv_mvu node's config with the schedule
    recorded in the autotune cache (``repro.core.autotune``): explicit
    kernel blocks plus the winning backend.  ``mode="cache"`` only consumes
    committed results (zero measurement); ``mode="auto"`` measures misses
    and fills the cache.  Returns a new graph; the input is untouched.
    """
    from repro.core import autotune

    return autotune.tune_graph(graph, cache=cache, mode=mode, device=device)


def pack_weights(graph: Graph, *, force: bool = False) -> Graph:
    """Packing rewrite: store MVU weights in their bit-packed form.

    Rewrites every finalized dense ``mvu`` node whose config selects the
    packed datapath (``cfg.packed`` -- normally pinned by a tuned schedule
    entry carrying ``"packed": true``), or every packable one when
    ``force`` is set (the build's ``pack="always"`` policy).  Storage
    converts per coding: binary {0,1} int8 rows -> uint32 bitplanes (8x
    smaller), standard signed 2-bit rows -> uint8 lanes (4x), xnor rows
    are already uint32 words (storage no-op; the flag still routes the XLA
    backend onto the blocked-popcount path).  Conv nodes keep canonical
    storage -- the fused line-buffer gather consumes unpacked rows.
    Returns a new graph; rewritten nodes carry fresh params/attrs.
    """
    from repro.core.autotune import packable
    from repro.core.mvu import MVUParams
    from repro.kernels.mvu_packed import pack_mvu_weights

    out = Graph()
    for node in graph:
        if node.op != "mvu" or "mvu" not in node.params:
            out.append(node)
            continue
        cfg: MVUConfig = node.attrs["config"]
        if not (cfg.packed or (force and packable(cfg))):
            out.append(node)
            continue
        params = node.params["mvu"]
        w = params.weights
        # idempotence: canonical non-xnor storage is int8 rows; packed
        # forms are uint32 words / uint8 lanes, so dtype tells us whether
        # the rewrite already ran
        if cfg.mode != "xnor" and w.dtype == jnp.int8:
            w = pack_mvu_weights(w, cfg.mode)
        new_params = MVUParams(weights=w, thresholds=params.thresholds,
                               out_scale=params.out_scale)
        new_cfg = (cfg if cfg.packed
                   else MVUConfig(**{**cfg.__dict__, "packed": True}))
        out.append(Node(node.op, node.name,
                        {**node.attrs, "config": new_cfg},
                        {**node.params, "mvu": new_params},
                        inputs=node.inputs))
    return out
