"""Streaming-dataflow schedule + executor (FINN backend analog).

FINN connects one compute unit per layer with AXI streams; throughput is set
by the slowest stage and small FIFOs decouple producer/consumer bursts
(paper section 5.3).  TPUs are statically scheduled, so the runtime analog
is (a) this schedule -- per-stage cycle counts, bottleneck stage, FIFO
depths -- and (b) the pipeline-parallel executor in
``repro.distributed.pipeline`` which streams microbatches through stages
with ``ppermute`` transfers standing in for the AXI streams.

``execute`` runs the lowered graph functionally (the behavioural model the
RTL was validated against); integer semantics end-to-end.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import ir, swu as swu_mod
from repro.core.ir import Graph
from repro.core.mvu import MVUConfig, MVULayer
from repro.core.resource_model import MVUResources
from repro.kernels import ops, packing


@dataclasses.dataclass
class StageInfo:
    name: str
    cycles: int
    resources: MVUResources
    fifo_depth: int
    n_pixels: int = 1  # output pixels per sample (conv stages; 1 for dense)
    block_m: int = 128  # resident M tile of the stage's kernel
    branch: str = "main"  # which arm of a fork the stage sits on


@dataclasses.dataclass
class JoinInfo:
    """One fan-in point (elementwise-binary node) of a branched graph.

    FINN sizes the FIFO on the *shorter* arm of a residual join to absorb
    the latency skew between the two branches -- otherwise the early arm
    stalls the whole pipeline while the long arm drains.  ``fifo_depth`` is
    that balance depth in steady-state bursts: the branch latency
    difference divided by the pipeline's initiation interval (how many
    extra results the fast arm produces before the slow arm's first one
    lands), floored at the usual decoupling minimum of 2."""

    name: str
    branches: tuple[str, str]  # branch label of each joined input
    branch_latency: tuple[int, int]  # critical-path cycles into each input
    fifo_depth: int


@dataclasses.dataclass
class DataflowSchedule:
    stages: list[StageInfo]
    joins: list[JoinInfo] = dataclasses.field(default_factory=list)
    # critical-path latency through the DAG (equals the stage sum on
    # chains); None -> fall back to the chain-era sum
    critical_path_cycles: int | None = None

    @property
    def bottleneck(self) -> StageInfo:
        return max(self.stages, key=lambda s: s.cycles)

    @property
    def steady_state_interval(self) -> int:
        """Cycles between successive inferences once the pipeline is full."""
        return self.bottleneck.cycles

    @property
    def latency_cycles(self) -> int:
        if self.critical_path_cycles is not None:
            return self.critical_path_cycles
        return sum(s.cycles for s in self.stages)

    def summary(self) -> dict:
        out = {
            "stages": len(self.stages),
            "latency_cycles": self.latency_cycles,
            "interval_cycles": self.steady_state_interval,
            "bottleneck": self.bottleneck.name,
            "total_bram_bytes": sum(s.resources.bram_bytes for s in self.stages),
            "total_lut_bytes": sum(s.resources.lut_bytes for s in self.stages),
        }
        if self.joins:
            out["joins"] = [{
                "name": j.name, "branches": list(j.branches),
                "branch_latency": list(j.branch_latency),
                "fifo_depth": j.fifo_depth,
            } for j in self.joins]
        return out


# The paper's RTL targets a 200 MHz FPGA clock (section 6); with no measured
# cycle time this nominal clock converts schedule cycles to wall-clock time.
DEFAULT_CLOCK_HZ = 200e6


def interval_seconds(sched: DataflowSchedule, *, cache=None,
                     device: str | None = None,
                     clock_hz: float = DEFAULT_CLOCK_HZ) -> float:
    """Wall-clock seconds per steady-state interval (one microbatch burst).

    This is the bridge from the schedule's cycle algebra to serving-time
    budgets: the continuous batcher flushes when a request's deadline slack
    shrinks to one engine interval (``repro.serving.batcher``).  When an
    autotune cache holds a *measured* cycle time for this device (recorded
    by ``repro.serving.batcher.calibrate_cycle_time`` or a benchmark run),
    that measurement wins; otherwise the nominal ``clock_hz`` converts the
    analytic cycle count.
    """
    from repro.core import autotune

    if cache is None:
        try:
            cache = autotune.default_cache()
        except Exception:  # pragma: no cover - configs unavailable
            cache = None
    if cache is not None:
        ent = cache.get(autotune.cycle_time_key(device))
        if ent is not None and ent.get("s_per_cycle"):
            return sched.steady_state_interval * float(ent["s_per_cycle"])
    return sched.steady_state_interval / clock_hz


def schedule(graph: Graph) -> DataflowSchedule:
    info = ir.io_shapes(graph)
    branches = ir.branch_labels(graph)
    stages: list[StageInfo] = []
    # per-node bookkeeping threaded along edges (the chain era threaded one
    # running value through list order): nearest upstream MVU stage's cycle
    # count, and the critical-path latency into each node's output
    upstream: dict[str, int | None] = {}
    lat: dict[str, int] = {}
    for node, _, out_shape in info:
        ins = node.inputs or ()
        prevs = [upstream.get(s) for s in ins]
        prev_cycles = max((p for p in prevs if p is not None), default=None)
        in_lat = max((lat[s] for s in ins), default=0)
        if node.op not in ("mvu", "conv_mvu"):
            upstream[node.name] = prev_cycles
            lat[node.name] = in_lat
            continue
        cfg: MVUConfig = node.attrs["config"]
        px = ir.n_pixels(out_shape)
        layer = MVULayer(cfg)
        res = layer.resources(n_pixels=px)
        # FIFO sizing: enough to absorb one producer burst while the
        # consumer drains at its own rate (paper 5.3.2's small FIFO).  At a
        # fan-in the slowest producer governs the drain ratio.
        fold = cfg.resolved_folding()
        burst = fold.pe  # outputs produced per cycle group
        drain = 1 if prev_cycles is None else max(1, res.cycles // max(prev_cycles, 1))
        fifo = max(2, burst * min(drain, 8))
        stages.append(StageInfo(node.name, res.cycles, res, fifo,
                                n_pixels=px, block_m=cfg.block_m,
                                branch=branches.get(node.name, "main")))
        upstream[node.name] = res.cycles
        lat[node.name] = in_lat + res.cycles
    # fan-in FIFOs: balance the latency skew between the joined branches
    # (JoinInfo docstring) against the pipeline's steady-state interval
    interval = max((s.cycles for s in stages), default=1)
    joins = [
        JoinInfo(
            node.name,
            tuple(branches.get(s, "main") for s in node.inputs),
            tuple(lat[s] for s in node.inputs),
            max(2, -(-abs(lat[node.inputs[0]] - lat[node.inputs[1]])
                     // max(1, interval))),
        )
        for node, _, _ in info if node.op in ir.ELTWISE_OPS
    ]
    return DataflowSchedule(stages, joins=joins,
                            critical_path_cycles=max(lat.values(), default=0))


def node_runner(node):
    """Per-node semantics as ``(params, fn)`` with ``fn(params, *xs) -> x``.

    The eager interpreter (:func:`execute`) and the fused engine
    (``repro.core.engine``) both apply nodes through this single definition,
    so the jit-compiled engine is bit-exact with the behavioural model by
    construction.  ``params`` is the node's traced pytree (or ``None``).
    Single-input ops take one array; elementwise-binary ops take two.
    """
    if node.op == "input":
        return None, lambda p, x: x
    if node.op in ir.ELTWISE_OPS:
        sa, sb = node.attrs.get("scales", (1, 1))
        opf = {"add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply}[node.op]

        def run_eltwise(p, a, b):
            # FINN broadcast semantics on per-sample shapes: align trailing
            # dims, keeping the batch dim (axis 0) out of the broadcast by
            # padding singleton dims right after it.
            rank = max(a.ndim, b.ndim)
            a2 = a.reshape(a.shape[0], *((1,) * (rank - a.ndim)), *a.shape[1:])
            b2 = b.reshape(b.shape[0], *((1,) * (rank - b.ndim)), *b.shape[1:])
            # per-input integer quantization-alignment scales
            return opf(a2 * sa, b2 * sb)

        return None, run_eltwise
    if node.op == "swu":
        kd, st, pd = node.attrs["kernel"], node.attrs["stride"], node.attrs["pad"]

        def run_swu(p, x):
            # keep the spatial layout so conv stages chain: (B, OH, OW, K)
            b, h, w, _ = x.shape
            cols = swu_mod.sliding_window(x, kd, st, pd)  # (B, P, K)
            oh = swu_mod.out_dim(h, kd, st, pd)
            ow = swu_mod.out_dim(w, kd, st, pd)
            return cols.reshape(b, oh, ow, cols.shape[-1])

        return None, run_swu
    if node.op == "conv_mvu":
        cfg: MVUConfig = node.attrs["config"]
        kd, st, pd = node.attrs["kernel"], node.attrs["stride"], node.attrs["pad"]

        def run_conv(p, x):
            b, h, w, _ = x.shape
            out = ops.conv_mvu(
                x, p.weights,
                kernel=kd, stride=st, pad=pd, mode=cfg.mode,
                k_bits=cfg.in_features if cfg.mode == "xnor" else None,
                thresholds=p.thresholds, out_scale=p.out_scale,
                backend=cfg.backend, **cfg.kernel_blocks(),
            )  # (B, OH*OW, N)
            oh = swu_mod.out_dim(h, kd, st, pd)
            ow = swu_mod.out_dim(w, kd, st, pd)
            return out.reshape(b, oh, ow, cfg.out_features)

        return node.params["mvu"], run_conv
    if node.op == "maxpool":
        size = node.attrs["size"]
        st = node.attrs.get("stride", size)

        def run_pool(p, x):
            init = x.dtype.type(jnp.iinfo(x.dtype).min) if jnp.issubdtype(
                x.dtype, jnp.integer) else x.dtype.type(-jnp.inf)
            return jax.lax.reduce_window(
                x, init, jax.lax.max,
                (1, size, size, 1), (1, st, st, 1), "VALID",
            )

        return None, run_pool
    if node.op == "flatten":
        return None, lambda p, x: x.reshape(x.shape[0], -1)
    if node.op == "mvu":
        cfg: MVUConfig = node.attrs["config"]
        layer = MVULayer(cfg)

        def run_mvu(p, x):
            if cfg.mode == "xnor" and x.dtype != jnp.uint32:
                x = packing.pack_bits(x.astype(jnp.int32))
            return layer(p, x)

        return node.params["mvu"], run_mvu
    if node.op == "batchnorm":
        p = {k: node.params[k] for k in ("gamma", "beta", "mean", "var")}
        return p, lambda p, x: (
            (x - p["mean"]) * p["gamma"] / jnp.sqrt(p["var"] + 1e-5) + p["beta"]
        )
    if node.op == "quant_act":
        bits = node.attrs["bits"]
        s = node.attrs.get("act_scale", 1.0)
        # round-half-up: level j iff x >= (j - 0.5) * s, the multi-threshold
        # unit's decision rule, so threshold fusion (streamline /
        # fuse_epilogues) is exact even at half-level ties.
        return None, lambda p, x: jnp.clip(
            jnp.floor(x / s + 0.5), 0, 2**bits - 1
        ).astype(jnp.int32)
    raise ValueError(f"unknown op {node.op!r} ({node.name})")


def trace(graph: Graph, x) -> dict[str, jax.Array]:
    """Run the graph eagerly and return EVERY node's output, keyed by name.

    This is the DAG interpreter's environment: :func:`execute` reads the
    sink out of it, and the build pipeline's divergence localizer compares
    two of them node-by-node to name the branch/node where a rewrite first
    changed the numbers.  ``x`` is one array when the graph has a single
    input node, or a ``{input-name: array}`` dict for multi-input graphs.
    """
    order = ir.toposort(graph)
    if isinstance(x, dict):
        feeds = dict(x)
    else:
        heads = [n for n in order if n.op == "input"]
        if len(heads) != 1:
            raise ValueError(
                f"graph has {len(heads)} input nodes; pass a "
                "{name: array} dict instead of one array")
        feeds = {heads[0].name: x}
    env: dict[str, jax.Array] = {}
    for node in order:
        params, fn = node_runner(node)
        if node.op == "input":
            if node.name not in feeds:
                raise ValueError(f"no feed for input node {node.name!r}")
            env[node.name] = fn(params, feeds[node.name])
        else:
            env[node.name] = fn(params, *(env[s] for s in node.inputs))
    return env


def execute(graph: Graph, x) -> jax.Array:
    """Run the lowered integer graph on host (behavioural model).

    x: for conv nets (B, H, W, C); for MLPs (B, K).  Integer dtypes.
    This is the eager per-node reference; ``repro.core.engine.FusedEngine``
    compiles the same dataflow graph into one jit'd streaming executable.
    The graph's single sink is the output; branched (fan-out/fan-in) graphs
    run exactly like chains.
    """
    return trace(graph, x)[ir.graph_output(graph).name]
