"""Streaming-dataflow schedule + executor (FINN backend analog).

FINN connects one compute unit per layer with AXI streams; throughput is set
by the slowest stage and small FIFOs decouple producer/consumer bursts
(paper section 5.3).  TPUs are statically scheduled, so the runtime analog
is (a) this schedule -- per-stage cycle counts, bottleneck stage, FIFO
depths -- and (b) the pipeline-parallel executor in
``repro.distributed.pipeline`` which streams microbatches through stages
with ``ppermute`` transfers standing in for the AXI streams.

``execute`` runs the lowered graph functionally (the behavioural model the
RTL was validated against); integer semantics end-to-end.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import swu as swu_mod
from repro.core.ir import Graph
from repro.core.mvu import MVUConfig, MVULayer
from repro.core.resource_model import MVUResources
from repro.kernels import packing


@dataclasses.dataclass
class StageInfo:
    name: str
    cycles: int
    resources: MVUResources
    fifo_depth: int


@dataclasses.dataclass
class DataflowSchedule:
    stages: list[StageInfo]

    @property
    def bottleneck(self) -> StageInfo:
        return max(self.stages, key=lambda s: s.cycles)

    @property
    def steady_state_interval(self) -> int:
        """Cycles between successive inferences once the pipeline is full."""
        return self.bottleneck.cycles

    @property
    def latency_cycles(self) -> int:
        return sum(s.cycles for s in self.stages)

    def summary(self) -> dict:
        return {
            "stages": len(self.stages),
            "latency_cycles": self.latency_cycles,
            "interval_cycles": self.steady_state_interval,
            "bottleneck": self.bottleneck.name,
            "total_bram_bytes": sum(s.resources.bram_bytes for s in self.stages),
            "total_lut_bytes": sum(s.resources.lut_bytes for s in self.stages),
        }


def schedule(graph: Graph) -> DataflowSchedule:
    shape = None
    stages: list[StageInfo] = []
    prev_cycles = None
    for node in graph:
        if node.op == "input":
            shape = node.attrs["shape"]
        elif node.op == "swu":
            h, w, c = shape
            kd, st, pd = node.attrs["kernel"], node.attrs["stride"], node.attrs["pad"]
            shape = (
                swu_mod.out_dim(h, kd, st, pd),
                swu_mod.out_dim(w, kd, st, pd),
                kd * kd * c,
            )
        elif node.op == "mvu":
            cfg: MVUConfig = node.attrs["config"]
            px = shape[0] * shape[1] if (isinstance(shape, tuple) and len(shape) == 3) else 1
            layer = MVULayer(cfg)
            res = layer.resources(n_pixels=px)
            # FIFO sizing: enough to absorb one producer burst while the
            # consumer drains at its own rate (paper 5.3.2's small FIFO).
            fold = cfg.resolved_folding()
            burst = fold.pe  # outputs produced per cycle group
            drain = 1 if prev_cycles is None else max(1, res.cycles // max(prev_cycles, 1))
            fifo = max(2, burst * min(drain, 8))
            stages.append(StageInfo(node.name, res.cycles, res, fifo))
            prev_cycles = res.cycles
            if isinstance(shape, tuple) and len(shape) == 3:
                shape = (shape[0], shape[1], cfg.out_features)
    return DataflowSchedule(stages)


def execute(graph: Graph, x: jax.Array) -> jax.Array:
    """Run the lowered integer graph on host (behavioural model).

    x: for conv nets (B, H, W, C); for MLPs (B, K).  Integer dtypes.
    """
    cur = x
    for node in graph:
        if node.op == "input":
            continue
        if node.op == "swu":
            cur = swu_mod.sliding_window(
                cur, node.attrs["kernel"], node.attrs["stride"], node.attrs["pad"]
            )  # (B, P, K)
        elif node.op == "mvu":
            cfg: MVUConfig = node.attrs["config"]
            layer = MVULayer(cfg)
            params = node.params["mvu"]
            xin = cur
            if cfg.mode == "xnor" and xin.dtype != jnp.uint32:
                xin = packing.pack_bits(xin.astype(jnp.int32))
            cur = layer(params, xin)
        elif node.op == "batchnorm":
            g, b = node.params["gamma"], node.params["beta"]
            m, v = node.params["mean"], node.params["var"]
            cur = (cur - m) * g / jnp.sqrt(v + 1e-5) + b
        elif node.op == "quant_act":
            bits = node.attrs["bits"]
            s = node.attrs.get("act_scale", 1.0)
            cur = jnp.clip(jnp.round(cur / s), 0, 2**bits - 1).astype(jnp.int32)
    return cur
