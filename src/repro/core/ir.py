"""A small layer-graph IR -- the FINN-ONNX analog.

FINN dataflow accelerators are (almost always) linear chains of layers, so
the IR is a list of nodes.  Transformation passes (lowering.py) rewrite the
chain exactly like FINN's *Lowering and Conversion to HLS Layers* and
*Streamlining* passes; dataflow.py then plays the role of *Folding and
Resource Estimation*.

Supported ops:
    input            attrs: shape, bits
    conv             attrs: kernel, stride, pad; params: w (Kd,Kd,Cin,Cout)
    linear           attrs: -; params: w (N, K) float
    batchnorm        params: gamma, beta, mean, var
    quant_act        attrs: bits, act_scale
    maxpool          attrs: size, stride (defaults to size)
    flatten          attrs: -
    swu              attrs: kernel, stride, pad  (after lowering)
    mvu              attrs: MVUConfig; params: MVUParams (after lowering)
    conv_mvu         attrs: MVUConfig + kernel/stride/pad; params: MVUParams
                     (after ``lowering.fuse_swu`` collapses a swu+mvu pair)
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class Node:
    op: str
    name: str
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)
    params: dict[str, Any] = dataclasses.field(default_factory=dict)


Graph = list

KNOWN_OPS = {
    "input", "conv", "linear", "batchnorm", "quant_act",
    "maxpool", "flatten", "swu", "mvu", "conv_mvu",
}


# ops that consume a spatial (H, W, C) activation; everything else takes
# whatever its producer yields
SPATIAL_OPS = ("conv", "swu", "conv_mvu", "maxpool")


def _describe(i: int, node: Node) -> str:
    return f"node {i} ({node.op} {node.name!r})"


def validate_chain(graph: Graph) -> None:
    """Structural validation with actionable errors.

    Every failure names the offending node's index and op plus what the
    chain expected of its producer/consumer, so a malformed graph fails at
    build time with a pointer to the node -- not deep inside a transform
    with a bare assert or an index error.
    """
    if not graph:
        raise ValueError(
            "empty graph: a dataflow chain must start with an 'input' node")
    if graph[0].op != "input":
        raise ValueError(
            f"graph must start with an 'input' node, got "
            f"{_describe(0, graph[0])}")
    shape: tuple | None = None
    prev: Node | None = None
    for i, node in enumerate(graph):
        if node.op not in KNOWN_OPS:
            raise ValueError(
                f"{_describe(i, node)}: unknown op; known ops are "
                f"{sorted(KNOWN_OPS)}")
        if node.op == "input" and i > 0:
            raise ValueError(
                f"{_describe(i, node)}: 'input' is only legal at index 0 "
                f"(producer here is {prev.op!r} {prev.name!r})")
        if prev is not None and prev.op == "swu" and node.op != "mvu":
            raise ValueError(
                f"{_describe(i, node)}: a sliding-window unit must feed an "
                f"'mvu' consumer (producer {prev.op!r} {prev.name!r} at "
                f"index {i - 1} yields im2col windows)")
        if node.op in SPATIAL_OPS and i > 0 and (shape is None or len(shape) != 3):
            raise ValueError(
                f"{_describe(i, node)}: needs a spatial (H, W, C) "
                f"activation, but producer {prev.op!r} ({prev.name!r}, "
                f"index {i - 1}) yields shape {shape}")
        try:
            shape = propagate(shape, node)
        except KeyError as e:
            raise ValueError(
                f"{_describe(i, node)}: missing required attr/param "
                f"{e.args[0]!r} for this op") from None
        prev = node
    if graph[-1].op == "swu":
        raise ValueError(
            f"{_describe(len(graph) - 1, graph[-1])}: a sliding-window unit "
            f"cannot terminate the chain; expected an 'mvu' consumer")


def propagate(shape: tuple, node: Node) -> tuple:
    """Track the activation shape through one node.

    Spatial activations are ``(H, W, C)`` tuples, flat ones ``(K,)`` -- the
    shared shape algebra behind ``lowering.apply_folding``,
    ``dataflow.schedule``, and the engine's stream planning.
    """
    if node.op == "input":
        return tuple(node.attrs["shape"])
    if node.op in ("conv", "swu", "conv_mvu", "maxpool"):
        from repro.core.swu import out_dim as _conv_out  # shared size algebra

        h, w = shape[0], shape[1]
        if node.op == "maxpool":
            kd = node.attrs["size"]
            st, pd = node.attrs.get("stride", kd), 0
        else:
            kd = node.attrs["kernel"]
            st, pd = node.attrs["stride"], node.attrs["pad"]
        oh, ow = _conv_out(h, kd, st, pd), _conv_out(w, kd, st, pd)
        if node.op == "swu":
            return (oh, ow, kd * kd * shape[2])
        if node.op == "maxpool":
            return (oh, ow, shape[2])
        n = (node.params["w"].shape[-1] if node.op == "conv"
             else node.attrs["config"].out_features)
        return (oh, ow, n)
    if node.op == "flatten":
        size = 1
        for d in shape:
            size *= d
        return (size,)
    if node.op == "linear":
        return (node.params["w"].shape[0],)
    if node.op == "mvu":
        n = node.attrs["config"].out_features
        return (*shape[:-1], n) if len(shape) == 3 else (n,)
    return shape  # batchnorm / quant_act keep the shape


def n_pixels(shape: tuple) -> int:
    """Output pixels an MVU processes per sample (1 for flat activations)."""
    return shape[0] * shape[1] if len(shape) == 3 else 1


def find(graph: Graph, op: str) -> list[Node]:
    return [n for n in graph if n.op == op]
