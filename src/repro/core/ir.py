"""A layer-graph IR with explicit dataflow edges -- the FINN-ONNX analog.

FINN dataflow accelerators are streaming *graphs*: mostly linear chains of
compute units, but with fan-out (one producer feeding several consumers)
and fan-in (elementwise-binary joins) for residual/skip-connection
topologies.  The IR is a list of :class:`Node` objects; each node names
its producers in ``inputs``.  For plain chains ``inputs`` may be left
``None`` -- the edge to the previous list node is implied, so every
pre-DAG graph keeps working unchanged -- and :func:`as_graph` materializes
the implied edges.

Transformation passes (lowering.py) rewrite the graph exactly like FINN's
*Lowering and Conversion to HLS Layers* and *Streamlining* passes;
dataflow.py then plays the role of *Folding and Resource Estimation*.

Supported ops:
    input            attrs: shape, bits                 (0 inputs)
    conv             attrs: kernel, stride, pad; params: w (Kd,Kd,Cin,Cout)
    linear           attrs: -; params: w (N, K) float
    batchnorm        params: gamma, beta, mean, var
    quant_act        attrs: bits, act_scale
    maxpool          attrs: size, stride (defaults to size)
    flatten          attrs: -
    swu              attrs: kernel, stride, pad  (after lowering)
    mvu              attrs: MVUConfig; params: MVUParams (after lowering)
    conv_mvu         attrs: MVUConfig + kernel/stride/pad; params: MVUParams
                     (after ``lowering.fuse_swu`` collapses a swu+mvu pair)
    add / sub / mul  attrs: scales=(sa, sb) optional per-input integer
                     quantization-alignment scales (default (1, 1));
                     2 inputs, FINN elementwise-binary broadcast semantics
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any


@dataclasses.dataclass
class Node:
    op: str
    name: str
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)
    params: dict[str, Any] = dataclasses.field(default_factory=dict)
    # named producer edges; None = chain-implied (the previous list node)
    inputs: tuple[str, ...] | None = None


class Graph(list):
    """A graph is a list of nodes (list order = authoring order; use
    :func:`toposort` for dataflow order).  Subclassing ``list`` keeps every
    chain-era consumer -- iteration, indexing, ``isinstance(g, list)`` --
    working on DAGs unchanged."""


# the streaming elementwise-binary family (FINN ElementwiseBinaryOperation)
ELTWISE_OPS = ("add", "sub", "mul")

KNOWN_OPS = {
    "input", "conv", "linear", "batchnorm", "quant_act",
    "maxpool", "flatten", "swu", "mvu", "conv_mvu", *ELTWISE_OPS,
}


# ops that consume a spatial (H, W, C) activation; everything else takes
# whatever its producer yields
SPATIAL_OPS = ("conv", "swu", "conv_mvu", "maxpool")

# one DeprecationWarning per process for each legacy entry point (the
# EngineServer shim pattern)
_VALIDATE_CHAIN_WARNED = False
_PROPAGATE_SHIM_WARNED = False


def describe(node: Node) -> str:
    """The error-message handle for one node: its id (name) plus its op."""
    return f"node {node.name!r} ({node.op})"


# ------------------------------------------------------------- graph algebra
def as_graph(graph) -> Graph:
    """Materialize chain-implied edges: every returned node has explicit
    ``inputs`` (``()`` for input nodes).  Nodes that already carry explicit
    edges pass through untouched; implied ones are shallow-replaced, sharing
    their ``attrs``/``params`` dicts so in-place config rewrites (folding)
    still reach the caller's graph."""
    out = Graph()
    prev: Node | None = None
    for node in graph:
        if node.inputs is None:
            implied = () if node.op == "input" or prev is None else (prev.name,)
            node = dataclasses.replace(node, inputs=implied)
        out.append(node)
        prev = node
    return out


def producer_map(graph) -> dict[str, Node]:
    return {n.name: n for n in graph}


def consumer_map(graph) -> dict[str, list[Node]]:
    g = as_graph(graph)
    cons: dict[str, list[Node]] = {n.name: [] for n in g}
    for n in g:
        for src in n.inputs:
            if src in cons:
                cons[src].append(n)
    return cons


def toposort(graph) -> Graph:
    """Dataflow-ordered node list (stable: list order breaks ties).

    Raises ``ValueError`` naming the offending nodes when the graph has a
    cycle.  Dangling edges are ignored here -- :func:`validate_graph` turns
    them into a proper per-node diagnostic."""
    g = as_graph(graph)
    names = {n.name for n in g}
    done: set[str] = set()
    order = Graph()
    remaining = list(g)
    while remaining:
        rest: list[Node] = []
        for n in remaining:
            if all(s in done or s not in names for s in n.inputs):
                order.append(n)
                done.add(n.name)
            else:
                rest.append(n)
        if len(rest) == len(remaining):
            cyc = ", ".join(describe(n) for n in rest)
            raise ValueError(f"graph contains a cycle through {cyc}")
        remaining = rest
    return order


def graph_output(graph) -> Node:
    """The single sink node (the graph's output stream)."""
    cons = consumer_map(graph)
    sinks = [n for n in as_graph(graph) if not cons[n.name]]
    if len(sinks) != 1:
        names = ", ".join(describe(n) for n in sinks)
        raise ValueError(
            f"graph must have exactly one output (sink) node, found "
            f"{len(sinks)}: [{names}]")
    return sinks[0]


def edge_list(graph) -> list[list[str]]:
    """All ``[producer, consumer]`` edges, in graph list order (the
    BuildReport's serialized topology)."""
    return [[src, n.name] for n in as_graph(graph) for src in n.inputs]


def branch_labels(graph) -> dict[str, str]:
    """A human-readable branch path per node.

    The trunk (and every join, where branches merge back) is ``"main"``;
    the first node past a fan-out point starts a branch named
    ``"<fork-producer>/<entry-node>"`` which its single-input successors
    inherit -- the handle verification errors and reports use to say *which
    arm* of a fork a node sits on."""
    g = toposort(graph)
    cons = consumer_map(g)
    labels: dict[str, str] = {}
    for n in g:
        if not n.inputs or len(n.inputs) > 1:
            labels[n.name] = "main"
            continue
        src = n.inputs[0]
        if len(cons.get(src, ())) > 1:
            labels[n.name] = f"{src}/{n.name}"
        else:
            labels[n.name] = labels.get(src, "main")
    return labels


# -------------------------------------------------------------- validation
def validate_graph(graph) -> None:
    """Structural DAG validation with actionable, node-id-keyed errors.

    Every failure names the offending node (``node 'fc0' (linear)``) and
    what the graph expected of its producers/consumers, so a malformed
    graph fails at build time with a pointer to the node -- not deep inside
    a transform with a bare assert or a KeyError.  Checks: unique names,
    known ops, per-op input arity, dangling edges, acyclicity, at least one
    input node, exactly one sink (no dangling branches), spatial/flat
    domain rules per branch, swu->mvu streaming contract, elementwise
    broadcast legality, and shape/attr propagation."""
    if not graph:
        raise ValueError(
            "empty graph: a dataflow graph must contain an 'input' node")
    seen: dict[str, Node] = {}
    for n in graph:
        if n.name in seen:
            raise ValueError(
                f"{describe(n)}: duplicate node name (also a "
                f"{seen[n.name].op!r} node); edges are keyed by name, so "
                f"names must be unique")
        seen[n.name] = n
    g = as_graph(graph)
    prod = producer_map(g)
    for n in g:
        if n.op not in KNOWN_OPS:
            raise ValueError(
                f"{describe(n)}: unknown op; known ops are {sorted(KNOWN_OPS)}")
        for src in n.inputs:
            if src not in prod:
                raise ValueError(
                    f"{describe(n)}: dangling input edge from {src!r} -- no "
                    f"node of that name in the graph")
        want = 0 if n.op == "input" else 2 if n.op in ELTWISE_OPS else 1
        if len(n.inputs) != want:
            if n.op == "input":
                raise ValueError(
                    f"{describe(n)}: an 'input' node takes no inputs, got "
                    f"edges from {list(n.inputs)} (a mid-chain 'input' is "
                    f"illegal; start a second stream with an explicit "
                    f"edge-free input node instead)")
            raise ValueError(
                f"{describe(n)}: {n.op!r} takes exactly {want} "
                f"input{'s' if want > 1 else ''}, got {len(n.inputs)} "
                f"({list(n.inputs)})")
    if not any(n.op == "input" for n in g):
        raise ValueError(
            "graph has no 'input' node: a dataflow graph must read at "
            "least one streamed input")
    order = toposort(g)  # raises on cycles
    cons = consumer_map(g)
    sinks = [n for n in g if not cons[n.name]]
    if len(sinks) != 1:
        names = ", ".join(describe(n) for n in sinks)
        raise ValueError(
            f"graph must have exactly one output (sink) node, found "
            f"{len(sinks)}: [{names}] -- a dangling branch never reaches "
            f"the output stream")
    shapes: dict[str, tuple] = {}
    for n in order:
        ins = tuple(shapes[s] for s in n.inputs)
        if n.op in SPATIAL_OPS and n.inputs:
            for src, shp in zip(n.inputs, ins):
                if len(shp) != 3:
                    p = prod[src]
                    raise ValueError(
                        f"{describe(n)}: needs a spatial (H, W, C) "
                        f"activation, but producer {p.op!r} ({p.name!r}) "
                        f"yields shape {shp}")
        try:
            shapes[n.name] = propagate(n, *ins)
        except KeyError as e:
            raise ValueError(
                f"{describe(n)}: missing required attr/param "
                f"{e.args[0]!r} for this op") from None
        except ValueError as e:
            raise ValueError(f"{describe(n)}: {e}") from None
        if n.op == "swu":
            if not cons[n.name]:
                raise ValueError(
                    f"{describe(n)}: a sliding-window unit cannot terminate "
                    f"the graph; expected an 'mvu' consumer")
            for c in cons[n.name]:
                if c.op != "mvu":
                    raise ValueError(
                        f"{describe(c)}: a sliding-window unit must feed an "
                        f"'mvu' consumer (producer 'swu' {n.name!r} yields "
                        f"im2col windows)")


def validate_chain(graph) -> None:
    """Deprecated alias of :func:`validate_graph`.

    Chains are DAGs whose edges are all chain-implied; there is no separate
    linear validator any more.  Kept as a shim (one ``DeprecationWarning``
    per process, mirroring the ``EngineServer`` shim) so pre-DAG callers
    keep working; new code should call :func:`validate_graph`."""
    global _VALIDATE_CHAIN_WARNED
    if not _VALIDATE_CHAIN_WARNED:
        _VALIDATE_CHAIN_WARNED = True
        warnings.warn(
            "ir.validate_chain is deprecated: the IR is a DAG now -- call "
            "ir.validate_graph (chains validate identically through it)",
            DeprecationWarning, stacklevel=2)
    validate_graph(graph)


# ------------------------------------------------------- shape propagation
def broadcast_shapes(a: tuple, b: tuple) -> tuple:
    """FINN/numpy multidirectional broadcast of two per-sample shapes
    (trailing-dim alignment; the batch dim is outside this algebra)."""
    a, b = tuple(a), tuple(b)
    rank = max(len(a), len(b))
    pa = (1,) * (rank - len(a)) + a
    pb = (1,) * (rank - len(b)) + b
    out = []
    for da, db in zip(pa, pb):
        if da != db and 1 not in (da, db):
            raise ValueError(
                f"cannot broadcast per-sample shapes {a} and {b} "
                f"(dim {da} vs {db})")
        out.append(max(da, db))
    return tuple(out)


def propagate(node: Node, *input_shapes: tuple) -> tuple:
    """Multi-input shape inference for one node.

    Spatial activations are ``(H, W, C)`` tuples, flat ones ``(K,)`` -- the
    shared shape algebra behind :func:`validate_graph`,
    ``dataflow.schedule``, ``lowering.apply_folding``, and the engine's
    stream planning.  Elementwise-binary nodes take two input shapes and
    broadcast them; every other op takes at most one.

    The legacy chain signature ``propagate(shape, node)`` still works
    through a compat shim (one ``DeprecationWarning`` per process)."""
    if not isinstance(node, Node):
        # legacy (shape, node) calling convention
        global _PROPAGATE_SHIM_WARNED
        if not _PROPAGATE_SHIM_WARNED:
            _PROPAGATE_SHIM_WARNED = True
            warnings.warn(
                "ir.propagate(shape, node) is deprecated: call "
                "ir.propagate(node, *input_shapes)",
                DeprecationWarning, stacklevel=2)
        shape, legacy_node = node, input_shapes[0]
        return propagate(legacy_node,
                         *(() if shape is None else (tuple(shape),)))
    if node.op == "input":
        return tuple(node.attrs["shape"])
    if node.op in ELTWISE_OPS:
        if len(input_shapes) != 2:
            raise ValueError(
                f"{node.op!r} takes exactly 2 input shapes, got "
                f"{len(input_shapes)}")
        return broadcast_shapes(*input_shapes)
    shape = input_shapes[0] if input_shapes else None
    if node.op in ("conv", "swu", "conv_mvu", "maxpool"):
        from repro.core.swu import out_dim as _conv_out  # shared size algebra

        h, w = shape[0], shape[1]
        if node.op == "maxpool":
            kd = node.attrs["size"]
            st, pd = node.attrs.get("stride", kd), 0
        else:
            kd = node.attrs["kernel"]
            st, pd = node.attrs["stride"], node.attrs["pad"]
        oh, ow = _conv_out(h, kd, st, pd), _conv_out(w, kd, st, pd)
        if node.op == "swu":
            return (oh, ow, kd * kd * shape[2])
        if node.op == "maxpool":
            return (oh, ow, shape[2])
        n = (node.params["w"].shape[-1] if node.op == "conv"
             else node.attrs["config"].out_features)
        return (oh, ow, n)
    if node.op == "flatten":
        size = 1
        for d in shape:
            size *= d
        return (size,)
    if node.op == "linear":
        return (node.params["w"].shape[0],)
    if node.op == "mvu":
        n = node.attrs["config"].out_features
        return (*shape[:-1], n) if len(shape) == 3 else (n,)
    return shape  # batchnorm / quant_act keep the shape


def infer_shapes(graph) -> dict[str, tuple]:
    """Per-node output shapes, keyed by node name (topo-order propagation)."""
    shapes: dict[str, tuple] = {}
    for node in toposort(graph):
        shapes[node.name] = propagate(node, *(shapes[s] for s in node.inputs))
    return shapes


def io_shapes(graph) -> list[tuple[Node, tuple[tuple, ...], tuple]]:
    """``(node, input_shapes, output_shape)`` for every node, in topo order.

    The one shape-walk every multi-node consumer (scheduling, folding,
    autotune keys, report tables) shares -- the DAG replacement for the
    chain era's running ``shape = propagate(shape, node)`` loops."""
    out: list[tuple[Node, tuple[tuple, ...], tuple]] = []
    shapes: dict[str, tuple] = {}
    for node in toposort(graph):
        ins = tuple(shapes[s] for s in node.inputs)
        shapes[node.name] = propagate(node, *ins)
        out.append((node, ins, shapes[node.name]))
    return out


def n_pixels(shape: tuple) -> int:
    """Output pixels an MVU processes per sample (1 for flat activations)."""
    return shape[0] * shape[1] if len(shape) == 3 else 1


def find(graph, op: str) -> list[Node]:
    return [n for n in graph if n.op == op]
