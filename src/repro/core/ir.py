"""A small layer-graph IR -- the FINN-ONNX analog.

FINN dataflow accelerators are (almost always) linear chains of layers, so
the IR is a list of nodes.  Transformation passes (lowering.py) rewrite the
chain exactly like FINN's *Lowering and Conversion to HLS Layers* and
*Streamlining* passes; dataflow.py then plays the role of *Folding and
Resource Estimation*.

Supported ops:
    input            attrs: shape, bits
    conv             attrs: kernel, stride, pad; params: w (Kd,Kd,Cin,Cout)
    linear           attrs: -; params: w (N, K) float
    batchnorm        params: gamma, beta, mean, var
    quant_act        attrs: bits, act_scale
    swu              attrs: kernel, stride, pad  (after lowering)
    mvu              attrs: MVUConfig; params: MVUParams (after lowering)
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class Node:
    op: str
    name: str
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)
    params: dict[str, Any] = dataclasses.field(default_factory=dict)


Graph = list


def validate_chain(graph: Graph) -> None:
    if not graph or graph[0].op != "input":
        raise ValueError("graph must start with an input node")
    known = {"input", "conv", "linear", "batchnorm", "quant_act", "swu", "mvu"}
    for node in graph:
        if node.op not in known:
            raise ValueError(f"unknown op {node.op!r} ({node.name})")


def find(graph: Graph, op: str) -> list[Node]:
    return [n for n in graph if n.op == op]
