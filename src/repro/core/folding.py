"""FINN's folding pass, ported to TPU tile selection.

FINN time-multiplexes the weight matrix (N = O_c rows, K = Kd^2*I_c cols)
onto a PE x SIMD array:

    neuron fold   NF = N / PE        (PE must divide N)
    synapse fold  SF = K / SIMD      (SIMD must divide K)
    cycles per output pixel = NF * SF   at II = 1
    total cycles = n_pixels * NF * SF

On TPU, PE maps to the kernel's block_n and SIMD to block_k (x32 synapses
per packed word for the XNOR datapath), so "folding" becomes BlockSpec tile
selection under a VMEM budget -- same math, same balance condition.

The pipeline balancer reproduces FINN's *Folding and Resource Estimation*
pass: given a cycle target, assign each layer the smallest PE*SIMD product
that meets it, which rate-matches the streaming pipeline (the slowest layer
sets the initiation interval of the whole dataflow graph).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.kernels.packing import WORD_BITS


@dataclasses.dataclass(frozen=True)
class Folding:
    pe: int
    simd: int

    def cycles(self, n: int, k: int, n_pixels: int = 1) -> int:
        nf = -(-n // self.pe)
        sf = -(-k // self.simd)
        return n_pixels * nf * sf

    def conv_cycles(self, n: int, k: int, oh: int, ow: int) -> int:
        """Paper Eq. 1 over the pixel dimension: the SWU feeds one K-window
        per output pixel, so a conv layer costs OH*OW * NF * SF cycles."""
        return self.cycles(n, k, n_pixels=oh * ow)

    def validate(self, n: int, k: int) -> None:
        if n % self.pe:
            raise ValueError(f"PE={self.pe} must divide N={n}")
        if k % self.simd:
            raise ValueError(f"SIMD={self.simd} must divide K={k}")


def divisors(x: int) -> list[int]:
    out = [d for d in range(1, int(math.isqrt(x)) + 1) if x % d == 0]
    return sorted(set(out + [x // d for d in out]))


def weight_mem_depth(n: int, k: int, fold: Folding) -> int:
    """Paper Eq. (2): D_mem = K*N / (SIMD*PE), per-PE weight memory depth."""
    return (k * n) // (fold.simd * fold.pe)


def input_buffer_depth(k: int, fold: Folding) -> int:
    """Input buffer depth K/SIMD (reused across the NF row groups)."""
    return -(-k // fold.simd)


def choose_folding(
    n: int,
    k: int,
    *,
    target_cycles: int | None = None,
    max_pe: int = 128,
    max_simd: int = 128,
    n_pixels: int = 1,
) -> Folding:
    """Smallest PE*SIMD meeting ``target_cycles`` (FINN folding objective).

    With no target, returns the largest legal array (fully-parallel bound).
    Ties break toward larger SIMD (deeper dot products amortize the
    accumulator, mirroring FINN's preference for SIMD before PE).
    """
    pes = [d for d in divisors(n) if d <= max_pe]
    simds = [d for d in divisors(k) if d <= max_simd]
    if target_cycles is None:
        return Folding(max(pes), max(simds))
    best: Folding | None = None
    best_cost = None
    for pe in pes:
        for simd in simds:
            f = Folding(pe, simd)
            if f.cycles(n, k, n_pixels) <= target_cycles:
                cost = (pe * simd, -simd)
                if best_cost is None or cost < best_cost:
                    best, best_cost = f, cost
    if best is None:
        best = Folding(max(pes), max(simds))  # can't meet target: go maximal
    return best


def balance_pipeline(
    layer_shapes: Sequence[tuple[int, int, int]],  # (N, K, n_pixels)
    *,
    slowest_cycles: int | None = None,
    max_pe: int = 128,
    max_simd: int = 128,
) -> list[Folding]:
    """Rate-match a chain of MVU layers (FINN balanced-pipeline condition).

    Every layer gets the cheapest folding whose cycle count does not exceed
    the pipeline target; the default target is the cycle count of the
    heaviest layer at full parallelism (nothing can beat that anyway).
    """
    if slowest_cycles is None:
        slowest_cycles = max(
            Folding(min(max_pe, n), min(max_simd, k)).cycles(n, k, px)
            for n, k, px in layer_shapes
        )
    return [
        choose_folding(n, k, target_cycles=slowest_cycles,
                       max_pe=max_pe, max_simd=max_simd, n_pixels=px)
        for n, k, px in layer_shapes
    ]


def to_tpu_blocks(fold: Folding, mode: str, m: int = 128, *,
                  packed: bool = False) -> dict[str, int]:
    """Map (PE, SIMD) onto Pallas block shapes.

    block_n = PE (output rows in parallel), block_k = SIMD synapses per grid
    step; the XNOR datapath packs 32 synapses per word so block_kw =
    SIMD / 32, and the packed binary datapath steps the same word axis.
    Packed 2-bit weights carry 4 lanes per byte, so block_k rounds up to a
    whole number of bytes.  Values are clamped up to TPU-friendly minima
    (8 sublanes / 128 lanes) -- small FPGA-style arrays are legal but pad
    on real silicon.
    """
    if mode == "xnor" or (packed and mode == "binary"):
        bkw = max(1, fold.simd // WORD_BITS)
        return {"block_m": m, "block_n": max(8, fold.pe), "block_kw": bkw}
    bk = max(8, fold.simd)
    if packed:  # 2-bit lane storage: whole bytes per K step
        bk = -(-bk // 4) * 4
    return {"block_m": m, "block_n": max(8, fold.pe), "block_k": bk}


def block_candidates(
    n: int,
    k: int,
    mode: str,
    *,
    block_ms: Sequence[int] = (32, 128, 256),
    max_block: int = 512,
    packed: bool = False,
) -> list[dict[str, int]]:
    """Enumerate the legal Pallas tile schedules for an (N, K) layer.

    The candidate axes come from the layer's folding divisors, clamped to
    the TPU minima exactly like :func:`to_tpu_blocks` (block_n/block_k >= 8),
    plus the full-MXU defaults -- so the heuristic schedule is always in the
    set and the autotuner can only match or beat it.  ``block_kw`` (xnor and
    the packed binary datapath) ranges over divisors of the packed word
    count; packed 2-bit block_k is held to whole bytes.  Candidates are
    unique dicts; ordering/pruning is the caller's job
    (``repro.core.autotune``).
    """
    bns = sorted({max(8, d) for d in divisors(n)} | {128})
    bns = [b for b in bns if b <= max(max_block, 8)]
    out: list[dict[str, int]] = []
    if mode == "xnor" or (packed and mode == "binary"):
        n_words = -(-k // WORD_BITS)
        bkws = sorted({d for d in divisors(n_words)} | {min(8, n_words)})
        for bm in block_ms:
            for bn in bns:
                for bkw in bkws:
                    out.append({"block_m": bm, "block_n": bn, "block_kw": bkw})
    else:
        bks = sorted({max(8, d) for d in divisors(k)} | {128, min(512, max(8, k))})
        bks = [b for b in bks if b <= max(max_block, 8)]
        if packed:  # 2-bit lane storage: whole bytes per K step
            bks = sorted({-(-b // 4) * 4 for b in bks})
        for bm in block_ms:
            for bn in bns:
                for bk in bks:
                    out.append({"block_m": bm, "block_n": bn, "block_k": bk})
    seen: set[tuple] = set()
    uniq = []
    for c in out:
        key = tuple(sorted(c.items()))
        if key not in seen:
            seen.add(key)
            uniq.append(c)
    return uniq
