"""Quantizers with straight-through estimators (the Brevitas-analog layer).

FINN consumes networks trained quantization-aware (Brevitas).  This module
is the training-side counterpart: fake-quantizers whose forward pass emits
the integer grid FINN's MVU consumes and whose backward pass is the usual
straight-through estimator (STE).

Conventions
-----------
* ``signed`` integer grids are symmetric: ``[-2^{b-1}+1, 2^{b-1}-1]`` (FINN
  uses symmetric weight quantization so that weight*scale factorizes out).
* ``unsigned`` grids are ``[0, 2^b - 1]`` (post-threshold activations).
* 1-bit weights are bipolar {-1, +1} (paper Fig. 4a/4b).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def _ste(x: jax.Array, q: jax.Array) -> jax.Array:
    """Straight-through: forward ``q``, gradient of identity in ``x``."""
    return x + jax.lax.stop_gradient(q - x)


def int_bounds(bits: int, signed: bool) -> tuple[int, int]:
    if bits == 1 and signed:
        return -1, 1  # bipolar
    if signed:
        return -(2 ** (bits - 1)) + 1, 2 ** (bits - 1) - 1
    return 0, 2**bits - 1


class QTensor(NamedTuple):
    """An integer tensor plus the scale taking it back to real values."""

    values: jax.Array  # integer grid (stored in int8/int32)
    scale: jax.Array  # per-channel or scalar: real = values * scale
    bits: int
    signed: bool


def quantize_weights(w: jax.Array, bits: int, axis: int | None = 0) -> QTensor:
    """Post-training symmetric weight quantization (per-output-channel).

    ``axis`` is the output-channel axis kept un-reduced for the scale; pass
    ``None`` for a single tensor-wide scale.
    """
    lo, hi = int_bounds(bits, signed=True)
    if bits == 1:
        # bipolar: scale = mean |w| per channel (XNOR-Net style)
        reduce_axes = tuple(i for i in range(w.ndim) if i != axis) if axis is not None else None
        scale = jnp.mean(jnp.abs(w), axis=reduce_axes, keepdims=True)
        q = jnp.where(w >= 0, 1, -1).astype(jnp.int8)
        return QTensor(q, scale, bits, True)
    reduce_axes = tuple(i for i in range(w.ndim) if i != axis) if axis is not None else None
    amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / hi
    q = jnp.clip(jnp.round(w / scale), lo, hi).astype(jnp.int8)
    return QTensor(q, scale, bits, True)


def fake_quant_weights(w: jax.Array, bits: int, axis: int | None = 0) -> jax.Array:
    """QAT fake-quantization of weights with STE (returns real-valued grid)."""
    if bits >= 16:
        return w
    if bits == 1:
        reduce_axes = tuple(i for i in range(w.ndim) if i != axis) if axis is not None else None
        scale = jnp.mean(jnp.abs(w), axis=reduce_axes, keepdims=True)
        q = jnp.where(w >= 0, scale, -scale)
        return _ste(w, q)
    lo, hi = int_bounds(bits, signed=True)
    reduce_axes = tuple(i for i in range(w.ndim) if i != axis) if axis is not None else None
    amax = jax.lax.stop_gradient(jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True))
    scale = jnp.maximum(amax, 1e-8) / hi
    q = jnp.clip(jnp.round(w / scale), lo, hi) * scale
    return _ste(w, q)


def quantize_activations(x: jax.Array, bits: int, scale: jax.Array | float) -> jax.Array:
    """Real -> unsigned integer activation grid (what thresholds produce)."""
    lo, hi = int_bounds(bits, signed=False)
    return jnp.clip(jnp.round(x / scale), lo, hi).astype(jnp.int32)


def fake_quant_activations(x: jax.Array, bits: int, max_val: float = 1.0) -> jax.Array:
    """QAT activation fake-quant: clipped ReLU onto a 2^bits-level grid, STE."""
    if bits >= 16:
        return x
    if bits == 1:
        q = (x >= 0).astype(x.dtype)
        return _ste(x, q)
    n = 2**bits - 1
    xc = jnp.clip(x, 0.0, max_val)
    q = jnp.round(xc * (n / max_val)) * (max_val / n)
    return _ste(xc, q)


def binarize_bipolar(x: jax.Array) -> jax.Array:
    """Sign binarization with the BNN clipped-identity STE."""
    q = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    xc = jnp.clip(x, -1.0, 1.0)
    return xc + jax.lax.stop_gradient(q - xc)
