"""Multi-threshold activation: FINN's fused BatchNorm + quantized activation.

FINN's MVU is really an MV*T*U: after the integer dot product it compares the
accumulator against a sorted per-channel threshold vector and emits

    act[c] = sum_t  (acc[c] >= T[c, t])        in  [0, 2^bits - 1]

which is exactly ``quantize(BN(acc))`` once BN and the activation quantizer
are folded into integer thresholds (the FINN "streamlining" pass).  This
module computes those thresholds and provides the reference epilogue; the
Pallas kernels fuse the same comparison loop after their accumulators.

Negative BN gamma flips the comparison direction.  As in FINN streamlining we
normalize that offline: rows with gamma < 0 have their weights (and
thresholds) negated so the kernel only ever implements ``>=``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ThresholdSpec(NamedTuple):
    thresholds: jax.Array  # (out_channels, n_levels - 1), ascending per row
    bits: int  # output activation bits; n_levels = 2**bits


def apply_thresholds(acc: jax.Array, thresholds: jax.Array) -> jax.Array:
    """Reference epilogue: acc (..., C), thresholds (C, T) -> (..., C) int32."""
    return jnp.sum(acc[..., None] >= thresholds, axis=-1).astype(jnp.int32)


def bn_quant_thresholds(
    gamma: jax.Array,
    beta: jax.Array,
    mean: jax.Array,
    var: jax.Array,
    *,
    bits: int,
    acc_scale: float | jax.Array = 1.0,
    act_scale: float | jax.Array = 1.0,
    eps: float = 1e-5,
) -> tuple[jax.Array, jax.Array]:
    """Fold ``quant(BN(acc * acc_scale))`` into integer accumulator thresholds.

    The quantizer maps real y to level j when  y >= (j - 0.5) * act_scale
    (round-to-nearest on an unsigned grid with step ``act_scale``), for
    j = 1..2^bits - 1.  Solving  BN(acc*acc_scale) >= y_j  for acc gives the
    per-channel threshold

        T[c, j] = ((y_j - beta[c]) * sqrt(var[c] + eps) / gamma[c] + mean[c])
                  / acc_scale

    Returns ``(thresholds, flip)`` where ``flip[c]`` is True for channels with
    gamma < 0; callers must negate those weight rows (and the returned rows
    are already negated accordingly) — see :func:`streamline_signs`.
    Thresholds are *real-valued* here; for integer accumulators take
    ``ceil`` (``acc >= T`` with integer acc is equivalent to ``acc >= ceil(T)``).
    """
    n_levels = 2**bits
    j = jnp.arange(1, n_levels, dtype=jnp.float32)
    y = (j - 0.5) * jnp.asarray(act_scale, jnp.float32)  # quantizer decision boundaries
    std = jnp.sqrt(var + eps)
    g = jnp.where(gamma == 0, 1e-12, gamma)
    t = ((y[None, :] - beta[:, None]) * (std / g)[:, None] + mean[:, None]) / acc_scale
    flip = gamma < 0
    # for flipped rows the weight negation maps acc -> -acc, so T -> -T and
    # the per-row threshold order reverses; re-sort ascending.
    t = jnp.where(flip[:, None], -t[:, ::-1], t)
    return t, flip


def streamline_signs(w: jax.Array, flip: jax.Array) -> jax.Array:
    """Negate the weight rows whose BN gamma was negative (w: (out, in))."""
    return jnp.where(flip[:, None], -w, w)


def integerize_thresholds(t: jax.Array) -> jax.Array:
    """Real thresholds -> smallest integers giving identical >= decisions."""
    return jnp.ceil(t).astype(jnp.int32)
