"""Fused streaming dataflow engine: the whole lowered graph as ONE executable.

The paper's central argument (section 5.3) is architectural: FINN instantiates
one MVU per layer, chains them with small AXI FIFOs, and lets the slowest
stage set the initiation interval — no monolithic controller, no per-layer
host round-trips.  ``dataflow.execute`` reproduces the *semantics* of that
graph but runs it as an eager Python loop: one XLA dispatch per node, float
batchnorm/quant epilogues on the host path, nothing fused.  ``FusedEngine``
is the runtime analog of the paper's full dataflow build:

    paper (section 5.3)                      FusedEngine
    ------------------------------------     ------------------------------------
    MVTU: thresholds fused after the         ``lowering.fuse_epilogues`` folds
    accumulator (Fig. 3, T&geq; unit)        batchnorm+quant_act into the MVU
                                             kernel's threshold epilogue
    one compute unit per layer, AXI          one jit'd program; stages traced
    streams between them                     back-to-back, XLA fuses transfers
    FIFO decoupling (5.3.2): small           microbatch streaming: the batch is
    buffers absorb producer bursts           split into ``StreamPlan.n_micro``
                                             chunks scanned through the chain
    II = bottleneck stage cycles             ``DataflowSchedule.steady_state_
                                             interval`` sizes the microbatch plan
    multi-FPGA / SLR partitioning            ``as_pipeline`` maps stages onto a
                                             device mesh via
                                             ``distributed.pipeline.pipeline_apply``

The microbatch size comes from the schedule: one microbatch is the
bottleneck MVU's resident input tile (``block_m`` — the Eq. 2 input buffer),
i.e. exactly one producer burst, so every stage's kernel runs a single
M step per microbatch and the decoupling FIFO between stages never holds
more than one burst — the same "big enough to decouple, small enough to
fit" sizing rule FINN applies to its AXI FIFOs.  The smallest FIFO depth
caps in-flight microbatches on the multi-device pipeline schedule.

Usage::

    graph  = lowering.finalize(lowering.lower_to_mvu(g))  # may keep bn/quant
    engine = FusedEngine(graph)      # fuses epilogues, compiles on first call
    y      = engine(x)               # bit-exact with dataflow.execute(graph, x)
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.core import dataflow, ir, lowering
from repro.core.ir import Graph


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Microbatch schedule for one engine invocation (FINN FIFO analog)."""

    n_micro: int  # microbatches streamed through the stage chain
    microbatch: int  # samples per microbatch (batch padded up to n*mb)
    interval_cycles: int  # bottleneck stage cycles (steady-state II)
    fifo_bound: int  # smallest inter-stage FIFO depth (pipeline in-flight cap)


class FusedEngine:
    """Compile a lowered :class:`~repro.core.ir.Graph` into a single jit'd,
    microbatch-streaming executable.

    * Epilogue fusion: standalone ``batchnorm``/``quant_act`` successors of
      each MVU are folded into the kernel's multi-threshold epilogue at
      compile time (``fuse=False`` keeps the graph as-is).
    * Streaming: batches are split into microbatches per :meth:`plan` and
      scanned through the stage chain — the statically-scheduled analog of
      FINN's FIFO-decoupled layer pipeline.
    * The node semantics come from :func:`repro.core.dataflow.node_runner`,
      the same definition the eager interpreter uses, so outputs are
      bit-exact with ``dataflow.execute`` on the unfused graph.
    """

    TUNE_MODES = ("off", "cache", "auto")

    def __init__(self, graph: Graph, *, fuse: bool = True,
                 microbatches: int | None = None,
                 tune: str = "off", cache=None,
                 tune_kwargs: dict | None = None):
        if tune not in self.TUNE_MODES:
            raise ValueError(f"tune must be one of {self.TUNE_MODES}, got {tune!r}")
        g: Graph = lowering.fuse_epilogues(graph) if fuse else list(graph)
        # swu+mvu pairs collapse into the line-buffer conv kernel, so the
        # im2col matrix never materializes between stages (FINN's SWU->MVU
        # AXI stream; the conv analog of epilogue fusion).
        self.graph = lowering.fuse_swu(g) if fuse else g
        self._tile: int | None = None
        if tune != "off":
            # tune="cache" is a pure lookup over committed results -- no
            # timer ever runs at construction; tune="auto" measures cache
            # misses once and records them (see repro.core.autotune).
            from repro.core import autotune

            cache = cache if cache is not None else autotune.default_cache()
            self.graph = autotune.tune_graph(self.graph, cache=cache,
                                             mode=tune, **(tune_kwargs or {}))
            # the engine-level entry lives in the same device namespace as
            # the node entries, so a device override must scope both lookups
            device = (tune_kwargs or {}).get("device")
            ent = cache.get(autotune.engine_key(self.graph, device=device))
            if ent is not None:
                self._tile = max(1, int(ent["microbatch"]))
        self.schedule = dataflow.schedule(self.graph)
        # stage order is the dataflow (topological) order -- identical to
        # list order for chains, and the streaming order for branched graphs
        order = ir.toposort(self.graph)
        runners = [dataflow.node_runner(n) for n in order]
        self._fns = tuple(fn for _, fn in runners)
        self.params = [p for p, _ in runners]
        self._names = tuple(n.name for n in order)
        self._in_names = tuple(n.inputs for n in order)
        self._out_name = ir.graph_output(self.graph).name
        self._microbatches = microbatches
        self._jit = jax.jit(self._stream, static_argnums=(2,))

    # ------------------------------------------------------------- schedule
    def plan(self, batch: int) -> StreamPlan:
        """Derive the microbatch schedule from the dataflow schedule.

        The microbatch size is the bottleneck MVU's resident input tile
        (its ``block_m`` — the paper Eq. 2 input buffer holds one tile of
        activations while the NF x SF loop drains it), so each streamed
        microbatch is exactly one producer burst: every stage's kernel runs
        a single M step and the inter-stage FIFO never sees more than one
        burst in flight.  ``n_micro`` is then the number of bursts the batch
        decomposes into; ``fifo_bound`` (smallest FIFO depth) caps in-flight
        microbatches on the :meth:`as_pipeline` multi-device schedule, where
        stages genuinely overlap.
        """
        s = self.schedule
        if not s.stages or batch <= 1:
            interval = s.steady_state_interval if s.stages else 0
            return StreamPlan(1, max(batch, 1), interval, 0)
        fifo_bound = max(2, min(st.fifo_depth for st in s.stages))
        # Samples per burst: a dense stage's kernel holds block_m samples per
        # M tile; a conv stage's M tile holds block_m *pixels*, i.e.
        # block_m // n_pixels whole images -- the conv bottleneck sets the
        # microbatch for the whole chain.  An engine-level autotune entry
        # (``autotune.tune_engine``) overrides the heuristic tile.
        tile = self._tile or min(max(1, st.block_m // st.n_pixels)
                                 for st in s.stages)
        n_micro = max(1, min(math.ceil(batch / tile), batch))
        if self._microbatches is not None:
            n_micro = max(1, min(self._microbatches, batch))
        return StreamPlan(
            n_micro, -(-batch // n_micro), s.steady_state_interval, fifo_bound
        )

    # -------------------------------------------------------------- forward
    def _chain(self, params, x):
        # traced once under jit: the env is a compile-time dict of traced
        # values, so fan-out reuses one stream and joins consume both arms
        # inside the same fused program -- no interpreter overhead survives.
        env: dict = {}
        for name, ins, p, fn in zip(self._names, self._in_names,
                                    params, self._fns):
            args = (x,) if not ins else tuple(env[s] for s in ins)
            env[name] = fn(p, *args)
        return env[self._out_name]

    def _stream(self, params, x, n_micro: int):
        b = x.shape[0]
        if n_micro <= 1:
            return self._chain(params, x)
        mb = -(-b // n_micro)
        pad = n_micro * mb - b
        if pad:
            x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
        xs = x.reshape(n_micro, mb, *x.shape[1:])
        ys = jax.lax.map(functools.partial(self._chain, params), xs)
        return ys.reshape(n_micro * mb, *ys.shape[2:])[:b]

    def dispatch(self, x: jax.Array, *, params=None,
                 tracer=None) -> tuple[jax.Array, StreamPlan]:
        """Non-blocking submit: enqueue one batch, return the un-resolved
        device array plus the stream plan it runs under.

        JAX dispatch is asynchronous -- the call returns as soon as the
        computation is enqueued on its device, so a serving front-end can go
        straight back to admitting requests and block only when it resolves
        the result (``np.asarray`` / ``jax.block_until_ready``).  ``params``
        overrides the engine's resident parameters with a replica's copy
        (``repro.serving.pool`` places them per device); the computation
        runs wherever the committed operands live.

        ``tracer`` (a :class:`repro.telemetry.Tracer`) records the host-side
        enqueue as an ``engine.dispatch`` span -- the duration is submit
        cost, not compute (the call does not block); per-node compute spans
        come from :meth:`profile`.
        """
        plan = self.plan(int(x.shape[0]))
        params = self.params if params is None else params
        if tracer is None:
            return self._jit(params, x, plan.n_micro), plan
        with tracer.span("engine.dispatch", cat="engine",
                         batch=int(x.shape[0]), n_micro=plan.n_micro,
                         microbatch=plan.microbatch,
                         interval_cycles=plan.interval_cycles):
            out = self._jit(params, x, plan.n_micro)
        return out, plan

    def profile(self, x: jax.Array, tracer, *, drift=None
                ) -> tuple[jax.Array, StreamPlan]:
        """Instrumented run: per-node, per-microbatch duration spans.

        The jit'd :meth:`dispatch` path is one fused program -- XLA leaves
        no per-node boundary to time -- so profiling re-runs the SAME node
        runners (``dataflow.node_runner``, the definitions the fused chain
        traced) eagerly per microbatch, blocking after each node.  Every op
        is per-sample, so the output is bit-exact with :meth:`dispatch`;
        only the timing differs (each node pays its own dispatch, which is
        the point).  Span tree::

            engine.profile
              micro0
                <node name>   one span per graph node, cat="node"
              micro1
                ...

        ``drift`` (a :class:`repro.telemetry.DriftMonitor`) receives each
        node span duration keyed by node name -- with predictions from
        ``DriftMonitor.from_schedule(engine.schedule, s_per_cycle)`` this
        compares measured per-node intervals against the calibrated cycle
        model online.
        """
        b = int(x.shape[0])
        plan = self.plan(b)
        mb = plan.microbatch
        pad = plan.n_micro * mb - b
        xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
        xs = xp.reshape(plan.n_micro, mb, *x.shape[1:])
        outs = []
        with tracer.span("engine.profile", cat="engine", batch=b,
                         n_micro=plan.n_micro, microbatch=mb):
            for m in range(plan.n_micro):
                with tracer.span(f"micro{m}", cat="engine"):
                    env: dict = {}
                    for name, ins, p, fn in zip(self._names, self._in_names,
                                                self.params, self._fns):
                        with tracer.span(name, cat="node", micro=m) as sp:
                            args = ((xs[m],) if not ins
                                    else tuple(env[s] for s in ins))
                            env[name] = jax.block_until_ready(fn(p, *args))
                        if drift is not None:
                            drift.observe(name, sp.dur)
                    outs.append(env[self._out_name])
        y = jnp.concatenate(outs)[:b]
        return y, plan

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.dispatch(x)[0]

    # ---------------------------------------------------------- multi-device
    def as_pipeline(self, mesh, *, axis: str = "stage", tracer=None):
        """Map stages onto mesh devices, one layer range per device, reusing
        :func:`repro.distributed.pipeline.pipeline_apply` (ppermute links as
        the AXI streams).

        Stacking per-stage params requires a homogeneous chain: every node an
        MVU of the same (N, K) and mode (not xnor — its static packed width
        breaks stacking) with a uniform epilogue.  Heterogeneous graphs run
        single-device via ``__call__``.  Returns ``run(xs)`` taking
        microbatched input ``(n_micro, mb, K)``.

        With ``tracer``, each ``run`` records a ``pipeline.run`` span plus
        reconstructed per-stage occupancy lanes: the schedule is one fused
        XLA program (nothing to time inside), so the measured wall interval
        is overlaid with the static GPipe schedule -- busy ``microN`` spans
        and ``bubble`` fill/drain spans per stage, with the occupancy
        fraction in the span args (see
        :func:`repro.distributed.pipeline.emit_schedule_spans`).
        """
        from repro.distributed.pipeline import (
            emit_schedule_spans,
            pipeline_apply,
            stage_params_split,
        )
        from repro.kernels import ops as kops

        non_input = [n for n in self.graph if n.op != "input"]
        if any(n.op != "mvu" for n in non_input):
            raise ValueError(
                "as_pipeline needs a pure MVU chain; fuse_epilogues removes "
                f"bn/quant nodes, got ops {[n.op for n in non_input]}"
            )
        cfgs = [n.attrs["config"] for n in non_input]
        shapes = {(c.mode, c.out_features, c.in_features) for c in cfgs}
        if len(shapes) != 1 or cfgs[0].mode == "xnor":
            raise ValueError(f"stages must be homogeneous non-xnor MVUs, got {shapes}")
        thr = [n.params["mvu"].thresholds for n in non_input]
        scl = [n.params["mvu"].out_scale for n in non_input]
        for part in (thr, scl):
            if any(p is None for p in part) and not all(p is None for p in part):
                raise ValueError("stages must share one epilogue form")
        stacked = {"w": jnp.stack([n.params["mvu"].weights for n in non_input])}
        if thr[0] is not None:
            stacked["t"] = jnp.stack(thr)
        if scl[0] is not None:
            stacked["s"] = jnp.stack(scl)
        layer_fn = kops.mvu_layer_fn(
            cfgs[0].mode, backend=cfgs[0].backend, **cfgs[0].kernel_blocks()
        )
        n_stages = mesh.shape[axis]
        stage_params = stage_params_split(stacked, n_stages)

        def run(xs: jax.Array) -> jax.Array:
            if tracer is None:
                return pipeline_apply(layer_fn, stage_params, xs, mesh,
                                      axis=axis)
            n_micro = int(xs.shape[0])
            with tracer.span("pipeline.run", cat="pipeline",
                             n_stages=n_stages, n_micro=n_micro) as sp:
                out = jax.block_until_ready(
                    pipeline_apply(layer_fn, stage_params, xs, mesh, axis=axis)
                )
            occ = emit_schedule_spans(tracer, n_stages, n_micro,
                                      sp.t0, sp.t1)
            sp.args.update(occupancy=occ["occupancy"],
                           bubble_ticks=occ["bubble_ticks_per_stage"])
            return out

        return run
