"""Sliding Window Unit (SWU): FINN's on-the-fly im2col.

Lowers a convolution input (B, H, W, C) into the GEMM activation matrix of
paper Fig. 1: each output pixel becomes one row of K = Kd^2 * C features,
ordered (ky, kx, c) -- the same order the weight matrix rows are packed in
(see :func:`pack_conv_weights`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def out_dim(size: int, kernel: int, stride: int, pad: int) -> int:
    return (size + 2 * pad - kernel) // stride + 1


def sliding_window(
    x: jax.Array, kernel: int, stride: int = 1, pad: int = 0
) -> jax.Array:
    """(B, H, W, C) -> (B, OH*OW, Kd^2*C) in (ky, kx, c) feature order."""
    b, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = out_dim(h, kernel, stride, pad)
    ow = out_dim(w, kernel, stride, pad)
    # gather rows/cols: (OH, Kd) and (OW, Kd) index grids
    iy = (jnp.arange(oh) * stride)[:, None] + jnp.arange(kernel)[None, :]
    ix = (jnp.arange(ow) * stride)[:, None] + jnp.arange(kernel)[None, :]
    # (B, OH, Kd, W', C) -> (B, OH, Kd, OW, Kd, C)
    g = x[:, iy]  # (B, OH, Kd, Wp, C)
    g = g[:, :, :, ix]  # (B, OH, Kd, OW, Kd, C)
    g = jnp.moveaxis(g, 3, 1)  # (B, OW, OH, Kd, Kd, C) -> fix order below
    g = jnp.swapaxes(g, 1, 2)  # (B, OH, OW, Kd, Kd, C): (ky, kx, c) per pixel
    return g.reshape(b, oh * ow, kernel * kernel * c)


def pack_conv_weights(w: jax.Array) -> jax.Array:
    """Conv weights (Kd, Kd, Cin, Cout) -> MVU matrix (Cout, Kd^2*Cin)."""
    kd, kd2, cin, cout = w.shape
    assert kd == kd2
    return jnp.transpose(w, (3, 0, 1, 2)).reshape(cout, kd * kd * cin)


def conv_via_swu_mvu(
    x: jax.Array, w: jax.Array, stride: int = 1, pad: int = 0
) -> jax.Array:
    """Reference conv = SWU + dense MVU matmul (for testing the lowering)."""
    b, h, ww, c = x.shape
    kd = w.shape[0]
    cols = sliding_window(x, kd, stride, pad)  # (B, P, K)
    wm = pack_conv_weights(w)  # (N, K)
    out = jnp.einsum("bpk,nk->bpn", cols.astype(jnp.float32), wm.astype(jnp.float32))
    oh = out_dim(h, kd, stride, pad)
    ow = out_dim(ww, kd, stride, pad)
    return out.reshape(b, oh, ow, w.shape[-1])
