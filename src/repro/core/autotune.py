"""Empirical folding autotuner: design-space search over Pallas tile schedules.

The paper's central exercise is a *sweep*: PE x SIMD folding and weight
codings are enumerated per layer and the hand-scheduled implementation wins
exactly when its schedule matches the problem size.  The runtime analog of
that sweep lives here.  Instead of picking every kernel schedule from the
one-shot ``choose_folding`` + ``to_tpu_blocks`` heuristic (frozen
``block_m=128 / block_n=128 / block_k=512`` style defaults that pad small
layers up to full MXU tiles), the autotuner

  1. enumerates candidate schedules per MVU/conv node from the layer's
     folding divisors (``folding.block_candidates``) plus the
     pallas-vs-xla backend axis,
  2. prunes them with the analytic resource model: candidates whose VMEM
     working set exceeds the budget are rejected outright, the survivors
     are ordered by predicted cycles so measurement starts from the
     model's best guess,
  3. measures the shortlist with the paired interleaved timer
     (``benchmarks/common.py``) against the heuristic schedule, keeping
     only bit-exact winners,
  4. records winners in a persistent JSON cache keyed by
     ``(device kind, op/conv-geometry, mode, N, K, epilogue form,
     n_pixels)``.

``tune_graph`` annotates every node of a lowered graph with its tuned
blocks; ``FusedEngine(tune="cache")`` consumes committed results with zero
measurement at load time, ``tune="auto"`` fills misses by measuring.
``tune_engine`` extends the search one level up: the engine's microbatch
tile is itself a design dimension (FINN's FIFO depth analog) and gets its
own cache entry keyed by the graph signature.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time

import jax
import numpy as np

from repro.core import ir
from repro.core.folding import Folding, block_candidates, divisors
from repro.core.ir import Graph, Node
from repro.core.mvu import KernelBlocks, MVUConfig
from repro.core.resource_model import VMEM_BYTES, mvu_resources
from repro.core.swu import out_dim
from repro.kernels import ops, packing
from repro.kernels.packing import WORD_BITS
from repro.kernels.swu_mvu import conv_rows_per_tile, conv_vmem_bytes

CACHE_VERSION = 1
# user-side persistent cache (the committed defaults ship in repro.configs)
DEFAULT_CACHE_PATH = os.path.join("experiments", "autotune", "cache.json")
CACHE_PATH_ENV = "REPRO_AUTOTUNE_CACHE"


# --------------------------------------------------------------------- keys
def device_kind() -> str:
    """Stable schedule-cache device key, e.g. ``cpu`` or ``tpu-v5e``."""
    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # pragma: no cover - no devices at all
        kind = jax.default_backend()
    return str(kind).strip().lower().replace(" ", "-")


def epilogue_form(params) -> str:
    """``thresh`` / ``scale`` / ``raw`` -- the MVTU epilogue variant."""
    if params is None:
        return "raw"
    if getattr(params, "thresholds", None) is not None:
        return "thresh"
    if getattr(params, "out_scale", None) is not None:
        return "scale"
    return "raw"


def op_tag(node: Node, in_shape: tuple | None = None) -> str:
    """Distinguish op kind and conv geometry in cache keys.

    Dense nodes are all ``mvu``; conv nodes with the same (mode, N, K,
    n_pixels) can still differ in kernel/stride/pad and the resident input
    image -- the schedule tuned (and VMEM-pruned) for one geometry must not
    be applied to another.
    """
    if node.op != "conv_mvu":
        return "mvu"
    kd, st, pd = node.attrs["kernel"], node.attrs["stride"], node.attrs["pad"]
    hwc = "x".join(str(d) for d in (in_shape or ()))
    return f"conv{kd}s{st}p{pd}@{hwc}"


def node_key(cfg: MVUConfig, *, epilogue: str = "raw", n_pixels: int = 1,
             device: str | None = None, op: str = "mvu") -> str:
    # None = the live host; "" is a valid (device-less) scope used by
    # engine_key's digest parts and must NOT fall back to device_kind()
    device = device_kind() if device is None else device
    key = "|".join([
        device, op, cfg.mode, f"n{cfg.out_features}", f"k{cfg.in_features}",
        epilogue, f"px{n_pixels}",
    ])
    # packed-datapath configs get their own key space: a schedule tuned for
    # bit-packed weight storage must never alias the canonical one.  The
    # suffix is appended only when packed, so every committed (unpacked)
    # cache entry and engine digest stays valid.
    return key + "|packed" if cfg.packed else key


def graph_node_keys(graph: Graph, *, device: str | None = None) -> list[str]:
    """Schedule-cache keys for every tunable node of a lowered graph.

    One key per finalized ``mvu``/``conv_mvu`` node, in chain order -- the
    exact keys :func:`tune_graph` will look up (same shape propagation,
    same epilogue/op tagging).  The build pipeline's cache-hit accounting
    and the design-space explorer's warm-sweep assertions both consume
    this instead of re-deriving the key recipe.
    """
    keys: list[str] = []
    for node, ins, out_shape in ir.io_shapes(graph):
        if node.op not in ("mvu", "conv_mvu") or "mvu" not in node.params:
            continue
        in_shape = ins[0] if ins else None
        keys.append(node_key(
            node.attrs["config"],
            epilogue=epilogue_form(node.params["mvu"]),
            n_pixels=ir.n_pixels(out_shape), device=device,
            op=op_tag(node, in_shape)))
    return keys


def cycle_time_key(device: str | None = None) -> str:
    """Cache key for the measured wall-clock seconds per schedule cycle.

    Recorded by ``repro.serving.batcher.calibrate_cycle_time``; consumed by
    ``dataflow.interval_seconds`` to turn the steady-state interval into the
    serving batcher's flush time budget.
    """
    device = device_kind() if device is None else device
    return f"cycletime|{device}"


def engine_key(graph: Graph, *, device: str | None = None) -> str:
    """Cache key for engine-level (microbatch) tuning of one stage chain.

    The digest is built from device-less node keys, so the same graph gets
    the same digest on every host and only the ``engine|<device>|`` prefix
    scopes the entry -- a ``device`` override therefore resolves entries
    recorded on another machine.
    """
    device = device_kind() if device is None else device
    parts = []
    for node, ins, out_shape in ir.io_shapes(graph):
        if node.op in ("mvu", "conv_mvu") and "mvu" in node.params:
            cfg = node.attrs["config"]
            in_shape = ins[0] if ins else None
            parts.append(node_key(cfg, epilogue=epilogue_form(node.params["mvu"]),
                                  n_pixels=ir.n_pixels(out_shape), device="",
                                  op=op_tag(node, in_shape)))
    digest = hashlib.sha1("~".join(parts).encode()).hexdigest()[:12]
    return f"engine|{device}|{digest}"


# -------------------------------------------------------------------- cache
class ScheduleCache:
    """Persistent key -> schedule-entry store (JSON on disk).

    Entries are plain dicts (backend + block shapes + bookkeeping) so the
    cache file diffs cleanly and can be committed / uploaded as a CI
    artifact.  ``merge`` lets the committed per-config defaults
    (``repro.configs.*.TUNED_SCHEDULES``) and a user cache coexist.
    """

    def __init__(self, entries: dict | None = None, path: str | None = None):
        self.entries: dict[str, dict] = {k: dict(v) for k, v in (entries or {}).items()}
        self.path = path

    def get(self, key: str) -> dict | None:
        return self.entries.get(key)

    def put(self, key: str, entry: dict) -> None:
        self.entries[key] = dict(entry)

    def merge(self, other: "ScheduleCache") -> "ScheduleCache":
        self.entries.update({k: dict(v) for k, v in other.entries.items()})
        return self

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    @classmethod
    def load(cls, path: str) -> "ScheduleCache":
        with open(path) as f:
            payload = json.load(f)
        if payload.get("version") != CACHE_VERSION:
            raise ValueError(
                f"autotune cache {path} has version {payload.get('version')!r}, "
                f"expected {CACHE_VERSION}")
        return cls(payload.get("entries", {}), path=path)

    def save(self, path: str | None = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("no cache path to save to")
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": self.entries},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        self.path = path
        return path


def default_cache() -> ScheduleCache:
    """Committed tuned defaults (configs) + the local persistent cache.

    The per-config ``TUNED_SCHEDULES`` dicts ship in the package (zero I/O,
    zero measurement to consume); a user cache file -- ``$REPRO_AUTOTUNE_CACHE``
    or ``experiments/autotune/cache.json`` -- overrides them when present.
    """
    cache = ScheduleCache()
    from repro.configs import cnv_bnn, nid_mlp

    for mod in (nid_mlp, cnv_bnn):
        cache.merge(ScheduleCache(getattr(mod, "TUNED_SCHEDULES", {})))
    path = os.environ.get(CACHE_PATH_ENV, DEFAULT_CACHE_PATH)
    if os.path.exists(path):
        cache.merge(ScheduleCache.load(path))
        cache.path = path
    return cache


# --------------------------------------------------------------- candidates
@dataclasses.dataclass(frozen=True)
class Candidate:
    backend: str
    blocks: KernelBlocks
    predicted_cycles: int
    vmem_bytes: int
    packed: bool = False  # bit-packed weight storage + packed kernel family

    def entry(self, **extra) -> dict:
        out = {
            "backend": self.backend,
            **dataclasses.asdict(self.blocks),
            "predicted_cycles": int(self.predicted_cycles),
            **extra,
        }
        if self.packed:  # legacy (unpacked) entries stay byte-identical
            out["packed"] = True
        return out


def _blocks_folding(blocks: KernelBlocks, mode: str,
                    packed: bool = False) -> Folding:
    """The folding a block schedule *acts* as (PE=block_n, SIMD=K step)."""
    if mode == "xnor" or (packed and mode == "binary"):
        simd = blocks.block_kw * WORD_BITS
    else:
        simd = blocks.block_k
    return Folding(blocks.block_n, simd)


def packable(cfg: MVUConfig) -> bool:
    """Whether the packed datapath exists for this config's weight coding.

    All 1-bit codings pack into uint32 bitplanes; standard weights pack
    into 2-bit lanes only when they actually fit signed 2 bits.
    """
    return cfg.mode in ("xnor", "binary") or cfg.weight_bits <= 2


def natively_packed(cfg: MVUConfig, backend: str) -> bool:
    """Whether this (coding, backend) kernel already IS the packed datapath.

    The xnor Pallas kernel consumes packed uint32 words for both operands
    (the paper's Fig. 4a XNOR/popcount array) -- its candidates carry
    ``packed=True`` so the tuned entry records the datapath that actually
    ran, and the canonical comparator stays the unpack+matmul XLA path.
    """
    return cfg.mode == "xnor" and backend == "pallas"


def enumerate_candidates(
    cfg: MVUConfig,
    *,
    n_pixels: int = 1,
    n_thresh: int = 0,
    in_shape: tuple | None = None,
    conv: dict | None = None,
    vmem_bytes: int = VMEM_BYTES,
    max_measure: int = 8,
) -> list[Candidate]:
    """Model-pruned, cycle-ordered shortlist for one node.

    Every candidate whose VMEM working set exceeds ``vmem_bytes`` is
    rejected; the survivors are ordered by the analytic cycle model (best
    guess first) and capped at ``max_measure`` pallas schedules.  The
    heuristic schedule and the XLA backend are always appended so the
    search space contains the status quo and the compiler path.
    """
    n, k = cfg.out_features, cfg.in_features
    cands: list[Candidate] = []
    if conv is not None:
        # fused conv kernel: full-K dot per step; the schedule is block_n x
        # rows_per_tile (block_m only acts through the derived row tile, so
        # it is pinned explicitly on the candidate)
        h, w, c = in_shape
        oh = out_dim(h, conv["kernel"], conv["stride"], conv["pad"])
        ow = out_dim(w, conv["kernel"], conv["stride"], conv["pad"])
        for bm in (32, 128, 256):
            for bn in sorted({max(8, d) for d in divisors(n)} | {128}):
                if bn > 512:
                    continue
                vm = conv_vmem_bytes(
                    h, w, c, n, k, kernel=conv["kernel"], stride=conv["stride"],
                    pad=conv["pad"], block_m=bm, block_n=bn, n_thresh=n_thresh)
                blocks = KernelBlocks(
                    block_m=bm, block_n=bn,
                    rows_per_tile=conv_rows_per_tile(oh, ow, bm))
                cyc = Folding(bn, k).cycles(n, k, n_pixels)
                cands.append(Candidate("pallas", blocks, cyc, vm))
    else:
        # joint folding x packing space: each legal tile schedule exists
        # once per weight-storage form the coding supports (the xnor Pallas
        # kernel is natively packed, so its packed variant would duplicate)
        packed_axes = [False]
        if packable(cfg) and cfg.mode != "xnor":
            packed_axes.append(True)
        for pk in packed_axes:
            for blk in block_candidates(n, k, cfg.mode, packed=pk):
                blocks = KernelBlocks.from_blocks(blk)
                fold = _blocks_folding(blocks, cfg.mode, pk)
                res = mvu_resources(
                    n, k, fold, mode=cfg.mode, weight_bits=cfg.weight_bits,
                    act_bits=cfg.act_bits, n_pixels=n_pixels,
                    block_m=blocks.block_m, n_thresh=n_thresh,
                    blocks=blocks.as_kwargs(cfg.mode, pk), packed=pk)
                cands.append(Candidate(
                    "pallas", blocks, res.cycles, res.lut_bytes,
                    packed=pk or natively_packed(cfg, "pallas")))

    survivors = [c for c in cands if c.vmem_bytes <= vmem_bytes]
    survivors.sort(key=lambda c: (c.predicted_cycles, c.vmem_bytes))
    survivors = survivors[:max_measure]

    heur = KernelBlocks.from_blocks(
        {**{"block_m": cfg.block_m}, **cfg.kernel_blocks()})
    heur_cycles = cfg.resolved_folding().cycles(n, k, n_pixels)
    if not any(c.blocks == heur for c in survivors):
        survivors.append(Candidate("pallas", heur, heur_cycles, 0,
                                   packed=natively_packed(cfg, "pallas")))
    # the XLA backend is one more point in the design space: on hosts where
    # the compiler's schedule beats interpret-mode Pallas (every CPU), the
    # empirical search must be allowed to find that out.
    survivors.append(Candidate("xla", heur, heur_cycles, 0))
    if conv is None and packable(cfg):
        # ... and so is the packed datapath compiled by XLA (the blocked
        # XNOR popcount path in particular is the memory-bandwidth-bound
        # fast path on large N*K layers) -- always in the measured set so
        # the packed-vs-unpacked decision is empirical, never assumed.
        survivors.append(Candidate("xla", heur, heur_cycles, 0, packed=True))
    return survivors


# -------------------------------------------------------------------- timer
def paired_times(fn_a, fn_b, *args, reps: int = 3, warmup: int = 1):
    """Paired interleaved A/B timer: ``(t_a, t_b, speedup_of_b_over_a)``.

    Each rep times both callables back-to-back, so environmental slowdowns
    (noisy CI neighbors, frequency scaling) hit both sides of the ratio;
    the reported speedup is the median of per-rep ratios, and the times are
    the per-side minima (the stable one-sided-noise estimator).  This is
    the single canonical estimator -- ``benchmarks.common`` re-exports it,
    so the tuner and the CI regression gate always measure the same way.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    tas, tbs, ratios = [], [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        ta = time.perf_counter() - t0
        t1 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        tb = time.perf_counter() - t1
        tas.append(ta)
        tbs.append(tb)
        ratios.append(ta / tb)
    return float(np.min(tas)), float(np.min(tbs)), float(np.median(ratios))


# the name tune_node/tune_engine resolve (and tests stub) at call time
paired_timer = paired_times


# -------------------------------------------------------------- measurement
def _synth_activations(cfg: MVUConfig, m: int, in_shape: tuple | None,
                       conv: dict | None, seed: int = 0):
    rng = np.random.default_rng(seed)
    import jax.numpy as jnp

    if conv is not None:
        # one image: the engine streams conv stages in single-image
        # microbatches (the conv bottleneck sets tile=1), so candidates
        # must be measured in that regime, not on a large batch
        h, w, c = in_shape
        lo, hi = (0, 2) if cfg.mode == "xnor" else (0, 2**cfg.act_bits)
        return jnp.asarray(rng.integers(lo, hi, (1, h, w, c)), jnp.int32)
    k = cfg.in_features
    if cfg.mode == "xnor":
        bits = jnp.asarray(rng.integers(0, 2, (m, k)), jnp.int32)
        return packing.pack_bits(bits)
    if cfg.mode == "binary":
        return jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)
    return jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)


def _node_fn(cfg: MVUConfig, params, cand: Candidate, conv: dict | None):
    blocks = cand.blocks.as_kwargs(cfg.mode, cand.packed)
    if conv is not None:
        def fn(x):
            return ops.conv_mvu(
                x, params.weights, kernel=conv["kernel"], stride=conv["stride"],
                pad=conv["pad"], mode=cfg.mode,
                k_bits=cfg.in_features if cfg.mode == "xnor" else None,
                thresholds=params.thresholds, out_scale=params.out_scale,
                backend=cand.backend, **blocks)
        return fn

    if cand.packed:
        # pack once outside the timed fn -- at run time the packed storage
        # is what lives in HBM (the pack_weights build rewrite).  Configs
        # already rewritten by that step carry packed weights as-is.
        from repro.kernels.mvu_packed import pack_mvu_weights

        w_packed = (params.weights if cfg.packed
                    else pack_mvu_weights(params.weights, cfg.mode))

        def fn(x):
            return ops.mvu(
                x, w_packed, cfg.mode, k_bits=cfg.in_features,
                thresholds=params.thresholds, out_scale=params.out_scale,
                backend=cand.backend, packed=True, **blocks)
        return fn

    def fn(x):
        return ops.mvu(
            x, params.weights, cfg.mode,
            k_bits=cfg.in_features if cfg.mode == "xnor" else None,
            thresholds=params.thresholds, out_scale=params.out_scale,
            backend=cand.backend, **blocks)
    return fn


def tune_node(
    node: Node,
    in_shape: tuple | None = None,
    *,
    vmem_bytes: int = VMEM_BYTES,
    sample_m: int = 256,
    reps: int = 3,
    max_measure: int = 8,
    margin: float = 0.05,
    timer=None,
    seed: int = 0,
    allow_packed: bool = True,
) -> dict:
    """Measure the pruned shortlist for one finalized mvu/conv_mvu node.

    Returns the winning cache entry.  Candidates whose output is not
    bit-exact with the heuristic schedule are discarded -- tuning must
    never trade correctness for speed -- and a challenger must beat the
    incumbent by ``margin`` (paired timing still jitters a few percent on
    shared hosts; a noise-driven "win" would churn the cache for nothing).
    """
    timer = timer if timer is not None else paired_timer
    cfg: MVUConfig = node.attrs["config"]
    params = node.params["mvu"]
    conv = None
    n_pixels = 1
    if node.op == "conv_mvu":
        conv = {k: node.attrs[k] for k in ("kernel", "stride", "pad")}
        out_shape = ir.propagate(node, in_shape)
        n_pixels = ir.n_pixels(out_shape)
    t = params.thresholds
    n_thresh = 0 if t is None else int(t.shape[-1])
    cands = enumerate_candidates(
        cfg, n_pixels=n_pixels, n_thresh=n_thresh, in_shape=in_shape,
        conv=conv, vmem_bytes=vmem_bytes, max_measure=max_measure)

    x = _synth_activations(cfg, sample_m, in_shape, conv, seed=seed)
    base_blocks = KernelBlocks.from_blocks(
        {**{"block_m": cfg.block_m}, **cfg.kernel_blocks()})
    base_cycles = cfg.resolved_folding().cycles(
        cfg.out_features, cfg.in_features, n_pixels)
    base = Candidate(cfg.backend, base_blocks, base_cycles, 0,
                     packed=cfg.packed or natively_packed(cfg, cfg.backend))
    base_fn = _node_fn(cfg, params, base, conv)
    want = np.asarray(base_fn(x))

    if conv is not None:
        oh = out_dim(in_shape[0], conv["kernel"], conv["stride"], conv["pad"])
        ow = out_dim(in_shape[1], conv["kernel"], conv["stride"], conv["pad"])

    def effective(c: Candidate) -> tuple:
        """What the kernel actually consumes -- candidates that differ only
        in ignored fields (conv ignores the K blocks, block_m acts through
        rows_per_tile) must not be timed against each other."""
        if conv is not None:
            rt = c.blocks.rows_per_tile or conv_rows_per_tile(
                oh, ow, c.blocks.block_m)
            return (c.backend, c.blocks.block_n, rt)
        kw = c.blocks.as_kwargs(cfg.mode, c.packed)
        kw.pop("rows_per_tile", None)
        # packed xnor runs the same Pallas kernel but a different XLA path,
        # so the storage axis is part of the effective identity throughout
        return (c.backend, c.packed, tuple(sorted(kw.items())))

    best, best_speed = base, 1.0
    measured = 0
    seen_eff = {effective(base)}
    for cand in cands:
        if cfg.packed and not cand.packed:
            continue  # packed storage cannot feed the canonical kernels
        if cand.packed and not allow_packed and cfg.mode != "xnor":
            continue  # pack="never": storage rewrite is policy-excluded
        if effective(cand) in seen_eff:
            continue
        seen_eff.add(effective(cand))
        fn = _node_fn(cfg, params, cand, conv)
        if not np.array_equal(np.asarray(fn(x)), want):
            continue  # never accept a schedule that changes the numbers
        _, _, speedup = timer(base_fn, fn, x, reps=reps)
        measured += 1
        if speedup > best_speed * (1.0 + margin):
            best, best_speed = cand, speedup
    return best.entry(
        speedup=float(best_speed),
        measured_candidates=measured,
        epilogue=epilogue_form(params),
        n_pixels=int(n_pixels),
    )


def apply_entry(cfg: MVUConfig, entry: dict) -> MVUConfig:
    """Pin a cache entry's schedule onto an MVUConfig.

    An entry carrying ``"packed": true`` selects the bit-packed datapath;
    the weight storage itself is rewritten later by the ``pack_weights``
    build step (``repro.core.lowering.pack_weights``).
    """
    blocks = KernelBlocks.from_blocks(entry)
    return MVUConfig(**{
        **cfg.__dict__,
        "backend": entry.get("backend", cfg.backend),
        "packed": bool(entry.get("packed", cfg.packed)),
        "blocks": blocks,
        "block_m": blocks.block_m,
    })


def tune_graph(
    graph: Graph,
    *,
    cache: ScheduleCache | None = None,
    mode: str = "cache",
    device: str | None = None,
    timer=None,
    vmem_bytes: int = VMEM_BYTES,
    allow_packed: bool = True,
    **tune_kwargs,
) -> Graph:
    """Annotate every finalized mvu/conv_mvu node with its tuned schedule.

    ``mode="cache"`` is a pure lookup: hits rewrite the node's config,
    misses keep the heuristic schedule, nothing is ever measured.
    ``mode="auto"`` measures misses via :func:`tune_node` and fills the
    cache.  Returns a new graph (input nodes are shared, rewritten nodes
    are fresh ``Node`` objects) so the caller's graph keeps its heuristic
    configs.
    """
    if mode not in ("cache", "auto"):
        raise ValueError(f"tune mode must be 'cache' or 'auto', got {mode!r}")
    cache = cache if cache is not None else default_cache()
    out: Graph = ir.Graph()
    for node, ins, out_shape in ir.io_shapes(graph):
        if node.op not in ("mvu", "conv_mvu") or "mvu" not in node.params:
            out.append(node)
            continue
        in_shape = ins[0] if ins else None
        cfg: MVUConfig = node.attrs["config"]
        if cfg.packed and cfg.blocks is not None:
            # the node already carries a tuned packed schedule (a prior
            # pass ran apply_entry); looking it up again under the
            # ``|packed``-suffixed key would re-measure on every
            # downstream pass and duplicate the entry in the cache
            out.append(node)
            continue
        key = node_key(cfg, epilogue=epilogue_form(node.params["mvu"]),
                       n_pixels=ir.n_pixels(out_shape), device=device,
                       op=op_tag(node, in_shape))
        entry = cache.get(key)
        if (entry is not None and entry.get("packed")
                and not allow_packed and cfg.mode != "xnor"):
            # pack="never" policy: a cached packed-datapath winner would
            # need the storage rewrite the build config forbids, so the
            # node keeps its heuristic schedule (xnor storage is packed
            # words either way -- its entries apply under any policy)
            entry = None
        elif entry is None and mode == "auto":
            entry = tune_node(node, in_shape, timer=timer,
                              vmem_bytes=vmem_bytes,
                              allow_packed=allow_packed, **tune_kwargs)
            cache.put(key, entry)
        if entry is None:
            out.append(node)
            continue
        out.append(Node(node.op, node.name,
                        {**node.attrs, "config": apply_entry(cfg, entry)},
                        node.params, inputs=node.inputs))
    return out


# ------------------------------------------------------------ engine level
def synth_input(graph: Graph, batch: int, seed: int = 0):
    """Random integer activations matching the graph's input node."""
    import jax.numpy as jnp

    heads = [n for n in graph if n.op == "input"]
    if len(heads) != 1:
        raise ValueError(
            f"graph must have exactly one input node, found {len(heads)}")
    head = heads[0]
    shape = tuple(head.attrs["shape"])
    bits = head.attrs.get("bits", 1)
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**bits, (batch, *shape)), jnp.int32)


def tune_engine(
    graph: Graph,
    batch: int,
    *,
    cache: ScheduleCache,
    device: str | None = None,
    tiles: tuple[int, ...] | None = None,
    reps: int = 5,
    margin: float = 0.1,
    timer=None,
    seed: int = 0,
) -> dict:
    """Search the engine-level microbatch tile (FINN FIFO-depth analog).

    Builds cache-tuned engines over the candidate tiles, times each against
    the heuristic plan with the paired timer, and records the winner under
    :func:`engine_key`.  The per-node schedules must already be in
    ``cache`` (run :func:`tune_graph` in auto mode first).  Whole-engine
    timings jitter more than kernel timings, so a challenger tile must beat
    the incumbent by ``margin`` before it displaces the heuristic plan.
    """
    from repro.core.engine import FusedEngine

    timer = timer if timer is not None else paired_timer
    # the baseline (and every candidate) must run the node-tuned schedules
    # WITHOUT any engine-level entry: a previous tune_engine result in
    # ``cache`` would otherwise contaminate the heuristic plan and the
    # recorded speedup would silently become relative-to-last-tuning
    node_cache = ScheduleCache({k: v for k, v in cache.entries.items()
                                if not k.startswith("engine|")})
    base = FusedEngine(graph, tune="cache", cache=node_cache)
    heur_tile = base.plan(batch).microbatch
    if tiles is None:
        tiles = tuple(sorted({heur_tile, heur_tile * 2, heur_tile * 4,
                              heur_tile * 8, batch}))
    x = synth_input(graph, batch, seed=seed)
    want = np.asarray(base(x))

    best_tile, best_speed = heur_tile, 1.0
    for tile in tiles:
        if tile == heur_tile or tile < 1:
            continue
        cand = FusedEngine(graph, tune="cache", cache=node_cache)
        cand._tile = int(tile)
        if not np.array_equal(np.asarray(cand(x)), want):
            continue
        _, _, speedup = timer(base, cand, x, reps=reps)
        if speedup > best_speed * (1.0 + margin):
            best_tile, best_speed = int(tile), speedup
    entry = {"microbatch": int(best_tile), "speedup": float(best_speed),
             "batch": int(batch)}
    cache.put(engine_key(base.graph, device=device), entry)
    return entry
