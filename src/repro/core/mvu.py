"""The MVU layer: FINN's Matrix-Vector-Threshold Unit as a JAX module.

Two facings:

* :class:`MVULayer` -- the faithful FINN unit. Integer/bit tensors in,
  integer activations out through the fused multi-threshold epilogue.
  This is what the NID example and the paper-sweep benchmarks instantiate.

* :func:`quantized_linear` -- the LM-framework facing: float activations
  are dynamically quantized, pushed through the integer MVU datapath, and
  dequantized.  This is how the paper's engine becomes a first-class
  ``Linear`` backend for the ten assigned architectures (W8A8 / W4A4 /
  binary / xnor projections).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.folding import Folding, choose_folding, to_tpu_blocks
from repro.core.quantize import QTensor, int_bounds, quantize_weights
from repro.core.resource_model import MVUResources, mvu_resources
from repro.core.thresholds import integerize_thresholds
from repro.kernels import ops, packing


@dataclasses.dataclass(frozen=True)
class KernelBlocks:
    """An explicit Pallas tile schedule for one MVU instance.

    ``to_tpu_blocks`` derives one of these from a (PE, SIMD) folding; the
    autotuner (``repro.core.autotune``) instead searches the legal schedule
    space and pins the winner here.  Hashable so tuned configs stay usable
    as set/dict members like untuned ones.
    """

    block_m: int = 128
    block_n: int = 128
    block_k: int = 128
    block_kw: int = 8  # packed-word K step (xnor datapath only)
    rows_per_tile: int | None = None  # conv line-buffer rows per grid step

    def as_kwargs(self, mode: str, packed: bool = False) -> dict[str, int]:
        """The kwargs the kernel entry points take (uniform plumbing: the
        dense path ignores ``rows_per_tile``, the conv path ignores the K
        blocks -- both accept the full set).  The packed binary datapath
        steps K in 32-bit words like xnor, so it takes ``block_kw``."""
        if mode == "xnor" or (packed and mode == "binary"):
            out = {"block_m": self.block_m, "block_n": self.block_n,
                   "block_kw": self.block_kw}
        else:
            out = {"block_m": self.block_m, "block_n": self.block_n,
                   "block_k": self.block_k}
        if self.rows_per_tile is not None:
            out["rows_per_tile"] = self.rows_per_tile
        return out

    @classmethod
    def from_blocks(cls, blocks: dict) -> "KernelBlocks":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: int(v) for k, v in blocks.items()
                      if k in known and v is not None})


@dataclasses.dataclass(frozen=True)
class MVUConfig:
    in_features: int  # K = Kd^2 * I_c
    out_features: int  # N = O_c
    mode: str = "standard"  # xnor | binary | standard
    weight_bits: int = 4
    act_bits: int = 4  # output activation precision when thresholds are used
    folding: Folding | None = None  # None = fully parallel tile defaults
    backend: str = "pallas"
    packed: bool = False  # bit-packed weight storage + packed datapath
    block_m: int = 128
    blocks: KernelBlocks | None = None  # explicit (tuned) schedule wins

    def resolved_folding(self) -> Folding:
        if self.folding is not None:
            # An explicit folding is a schedule claim: PE | N and SIMD | K
            # (FINN's legality condition).  Reject illegal choices here, at
            # config time, instead of letting them silently mis-tile.
            self.folding.validate(self.out_features, self.in_features)
            return self.folding
        return choose_folding(self.out_features, self.in_features)

    def kernel_blocks(self) -> dict[str, int]:
        if self.blocks is not None:
            return self.blocks.as_kwargs(self.mode, self.packed)
        return to_tpu_blocks(self.resolved_folding(), self.mode, self.block_m,
                             packed=self.packed)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MVUParams:
    """Deployed (post-streamlining) parameters of one MVU instance."""

    weights: jax.Array  # xnor: packed (N, Wd) uint32; else (N, K) int8
    thresholds: jax.Array | None  # (N, T) int32, ascending
    out_scale: jax.Array | None  # (N,) float32 dequant scale

    def tree_flatten(self):
        return (self.weights, self.thresholds, self.out_scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


class MVULayer:
    def __init__(self, config: MVUConfig):
        self.config = config

    def init_params(self, key: jax.Array) -> MVUParams:
        """Random integer weights on the mode's grid (tests/benchmarks)."""
        cfg = self.config
        n, k = cfg.out_features, cfg.in_features
        if cfg.mode == "xnor":
            bits = jax.random.bernoulli(key, 0.5, (n, k)).astype(jnp.int32)
            w = packing.pack_bits(bits)
        elif cfg.mode == "binary":
            w = jax.random.bernoulli(key, 0.5, (n, k)).astype(jnp.int8)
        else:
            lo, hi = int_bounds(cfg.weight_bits, signed=True)
            w = jax.random.randint(key, (n, k), lo, hi + 1, jnp.int8)
        if cfg.packed:
            from repro.kernels.mvu_packed import pack_mvu_weights

            w = pack_mvu_weights(w, cfg.mode)
        return MVUParams(weights=w, thresholds=None, out_scale=None)

    @staticmethod
    def from_float(
        config: MVUConfig,
        w_float: jax.Array,
        thresholds: jax.Array | None = None,
    ) -> tuple[MVUParams, QTensor]:
        """Quantize trained float weights (N, K) onto the MVU grid."""
        qt = quantize_weights(w_float, 1 if config.mode in ("xnor", "binary") else config.weight_bits)
        if config.mode == "xnor":
            w = packing.pack_bits(packing.bipolar_to_bits(qt.values))
        elif config.mode == "binary":
            w = packing.bipolar_to_bits(qt.values).astype(jnp.int8)
        else:
            w = qt.values
        if config.packed:
            from repro.kernels.mvu_packed import pack_mvu_weights

            w = pack_mvu_weights(w, config.mode)
        t = None if thresholds is None else integerize_thresholds(thresholds)
        scale = None if t is not None else qt.scale.reshape(-1).astype(jnp.float32)
        return MVUParams(weights=w, thresholds=t, out_scale=scale), qt

    def __call__(self, params: MVUParams, x: jax.Array) -> jax.Array:
        """x: (..., K) ints (standard/binary) or (..., Wd) packed (xnor)."""
        cfg = self.config
        w = params.weights
        if cfg.packed and cfg.mode != "xnor" and w.dtype == jnp.int8:
            # packed datapath selected but storage not yet rewritten --
            # the window between the tune step (apply_entry flips the
            # flag) and the pack_weights step (rewrites storage).  Pack on
            # the fly so the graph stays runnable/verifiable throughout.
            from repro.kernels.mvu_packed import pack_mvu_weights

            w = pack_mvu_weights(w, cfg.mode)
        lead = x.shape[:-1]
        xm = x.reshape(-1, x.shape[-1])
        out = ops.mvu(
            xm,
            w,
            cfg.mode,
            k_bits=(cfg.in_features
                    if cfg.mode == "xnor" or cfg.packed else None),
            thresholds=params.thresholds,
            out_scale=params.out_scale,
            backend=cfg.backend,
            packed=cfg.packed,
            **self.config.kernel_blocks(),
        )
        return out.reshape(*lead, cfg.out_features)

    def resources(self, n_pixels: int = 1) -> MVUResources:
        cfg = self.config
        t = 2**cfg.act_bits - 1
        return mvu_resources(
            cfg.out_features,
            cfg.in_features,
            cfg.resolved_folding(),
            mode=cfg.mode,
            weight_bits=cfg.weight_bits,
            act_bits=cfg.act_bits,
            n_pixels=n_pixels,
            block_m=cfg.block_m,
            n_thresh=t,
            blocks=cfg.kernel_blocks(),  # tuned schedules model what they run
            packed=cfg.packed,
        )


def quantized_linear(
    x: jax.Array,
    w_q: QTensor,
    *,
    act_bits: int = 8,
    backend: str = "xla",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """Float-facing MVU linear: y = x @ W_q^T with dynamic act quantization.

    x: (..., K) float; w_q: symmetric-int QTensor (N, K) with per-channel
    scale.  Activations get one dynamic per-tensor scale (abs-max), the
    integer MVU kernel runs the dot product, and the epilogue dequantizes.
    backend="xla" is the GSPMD-friendly path used by the sharded models;
    backend="pallas" runs the hand-scheduled kernel.
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    xm = x.reshape(-1, k)
    lo, hi = int_bounds(act_bits, signed=True)
    a_scale = jnp.maximum(jnp.max(jnp.abs(xm)), 1e-6) / hi
    a_int = jnp.clip(jnp.round(xm / a_scale), lo, hi).astype(jnp.int8)

    if w_q.bits == 1:
        w_bits = packing.bipolar_to_bits(w_q.values).astype(jnp.int8)
        out = ops.mvu(
            a_int, w_bits, "binary",
            out_scale=w_q.scale.reshape(-1).astype(jnp.float32),
            backend=backend, block_m=block_m, block_n=block_n, block_k=block_k,
        )
    else:
        out = ops.mvu(
            a_int, w_q.values, "standard",
            out_scale=w_q.scale.reshape(-1).astype(jnp.float32),
            backend=backend, block_m=block_m, block_n=block_n, block_k=block_k,
        )
    y = out * a_scale
    return y.reshape(*lead, -1).astype(x.dtype)
