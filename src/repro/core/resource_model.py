"""Analytical resource model -- the TPU analog of the paper's LUT/FF/BRAM
counts (Section 6.2) and cycle/critical-path analysis (6.3).

The RTL implementation's virtue in the paper is that its costs are
*predictable by construction* (explicit cycle-accurate schedule), while the
HLS side must be measured after compilation.  We keep that split:

  * this module = the predictable, closed-form model for the hand-scheduled
    Pallas kernel (the "RTL" side);
  * ``compiled.memory_analysis()/cost_analysis()`` on the XLA-compiled
    reference = the measured "HLS" side (see benchmarks/resource_sweep.py).

Metric mapping (DESIGN.md section 2):
    LUT analog   -> VMEM working-set bytes of one grid step (compute fabric)
    FF analog    -> persistent pipeline state (accumulators + control)
    BRAM analog  -> buffered memories: weight store + input buffer bytes
    critical path-> per-grid-step work (MACs) / array peak
    exec cycles  -> folding cycle model (II = 1)
"""

from __future__ import annotations

import dataclasses

from repro.core.folding import (
    Folding,
    input_buffer_depth,
    to_tpu_blocks,
    weight_mem_depth,
)
from repro.kernels.packing import WORD_BITS, num_int2_bytes, num_words

# TPU v5e hardware constants (roofline terms use the same numbers).
PEAK_BF16_FLOPS = 197e12
PEAK_INT8_OPS = 394e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9
VMEM_BYTES = 64 * 2**20  # conservative per-core working budget
CLOCK_HZ = 940e6  # v5e core clock, for cycle -> ns conversions


def _act_bytes(mode: str, bits: int) -> float:
    if mode == "xnor":
        return 1.0 / 8.0
    return 1.0  # int4 carried in int8 on the MXU path


@dataclasses.dataclass(frozen=True)
class MVUResources:
    lut_bytes: int  # VMEM working set per grid step
    ff_bytes: int  # persistent accumulator/control state
    bram_bytes: int  # weight memory + input buffer
    weight_mem_depth: int
    input_buffer_depth: int
    cycles: int
    macs: int
    ns_per_inference: float
    weight_bytes: int = 0  # HBM-resident weight bytes as stored
    canonical_weight_bytes: int = 0  # same weights without packing

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def weight_resident_bytes(n: int, k: int, mode: str, packed: bool) -> int:
    """HBM-resident bytes of one (N, K) weight matrix as actually stored.

    Canonical storage is int8 rows for binary/standard; the xnor coding is
    always bit-packed (its canonical form IS uint32 words).  Packed binary
    stores uint32 bitplanes (8x smaller than int8 rows); packed standard
    stores 4x 2-bit lanes per byte.
    """
    if mode == "xnor" or (packed and mode == "binary"):
        return n * num_words(k) * 4
    if packed:
        return n * num_int2_bytes(k)
    return n * k  # canonical int8 rows


def mvu_resources(
    n: int,
    k: int,
    fold: Folding,
    *,
    mode: str = "standard",
    weight_bits: int = 4,
    act_bits: int = 4,
    n_pixels: int = 1,
    block_m: int = 128,
    n_thresh: int = 0,
    blocks: dict | None = None,
    packed: bool = False,
) -> MVUResources:
    """Closed-form resource estimate for one MVU layer instance.

    The VMEM working set (``lut_bytes``) is computed from the *actual*
    kernel blocks, not the raw folding: ``to_tpu_blocks`` clamps ``block_n``
    and ``block_k`` up to TPU-friendly minima (8 sublanes), and the kernel
    pads K up to a whole number of ``block_k`` steps while keeping the A
    tile full-K resident in int8.  Pass ``blocks`` to estimate an explicit
    (e.g. autotuned) schedule; otherwise the folding's derived blocks are
    used.  ``packed`` models the bit-packed datapath: the weight tile (and
    the HBM-resident ``weight_bytes``) shrink by the packing factor while
    the A tile widens to the padded word span.  BRAM/cycle terms stay on
    the folding abstraction (paper Eq. 1/2).
    """
    wb = weight_bits / 8.0
    ab = _act_bytes(mode, act_bits)
    if blocks is None:
        blocks = to_tpu_blocks(fold, mode, block_m, packed=packed)
    block_m = blocks.get("block_m", block_m)
    bn = blocks["block_n"]

    if mode == "xnor":
        # packed-word datapath: operands live as uint32 words in VMEM
        bkw = blocks.get("block_kw", max(1, fold.simd // WORD_BITS))
        kw = -(-k // WORD_BITS)
        a_tile = block_m * (-(-kw // bkw) * bkw) * 4  # packed input, full K
        w_tile = bn * bkw * 4
    elif packed and mode == "binary":
        # bitplane weights stepped in words; A int8 over the padded span
        bkw = blocks.get("block_kw", max(1, fold.simd // WORD_BITS))
        kw = num_words(k)
        a_tile = block_m * (-(-kw // bkw) * bkw) * WORD_BITS * 1
        w_tile = bn * bkw * 4
    else:
        # int8 operands on the MXU path regardless of logical weight_bits;
        # A is full-K resident, padded up to whole block_k steps
        bk = blocks.get("block_k", max(8, fold.simd))
        a_tile = block_m * (-(-k // bk) * bk) * 1
        # packed standard: the weight tile is 2-bit lanes, 4 per byte
        w_tile = bn * (bk // 4 if packed else bk) * 1
    acc = block_m * bn * 4  # int32 PE accumulators
    thr = bn * n_thresh * 4
    out_tile = block_m * bn * 4

    lut = int(a_tile + w_tile + acc + out_tile + thr)
    ff = int(acc + 64)  # accumulators + FSM/counter state
    weight_store = int(n * k * wb)
    in_buf = int(k * ab)
    bram = weight_store + in_buf

    cycles = fold.cycles(n, k, n_pixels)
    macs = n * k * n_pixels
    ns = cycles / CLOCK_HZ * 1e9
    return MVUResources(
        lut_bytes=lut,
        ff_bytes=ff,
        bram_bytes=bram,
        weight_mem_depth=weight_mem_depth(n, k, fold),
        input_buffer_depth=input_buffer_depth(k, fold),
        cycles=cycles,
        macs=macs,
        ns_per_inference=ns,
        weight_bytes=weight_resident_bytes(n, k, mode, packed),
        canonical_weight_bytes=weight_resident_bytes(n, k, mode, False),
    )


# ---------------------------------------------------------- calibration
def fit_cycle_time(cycles, seconds) -> float:
    """Least-squares seconds-per-cycle over paired (cycles, measured s).

    The analytic model predicts *cycles*; turning them into wall-clock
    needs a realized cycle time.  Fitting one scalar across a whole sweep
    (every node of every design point) is the calibration the paper does
    implicitly when it reads its RTL cycle counts against a known clock:
    ``argmin_s sum_i (c_i * s - m_i)^2  =  sum(c*m) / sum(c^2)``.
    """
    c = [float(v) for v in cycles]
    m = [float(v) for v in seconds]
    if len(c) != len(m) or not c:
        raise ValueError("fit_cycle_time needs equal, non-empty sequences")
    denom = sum(v * v for v in c)
    if denom <= 0:
        raise ValueError("fit_cycle_time needs at least one non-zero cycle count")
    return sum(cv * mv for cv, mv in zip(c, m)) / denom


def cycle_model_errors(cycles, seconds, s_per_cycle: float | None = None
                       ) -> list[float]:
    """Signed relative error of the calibrated cycle model per sample:
    ``(predicted - measured) / measured`` with ``predicted = c * s``."""
    if s_per_cycle is None:
        s_per_cycle = fit_cycle_time(cycles, seconds)
    out = []
    for c, m in zip(cycles, seconds):
        m = float(m)
        if m <= 0:
            raise ValueError("measured seconds must be positive")
        out.append((float(c) * s_per_cycle - m) / m)
    return out


def error_summary(errors) -> dict:
    """Distribution summary of signed relative errors (JSON-safe).

    ``p50/p90/max`` are over |error| -- the calibration claim the CI gate
    holds (``model_error_p90`` in the explore artifact) is "the calibrated
    model lands within X% of the measurement for 90% of (node, design)
    pairs", not a statement about bias direction.
    """
    errs = [float(e) for e in errors]
    if not errs:
        return {"n": 0}
    mags = sorted(abs(e) for e in errs)

    def pct(q: float) -> float:
        idx = min(len(mags) - 1, max(0, int(round(q * (len(mags) - 1)))))
        return mags[idx]

    return {
        "n": len(errs),
        "mean_abs": sum(mags) / len(mags),
        "p50_abs": pct(0.50),
        "p90_abs": pct(0.90),
        "max_abs": mags[-1],
        "mean_signed": sum(errs) / len(errs),
    }


def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    *,
    chips: int,
    peak_flops: float = PEAK_BF16_FLOPS,
    hbm_bw: float = HBM_BW,
    link_bw: float = ICI_BW_PER_LINK,
) -> dict:
    """The three roofline terms (seconds) + dominant bottleneck."""
    compute_s = hlo_flops / (chips * peak_flops)
    memory_s = hlo_bytes / (chips * hbm_bw)
    collective_s = collective_bytes / (chips * link_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        **terms,
        "dominant": dominant.removesuffix("_s"),
        "bound_s": bound,
        "roofline_fraction": (bound / total) if total > 0 else 0.0,
    }
