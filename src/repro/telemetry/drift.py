"""Drift monitor: the paper's critical-path analysis made live.

The build calibrates a cycle model -- per-stage initiation intervals from
``repro.core.dataflow.schedule`` times a measured ``s_per_cycle`` -- and
everything downstream (batcher deadlines, pipeline occupancy, the
EXPERIMENTS tables) trusts it.  ``DriftMonitor`` closes the loop: every
measured interval is compared online against its prediction, per key
(a stage name, a ``replica:N``), and a key whose measured/predicted ratio
leaves the band is *flagged* -- a stalled stage, a FIFO backing up, or a
replica quietly running slower than the model is visible the moment it
happens instead of when a benchmark gate trips.

Two details matter in practice:

* **EWMA, not last-sample**: one noisy host-side hiccup should not flag a
  stage; the exponentially weighted ratio has to leave the band.
* **Censored observations**: a straggling primary whose hedge wins never
  resolves, so its true duration is unobservable -- but its *age so far*
  is a lower bound.  ``observe(..., censored=True)`` accepts such lower
  bounds and only counts ones that are already conclusive (the bound
  alone exceeds the band's high edge).  This is what lets an injected
  straggle replica be flagged even though hedging hides its completions.
"""

from __future__ import annotations

import math

DEFAULT_BAND = (0.5, 3.0)


class DriftMonitor:
    """Online measured-vs-predicted interval tracking with banded flagging.

    predictions: key -> predicted seconds (``observe`` may also pass an
        explicit ``predicted_s``, e.g. per-bucket serving predictions).
    band: (low, high) acceptable measured/predicted ratio; outside on the
        high side means slower than the model, low side faster (a model
        that overestimates is drift too -- FIFO sizing built on it is
        wasteful).
    alpha: EWMA weight of the newest ratio.
    min_samples: observations required for a key before it can flag.
    """

    def __init__(self, predictions: dict[str, float] | None = None, *,
                 band: tuple[float, float] = DEFAULT_BAND,
                 alpha: float = 0.3, min_samples: int = 1):
        lo, hi = band
        if not (0.0 < lo < hi):
            raise ValueError(f"need 0 < band_low < band_high, got {band}")
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"need 0 < alpha <= 1, got {alpha}")
        self.predictions = dict(predictions or {})
        self.band = (float(lo), float(hi))
        self.alpha = alpha
        self.min_samples = min_samples
        self._state: dict[str, dict] = {}
        # keys that were EVER flagged: the live flag clears when the EWMA
        # re-enters the band (recovery), but "did this replica drift at any
        # point in the run" is the question a post-mortem / chaos gate asks
        self._ever: set[str] = set()

    @classmethod
    def from_schedule(cls, schedule, s_per_cycle: float, **kwargs
                      ) -> "DriftMonitor":
        """Predictions from a :class:`DataflowSchedule` and the calibrated
        cycle time: per-stage predicted interval = cycles x s_per_cycle."""
        preds = {s.name: s.cycles * s_per_cycle for s in schedule.stages}
        return cls(preds, **kwargs)

    # ------------------------------------------------------------- recording
    def observe(self, key: str, measured_s: float, *,
                predicted_s: float | None = None,
                censored: bool = False) -> float | None:
        """Record one measured interval; returns the ratio (None if the
        observation was discarded as uninformative).

        ``censored=True`` marks ``measured_s`` as a lower bound on the true
        duration (an unresolved flight's age).  A censored bound inside the
        band proves nothing and is dropped; one already above the high edge
        is conclusive and recorded at its bound value.
        """
        if predicted_s is None:
            predicted_s = self.predictions.get(key)
        if predicted_s is None or predicted_s <= 0 or measured_s < 0:
            return None
        ratio = measured_s / predicted_s
        st = self._state.get(key)
        if censored and ratio <= self.band[1]:
            if st is not None:
                st["censored_dropped"] += 1
            return None
        if st is None:
            st = self._state[key] = {
                "count": 0, "ewma": ratio, "last": ratio,
                "predicted_s": predicted_s,
                "censored_hits": 0, "censored_dropped": 0,
            }
        st["count"] += 1
        st["last"] = ratio
        st["predicted_s"] = predicted_s
        st["ewma"] += self.alpha * (ratio - st["ewma"])
        if censored:
            st["censored_hits"] += 1
            # an accepted censored bound is conclusive on its own (the TRUE
            # duration is at least this far above the band), so it latches
            # the ever-flag even if later clean samples pull the EWMA back
            self._ever.add(key)
        elif st["count"] >= self.min_samples and not self._in_band(st):
            self._ever.add(key)
        return ratio

    # -------------------------------------------------------------- reading
    def _in_band(self, st: dict) -> bool:
        return self.band[0] <= st["ewma"] <= self.band[1]

    def flagged(self) -> list[str]:
        """Keys whose EWMA ratio is outside the band (enough samples seen)."""
        return sorted(k for k, st in self._state.items()
                      if st["count"] >= self.min_samples
                      and not self._in_band(st))

    def flagged_ever(self) -> list[str]:
        """Keys flagged at ANY point so far (latched; survives recovery)."""
        return sorted(self._ever)

    def ratio(self, key: str) -> float | None:
        st = self._state.get(key)
        return st["ewma"] if st else None

    def status(self) -> dict:
        """Full per-key state plus the flag list -- JSON-serializable."""
        keys = {}
        for k, st in sorted(self._state.items()):
            keys[k] = {
                "predicted_s": st["predicted_s"],
                "count": st["count"],
                "ratio_ewma": round(st["ewma"], 4),
                "ratio_last": round(st["last"], 4),
                "in_band": self._in_band(st),
                "censored_hits": st["censored_hits"],
                "censored_dropped": st["censored_dropped"],
            }
        return {"band": list(self.band), "alpha": self.alpha,
                "min_samples": self.min_samples,
                "flagged": self.flagged(),
                "flagged_ever": self.flagged_ever(), "keys": keys}

    def __repr__(self) -> str:
        flagged = self.flagged()
        return (f"DriftMonitor(keys={len(self._state)}, band={self.band}, "
                f"flagged={flagged!r})")
