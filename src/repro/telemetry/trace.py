"""Low-overhead structured tracing: nested spans, bounded buffer, Chrome export.

The paper's result is *per-stage* -- initiation intervals, critical-path
delay, FIFO occupancy -- so the runtime needs per-stage visibility, not
end-to-end aggregates.  ``Tracer`` is the one event sink every layer
(engine, pipeline executor, serving) writes into:

* **duration spans** (``span``): nested, per-thread stack discipline -- a
  span closes after every span opened inside it, so within one thread
  spans nest and never overlap (the invariant the test suite asserts),
* **async events** (``begin_async``/``end_async``): request lifecycles
  that overlap freely (hundreds of requests in flight), correlated by id,
* **instants** (``instant``): point annotations -- a retry, a hedge, a
  quarantine -- that land on the timeline where they happened,
* **counters** (``counter``): sampled time series (queue depth, ...).

Everything lands in ONE bounded in-memory ring (the FINN FIFO rule applied
to the bookkeeping): when ``capacity`` is reached the oldest events drop
and ``dropped`` counts them -- a long-running server's trace memory stays
flat.  ``to_chrome()``/``save()`` export the Chrome trace-event JSON
format, viewable in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.

Zero overhead when disabled is a hard requirement: components hold
``tracer = None`` and guard every emission with ``if tracer is not None``
-- one attribute load and an identity test, nothing allocated, nothing
called.  There is deliberately NO NullTracer object on the hot paths.
"""

from __future__ import annotations

import collections
import json
import os
import threading
import time


class SpanHandle:
    """Context manager for one open duration span.

    ``args`` stays mutable while the span is open, so a caller can attach
    facts it only learns mid-span (which replica a dispatch landed on,
    whether a probe recovered)::

        with tracer.span("dispatch", cat="serving") as sp:
            pending = pool.dispatch(xs, entries)
            sp.args["replica"] = pending.replica.index
    """

    __slots__ = ("_tracer", "name", "cat", "args", "t0", "t1", "depth")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "SpanHandle":
        stack = self._tracer._stack()
        self.depth = len(stack)
        stack.append(self)
        self.t0 = self._tracer.clock()
        return self

    def __exit__(self, *exc) -> None:
        t1 = self.t1 = self._tracer.clock()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._emit({
            "ph": "X", "name": self.name, "cat": self.cat,
            "t0": self.t0, "t1": t1, "depth": self.depth,
            "tid": threading.get_ident(), "args": self.args,
        })
        return None

    @property
    def dur(self) -> float:
        """Span duration in seconds (valid once the span has closed)."""
        return self.t1 - self.t0


class Tracer:
    """Bounded structured trace buffer with an explicit clock.

    capacity: maximum buffered events; overflow drops oldest (counted in
        :attr:`dropped`).
    clock: seconds-valued monotonic callable (``time.perf_counter``); an
        injected fake clock makes span timing deterministic in tests.
    meta: free-form dict stamped into the Chrome export's ``metadata``
        (e.g. the build name, the fault-plan seed).
    """

    def __init__(self, *, capacity: int = 65536, clock=time.perf_counter,
                 meta: dict | None = None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.clock = clock
        self.meta = dict(meta or {})
        # the hot path is LOCK-FREE: deque.append (and maxlen eviction) is
        # one GIL-atomic operation, so no Lock is acquired per event (the
        # lock was ~30% of the per-event cost).  Snapshots (list(deque))
        # are GIL-consistent.  The emission counter is a plain int bump --
        # diagnostic only; concurrent bumps may very occasionally coalesce,
        # which can only UNDERcount ``dropped``, never corrupt the buffer.
        self._events: collections.deque[dict] = collections.deque(maxlen=capacity)
        self._local = threading.local()
        self._emitted = 0
        self._t_origin = clock()

    # ------------------------------------------------------------- plumbing
    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _emit(self, ev: dict) -> None:
        self._events.append(ev)
        self._emitted += 1

    @property
    def dropped(self) -> int:
        """Events evicted by the capacity bound so far."""
        return max(0, self._emitted - len(self._events))

    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> list[dict]:
        """Snapshot of the buffered events (oldest first)."""
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()
        self._emitted = 0

    # ------------------------------------------------------------- recording
    def span(self, name: str, cat: str = "", **args) -> SpanHandle:
        """Open a nested duration span (use as a context manager)."""
        return SpanHandle(self, name, cat, args)

    def emit_span(self, name: str, t0: float, t1: float, *, cat: str = "",
                  tid=None, **args) -> None:
        """Record a span with explicit timestamps, outside the per-thread
        stack -- for *reconstructed* schedules (the pipeline executor's
        per-stage occupancy lanes), where the span was not a code region.
        ``tid`` may be any hashable lane id (e.g. ``"stage0"``)."""
        self._emit({"ph": "X", "name": name, "cat": cat, "t0": t0, "t1": t1,
                    "depth": 0,
                    "tid": threading.get_ident() if tid is None else tid,
                    "args": args})

    def instant(self, name: str, cat: str = "", **args) -> None:
        """Point annotation at the current clock (a retry, a quarantine)."""
        self._emit({"ph": "i", "name": name, "cat": cat, "t": self.clock(),
                    "tid": threading.get_ident(), "args": args})

    def begin_async(self, name: str, aid, cat: str = "", *,
                    t: float | None = None, **args) -> None:
        """Open one async (overlapping) interval, correlated by ``aid``."""
        self._emit({"ph": "b", "name": name, "cat": cat, "id": aid,
                    "t": self.clock() if t is None else t,
                    "tid": threading.get_ident(), "args": args})

    def end_async(self, name: str, aid, cat: str = "", *,
                  t: float | None = None, **args) -> None:
        self._emit({"ph": "e", "name": name, "cat": cat, "id": aid,
                    "t": self.clock() if t is None else t,
                    "tid": threading.get_ident(), "args": args})

    def counter(self, name: str, value, cat: str = "") -> None:
        """Sample a time-series value (rendered as a counter track)."""
        self._emit({"ph": "C", "name": name, "cat": cat, "t": self.clock(),
                    "tid": threading.get_ident(), "args": {"value": value}})

    # --------------------------------------------------------------- export
    def spans(self, *, name: str | None = None, cat: str | None = None
              ) -> list[dict]:
        """Buffered duration spans, optionally filtered, with ``dur`` (s)."""
        out = []
        for ev in self.events():
            if ev["ph"] != "X":
                continue
            if name is not None and ev["name"] != name:
                continue
            if cat is not None and ev["cat"] != cat:
                continue
            out.append({**ev, "dur": ev["t1"] - ev["t0"]})
        return out

    def summary(self) -> dict:
        """Per-span-name aggregate (count / total / max seconds) plus the
        buffer accounting -- the compact form a BuildReport embeds."""
        agg: dict[str, dict] = {}
        events = self.events()
        for ev in events:
            if ev["ph"] != "X":
                continue
            dur = ev["t1"] - ev["t0"]
            a = agg.setdefault(ev["name"], {"count": 0, "total_s": 0.0,
                                            "max_s": 0.0})
            a["count"] += 1
            a["total_s"] += dur
            a["max_s"] = max(a["max_s"], dur)
        counts = collections.Counter(ev["ph"] for ev in events)
        return {
            "spans": {k: {"count": v["count"],
                          "total_s": round(v["total_s"], 6),
                          "max_s": round(v["max_s"], 6)}
                      for k, v in sorted(agg.items())},
            "events": {"X": counts.get("X", 0), "i": counts.get("i", 0),
                       "async": counts.get("b", 0) + counts.get("e", 0),
                       "C": counts.get("C", 0)},
            "dropped": self.dropped,
            "capacity": self.capacity,
        }

    def _us(self, t: float) -> float:
        return (t - self._t_origin) * 1e6

    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON object (perfetto-viewable).

        Duration spans become complete (``ph:"X"``) events, instants stay
        instants, async intervals map to ``b``/``e`` pairs, counters to
        ``C`` events.  Timestamps are microseconds from the tracer's
        construction; lane ids (reconstructed-schedule spans) become
        named synthetic tids.
        """
        pid = os.getpid()
        tids: dict = {}

        def tid_of(raw) -> int:
            if isinstance(raw, int):
                return raw
            if raw not in tids:
                tids[raw] = len(tids) + 1  # small synthetic lane ids
            return tids[raw]

        out = []
        for ev in self.events():
            tid = tid_of(ev["tid"])
            base = {"name": ev["name"], "cat": ev["cat"] or "default",
                    "pid": pid, "tid": tid, "args": ev["args"]}
            if ev["ph"] == "X":
                out.append({**base, "ph": "X", "ts": self._us(ev["t0"]),
                            "dur": (ev["t1"] - ev["t0"]) * 1e6})
            elif ev["ph"] == "i":
                out.append({**base, "ph": "i", "ts": self._us(ev["t"]),
                            "s": "t"})
            elif ev["ph"] in ("b", "e"):
                out.append({**base, "ph": ev["ph"], "ts": self._us(ev["t"]),
                            "id": ev["id"]})
            elif ev["ph"] == "C":
                out.append({**base, "ph": "C", "ts": self._us(ev["t"])})
        # name the synthetic lanes so Perfetto shows "stage0", not "tid 3"
        for raw, tid in tids.items():
            if not isinstance(raw, int):
                out.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "args": {"name": str(raw)}})
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "metadata": {**self.meta, "dropped_events": self.dropped}}

    def save(self, path: str) -> str:
        """Serialize :meth:`to_chrome` to ``path`` (a ``.trace.json``)."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")
        return path
