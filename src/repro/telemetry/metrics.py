"""Time-series metrics primitives: log-bucketed histograms, windowed rates,
and a Prometheus-style text exposition.

``LogHistogram`` replaces the serving latency reservoir: instead of keeping
the last N raw samples, it keeps counts in geometrically spaced buckets
(``lo * growth**i``), so memory is O(buckets touched) regardless of how
long the server runs, and two histograms merge exactly (reservoirs don't).
With ``growth = 2**(1/16)`` each bucket is ~4.4% wide, so a percentile
read off the geometric bucket midpoint is within ~2.2% of the true value
-- comfortably inside the 5% tolerance the serving tests assert.

``WindowedRate`` is a slotted ring: events land in coarse time slots and
the rate is the sum of the slots still inside the window -- a "requests
per second over the last 10s" gauge with O(slots) memory.

``render_prometheus`` turns counters / gauges / histograms into the
Prometheus text exposition format (one scrape-able string), complementing
the JSON ``snapshot()``.
"""

from __future__ import annotations

import math
import time

# 16 buckets per octave: relative bucket width ~4.4%, midpoint error ~2.2%.
DEFAULT_GROWTH = 2.0 ** (1.0 / 16.0)
DEFAULT_LO = 1e-6  # 1 us: well below any engine call this repo makes


class LogHistogram:
    """Log-bucketed histogram over positive values (seconds, typically).

    Values ``<= lo`` land in the underflow bucket (index -1) and are
    counted in ``count``/``sum`` but contribute ``lo`` to percentiles --
    with ``lo`` at 1 us nothing real ever lands there.
    """

    __slots__ = ("lo", "growth", "_log_growth", "buckets", "count", "sum",
                 "min", "max")

    def __init__(self, *, lo: float = DEFAULT_LO,
                 growth: float = DEFAULT_GROWTH):
        if lo <= 0 or growth <= 1.0:
            raise ValueError(f"need lo > 0 and growth > 1, got {lo}, {growth}")
        self.lo = lo
        self.growth = growth
        self._log_growth = math.log(growth)
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _index(self, value: float) -> int:
        if value <= self.lo:
            return -1
        return int(math.log(value / self.lo) / self._log_growth)

    def _midpoint(self, index: int) -> float:
        if index < 0:
            return self.lo
        # geometric midpoint of [lo*g^i, lo*g^(i+1))
        return self.lo * self.growth ** (index + 0.5)

    def upper_edge(self, index: int) -> float:
        return self.lo * self.growth ** (index + 1)

    def observe(self, value: float, n: int = 1) -> None:
        idx = self._index(value)
        self.buckets[idx] = self.buckets.get(idx, 0) + n
        self.count += n
        self.sum += value * n
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def percentile(self, p: float) -> float | None:
        """Value at percentile ``p`` (0..100), or None when empty."""
        if self.count == 0:
            return None
        # rank in [1, count]: matches the "p% of mass at or below" reading
        target = max(1.0, math.ceil(p / 100.0 * self.count))
        cum = 0
        for idx in sorted(self.buckets):
            cum += self.buckets[idx]
            if cum >= target:
                # clamp the midpoint estimate into the observed range so a
                # single-sample histogram answers exactly that sample
                return min(max(self._midpoint(idx), self.min), self.max)
        return self.max

    def mean(self) -> float | None:
        return self.sum / self.count if self.count else None

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        if (other.lo, other.growth) != (self.lo, self.growth):
            raise ValueError("cannot merge histograms with different buckets")
        for idx, n in other.buckets.items():
            self.buckets[idx] = self.buckets.get(idx, 0) + n
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def to_json(self) -> dict:
        return {"lo": self.lo, "growth": self.growth, "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "buckets": {str(k): v for k, v in sorted(self.buckets.items())}}

    @classmethod
    def from_json(cls, d: dict) -> "LogHistogram":
        h = cls(lo=d["lo"], growth=d["growth"])
        h.buckets = {int(k): v for k, v in d["buckets"].items()}
        h.count = d["count"]
        h.sum = d["sum"]
        h.min = d["min"] if d["min"] is not None else math.inf
        h.max = d["max"] if d["max"] is not None else -math.inf
        return h


class WindowedRate:
    """Events-per-second over a sliding window, via a slotted ring.

    ``window_s`` is split into ``slots`` coarse slots; each event lands in
    the slot for its timestamp and ``rate()`` sums the slots still inside
    the window.  Accuracy is one slot width; memory is O(slots).
    """

    __slots__ = ("window_s", "slot_s", "_slots", "clock")

    def __init__(self, window_s: float = 10.0, *, slots: int = 20,
                 clock=time.perf_counter):
        if window_s <= 0 or slots <= 0:
            raise ValueError(f"need positive window/slots, got {window_s}, {slots}")
        self.window_s = window_s
        self.slot_s = window_s / slots
        self._slots: dict[int, float] = {}
        self.clock = clock

    def _prune(self, now: float) -> None:
        horizon = int((now - self.window_s) / self.slot_s)
        if len(self._slots) > 2 * int(self.window_s / self.slot_s):
            for k in [k for k in self._slots if k <= horizon]:
                del self._slots[k]

    def add(self, n: float = 1.0, *, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        key = int(now / self.slot_s)
        self._slots[key] = self._slots.get(key, 0.0) + n
        self._prune(now)

    def rate(self, *, now: float | None = None) -> float:
        now = self.clock() if now is None else now
        horizon = int((now - self.window_s) / self.slot_s)
        total = sum(v for k, v in self._slots.items() if k > horizon)
        return total / self.window_s


def _fmt(value) -> str:
    if value is None:
        return "NaN"  # Prometheus exposition spells missing values NaN
    return repr(float(value))


def render_prometheus(*, counters: dict | None = None,
                      gauges: dict | None = None,
                      histograms: dict[str, LogHistogram] | None = None,
                      prefix: str = "repro") -> str:
    """Prometheus text exposition (v0.0.4) for a set of metrics.

    Counters get a ``_total`` suffix; histograms render cumulative ``le``
    buckets (upper bucket edges, in the histogram's native unit) plus
    ``_sum``/``_count``, the standard histogram contract.
    """
    lines: list[str] = []
    for name, v in sorted((counters or {}).items()):
        full = f"{prefix}_{name}_total"
        lines.append(f"# TYPE {full} counter")
        lines.append(f"{full} {_fmt(v)}")
    for name, v in sorted((gauges or {}).items()):
        full = f"{prefix}_{name}"
        lines.append(f"# TYPE {full} gauge")
        lines.append(f"{full} {_fmt(v)}")
    for name, h in sorted((histograms or {}).items()):
        full = f"{prefix}_{name}"
        lines.append(f"# TYPE {full} histogram")
        cum = 0
        for idx in sorted(h.buckets):
            cum += h.buckets[idx]
            le = _fmt(h.upper_edge(idx))
            lines.append(f'{full}_bucket{{le="{le}"}} {cum}')
        lines.append(f'{full}_bucket{{le="+Inf"}} {h.count}')
        lines.append(f"{full}_sum {_fmt(h.sum)}")
        lines.append(f"{full}_count {h.count}")
    return "\n".join(lines) + "\n"
