"""Unified telemetry: dataflow tracing, request-lifecycle spans, and a
live cycle-model drift monitor.

Three pieces, one import surface:

* :class:`Tracer` -- nested duration spans, async request intervals,
  instants and counters in a bounded buffer, exported as Chrome
  trace-event JSON (perfetto-viewable).  Components take ``tracer=None``
  and guard every emission with ``if tracer is not None`` so a disabled
  build pays nothing.
* :class:`LogHistogram` / :class:`WindowedRate` /
  :func:`render_prometheus` -- mergeable bounded-memory time-series
  metrics and a Prometheus text exposition.
* :class:`DriftMonitor` -- measured-vs-predicted interval ratios per
  stage/replica against the calibrated cycle model, flagging keys whose
  EWMA leaves the band.

See docs/observability.md for the span taxonomy and workflows.
"""

from repro.telemetry.drift import DEFAULT_BAND, DriftMonitor
from repro.telemetry.metrics import (
    DEFAULT_GROWTH,
    DEFAULT_LO,
    LogHistogram,
    WindowedRate,
    render_prometheus,
)
from repro.telemetry.trace import SpanHandle, Tracer

__all__ = [
    "DEFAULT_BAND",
    "DEFAULT_GROWTH",
    "DEFAULT_LO",
    "DriftMonitor",
    "LogHistogram",
    "SpanHandle",
    "Tracer",
    "WindowedRate",
    "render_prometheus",
]
