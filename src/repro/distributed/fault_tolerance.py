"""Fault tolerance: restart manager, step watchdog, elastic rescale.

TPU-pod failure model: a chip/host failure kills the whole SPMD job (there
is no in-job node replacement on a synchronous TPU mesh); recovery is
restart-from-checkpoint, so MTTR is dominated by (a) checkpoint cadence and
(b) restore time.  Accordingly this module provides:

  * CheckpointManager -- cadence + retention + async save + resume-latest.
  * StepWatchdog      -- straggler detection: flags steps exceeding a
    multiple of the trailing-median step time (on real pods this feeds the
    preemption/abort decision; here it logs and counts).
  * elastic rescale   -- restore() onto a different mesh: sharding rules
    are mesh-shape-agnostic, so save-on-(2,2) / resume-on-(4,1) "just
    works"; tested in tests/test_distributed.py.
"""

from __future__ import annotations

import time

from repro.checkpoint import ckpt
from repro.distributed.stragglers import TrailingStats


class CheckpointManager:
    def __init__(self, directory: str, *, every: int = 100, keep: int = 3,
                 use_async: bool = True):
        self.dir = directory
        self.every = every
        self.keep = keep
        self.use_async = use_async
        self._pending = None

    def maybe_save(self, step: int, tree, *, extra: dict | None = None) -> bool:
        if step % self.every:
            return False
        self.wait()
        if self.use_async:
            # the in-flight save will be the keep-th checkpoint; prune the
            # completed ones to keep-1 BEFORE launching it, so a fast save
            # thread can't land in the prune's listing and evict its
            # predecessor (keep would drop to keep-1 on disk).
            ckpt.prune(self.dir, max(self.keep - 1, 1))
            self._pending = ckpt.save_async(self.dir, step, tree, extra=extra)
        else:
            ckpt.save(self.dir, step, tree, extra=extra)
            ckpt.prune(self.dir, self.keep)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def resume_latest(self, like, shardings=None):
        """Returns (step, tree) from the newest valid checkpoint, or (0, None)."""
        step = ckpt.latest_step(self.dir)
        if step is None:
            return 0, None
        return step, ckpt.restore(self.dir, step, like, shardings)


class StepWatchdog:
    """Context-manager timer over :class:`TrailingStats` -- the straggler
    test itself (trailing-median window, tested-before-appended, 8-sample
    warmup) is shared with the serving replica health machine."""

    def __init__(self, *, window: int = 32, straggler_factor: float = 3.0):
        self._stats = TrailingStats(window=window, factor=straggler_factor,
                                    min_samples=8)
        self._t0 = None

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._stats.observe(time.perf_counter() - self._t0)
        return False

    @property
    def times(self):
        return self._stats.times

    @property
    def factor(self) -> float:
        return self._stats.factor

    @property
    def stragglers(self) -> int:
        return self._stats.stragglers

    @property
    def median(self) -> float:
        return self._stats.median
