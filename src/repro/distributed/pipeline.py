"""Pipeline-parallel streaming executor: the FINN dataflow graph on a TPU
mesh (DESIGN.md section 2).

FINN instantiates one compute unit per layer and streams activations
through AXI links; the TPU analog assigns contiguous layer ranges to mesh
devices along a "stage" axis and streams *microbatches* through
``ppermute`` links (GPipe schedule).  The correspondences:

    AXI stream / TVALID-TREADY      ppermute send (statically scheduled)
    FIFO between layers             the in-flight microbatch buffer
    FINN folding / rate balancing   equal per-stage layer counts (the
                                    folding pass equalizes stage cycles)
    II = 1 steady state             one microbatch per stage per tick
    pipeline bubbles                (S-1) fill + (S-1) drain ticks

``pipeline_apply`` is generic over the per-stage function; gradients flow
through (jax.grad of the whole schedule works) so it serves for training
and for serving.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def stage_params_split(params_stacked, n_stages: int):
    """Reshape a (L, ...)-stacked layer-param tree to (n_stages, L/S, ...)."""

    def r(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])

    return jax.tree.map(r, params_stacked)


def pipeline_apply(
    layer_fn: Callable,  # (layer_params, x) -> x
    stage_params,  # tree with leading (n_stages, layers_per_stage, ...)
    x: jax.Array,  # (n_micro, micro_batch, ...)
    mesh: Mesh,
    *,
    axis: str = "stage",
) -> jax.Array:
    """Run the microbatched GPipe schedule over the ``axis`` mesh axis."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    assert n_micro >= n_stages, "need >= n_stages microbatches to fill the pipe"

    def stage_fn(params, xs):
        # params: (1, layers_per_stage, ...); xs: (n_micro, mb, ...)
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])  # current microbatch at this stage
        out = jnp.zeros_like(xs)

        def apply_stage(b):
            def body(h, p):
                return layer_fn(p, h), None

            h, _ = jax.lax.scan(body, b, params)
            return h

        def tick(t, carry):
            buf, out = carry
            # stage 0 ingests microbatch t (when available)
            take = jnp.clip(t, 0, n_micro - 1)
            fresh = jax.lax.dynamic_index_in_dim(xs, take, 0, keepdims=False)
            cur = jnp.where(stage == 0, fresh, buf)
            y = apply_stage(cur)
            # last stage emits microbatch (t - n_stages + 1)
            emit_idx = jnp.clip(t - n_stages + 1, 0, n_micro - 1)
            do_emit = jnp.logical_and(stage == n_stages - 1, t >= n_stages - 1)
            emitted = jnp.where(do_emit, y, jax.lax.dynamic_index_in_dim(out, emit_idx, 0, keepdims=False))
            out = jax.lax.dynamic_update_index_in_dim(out, emitted, emit_idx, 0)
            # stream to the next stage (the AXI link)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return nxt, out

        buf, out = jax.lax.fori_loop(0, n_ticks, tick, (buf, out))
        # every stage returns its local out buffer; only the last stage's is
        # real.  Returning per-stage (out_specs=P(axis)) keeps autodiff exact:
        # cotangents route only into the last stage's block.
        return out

    pspec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(pspec_params, P()),
        out_specs=P(axis),
        check_rep=False,
    )
    stacked = fn(stage_params, x)  # (n_stages * n_micro, mb, ...)
    return stacked[(n_stages - 1) * n_micro :]


def pipeline_occupancy(n_stages: int, n_micro: int) -> dict:
    """Static GPipe schedule accounting: ticks, bubbles, occupancy.

    The schedule runs ``n_micro + n_stages - 1`` ticks; each stage computes
    for ``n_micro`` of them and idles through ``n_stages - 1`` fill/drain
    bubbles -- the paper's pipeline-fill latency term, counted in ticks
    instead of cycles.  ``occupancy`` is the busy fraction per stage.
    """
    ticks = n_micro + n_stages - 1
    bubble = n_stages - 1
    return {
        "n_stages": n_stages,
        "n_micro": n_micro,
        "ticks": ticks,
        "bubble_ticks_per_stage": bubble,
        "occupancy": n_micro / ticks if ticks else 0.0,
    }


def emit_schedule_spans(tracer, n_stages: int, n_micro: int,
                        t0: float, t1: float) -> dict:
    """Reconstruct the per-stage GPipe timeline as trace lanes.

    Spans inside ``shard_map``/``jit`` cannot be recorded (the schedule is
    one fused XLA program), so the executor measures the wall interval
    ``[t0, t1]`` and lays the *static* schedule over it: tick width
    ``(t1-t0)/ticks``, stage ``s`` busy with microbatch ``m`` during tick
    ``s + m``, idle ticks emitted as ``bubble`` spans.  One lane
    (``stageN``) per stage; returns the occupancy accounting.
    """
    occ = pipeline_occupancy(n_stages, n_micro)
    tick_s = (t1 - t0) / occ["ticks"]
    for s in range(n_stages):
        lane = f"stage{s}"
        for tick in range(occ["ticks"]):
            m = tick - s
            a, b = t0 + tick * tick_s, t0 + (tick + 1) * tick_s
            if 0 <= m < n_micro:
                tracer.emit_span(f"micro{m}", a, b, cat="pipeline",
                                 tid=lane, stage=s, micro=m, tick=tick)
            else:
                tracer.emit_span("bubble", a, b, cat="pipeline",
                                 tid=lane, stage=s, tick=tick)
    return occ


def sequential_reference(layer_fn, params_stacked, x):
    """Oracle: run all layers sequentially on every microbatch."""

    def body(h, p):
        return layer_fn(p, h), None

    def one(mb):
        h, _ = jax.lax.scan(body, mb, params_stacked)
        return h

    return jax.vmap(one)(x)
