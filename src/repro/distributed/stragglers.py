"""Shared straggler detection: trailing-median outlier test over a window.

Two consumers, one definition:

* the training-side :class:`~repro.distributed.fault_tolerance.StepWatchdog`
  flags SPMD steps that blow past a multiple of the trailing-median step
  time (on real pods this feeds the preemption/abort decision), and
* the serving-side replica health machine
  (:mod:`repro.serving.health`) flags replica dispatches whose resolve
  latency stragglers relative to the replica's own recent history.

The trailing *median* (not mean) is the robust center: a single straggler
landing in the window must not drag the threshold up and mask the next
one.  An EWMA is maintained alongside as a cheap smoothed-latency gauge
(hedging decisions want "typical recent latency" without a full sort).
"""

from __future__ import annotations

import collections
import statistics


class TrailingStats:
    """Bounded window of durations with a trailing-median straggler test.

    ``observe(dt)`` answers "is this observation a straggler relative to
    the window *before* it?" -- the sample is tested against the trailing
    median first and appended after, so one outlier never vouches for
    itself.  No verdict is issued until ``min_samples`` observations have
    accumulated (early measurements are compile/warmup noise).
    """

    def __init__(self, *, window: int = 32, factor: float = 3.0,
                 min_samples: int = 8, ewma_alpha: float = 0.25):
        if window <= 0:
            raise ValueError(f"window must be positive, got {window}")
        if factor <= 1.0:
            raise ValueError(f"factor must exceed 1.0, got {factor}")
        self.times: collections.deque[float] = collections.deque(maxlen=window)
        self.factor = factor
        self.min_samples = min_samples
        self._ewma_alpha = ewma_alpha
        self._ewma: float | None = None
        self.stragglers = 0

    def __len__(self) -> int:
        return len(self.times)

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0

    @property
    def ewma(self) -> float:
        """Exponentially-weighted moving average of the observations."""
        return 0.0 if self._ewma is None else self._ewma

    def threshold(self) -> float | None:
        """Current straggler cutoff, or None while under ``min_samples``."""
        if len(self.times) < self.min_samples:
            return None
        return self.factor * statistics.median(self.times)

    def would_flag(self, dt: float) -> bool:
        """The straggler test alone -- no recording (probe before commit)."""
        cut = self.threshold()
        return cut is not None and dt > cut

    def observe(self, dt: float) -> bool:
        """Record one duration; True when it straggled vs the trailing
        window (tested before appending, counted in ``stragglers``)."""
        flagged = self.would_flag(dt)
        if flagged:
            self.stragglers += 1
        self.times.append(dt)
        if self._ewma is None:
            self._ewma = dt
        else:
            a = self._ewma_alpha
            self._ewma = a * dt + (1.0 - a) * self._ewma
        return flagged
