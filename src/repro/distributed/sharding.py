"""Logical sharding rules: parameter-tree paths -> PartitionSpecs.

Rules are written against *logical* roles (column-parallel, row-parallel,
expert-sharded, head-sharded, replicated) and matched by path suffix, so
they hold for any mesh shape -- (16,16), (2,16,16), or a (2,2) host-device
test mesh.  Leading stack axes (scan over layers / hybrid groups) are
padded with None automatically.

TP layout (Megatron-style 2D GEMM sharding over "model"):
  wq/wk/wv, ffn up/gate, ssm z/x/dt projections: column-parallel
  wo, ffn down, ssm out_proj: row-parallel (psum on exit)
  experts: expert dim over "model" (EP); router replicated
  embed: vocab-sharded; unembed: vocab-sharded output
  per-head vectors (A_log, D, dt_bias), head-dim norms: "model"
Batch is sharded over ("pod","data") jointly (DP); long-context decode
shards KV-cache sequence over "model" (SP) -- see cache_pspec.
"""

from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path-suffix tokens, spec builder over the *core* dims)
# matched against "/".join(path); first hit wins.
_RULES: list[tuple[str, tuple]] = [
    ("embed/table", ("model", None)),
    ("unembed/w", (None, "model")),
    # integer-deployed MVU projections store (out, in) int8 + (out,) scale
    ("wq/values", ("model", None)),
    ("wk/values", ("model", None)),
    ("wv/values", ("model", None)),
    ("wo/values", (None, "model")),
    ("w_up/values", ("model", None)),
    ("w_gate/values", ("model", None)),
    ("w_down/values", (None, "model")),
    ("wq/scale", ("model",)),
    ("wk/scale", ("model",)),
    ("wv/scale", ("model",)),
    ("w_up/scale", ("model",)),
    ("w_gate/scale", ("model",)),
    ("wo/scale", (None,)),
    ("w_down/scale", (None,)),
    ("wq/w", (None, "model")),
    ("wk/w", (None, "model")),
    ("wv/w", (None, "model")),
    ("wo/w", ("model", None)),
    ("w_up/w", (None, "model")),
    ("w_gate/w", (None, "model")),
    ("w_down/w", ("model", None)),
    ("router/w", (None, None)),
    # MoE expert stacks (E, d, f) / (E, f, d): experts over "model"
    ("moe/w_up", ("model", None, None)),
    ("moe/w_gate", ("model", None, None)),
    ("moe/w_down", ("model", None, None)),
    # ssm projections
    ("w_z/w", (None, "model")),
    ("w_x/w", (None, "model")),
    ("w_B/w", (None, None)),
    ("w_C/w", (None, None)),
    ("w_dt/w", (None, "model")),
    ("conv_x/w", (None, "model")),
    ("conv_x/b", ("model",)),
    ("conv_B/w", (None, None)),
    ("conv_B/b", (None,)),
    ("conv_C/w", (None, None)),
    ("conv_C/b", (None,)),
    ("A_log", ("model",)),
    ("dt_bias", ("model",)),
    ("ssm/D", ("model",)),
    ("ssm/norm/scale", ("model",)),
    ("out_proj/w", ("model", None)),
    # norms & everything else: replicated
    ("scale", (None,)),
    ("bias", (None,)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for_path(path, ndim: int) -> P:
    s = _path_str(path)
    for suffix, core in _RULES:
        if suffix in s:
            pad = ndim - len(core)
            if pad < 0:  # leaf smaller than rule (e.g. scalar): replicate
                return P()
            return P(*([None] * pad + list(core)))
    return P()


def make_even(spec: P, shape, mesh: Mesh) -> P:
    """pjit requires input dims to divide their mesh-axis product; prune or
    relocate axes that don't.

    Relocation: a single failing axis moves to a *later* replicated dim that
    divides (e.g. embed (V, d) with odd V: vocab-sharding falls back to
    d_model-sharding -- production systems pad the vocab instead; we keep
    the assigned vocab exact).  Tuple entries drop members until they
    divide (batch=1 over ("pod","data") -> replicated).
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))

    def size_of(axes):
        s = 1
        for a in axes:
            s *= mesh.shape[a]
        return s

    for i, e in enumerate(entries):
        if e is None:
            continue
        axes = e if isinstance(e, tuple) else (e,)
        if shape[i] % size_of(axes) == 0:
            continue
        moved = False
        # relocation only for 2D weights (embed-style); for expert stacks a
        # relocated axis would land on a contraction dim and force a psum
        # inside every expert GEMM -- replicating (+ FSDP over "data") is
        # the cheaper fallback there.
        if not isinstance(e, tuple) and len(shape) == 2:
            for j in range(i + 1, len(entries)):
                if entries[j] is None and shape[j] > 1 and shape[j] % mesh.shape[e] == 0:
                    entries[j] = e
                    moved = True
                    break
        if not moved and isinstance(e, tuple):
            keep = []
            for a in axes:
                if shape[i] % size_of(keep + [a]) == 0:
                    keep.append(a)
            if keep:
                entries[i] = tuple(keep)
                continue
        entries[i] = None
    return P(*entries)


def _fsdp_extend(spec: P, shape) -> P:
    """ZeRO-3 / FSDP: additionally shard the *last* replicated dim of every
    >=2D weight over "data".  Combined with the TP rules this gives 2D
    (data x model) weight sharding; GSPMD inserts the per-layer all-gathers
    in fwd/bwd and the optimizer state inherits the full 2D sharding.
    The last dim is chosen so layer-stack leading dims (scanned) stay
    unsharded."""
    if len(shape) < 2:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i in range(len(entries) - 1, -1, -1):
        if entries[i] is None and shape[i] > 1:
            entries[i] = "data"
            break
    return P(*entries)


def param_pspecs(params_shape, mesh: Mesh | None = None, *, fsdp: bool = False) -> dict:
    """Pytree of PartitionSpecs matching a params (shape) tree."""
    import jax

    def spec(path, leaf):
        s = spec_for_path(path, len(leaf.shape))
        if fsdp:
            s = _fsdp_extend(s, leaf.shape)
        if mesh is not None:
            s = make_even(s, leaf.shape, mesh)
        return s

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def param_shardings(mesh: Mesh, params_shape, *, fsdp: bool = False):
    import jax

    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_pspecs(params_shape, mesh, fsdp=fsdp),
    )


def bytes_per_device(tree_shape, spec_tree, mesh: Mesh) -> float:
    """Total bytes of a (shape) pytree per device under the given specs."""
    import jax
    import numpy as np

    def leaf_bytes(leaf, spec):
        if hasattr(spec, "spec"):  # NamedSharding
            spec = spec.spec
        # int4 packs two elements per byte on TPU (jax itemsize reports 1)
        itemsize = 0.5 if "int4" in str(leaf.dtype) else leaf.dtype.itemsize
        n = float(np.prod(leaf.shape)) * itemsize if leaf.shape else itemsize
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shards *= mesh.shape[a]
        return n / shards

    leaves = jax.tree.leaves(tree_shape)
    specs = jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P) or hasattr(x, "spec")
    )
    return sum(leaf_bytes(l, s) for l, s in zip(leaves, specs))


def batch_pspec(mesh: Mesh) -> P:
    """Tokens (B, S): batch over pod+data."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(dp, None)


def batch_shardings(mesh: Mesh, batch_shape) -> dict:
    import jax

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def spec(path, leaf):
        ndim = len(leaf.shape)
        s = make_even(P(*([dp] + [None] * (ndim - 1))), leaf.shape, mesh)
        return NamedSharding(mesh, s)

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def cache_pspecs(mesh: Mesh, cache_shape, *, seq_over_model: bool = False):
    """Decode-state shardings.

    KV caches (L, B, T, G, hd): batch over DP axes; with seq_over_model the
    cache *sequence* dim additionally shards over "model" (SP decode for
    long contexts -- partial-softmax combining is inserted by GSPMD).
    SSM states (L, B, H, P, N): heads over "model".
    """
    import jax

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def spec(path, leaf):
        s = _path_str(path)
        nd = len(leaf.shape)
        if s.endswith("/k") or s.endswith("/v") or s.endswith("_scale"):
            tspec = "model" if seq_over_model else None
            p = P(None, dp, tspec, None, None)
        elif "state" in s:
            p = P(*([None] * (nd - 4) + [dp, "model", None, None]))
        elif "conv_x" in s:
            p = P(*([None] * (nd - 3) + [dp, None, "model"]))
        elif "conv_B" in s or "conv_C" in s:
            p = P(*([None] * (nd - 3) + [dp, None, None]))
        elif "pos" in s:
            p = P()
        elif "enc_out" in s:
            p = P(dp, None, None)
        elif nd >= 2:  # default: batch-shard dim 1 (dim 0 is the layer stack)
            p = P(*([None, dp] + [None] * (nd - 2)))
        else:
            p = P()
        return NamedSharding(mesh, make_even(p, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)
