"""Attention: MHA/GQA/MQA, causal + sliding-window masks, RoPE/M-RoPE,
prefill and single-token decode with a KV cache, encoder-decoder cross
attention.  Pure einsum formulation so GSPMD can shard heads over "model"
and (for long-context decode) the KV sequence over "data".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (
    Params,
    apply_mrope,
    apply_rope,
    linear,
    linear_init,
    rmsnorm,
    rmsnorm_init,
)


def attn_init(key, cfg, dtype=jnp.bfloat16) -> Params:
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": linear_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": linear_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": linear_init(ks[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = rmsnorm_init(hd, dtype)
        p["knorm"] = rmsnorm_init(hd, dtype)
    return p


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _qkv(p, cfg, x, positions, backend):
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = _split_heads(linear(p["wq"], x, backend=backend), cfg.num_heads, hd)
    k = _split_heads(linear(p["wk"], x, backend=backend), cfg.num_kv_heads, hd)
    v = _split_heads(linear(p["wv"], x, backend=backend), cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["qnorm"], q)
        k = rmsnorm(p["knorm"], k)
    if cfg.mrope:
        if positions.ndim == 2:  # text-only fallback: identical t/h/w ids
            positions = jnp.broadcast_to(positions[None], (3, *positions.shape))
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(sq: int, skv: int, *, causal: bool, window: int | None,
          q_offset: int = 0) -> jax.Array:
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(skv)[None, :]
    m = jnp.ones((sq, skv), bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m


def _sdpa(q, k, v, mask=None):
    """q (B,Sq,H,hd); k,v (B,Skv,G,hd) with H = G*rep (GQA)."""
    b, sq, h, hd = q.shape
    g = k.shape[2]
    rep = h // g
    q = q.reshape(b, sq, g, rep, hd)
    scores = jnp.einsum("bsgrh,btgh->bgrst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrst,btgh->bsgrh", probs, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_chunked(q, k, v, *, causal, window, q_chunk):
    """Exact attention scanned over query chunks: peak score memory drops
    from O(S^2) to O(q_chunk * S) and the backward pass rematerializes per
    chunk.  The TPU-native answer to the paper's input-buffer discipline:
    stream the query stripe, keep K/V resident."""
    b, s, h, hd = q.shape
    nc = s // q_chunk
    qc = jnp.moveaxis(q.reshape(b, nc, q_chunk, h, hd), 1, 0)  # (nc, b, qc, h, hd)

    def body(_, inp):
        qi, idx = inp
        mask = _mask(q_chunk, s, causal=causal, window=window,
                     q_offset=idx * q_chunk)
        return None, _sdpa(qi, k, v, mask)

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(nc)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)


def _sdpa_auto(q, k, v, *, causal, window, q_chunk):
    s = q.shape[1]
    if q_chunk and s > q_chunk and s % q_chunk == 0 and q.shape[1] == k.shape[1]:
        return _sdpa_chunked(q, k, v, causal=causal, window=window, q_chunk=q_chunk)
    mask = _mask(s, k.shape[1], causal=causal, window=window)
    return _sdpa(q, k, v, mask if (causal or window) else None)


def attention(
    p: Params,
    cfg,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S) or (3, B, S) for M-RoPE
    *,
    causal: bool = True,
    backend: str = "dense",
) -> jax.Array:
    q, k, v = _qkv(p, cfg, x, positions, backend)
    window = cfg.window if cfg.attn_type == "swa" else None
    out = _sdpa_auto(q, k, v, causal=causal, window=window,
                     q_chunk=cfg.attn_q_chunk)
    return linear(p["wo"], out.reshape(*x.shape[:-1], -1), backend=backend)


# ------------------------------------------------------------------ decode
def _quant_kv(x):
    """(.., hd) -> int8 values + per-(token,head) f32 scale (KIVI-style)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros((*shape[:-1], 1), jnp.float32),
            "v_scale": jnp.zeros((*shape[:-1], 1), jnp.float32),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _cache_write(cfg, cache, k, v, idx):
    if cfg.kv_quant:
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        return {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, idx, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, idx, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, idx, 0, 0)),
            "v_scale": jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, idx, 0, 0)),
        }
    return {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0)),
    }


def _cache_read(cfg, cache, dtype):
    if cfg.kv_quant:
        return (_dequant_kv(cache["k"], cache["k_scale"], dtype),
                _dequant_kv(cache["v"], cache["v_scale"], dtype))
    return cache["k"], cache["v"]


def attention_prefill(
    p: Params, cfg, x: jax.Array, positions: jax.Array, cache, *,
    backend: str = "dense",
):
    """Full-sequence pass that also fills the KV cache (serving prefill)."""
    q, k, v = _qkv(p, cfg, x, positions, backend)
    cache = _cache_write(cfg, cache, k, v, 0)
    window = cfg.window if cfg.attn_type == "swa" else None
    out = _sdpa_auto(q, k, v, causal=True, window=window,
                     q_chunk=cfg.attn_q_chunk)
    return linear(p["wo"], out.reshape(*x.shape[:-1], -1), backend=backend), cache


def attention_decode(
    p: Params, cfg, x: jax.Array, pos: jax.Array, cache, *,
    backend: str = "dense",
):
    """One-token decode: x (B, 1, d), pos (B, 1); cache (B, T, G, hd)."""
    q, k, v = _qkv(p, cfg, x, pos, backend)
    b, t = cache["k"].shape[:2]
    # write the new K/V at position pos (same for all batch rows in this
    # framework: right-aligned serving) then attend over the full cache.
    idx = pos[0, 0]
    cache = _cache_write(cfg, cache, k, v, idx)
    kk, vv = _cache_read(cfg, cache, q.dtype)
    valid = jnp.arange(t)[None, :] <= idx  # (1, T)
    if cfg.attn_type == "swa" and cfg.window is not None:
        valid &= jnp.arange(t)[None, :] > idx - cfg.window
    g = kk.shape[2]
    h = cfg.num_heads
    rep = h // g
    qh = q.reshape(b, 1, g, rep, cfg.head_dim)
    scores = jnp.einsum("bsgrh,btgh->bgrst", qh, kk).astype(jnp.float32)
    scores = scores / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
    scores = jnp.where(valid[:, None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrst,btgh->bsgrh", probs, vv).reshape(b, 1, h * cfg.head_dim)
    return linear(p["wo"], out, backend=backend), cache


# ------------------------------------------------------------------ cross
def cross_attn_init(key, cfg, dtype=jnp.bfloat16) -> Params:
    return attn_init(key, cfg, dtype)


def cross_attention(
    p: Params, cfg, x: jax.Array, kv_src: jax.Array, *, backend: str = "dense"
) -> jax.Array:
    """Decoder query over encoder memory (Whisper); no mask, no rope."""
    hd = cfg.head_dim
    q = _split_heads(linear(p["wq"], x, backend=backend), cfg.num_heads, hd)
    k = _split_heads(linear(p["wk"], kv_src, backend=backend), cfg.num_kv_heads, hd)
    v = _split_heads(linear(p["wv"], kv_src, backend=backend), cfg.num_kv_heads, hd)
    out = _sdpa(q, k, v, None)
    return linear(p["wo"], out.reshape(*x.shape[:-1], -1), backend=backend)
