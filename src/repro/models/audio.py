"""Whisper backbone support: conv-stem stub.

The paper assignment specifies the transformer BACKBONE only; the mel ->
conv1d x2 frontend is a STUB that provides precomputed frame embeddings
(B, T_frames, d_model) directly to the encoder.
"""

from __future__ import annotations

import jax.numpy as jnp


def conv_frontend_stub(batch: int, n_frames: int, d_model: int, dtype=jnp.bfloat16):
    """Stand-in for log-mel + 2x strided conv1d stem."""
    return jnp.zeros((batch, n_frames, d_model), dtype)
