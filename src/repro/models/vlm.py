"""Qwen2-VL backbone support: M-RoPE position builder + patch-embed stub.

Per the assignment, the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, P, d_model).  The backbone is the
full GQA transformer with multimodal rotary positions: vision tokens carry
(temporal, height, width) ids over the patch grid, text tokens carry equal
t/h/w ids continuing after the vision prefix (degenerates to 1-D RoPE).
"""

from __future__ import annotations

import jax.numpy as jnp


def mrope_positions(batch: int, prefix: int, seq: int, grid_w: int = 16):
    """(3, B, prefix+seq) int32 position ids for [vision prefix | text]."""
    if prefix:
        vp = jnp.arange(prefix)
        t_v = jnp.zeros((prefix,), jnp.int32)
        h_v = (vp // grid_w).astype(jnp.int32)
        w_v = (vp % grid_w).astype(jnp.int32)
        base = jnp.maximum(jnp.maximum(t_v.max(), h_v.max()), w_v.max()) + 1
    else:
        t_v = h_v = w_v = jnp.zeros((0,), jnp.int32)
        base = 0
    txt = base + jnp.arange(seq, dtype=jnp.int32)
    t = jnp.concatenate([t_v, txt])
    h = jnp.concatenate([h_v, txt])
    w = jnp.concatenate([w_v, txt])
    pos = jnp.stack([t, h, w])  # (3, P+S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, prefix + seq))


def patch_embed_stub(batch: int, n_patches: int, d_model: int, dtype=jnp.bfloat16):
    """Stand-in for the ViT frontend: precomputed patch embeddings."""
    return jnp.zeros((batch, n_patches, d_model), dtype)
