"""Model facade: build(config) -> init / loss / prefill / decode_step.

One uniform interface over all ten architectures:

    batch (train):
      LM/MoE/SSM/hybrid: {"tokens": (B, S+1) int32}
      vlm:    + {"prefix_embeds": (B, P, d)}
      audio:  {"enc_embeds": (B, T, d), "tokens": (B, S+1)}

    decode state: {"caches": ..., "pos": (B, 1) int32, ["enc_out"]}
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.layers import embed, embed_init, linear, linear_init, unembed
from repro.models.vlm import mrope_positions


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_decode_state: Callable[..., Any]


def _positions(cfg, batch, seq, prefix=0):
    if cfg.mrope:
        return mrope_positions(batch, prefix, seq)
    return jnp.broadcast_to(
        jnp.arange(prefix + seq, dtype=jnp.int32)[None], (batch, prefix + seq)
    )


def _ce_loss(logits: jax.Array, targets: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def build(cfg: ModelConfig) -> Model:
    dt = _dtype(cfg)

    # ----------------------------------------------------------------- init
    def init(key: jax.Array):
        k_emb, k_stack, k_out = jax.random.split(key, 3)
        params = {"embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt)}
        if cfg.encdec:
            params["encdec"] = tf.encdec_init(k_stack, cfg, dt)
        else:
            params["layers"] = tf.stack_init(k_stack, cfg, dt)
        params["ln_f"] = (
            tf.layernorm_init(cfg.d_model, dt)
            if cfg.norm == "layernorm"
            else tf.rmsnorm_init(cfg.d_model, dt)
        )
        if not cfg.tie_embeddings:
            params["unembed"] = linear_init(k_out, cfg.d_model, cfg.vocab_size, dt)
        return params

    def _norm_f(params, x):
        from repro.models.layers import layernorm, rmsnorm

        fn = layernorm if cfg.norm == "layernorm" else rmsnorm
        return fn(params["ln_f"], x, cfg.norm_eps)

    def _logits(params, x):
        if cfg.tie_embeddings:
            return unembed(params["embed"], x)
        return linear(params["unembed"], x)

    # ----------------------------------------------------------------- loss
    def loss(params, batch):
        tokens = batch["tokens"]
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        b, s = inputs.shape
        x = embed(params["embed"], inputs)

        if cfg.encdec:
            enc_in = batch["enc_embeds"].astype(dt)
            enc_pos = _positions(cfg, b, enc_in.shape[1])
            enc_out = tf.encoder_forward(params["encdec"], cfg, enc_in, enc_pos)
            pos = _positions(cfg, b, s)
            x = tf.decoder_forward(params["encdec"], cfg, x, pos, enc_out)
            aux = jnp.zeros((), jnp.float32)
        elif cfg.family == "vlm" and "prefix_embeds" in batch:
            prefix = batch["prefix_embeds"].astype(dt)
            p_len = prefix.shape[1]
            x = jnp.concatenate([prefix, x], axis=1)
            pos = _positions(cfg, b, s, prefix=p_len)
            x, aux = tf.stack_forward(params["layers"], cfg, x, pos)
            x = x[:, p_len:]
        else:
            pos = _positions(cfg, b, s)
            x, aux = tf.stack_forward(params["layers"], cfg, x, pos)

        logits = _logits(params, _norm_f(params, x))
        ce = _ce_loss(logits, targets)
        total = ce + cfg.aux_loss_weight * aux
        return total, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- serving
    def init_decode_state(batch: int, max_len: int):
        state: dict[str, Any] = {"pos": jnp.zeros((batch, 1), jnp.int32)}
        if cfg.encdec:
            state["caches"] = jax.vmap(
                lambda _: tf.init_kv_cache(cfg, batch, max_len, dt)
            )(jnp.arange(cfg.num_layers))
            state["enc_out"] = jnp.zeros((batch, 1, cfg.d_model), dt)  # placeholder
        else:
            state["caches"] = tf.init_stack_caches(cfg, batch, max_len, dt)
        return state

    def prefill(params, batch, state):
        """Process the full prompt; returns (last-token logits, state)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = embed(params["embed"], tokens)
        if cfg.encdec:
            enc_in = batch["enc_embeds"].astype(dt)
            enc_pos = _positions(cfg, b, enc_in.shape[1])
            enc_out = tf.encoder_forward(params["encdec"], cfg, enc_in, enc_pos)
            pos = _positions(cfg, b, s)
            # teacher-forced pass filling self-attn caches
            def body(h, scanned):
                p, cache = scanned
                from repro.models.attention import attention_prefill, cross_attention

                y, cache = attention_prefill(
                    p["attn"], cfg, tf._norm(cfg, p["ln1"], h), pos, cache,
                    backend=cfg.linear_backend)
                h = h + y
                h = h + cross_attention(p["xattn"], cfg, tf._norm(cfg, p["lnx"], h),
                                        enc_out, backend=cfg.linear_backend)
                h = h + tf.ffn(p["ffn"], cfg, tf._norm(cfg, p["ln2"], h),
                               backend=cfg.linear_backend)
                return h, cache

            x, caches = jax.lax.scan(body, x, (params["encdec"]["dec"], state["caches"]))
            state = {**state, "caches": caches, "enc_out": enc_out,
                     "pos": jnp.full((b, 1), s, jnp.int32)}
        else:
            pos = _positions(cfg, b, s)
            x, caches = tf.stack_prefill(params["layers"], cfg, x, pos, state["caches"])
            state = {**state, "caches": caches, "pos": jnp.full((b, 1), s, jnp.int32)}
        logits = _logits(params, _norm_f(params, x[:, -1:]))
        return logits[:, 0], state

    def decode_step(params, state, tokens):
        """tokens (B,) -> (logits (B, V), new state); one step, KV cache."""
        b = tokens.shape[0]
        x = embed(params["embed"], tokens[:, None])
        pos = state["pos"]
        if cfg.encdec:
            x, caches = tf.decoder_decode(params["encdec"], cfg, x, pos,
                                          state["caches"], state["enc_out"])
        else:
            x, caches = tf.stack_decode(params["layers"], cfg, x, pos, state["caches"])
        logits = _logits(params, _norm_f(params, x))
        new_state = {**state, "caches": caches, "pos": pos + 1}
        return logits[:, 0], new_state

    return Model(cfg, init, loss, prefill, decode_step, init_decode_state)
