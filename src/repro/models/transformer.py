"""Transformer assembly: uniform decoder stacks (dense/MoE/SSM), hybrid
interleave (Jamba), and encoder-decoder (Whisper).  Layer stacks are scanned
(stacked params) with optional remat; caches thread through the same scans
for decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_mod
from repro.models.attention import (
    attention,
    attention_decode,
    attention_prefill,
    attn_init,
    cross_attention,
    init_kv_cache,
)
from repro.models.layers import (
    Params,
    activation,
    is_gated,
    layernorm,
    layernorm_init,
    linear,
    linear_init,
    rmsnorm,
    rmsnorm_init,
    seq_shard,
)
from repro.models.moe import moe_ffn, moe_init


def _norm_init(cfg, dtype):
    return layernorm_init(cfg.d_model, dtype) if cfg.norm == "layernorm" else rmsnorm_init(cfg.d_model, dtype)


def _norm(cfg, p, x):
    return layernorm(p, x, cfg.norm_eps) if cfg.norm == "layernorm" else rmsnorm(p, x, cfg.norm_eps)


# ------------------------------------------------------------------- FFN
def ffn_init(key, cfg, dtype, d_ff=None) -> Params:
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": linear_init(ks[0], cfg.d_model, ff, dtype),
        "w_down": linear_init(ks[1], ff, cfg.d_model, dtype),
    }
    if is_gated(cfg.activation):
        p["w_gate"] = linear_init(ks[2], cfg.d_model, ff, dtype)
    return p


def ffn(p: Params, cfg, x: jax.Array, *, backend: str = "dense") -> jax.Array:
    up = linear(p["w_up"], x, backend=backend)
    if is_gated(cfg.activation):
        gate = linear(p["w_gate"], x, backend=backend)
        h = activation(cfg.activation, gate, up)
    else:
        h = activation(cfg.activation, up)
    return linear(p["w_down"], h, backend=backend)


# ------------------------------------------------------------ uniform block
def block_init(key, cfg, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p = {"ln1": _norm_init(cfg, dtype), "ln2": _norm_init(cfg, dtype)}
    if cfg.family == "ssm":
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
        del p["ln2"]
        return p
    p["attn"] = attn_init(ks[0], cfg, dtype)
    if cfg.is_moe:
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        p["ffn"] = ffn_init(ks[1], cfg, dtype)
    return p


def block_forward(p, cfg, x, positions, *, causal=True):
    be = cfg.linear_backend
    if cfg.seq_sharded_acts:
        x = seq_shard(x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        return x + ssm_mod.ssm_forward(p["ssm"], cfg, _norm(cfg, p["ln1"], x),
                                       chunk=cfg.ssd_chunk, backend=be), aux
    x = x + attention(p["attn"], cfg, _norm(cfg, p["ln1"], x), positions,
                      causal=causal, backend=be)
    if cfg.is_moe:
        y, aux = moe_ffn(p["moe"], cfg, _norm(cfg, p["ln2"], x),
                         group_size=cfg.moe_group_size,
                         capacity_factor=cfg.capacity_factor, backend=be)
        x = x + y
    else:
        x = x + ffn(p["ffn"], cfg, _norm(cfg, p["ln2"], x), backend=be)
    return x, aux


# ------------------------------------------------------------ hybrid (Jamba)
def group_init(key, cfg, dtype) -> Params:
    """One Jamba group = attn_period layers: 1 attention + (P-1) mamba,
    FFN after every layer; MoE FFN on odd in-group indices."""
    per = cfg.attn_period
    n_moe = per // 2
    n_dense = per - n_moe
    ks = jax.random.split(key, 6)
    sub = lambda k, n, fn: jax.vmap(lambda kk: fn(kk))(jax.random.split(k, n))
    return {
        "ln_mix": sub(ks[0], per, lambda k: _norm_init(cfg, dtype)),
        "ln_ffn": sub(ks[1], per, lambda k: _norm_init(cfg, dtype)),
        "attn": attn_init(ks[2], cfg, dtype),
        "ssm": sub(ks[3], per - 1, lambda k: ssm_mod.ssm_init(k, cfg, dtype)),
        "ffn": sub(ks[4], n_dense, lambda k: ffn_init(k, cfg, dtype)),
        "moe": sub(ks[5], n_moe, lambda k: moe_init(k, cfg, dtype)),
    }


def group_forward(p, cfg, x, positions):
    be = cfg.linear_backend
    if cfg.seq_sharded_acts:
        x = seq_shard(x)
    per = cfg.attn_period
    attn_at = per // 2
    aux = jnp.zeros((), jnp.float32)
    tree_i = lambda t, i: jax.tree.map(lambda a: a[i], t)
    si = di = mi = 0
    for j in range(per):
        h = _norm(cfg, tree_i(p["ln_mix"], j), x)
        if j == attn_at:
            x = x + attention(p["attn"], cfg, h, positions, backend=be)
        else:
            x = x + ssm_mod.ssm_forward(tree_i(p["ssm"], si), cfg, h,
                                        chunk=cfg.ssd_chunk, backend=be)
            si += 1
        h = _norm(cfg, tree_i(p["ln_ffn"], j), x)
        if j % 2 == 1:
            y, a = moe_ffn(tree_i(p["moe"], mi), cfg, h,
                           group_size=cfg.moe_group_size,
                           capacity_factor=cfg.capacity_factor, backend=be)
            x = x + y
            aux = aux + a
            mi += 1
        else:
            x = x + ffn(tree_i(p["ffn"], di), cfg, h, backend=be)
            di += 1
    return x, aux


# --------------------------------------------------------------- stacks
def stack_init(key, cfg, dtype) -> Params:
    """Stacked per-layer params: leading axis = scan axis."""
    if cfg.is_hybrid:
        n = cfg.num_layers // cfg.attn_period
        return jax.vmap(lambda k: group_init(k, cfg, dtype))(jax.random.split(key, n))
    return jax.vmap(lambda k: block_init(k, cfg, dtype))(jax.random.split(key, cfg.num_layers))


def stack_forward(params, cfg, x, positions, *, causal=True):
    fwd = group_forward if cfg.is_hybrid else functools.partial(block_forward, causal=causal)

    def body(carry, layer_params):
        h, aux = carry
        h, a = fwd(layer_params, cfg, h, positions)
        return (h, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params,
                               unroll=cfg.scan_unroll)
    return x, aux


# --------------------------------------------------------------- decode path
def init_block_cache(cfg, batch: int, max_len: int, dtype):
    if cfg.family == "ssm":
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    return init_kv_cache(cfg, batch, max_len, dtype)


def init_stack_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.is_hybrid:
        n = cfg.num_layers // cfg.attn_period
        per = cfg.attn_period

        def one(_):
            return {
                "attn": init_kv_cache(cfg, batch, max_len, dtype),
                "ssm": jax.vmap(lambda _: ssm_mod.init_ssm_cache(cfg, batch, jnp.float32))(
                    jnp.arange(per - 1)
                ),
            }

        return jax.vmap(one)(jnp.arange(n))
    return jax.vmap(lambda _: init_block_cache(cfg, batch, max_len, dtype))(
        jnp.arange(cfg.num_layers)
    )


def _block_decode(p, cfg, x, pos, cache):
    be = cfg.linear_backend
    if cfg.family == "ssm":
        y, cache = ssm_mod.ssm_decode_step(p["ssm"], cfg, _norm(cfg, p["ln1"], x),
                                           cache, backend=be)
        return x + y, cache
    y, cache = attention_decode(p["attn"], cfg, _norm(cfg, p["ln1"], x), pos,
                                cache, backend=be)
    x = x + y
    if cfg.is_moe:
        y, _ = moe_ffn(p["moe"], cfg, _norm(cfg, p["ln2"], x),
                       group_size=cfg.moe_group_size,
                       capacity_factor=2.0, backend=be)
        x = x + y
    else:
        x = x + ffn(p["ffn"], cfg, _norm(cfg, p["ln2"], x), backend=be)
    return x, cache


def _group_decode(p, cfg, x, pos, cache):
    be = cfg.linear_backend
    per = cfg.attn_period
    attn_at = per // 2
    tree_i = lambda t, i: jax.tree.map(lambda a: a[i], t)
    si = di = mi = 0
    new_ssm = []
    attn_cache = cache["attn"]
    for j in range(per):
        h = _norm(cfg, tree_i(p["ln_mix"], j), x)
        if j == attn_at:
            y, attn_cache = attention_decode(p["attn"], cfg, h, pos, attn_cache, backend=be)
            x = x + y
        else:
            y, c = ssm_mod.ssm_decode_step(tree_i(p["ssm"], si), cfg, h,
                                           tree_i(cache["ssm"], si), backend=be)
            x = x + y
            new_ssm.append(c)
            si += 1
        h = _norm(cfg, tree_i(p["ln_ffn"], j), x)
        if j % 2 == 1:
            y, _ = moe_ffn(tree_i(p["moe"], mi), cfg, h,
                           group_size=cfg.moe_group_size, capacity_factor=2.0,
                           backend=be)
            x = x + y
            mi += 1
        else:
            x = x + ffn(tree_i(p["ffn"], di), cfg, h, backend=be)
            di += 1
    ssm_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_ssm)
    return x, {"attn": attn_cache, "ssm": ssm_stacked}


def stack_decode(params, cfg, x, pos, caches):
    dec = _group_decode if cfg.is_hybrid else _block_decode

    def body(carry, scanned):
        h = carry
        layer_params, cache = scanned
        h, new_cache = dec(layer_params, cfg, h, pos, cache)
        return h, new_cache

    x, new_caches = jax.lax.scan(body, x, (params, caches),
                                 unroll=cfg.scan_unroll)
    return x, new_caches


def _block_prefill(p, cfg, x, positions, cache):
    """Full-seq pass that fills caches (serving prefill)."""
    be = cfg.linear_backend
    if cfg.family == "ssm":
        h = _norm(cfg, p["ln1"], x)
        y, new_cache = ssm_mod.ssm_prefill(p["ssm"], cfg, h, chunk=cfg.ssd_chunk,
                                           backend=be)
        return x + y, new_cache
    y, cache = attention_prefill(p["attn"], cfg, _norm(cfg, p["ln1"], x),
                                 positions, cache, backend=be)
    x = x + y
    if cfg.is_moe:
        y, _ = moe_ffn(p["moe"], cfg, _norm(cfg, p["ln2"], x),
                       group_size=cfg.moe_group_size,
                       capacity_factor=cfg.capacity_factor, backend=be)
        x = x + y
    else:
        x = x + ffn(p["ffn"], cfg, _norm(cfg, p["ln2"], x), backend=be)
    return x, cache


def _group_prefill(p, cfg, x, positions, cache):
    be = cfg.linear_backend
    per = cfg.attn_period
    attn_at = per // 2
    tree_i = lambda t, i: jax.tree.map(lambda a: a[i], t)
    si = di = mi = 0
    new_ssm = []
    attn_cache = cache["attn"]
    for j in range(per):
        h = _norm(cfg, tree_i(p["ln_mix"], j), x)
        if j == attn_at:
            y, attn_cache = attention_prefill(p["attn"], cfg, h, positions,
                                              attn_cache, backend=be)
            x = x + y
        else:
            sp = tree_i(p["ssm"], si)
            y, c = ssm_mod.ssm_prefill(sp, cfg, h, chunk=cfg.ssd_chunk, backend=be)
            x = x + y
            new_ssm.append(c)
            si += 1
        h = _norm(cfg, tree_i(p["ln_ffn"], j), x)
        if j % 2 == 1:
            y, _ = moe_ffn(tree_i(p["moe"], mi), cfg, h,
                           group_size=cfg.moe_group_size,
                           capacity_factor=cfg.capacity_factor, backend=be)
            x = x + y
            mi += 1
        else:
            x = x + ffn(tree_i(p["ffn"], di), cfg, h, backend=be)
            di += 1
    ssm_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_ssm)
    return x, {"attn": attn_cache, "ssm": ssm_stacked}


def stack_prefill(params, cfg, x, positions, caches):
    pre = _group_prefill if cfg.is_hybrid else _block_prefill

    def body(carry, scanned):
        h = carry
        layer_params, cache = scanned
        h, new_cache = pre(layer_params, cfg, h, positions, cache)
        return h, new_cache

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, new_caches = jax.lax.scan(body, x, (params, caches),
                                 unroll=cfg.scan_unroll)
    return x, new_caches


# --------------------------------------------------------- encoder-decoder
def encdec_init(key, cfg, dtype) -> Params:
    ks = jax.random.split(key, 4)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": _norm_init(cfg, dtype), "attn": attn_init(k1, cfg, dtype),
                "ln2": _norm_init(cfg, dtype), "ffn": ffn_init(k2, cfg, dtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": _norm_init(cfg, dtype), "attn": attn_init(k1, cfg, dtype),
            "lnx": _norm_init(cfg, dtype), "xattn": attn_init(k2, cfg, dtype),
            "ln2": _norm_init(cfg, dtype), "ffn": ffn_init(k3, cfg, dtype),
        }

    return {
        "enc": jax.vmap(enc_layer)(jax.random.split(ks[0], cfg.enc_layers)),
        "dec": jax.vmap(dec_layer)(jax.random.split(ks[1], cfg.num_layers)),
        "ln_enc": _norm_init(cfg, dtype),
    }


def encoder_forward(params, cfg, x, positions):
    be = cfg.linear_backend

    def body(h, p):
        h = h + attention(p["attn"], cfg, _norm(cfg, p["ln1"], h), positions,
                          causal=False, backend=be)
        h = h + ffn(p["ffn"], cfg, _norm(cfg, p["ln2"], h), backend=be)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc"], unroll=cfg.scan_unroll)
    return _norm(cfg, params["ln_enc"], x)


def decoder_forward(params, cfg, x, positions, enc_out):
    be = cfg.linear_backend

    def body(h, p):
        h = h + attention(p["attn"], cfg, _norm(cfg, p["ln1"], h), positions,
                          causal=True, backend=be)
        h = h + cross_attention(p["xattn"], cfg, _norm(cfg, p["lnx"], h),
                                enc_out, backend=be)
        h = h + ffn(p["ffn"], cfg, _norm(cfg, p["ln2"], h), backend=be)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec"], unroll=cfg.scan_unroll)
    return x


def decoder_decode(params, cfg, x, pos, caches, enc_out):
    be = cfg.linear_backend

    def body(h, scanned):
        p, cache = scanned
        y, cache = attention_decode(p["attn"], cfg, _norm(cfg, p["ln1"], h),
                                    pos, cache, backend=be)
        h = h + y
        h = h + cross_attention(p["xattn"], cfg, _norm(cfg, p["lnx"], h),
                                enc_out, backend=be)
        h = h + ffn(p["ffn"], cfg, _norm(cfg, p["ln2"], h), backend=be)
        return h, cache

    x, new_caches = jax.lax.scan(body, x, (params["dec"], caches),
                                 unroll=cfg.scan_unroll)
    return x, new_caches
