"""Shared model layers: norms, linears (dense | MVU-quantized), rotary
embeddings (RoPE / partial / M-RoPE), activations.

Everything is functional: params are plain dict pytrees, layers are pure
functions.  ``linear`` is the integration point for the paper's technique:
with ``backend="mvu_*"`` the projection runs through the quantized MVU
datapath (fake-quant STE during training, integer kernels at serving).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.mvu import quantized_linear
from repro.core.quantize import QTensor, fake_quant_weights

Params = dict[str, Any]


# ---------------------------------------------------------------- init utils
def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def linear_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> Params:
    return {"w": _dense_init(key, (d_in, d_out), dtype)}


# ---------------------------------------------------------------- linear
MVU_BACKENDS = {
    "mvu_w8a8": (8, 8),
    "mvu_w4a8": (4, 8),
    "mvu_w4a4": (4, 4),
    "mvu_binary": (1, 8),
}


def linear(p: Params, x: jax.Array, *, backend: str = "dense") -> jax.Array:
    """y = x @ w  (+ quantized datapaths).

    dense:     w stored (d_in, d_out), plain matmul.
    mvu_* fake-quant (training): weights STE-quantized, float matmul.
    mvu_* integer (serving): p holds {"values" (out,in) int8, "scale"} and
    the MVU kernel (xla backend for GSPMD-sharded graphs) runs the dot.
    """
    if "values" in p:  # integer-deployed MVU weights
        w_bits, a_bits = MVU_BACKENDS[backend] if backend in MVU_BACKENDS else (8, 8)
        vals = p["values"]
        if "int4" in str(vals.dtype):  # unpack for the int8-carried datapath
            vals = vals.astype(jnp.int8)
        qt = QTensor(vals, p["scale"], w_bits, True)
        return quantized_linear(x, qt, act_bits=a_bits, backend="xla")
    w = p["w"]
    if backend in MVU_BACKENDS:
        w_bits, _ = MVU_BACKENDS[backend]
        w = fake_quant_weights(w, w_bits, axis=1)
    return x @ w


def quantize_linear_params(p: Params, backend: str) -> Params:
    """dense params -> integer MVU deployment params (out,in int8 + scale)."""
    from repro.core.quantize import quantize_weights

    w_bits, _ = MVU_BACKENDS[backend]
    qt = quantize_weights(p["w"].T.astype(jnp.float32), w_bits, axis=0)
    vals = qt.values.astype(jnp.int4) if w_bits <= 4 else qt.values
    return {"values": vals, "scale": qt.scale.reshape(-1)}


PROJ_NAMES = ("wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down")


def quantize_model_params(params: Params, backend: str) -> Params:
    """Post-training quantization of every projection in a model tree onto
    the MVU integer grid (handles layer-stacked (L, in, out) weights)."""

    def one(node):
        w = node["w"]
        if w.ndim == 2:
            return quantize_linear_params(node, backend)
        flat = w.reshape(-1, *w.shape[-2:])
        outs = [quantize_linear_params({"w": flat[i]}, backend) for i in range(flat.shape[0])]
        vals = jnp.stack([o["values"] for o in outs]).reshape(
            *w.shape[:-2], w.shape[-1], w.shape[-2])
        scales = jnp.stack([o["scale"] for o in outs]).reshape(*w.shape[:-2], w.shape[-1])
        return {"values": vals, "scale": scales}

    def walk(node, name):
        if isinstance(node, dict):
            if name in PROJ_NAMES and set(node) == {"w"} and node["w"].ndim >= 2:
                return one(node)
            return {k: walk(v, k) for k, v in node.items()}
        return node

    return walk(params, "")


# ---------------------------------------------------------------- norms
def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- activations
def activation(name: str, gate: jax.Array, up: jax.Array | None = None) -> jax.Array:
    if name == "swiglu":
        assert up is not None
        return jax.nn.silu(gate) * up
    if name == "geglu":
        assert up is not None
        return jax.nn.gelu(gate) * up
    if name == "squared_relu":  # Nemotron-4 (Primer)
        return jnp.square(jax.nn.relu(gate))
    if name == "gelu":
        return jax.nn.gelu(gate)
    raise ValueError(f"unknown activation {name!r}")


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


# ---------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float, rot_dim: int | None = None) -> jax.Array:
    rd = rot_dim or head_dim
    return 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))


def apply_rope(
    x: jax.Array,  # (B, S, H, hd)
    positions: jax.Array,  # (B, S)
    theta: float = 1e4,
    rot_dim: int | None = None,
) -> jax.Array:
    hd = x.shape[-1]
    rd = rot_dim or hd
    freqs = rope_freqs(hd, theta, rd)  # (rd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, rd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def apply_mrope(
    x: jax.Array,  # (B, S, H, hd)
    positions: jax.Array,  # (3, B, S): temporal, height, width ids
    theta: float = 1e6,
    sections: tuple[int, int, int] = (16, 24, 24),  # half-dims per axis
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the rotary half-dims are split into
    (temporal, height, width) sections, each rotated by its own position id.
    Text tokens carry identical t/h/w ids, which degenerates to 1-D RoPE.
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))  # (half,)
    # per-frequency position id chosen by section
    sec_id = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (half,)
    pos = positions.astype(jnp.float32)  # (3, B, S)
    pos_per_freq = jnp.take(pos, sec_id, axis=0)  # (half, B, S) -> gather axis0
    ang = jnp.moveaxis(pos_per_freq, 0, -1) * inv  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- embeddings
def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: Params, x: jax.Array) -> jax.Array:
    return x @ p["table"].T


def seq_shard(x: jax.Array) -> jax.Array:
    """Megatron-style sequence parallelism: shard the residual stream's
    sequence dim over "model".  Cuts the remat-saved activation footprint by
    the TP degree; GSPMD inserts the all-gather before attention/MLP matmuls
    and the reduce-scatter after (see EXPERIMENTS.md section Perf).

    No-op when no mesh context is active, when "model" is absent, or when
    the sequence does not divide evenly (e.g. decode steps).
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - old jax
        return x
    if mesh is None or not getattr(mesh, "axis_names", None):
        return x
    if "model" not in mesh.axis_names or x.ndim < 3:
        return x
    size = dict(mesh.shape)["model"]
    if x.shape[1] <= 1 or x.shape[1] % size:
        return x
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = P(dp if dp else None, "model", None)
    return jax.lax.with_sharding_constraint(x, spec)
