"""Mixture-of-Experts FFN: top-k routing with capacity-grouped one-hot
dispatch (GShard/Switch style), expressed as einsums so GSPMD shards the
expert dimension over the "model" mesh axis (expert parallelism).

Tokens are processed in groups of ``group_size``; each expert owns
``capacity = group_size * top_k * capacity_factor / num_experts`` slots per
group.  Overflow tokens are dropped (their residual stream passes through),
the standard dropping-MoE training formulation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, activation, is_gated, linear_init


def moe_init(key, cfg, dtype=jnp.bfloat16) -> Params:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": linear_init(ks[0], d, e, jnp.float32),
        "w_up": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (e, f, d)) * (1.0 / jnp.sqrt(f))).astype(dtype),
    }
    if is_gated(cfg.activation):
        p["w_gate"] = (jax.random.normal(ks[3], (e, d, f)) * scale).astype(dtype)
    return p


def _capacity(group: int, e: int, k: int, factor: float) -> int:
    return max(4, int(group * k * factor / e))


def route_topk(logits: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """(T, E) -> (weights (T, k), idx (T, k)); weights renormalized softmax."""
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals.astype(jnp.float32), axis=-1)
    return w, idx


def dispatch_combine(
    idx: jax.Array,  # (G, k) expert ids per token in group
    weights: jax.Array,  # (G, k)
    e: int,
    capacity: int,
):
    """Build one-hot dispatch (G, E, C) bool-ish and combine (G, E, C) f32."""
    g, k = idx.shape
    dispatch = jnp.zeros((g, e, capacity), jnp.bfloat16)
    combine = jnp.zeros((g, e, capacity), jnp.float32)
    counts = jnp.zeros((e,), jnp.int32)
    for j in range(k):  # k is small and static
        onehot = jax.nn.one_hot(idx[:, j], e, dtype=jnp.int32)  # (G, E)
        pos = jnp.cumsum(onehot, axis=0) - 1 + counts[None, :]  # (G, E)
        keep = (pos < capacity) & (onehot > 0)
        pos_c = jax.nn.one_hot(pos, capacity, dtype=jnp.bfloat16)  # (G, E, C)
        sel = pos_c * keep[..., None].astype(jnp.bfloat16)
        dispatch = dispatch + sel
        combine = combine + sel.astype(jnp.float32) * weights[:, j, None, None]
        counts = counts + jnp.sum(onehot * keep.astype(jnp.int32), axis=0)
    return dispatch, combine


def load_balancing_loss(logits: jax.Array, idx: jax.Array, e: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e (fraction routed) * (mean prob)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (T, E)
    frac = jnp.mean(
        jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=0
    )  # top-1 routed fraction
    return e * jnp.sum(frac * jnp.mean(probs, axis=0))


def moe_ffn(
    p: Params,
    cfg,
    x: jax.Array,  # (B, S, d)
    *,
    group_size: int = 512,
    capacity_factor: float = 1.25,
    backend: str = "dense",
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = b * s
    g = min(group_size, t)
    n_groups = t // g
    xt = x.reshape(n_groups, g, d)

    logits = jnp.einsum("ngd,de->nge", xt.astype(jnp.float32), p["router"]["w"])
    weights, idx = route_topk(logits.reshape(-1, e), k)
    aux = load_balancing_loss(logits.reshape(-1, e), idx, e)
    weights = weights.reshape(n_groups, g, k)
    idx = idx.reshape(n_groups, g, k)

    cap = _capacity(g, e, k, capacity_factor)
    dispatch, combine = jax.vmap(
        lambda i, w: dispatch_combine(i, w, e, cap)
    )(idx, weights)  # (n, G, E, C) each

    # expert inputs: (n, E, C, d); experts sharded over "model" via e-dim
    xe = jnp.einsum("ngec,ngd->necd", dispatch, xt.astype(jnp.bfloat16))
    up = jnp.einsum("necd,edf->necf", xe, p["w_up"])
    if is_gated(cfg.activation):
        gate = jnp.einsum("necd,edf->necf", xe, p["w_gate"])
        h = activation(cfg.activation, gate, up)
    else:
        h = activation(cfg.activation, up)
    ye = jnp.einsum("necf,efd->necd", h, p["w_down"])
    out = jnp.einsum("ngec,necd->ngd", combine.astype(ye.dtype), ye)
    return out.reshape(b, s, d).astype(x.dtype), aux.astype(jnp.float32)
