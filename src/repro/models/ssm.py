"""Mamba-2: the SSD (state-space duality) layer [arXiv:2405.21060].

Chunked SSD for training/prefill (quadratic attention-like term inside
chunks, linear recurrence across chunk states) and the O(1)-per-token
recurrent form for decode.

Projections are stored *unfused* (w_z / w_x / w_B / w_C / w_dt and a
per-segment depthwise conv) so tensor parallelism shards heads cleanly over
the "model" mesh axis: z/x/dt columns and A/D/dt_bias/state head dims are
all multiples of the head count; B/C (ngroups * dstate) stay replicated.
XLA re-fuses the matmuls; GSPMD never has to split a fused projection at
segment boundaries.

Shapes (mamba2-780m): d_model 1536, expand 2 -> d_inner 3072, headdim 64 ->
48 heads, ngroups 1, dstate 128, conv kernel 4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, linear, linear_init, rmsnorm

NEG_INF = -1e30


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_inner, nheads, conv_dim


def ssm_init(key, cfg, dtype=jnp.bfloat16) -> Params:
    d_inner, nheads, _ = ssm_dims(cfg)
    gn = cfg.ssm_groups * cfg.ssm_state
    ks = jax.random.split(key, 7)
    conv = lambda k, c: (jax.random.normal(k, (cfg.ssm_conv, c)) * 0.2).astype(dtype)
    return {
        "w_z": linear_init(ks[0], cfg.d_model, d_inner, dtype),
        "w_x": linear_init(ks[1], cfg.d_model, d_inner, dtype),
        "w_B": linear_init(ks[2], cfg.d_model, gn, dtype),
        "w_C": linear_init(ks[3], cfg.d_model, gn, dtype),
        "w_dt": linear_init(ks[4], cfg.d_model, nheads, dtype),
        "conv_x": {"w": conv(ks[5], d_inner), "b": jnp.zeros((d_inner,), dtype)},
        "conv_B": {"w": conv(ks[6], gn), "b": jnp.zeros((gn,), dtype)},
        "conv_C": {"w": conv(ks[6], gn), "b": jnp.zeros((gn,), dtype)},
        "A_log": jnp.log(jnp.arange(1, nheads + 1, dtype=jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nheads,), 0.01, jnp.float32))),
        "norm": {"scale": jnp.ones((d_inner,), dtype)},
        "out_proj": linear_init(ks[4], d_inner, cfg.d_model, dtype),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """(..., q) -> (..., q, q): s[i,j] = sum_{j<t<=i} a[t], -inf above diag."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, d, NEG_INF)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d + SiLU: x (B, S, C), w (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w[:, None, :].astype(jnp.float32),  # (K, 1, C) HIO
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=w.shape[1],
    )
    return (jax.nn.silu(out + b.astype(jnp.float32))).astype(x.dtype)


def _conv_step(win: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """One-token conv: win (B, K, C) -> (B, C)."""
    out = jnp.einsum("bkc,kc->bc", win.astype(jnp.float32), w.astype(jnp.float32))
    return jax.nn.silu(out + b.astype(jnp.float32))


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) softplus'd
    a_log: jax.Array,  # (H,)
    b_mat: jax.Array,  # (B, S, G, N)
    c_mat: jax.Array,  # (B, S, G, N)
    chunk: int = 128,
    init_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    rep = h // g  # heads per B/C group

    a = -jnp.exp(a_log)  # (H,) negative
    da = dt * a[None, None, :]  # (B, S, H) log-decay per step
    xdt = x * dt[..., None]  # (B, S, H, P) dt-scaled input

    chv = lambda t: t.reshape(bsz, nc, chunk, *t.shape[2:])
    xc, dac = chv(xdt), chv(da)
    bc, cc = chv(b_mat), chv(c_mat)
    dac_h = jnp.moveaxis(dac, -1, 2)  # (B, nc, H, q)

    # 1) intra-chunk (diagonal) term
    lmat = jnp.exp(_segsum(dac_h))  # (B, nc, H, q, q)
    bh = jnp.repeat(bc, rep, axis=3)  # (B, nc, q, H, N)
    ch = jnp.repeat(cc, rep, axis=3)
    y_diag = jnp.einsum(
        "bcqhn,bckhn,bchqk,bckhp->bcqhp",
        ch.astype(jnp.float32), bh.astype(jnp.float32), lmat,
        xc.astype(jnp.float32),
    )

    # 2) chunk-final states
    a_cum = jnp.cumsum(dac_h, axis=-1)  # (B, nc, H, q)
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)
    states = jnp.einsum(
        "bckhn,bchk,bckhp->bchpn",
        bh.astype(jnp.float32), decay_states, xc.astype(jnp.float32),
    )  # (B, nc, H, P, N)

    # 3) inter-chunk recurrence over chunk states (associative scan)
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B, nc, H)

    def combine(left, right):
        dl, sl = left
        dr, sr = right
        return dl * dr, sr + sl * dr[..., None, None]

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, st = jax.lax.associative_scan(combine, (chunk_decay, states), axis=1)
    # st[c] = chunk-exit state with zero init; state *entering* chunk c is
    # st[c-1] plus the initial state decayed through chunks 0..c-1.
    tot_dec = jnp.cumprod(chunk_decay, axis=1)
    init_in = jnp.concatenate(
        [jnp.ones_like(tot_dec[:, :1]), tot_dec[:, :-1]], axis=1
    )  # (B, nc, H)
    prev = jnp.concatenate([jnp.zeros_like(st[:, :1]), st[:, :-1]], axis=1)
    st_in = prev + init_in[..., None, None] * init_state[:, None]

    # 4) inter-chunk (off-diagonal) output term
    state_decay_out = jnp.exp(a_cum)  # (B, nc, H, q)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bchq->bcqhp",
        ch.astype(jnp.float32), st_in, state_decay_out,
    )

    y = (y_diag + y_off).reshape(bsz, sp, h, p)[:, :s]
    final_state = st[:, -1] + tot_dec[:, -1][..., None, None] * init_state
    return y, final_state


def _project(p, cfg, x, be):
    """x (B,S,d) -> (z, xs, B, C, dt_raw) with per-segment causal convs."""
    d_inner, nheads, _ = ssm_dims(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    bsz, s, _ = x.shape
    z = linear(p["w_z"], x, backend=be)
    xs = _causal_conv(linear(p["w_x"], x, backend=be), p["conv_x"]["w"], p["conv_x"]["b"])
    bm = _causal_conv(linear(p["w_B"], x, backend=be), p["conv_B"]["w"], p["conv_B"]["b"])
    cm = _causal_conv(linear(p["w_C"], x, backend=be), p["conv_C"]["w"], p["conv_C"]["b"])
    dt_raw = linear(p["w_dt"], x, backend=be)
    xs = xs.reshape(bsz, s, nheads, cfg.ssm_headdim)
    bm = bm.reshape(bsz, s, g, n)
    cm = cm.reshape(bsz, s, g, n)
    return z, xs, bm, cm, dt_raw


def _finish(p, cfg, y, xs, z, be, bsz, s):
    d_inner, _, _ = ssm_dims(cfg)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(bsz, s, d_inner).astype(z.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y, backend=be)


def ssm_forward(
    p: Params, cfg, x: jax.Array, *, chunk: int = 128, backend: str = "dense"
) -> jax.Array:
    """Full-sequence Mamba-2 block: x (B, S, d_model) -> (B, S, d_model)."""
    bsz, s, _ = x.shape
    z, xs, bm, cm, dt_raw = _project(p, cfg, x, backend)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    y, _ = ssd_chunked(xs, dt, p["A_log"], bm, cm, chunk=chunk)
    return _finish(p, cfg, y, xs, z, backend, bsz, s)


def ssm_prefill(p: Params, cfg, x: jax.Array, *, chunk: int = 128,
                backend: str = "dense"):
    """Full-seq pass returning the decode cache (conv tails + final state)."""
    bsz, s, _ = x.shape
    kc = cfg.ssm_conv - 1
    z = linear(p["w_z"], x, backend=backend)
    x_pre = linear(p["w_x"], x, backend=backend)
    b_pre = linear(p["w_B"], x, backend=backend)
    c_pre = linear(p["w_C"], x, backend=backend)
    xs = _causal_conv(x_pre, p["conv_x"]["w"], p["conv_x"]["b"])
    bm = _causal_conv(b_pre, p["conv_B"]["w"], p["conv_B"]["b"])
    cm = _causal_conv(c_pre, p["conv_C"]["w"], p["conv_C"]["b"])
    dt_raw = linear(p["w_dt"], x, backend=backend)
    d_inner, nheads, _ = ssm_dims(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    xs = xs.reshape(bsz, s, nheads, cfg.ssm_headdim)
    bm = bm.reshape(bsz, s, g, n)
    cm = cm.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    y, state = ssd_chunked(xs, dt, p["A_log"], bm, cm, chunk=chunk)
    out = _finish(p, cfg, y, xs, z, backend, bsz, s)
    cache = {
        "conv_x": x_pre[:, -kc:, :],
        "conv_B": b_pre[:, -kc:, :],
        "conv_C": c_pre[:, -kc:, :],
        "state": state,
    }
    return out, cache


# ------------------------------------------------------------------ decode
def init_ssm_cache(cfg, batch: int, dtype=jnp.bfloat16):
    d_inner, nheads, _ = ssm_dims(cfg)
    gn = cfg.ssm_groups * cfg.ssm_state
    kc = cfg.ssm_conv - 1
    return {
        "conv_x": jnp.zeros((batch, kc, d_inner), dtype),
        "conv_B": jnp.zeros((batch, kc, gn), dtype),
        "conv_C": jnp.zeros((batch, kc, gn), dtype),
        "state": jnp.zeros((batch, nheads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
    }


def ssm_decode_step(
    p: Params, cfg, x: jax.Array, cache, *, backend: str = "dense"
):
    """x (B, 1, d_model) -> (y (B, 1, d_model), cache)."""
    d_inner, nheads, _ = ssm_dims(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    bsz = x.shape[0]
    xt = x[:, 0]
    z = linear(p["w_z"], xt, backend=backend)
    x_pre = linear(p["w_x"], xt, backend=backend)
    b_pre = linear(p["w_B"], xt, backend=backend)
    c_pre = linear(p["w_C"], xt, backend=backend)
    dt_raw = linear(p["w_dt"], xt, backend=backend)

    win_x = jnp.concatenate([cache["conv_x"], x_pre[:, None].astype(cache["conv_x"].dtype)], axis=1)
    win_b = jnp.concatenate([cache["conv_B"], b_pre[:, None].astype(cache["conv_B"].dtype)], axis=1)
    win_c = jnp.concatenate([cache["conv_C"], c_pre[:, None].astype(cache["conv_C"].dtype)], axis=1)
    xs = _conv_step(win_x, p["conv_x"]["w"], p["conv_x"]["b"]).astype(x.dtype)
    bm = _conv_step(win_b, p["conv_B"]["w"], p["conv_B"]["b"]).astype(x.dtype)
    cm = _conv_step(win_c, p["conv_C"]["w"], p["conv_C"]["b"]).astype(x.dtype)

    xs = xs.reshape(bsz, nheads, cfg.ssm_headdim)
    bm = bm.reshape(bsz, g, n)
    cm = cm.reshape(bsz, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a[None, :])  # (B, H)

    rep = nheads // g
    bh = jnp.repeat(bm, rep, axis=1)  # (B, H, N)
    ch = jnp.repeat(cm, rep, axis=1)
    state = cache["state"] * da[..., None, None] + (
        dt[..., None, None]
        * xs.astype(jnp.float32)[..., None]
        * bh.astype(jnp.float32)[:, :, None, :]
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, ch.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(bsz, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y, backend=backend)
    new_cache = {"conv_x": win_x[:, 1:], "conv_B": win_b[:, 1:],
                 "conv_C": win_c[:, 1:], "state": state}
    return out[:, None, :], new_cache
