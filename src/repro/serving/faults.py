"""Deterministic fault injection for the serving path.

The validation workload the paper targets (an always-on network-intrusion
-detection MLP) fails in ways a throughput benchmark never exercises:
dispatches raise, outputs silently corrupt (the FPGA analog: SEU bit
flips), replicas straggle, hang, or die.  ``FaultPlan`` is the *test
substrate* for all of it -- a seeded, reproducible schedule of injected
faults consulted by :class:`~repro.serving.pool.ReplicaPool` at every
dispatch:

* **explicit events** fire at a named replica's k-th dispatch (``"the
  pool's replica 2 hangs on its 8th launch"``), and
* **background rates** draw per-(replica, dispatch-index) from a
  counter-keyed RNG, so the same plan JSON replays the same fault at the
  same dispatch regardless of wall-clock timing or host load.

Fault kinds: ``error`` (the dispatch raises), ``corrupt`` (the resolved
output is bit-flipped out of the graph's value range), ``straggle`` (the
result is withheld for ``delay_s``), ``hang`` (the result never becomes
ready -- only a dispatch timeout recovers it), ``die`` (this and every
later dispatch on the replica raises).

The module also owns the **integrity guard**: because every target in
this repo is bit-exact by construction, the output of a healthy replica
is *exactly* the interval-arithmetic bound of the lowered graph --
``infer_output_range`` propagates value intervals through the MVU chain
and ``check_integrity`` rejects any resolved batch with a wrong dtype, a
non-finite value, or a value outside the graph's reachable range.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

FAULT_KINDS = ("error", "corrupt", "straggle", "hang", "die")


class DispatchError(RuntimeError):
    """An (injected or real) failure enqueueing a batch on a replica."""

    def __init__(self, msg: str, *, replica: int | None = None):
        super().__init__(msg)
        self.replica = replica


class IntegrityError(RuntimeError):
    """A resolved batch failed the output integrity guard."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault: ``kind`` at ``replica``'s ``at_dispatch``-th
    dispatch (0-based, counted per replica).  ``delay_s`` only applies to
    ``straggle``."""

    kind: str
    replica: int
    at_dispatch: int
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {self.kind!r}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "FaultEvent":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, reproducible fault schedule.

    rates: background per-dispatch probabilities ``{kind: p}``; drawn from
        an RNG keyed on ``(seed, replica, dispatch_index)`` so the draw for
        a given dispatch is a pure function of the plan -- reordering other
        replicas' traffic never changes it.
    events: explicit :class:`FaultEvent` list, consulted before the rates
        (an event at a dispatch suppresses the background draw).
    replicas: when set, background rates only apply to these replica
        indices (events carry their own replica).
    straggle_delay_s: withhold duration for rate-drawn ``straggle`` faults.
    """

    seed: int = 0
    rates: dict = dataclasses.field(default_factory=dict)
    events: tuple = ()
    replicas: tuple | None = None
    straggle_delay_s: float = 0.05

    def __post_init__(self):
        for kind, p in self.rates.items():
            if kind not in FAULT_KINDS:
                raise ValueError(
                    f"rate kind must be one of {FAULT_KINDS}, got {kind!r}")
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"rate for {kind!r} must be in [0, 1], got {p}")
        object.__setattr__(self, "events", tuple(
            e if isinstance(e, FaultEvent) else FaultEvent(**e)
            for e in self.events))
        if self.replicas is not None:
            object.__setattr__(self, "replicas", tuple(self.replicas))

    # ------------------------------------------------------------------ draw
    def draw(self, replica: int, dispatch_index: int) -> FaultEvent | None:
        """The fault (if any) for ``replica``'s ``dispatch_index``-th
        dispatch.  Deterministic: same plan, same arguments, same answer."""
        for ev in self.events:
            if ev.replica == replica and ev.at_dispatch == dispatch_index:
                return ev
        if not self.rates:
            return None
        if self.replicas is not None and replica not in self.replicas:
            return None
        # counter-keyed RNG: the draw depends only on (seed, replica, k)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, replica, dispatch_index]))
        u = rng.uniform()
        edge = 0.0
        for kind in FAULT_KINDS:  # fixed order keeps the draw stable
            p = self.rates.get(kind, 0.0)
            if p <= 0.0:
                continue
            edge += p
            if u < edge:
                delay = self.straggle_delay_s if kind == "straggle" else 0.0
                return FaultEvent(kind, replica, dispatch_index, delay)
        return None

    def corruption_rng(self, replica: int, dispatch_index: int):
        """Seeded RNG for reproducible output corruption of one dispatch."""
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, 7919, replica, dispatch_index]))

    # ------------------------------------------------------------------ json
    def to_json(self) -> dict:
        return {
            "seed": self.seed,
            "rates": dict(self.rates),
            "events": [e.to_json() for e in self.events],
            "replicas": None if self.replicas is None else list(self.replicas),
            "straggle_delay_s": self.straggle_delay_s,
        }

    @classmethod
    def from_json(cls, d: dict) -> "FaultPlan":
        d = dict(d)
        d["events"] = tuple(FaultEvent.from_json(e) for e in d.get("events", ()))
        if d.get("replicas") is not None:
            d["replicas"] = tuple(d["replicas"])
        return cls(**d)

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))


# --------------------------------------------------------------- corruption
def corrupt_array(y: np.ndarray, rng, *, fraction: float = 0.25) -> np.ndarray:
    """Deterministically corrupt a copy of ``y`` (never in place).

    Integer outputs get SEU-style high-bit flips (XOR with bit 30 -- the
    value blasts out of any reachable accumulator range, which is exactly
    what the integrity guard's interval bound catches); float outputs get
    NaNs.  At least one element is always corrupted.
    """
    out = np.array(y, copy=True)
    flat = out.reshape(-1)
    n = max(1, int(fraction * flat.size))
    idx = rng.choice(flat.size, size=n, replace=False)
    if np.issubdtype(out.dtype, np.integer):
        flat[idx] = flat[idx] ^ np.array(1 << 30, dtype=out.dtype)
    else:
        flat[idx] = np.nan
    return out


# ----------------------------------------------------------- integrity guard
def _mvu_interval(node, lo: float, hi: float) -> tuple[float, float] | None:
    """Output interval of an mvu/conv_mvu node given input interval."""
    p = node.params.get("mvu")
    if p is None:
        return None
    if getattr(p, "thresholds", None) is not None:
        # multi-threshold epilogue: output is the threshold level count
        t = np.asarray(p.thresholds)
        return (0.0, float(t.shape[-1]))
    cfg = node.attrs.get("config")
    mode = getattr(cfg, "mode", "standard")
    if mode == "xnor":
        # bipolar popcount dot: |y| <= K
        k = float(getattr(cfg, "in_features", 0) or 0)
        ylo, yhi = -k, k
    else:
        w = np.asarray(p.weights, dtype=np.float64)
        if w.ndim != 2:
            return None
        wpos = np.clip(w, 0.0, None)
        wneg = np.clip(w, None, 0.0)
        yhi = float((wpos * hi + wneg * lo).sum(axis=1).max())
        ylo = float((wpos * lo + wneg * hi).sum(axis=1).min())
    scale = getattr(p, "out_scale", None)
    if scale is not None:
        s = np.asarray(scale, dtype=np.float64)
        smax = float(np.abs(s).max()) if s.size else 1.0
        bound = max(abs(ylo), abs(yhi)) * smax
        return (-bound, bound)
    return (ylo, yhi)


def infer_output_range(graph) -> tuple[float, float] | None:
    """Conservative (lo, hi) bound on the graph's output values.

    Scalar interval arithmetic over the lowered op set -- exact enough to
    catch high-bit corruption (an SEU flip lands ~2^30 past any reachable
    accumulator), cheap enough to precompute once at pool construction.
    Returns None when the graph contains an op the propagation does not
    model (the range check is then disabled; dtype/finite checks remain).
    """
    from repro.core import ir

    try:
        graph = ir.as_graph(graph)
        order = ir.toposort(graph)
        sink = ir.graph_output(graph).name
    except Exception:
        return None
    ranges: dict[str, tuple[float, float]] = {}
    for node in order:
        ins = [ranges.get(src) for src in (node.inputs or ())]
        if node.op == "input":
            bits = int(node.attrs.get("bits", 1))
            r = (0.0, float(2 ** bits - 1))
        elif node.op in ("mvu", "conv_mvu"):
            if not ins or ins[0] is None:
                return None
            r = _mvu_interval(node, *ins[0])
        elif node.op == "quant_act":
            bits = int(node.attrs["bits"])
            r = (0.0, float(2 ** bits - 1))
        elif node.op in ("flatten", "maxpool", "swu"):
            r = ins[0] if ins else None
        elif node.op == "batchnorm":
            if not ins or ins[0] is None:
                return None
            lo, hi = ins[0]
            g = np.asarray(node.params["gamma"], dtype=np.float64)
            b = np.asarray(node.params["beta"], dtype=np.float64)
            m = np.asarray(node.params["mean"], dtype=np.float64)
            v = np.asarray(node.params["var"], dtype=np.float64)
            a = g / np.sqrt(v + 1e-5)
            cands = np.stack([a * (lo - m) + b, a * (hi - m) + b])
            r = (float(cands.min()), float(cands.max()))
        elif node.op in ("add", "sub", "mul"):
            if len(ins) != 2 or ins[0] is None or ins[1] is None:
                return None
            sa, sb = node.attrs.get("scales", (1, 1))
            (alo, ahi), (blo, bhi) = ins
            alo, ahi = sorted((alo * sa, ahi * sa))
            blo, bhi = sorted((blo * sb, bhi * sb))
            if node.op == "add":
                r = (alo + blo, ahi + bhi)
            elif node.op == "sub":
                r = (alo - bhi, ahi - blo)
            else:
                prods = [alo * blo, alo * bhi, ahi * blo, ahi * bhi]
                r = (min(prods), max(prods))
        else:
            return None
        if r is None:
            return None
        ranges[node.name] = r
    return ranges.get(sink)


def check_integrity(ys: np.ndarray, *, dtype=None,
                    value_range: tuple[float, float] | None = None) -> str | None:
    """Cheap per-batch output checks; returns a reason string on failure,
    None when the batch is clean.  O(batch) numpy reductions -- run on
    every resolved batch without denting throughput."""
    ys = np.asarray(ys)
    if dtype is not None and ys.dtype != np.dtype(dtype):
        return f"output dtype {ys.dtype} != expected {np.dtype(dtype)}"
    if np.issubdtype(ys.dtype, np.floating) and not np.isfinite(ys).all():
        return "non-finite values in output"
    if value_range is not None and ys.size:
        lo, hi = value_range
        ymin, ymax = float(ys.min()), float(ys.max())
        if ymin < lo or ymax > hi:
            return (f"output values [{ymin:.6g}, {ymax:.6g}] escape the "
                    f"graph's reachable range [{lo:.6g}, {hi:.6g}]")
    return None
