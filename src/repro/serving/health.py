"""Replica health state machine, fault policy, and brownout controller.

The serving failure model (the counterpart of the FPGA deployment
frameworks' validation hooks): every replica carries a health state

    healthy -> suspect -> quarantined -> (canary probe) -> healthy

driven by three signals --

* **consecutive dispatch failures** (raised exceptions / dead replicas),
* **straggler latencies** via the shared trailing-median detector
  (:class:`repro.distributed.stragglers.TrailingStats`, the same test the
  training-side ``StepWatchdog`` runs), and
* **integrity violations / timeouts**, which quarantine immediately --
  a replica that returned corrupt bits or hung once is not trusted again
  until it proves itself.

Quarantined replicas are skipped by the pool's ``pick`` and re-probed on
a capped-exponential-backoff schedule with a **golden canary**: a fixed
synthetic input whose expected output is bit-exact from the build's
reference, so recovery is proven exactly, never statistically.

:class:`FaultPolicy` is the single knob set for all of it (retry budgets,
timeouts, hedging, brownout thresholds); ``FaultPolicy.disabled()``
reproduces the pre-hardening serving behavior for A/B chaos benchmarks.

:class:`BrownoutController` implements graceful degradation: under
sustained replica loss or queue pressure it tiers admission (gold vs
best-effort -- the seed of the fleet-level SLO tiers), sheds best-effort
traffic first, and shrinks the active bucket grid so gold-tier flush
latency stays bounded by smaller launches.
"""

from __future__ import annotations

import dataclasses

from repro.distributed.stragglers import TrailingStats

HEALTHY = "healthy"
SUSPECT = "suspect"
QUARANTINED = "quarantined"

GOLD = "gold"
BEST_EFFORT = "best_effort"
TIERS = (GOLD, BEST_EFFORT)


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Failure-handling knobs for the serving path (plain data).

    enabled: master switch; ``disabled()`` replays the pre-hardening
        behavior (no retries, no timeouts, no health, no integrity) for
        chaos A/B baselines.  Failed dispatches still resolve their
        entries as shed -- a rid is never silently dropped either way.
    max_retries: per-request re-dispatch budget after a failed / timed-out
        / corrupted launch; exhausted or past-deadline requests complete
        as shed (``CompletedRequest.shed``), never retried past their SLO.
    retry_backoff_s: base delay before a retry launch; doubles per attempt.
    dispatch_timeout_s: wall-clock bound on one launch; an un-ready batch
        past it quarantines its replica and re-dispatches elsewhere, so
        ``harvest``/``drain`` can never block forever on a hung replica.
    hedge_after_s: duplicate a straggling launch onto a second healthy
        replica after this long; first bit-exact result wins.  ``None``
        derives it from the replica's own EWMA latency
        (``hedge_factor`` x), which needs a few clean resolves to arm.
    suspect_after / quarantine_after: consecutive dispatch failures before
        healthy -> suspect and suspect -> quarantined.
    straggler_factor / straggler_window: trailing-median straggler test per
        replica (shared :class:`TrailingStats` semantics); a straggling
        replica goes suspect, repeated straggles quarantine it.
    probe_backoff_s / probe_backoff_cap_s: capped-exponential canary-probe
        schedule for quarantined replicas; probe_timeout_s bounds one probe.
    integrity: run the output guard on every resolved batch (dtype /
        finite / reachable-range); a corrupt batch quarantines its replica
        and re-executes on a healthy one.
    brownout: enable the degradation controller; *_frac thresholds below.
    """

    enabled: bool = True
    # request-level resilience
    max_retries: int = 2
    retry_backoff_s: float = 0.0
    dispatch_timeout_s: float | None = 30.0
    hedge_after_s: float | None = None
    hedge_factor: float = 4.0
    hedging: bool = False
    # replica health
    suspect_after: int = 1
    quarantine_after: int = 3
    straggler_factor: float = 4.0
    straggler_window: int = 32
    straggler_min_samples: int = 8
    straggles_to_quarantine: int = 3
    # canary probing
    probe_backoff_s: float = 0.05
    probe_backoff_cap_s: float = 2.0
    probe_timeout_s: float = 5.0
    # integrity guard
    integrity: bool = True
    # brownout
    brownout: bool = True
    brownout_healthy_frac: float = 0.5
    brownout_depth_frac: float = 0.75
    severe_healthy_frac: float = 0.25
    brownout_cooldown_s: float = 0.25

    @classmethod
    def disabled(cls) -> "FaultPolicy":
        """The pre-hardening serving behavior (chaos-benchmark baseline)."""
        return cls(enabled=False, max_retries=0, dispatch_timeout_s=None,
                   hedging=False, integrity=False, brownout=False)

    def hedge_delay(self, ewma_latency: float) -> float | None:
        """Seconds after which a launch is hedge-worthy, or None (never)."""
        if not (self.enabled and self.hedging):
            return None
        if self.hedge_after_s is not None:
            return self.hedge_after_s
        if ewma_latency <= 0.0:
            return None  # EWMA not armed yet: nothing to compare against
        return self.hedge_factor * ewma_latency


class ReplicaHealth:
    """Per-replica health state machine (see module docstring)."""

    def __init__(self, policy: FaultPolicy):
        self.policy = policy
        self.state = HEALTHY
        self.consecutive_failures = 0
        self.straggles = 0
        self.latency = TrailingStats(
            window=policy.straggler_window, factor=policy.straggler_factor,
            min_samples=policy.straggler_min_samples)
        self.quarantined_at: float | None = None
        self.quarantine_reason: str | None = None
        self.probe_failures = 0
        self.next_probe_at: float | None = None
        self.recoveries = 0
        self.dead = False  # set by an injected 'die' fault (permanent)

    # ------------------------------------------------------------- queries
    @property
    def usable(self) -> bool:
        """Eligible for regular dispatch (quarantined replicas are not)."""
        return self.state != QUARANTINED

    def due_probe(self, now: float) -> bool:
        return (self.state == QUARANTINED and self.next_probe_at is not None
                and now >= self.next_probe_at)

    # ---------------------------------------------------------- transitions
    def record_success(self, latency_s: float) -> str | None:
        """A clean resolve.  Returns None (fine), ``"straggle"`` (the
        latency straggled vs the trailing median), or ``"quarantine"``
        (straggled often enough that the caller should quarantine)."""
        self.consecutive_failures = 0
        if not self.latency.observe(latency_s):
            if self.state == SUSPECT:
                self.state = HEALTHY  # a clean, on-time resolve clears suspicion
                self.straggles = 0
            return None
        self.straggles += 1
        if self.straggles >= self.policy.straggles_to_quarantine:
            return "quarantine"
        if self.state == HEALTHY:
            self.state = SUSPECT
        return "straggle"

    def record_failure(self, now: float, reason: str) -> None:
        self.consecutive_failures += 1
        if self.state == QUARANTINED:
            return
        if self.consecutive_failures >= self.policy.quarantine_after:
            self.quarantine(now, reason)
        elif self.consecutive_failures >= self.policy.suspect_after:
            self.state = SUSPECT

    def quarantine(self, now: float, reason: str) -> None:
        """Hard transition (timeouts, corruption, failure threshold)."""
        if self.state != QUARANTINED:
            self.state = QUARANTINED
            self.quarantined_at = now
            self.probe_failures = 0
            self.next_probe_at = now + self.policy.probe_backoff_s
        self.quarantine_reason = reason

    def note_probe(self, ok: bool, now: float) -> bool:
        """Record a canary-probe outcome; True on recovery."""
        if ok:
            self.state = HEALTHY
            self.consecutive_failures = 0
            self.straggles = 0
            self.probe_failures = 0
            self.quarantined_at = self.next_probe_at = None
            self.quarantine_reason = None
            self.recoveries += 1
            return True
        self.probe_failures += 1
        backoff = min(
            self.policy.probe_backoff_s * (2 ** self.probe_failures),
            self.policy.probe_backoff_cap_s)
        self.next_probe_at = now + backoff
        return False

    def snapshot(self) -> dict:
        return {
            "state": self.state,
            "consecutive_failures": self.consecutive_failures,
            "straggles": self.straggles,
            "median_latency_s": self.latency.median,
            "ewma_latency_s": self.latency.ewma,
            "quarantine_reason": self.quarantine_reason,
            "recoveries": self.recoveries,
            "dead": self.dead,
        }


class BrownoutController:
    """Graceful degradation under replica loss / overload.

    Levels: 0 normal; 1 brownout (best-effort admission shed, queued
    best-effort dropped); 2 severe (additionally the active bucket grid
    shrinks below the largest bucket, so each gold launch is smaller and
    its flush latency bounded).  Entry is immediate on pressure; exit
    requires the pressure gone for ``brownout_cooldown_s`` (hysteresis --
    flapping between levels would churn the jit bucket grid)."""

    def __init__(self, policy: FaultPolicy, *, tracer=None):
        self.policy = policy
        self.level = 0
        self._calm_since: float | None = None
        # repro.telemetry.Tracer or None: level transitions are instants
        # (entering brownout is exactly the event an operator scrubs for)
        self.tracer = tracer

    def update(self, *, healthy_frac: float, depth_frac: float,
               now: float) -> int:
        """Advance the controller one tick; returns the (new) level."""
        p = self.policy
        if not (p.enabled and p.brownout):
            self.level = 0
            return 0
        before = self.level
        want = 0
        if healthy_frac <= p.brownout_healthy_frac or depth_frac >= p.brownout_depth_frac:
            want = 1
        if healthy_frac <= p.severe_healthy_frac or depth_frac >= 1.0:
            want = 2
        if want >= self.level:
            if want > self.level:
                self.level = want
            self._calm_since = None
        else:
            # de-escalate only after a calm cooldown window
            if self._calm_since is None:
                self._calm_since = now
            elif now - self._calm_since >= p.brownout_cooldown_s:
                self.level = want
                self._calm_since = None
        if self.tracer is not None and self.level != before:
            self.tracer.instant("brownout", cat="health", level=self.level,
                                previous=before, healthy_frac=healthy_frac,
                                depth_frac=depth_frac)
        return self.level

    @property
    def shedding_best_effort(self) -> bool:
        return self.level >= 1

    @property
    def shrink_buckets(self) -> bool:
        return self.level >= 2
