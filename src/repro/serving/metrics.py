"""Serving metrics: latency percentiles, throughput, queue depth, padding.

One ``ServingMetrics`` instance is shared by the admission queue, the
continuous batcher, and the replica pool; ``snapshot`` condenses it into a
plain dict (the monitoring-endpoint payload).  Latencies live in a bounded
reservoir so a long-running server never grows without bound -- the FINN
FIFO rule applied to the bookkeeping itself.
"""

from __future__ import annotations

import collections
import time

import numpy as np

PERCENTILES = (50.0, 95.0, 99.0)


class ServingMetrics:
    """Counters + gauges + a bounded latency reservoir with a snapshot API."""

    COUNTERS = (
        "requests", "completed", "rejected", "shed", "flushes",
        "padded_samples", "deadline_misses", "dispatched_samples",
        # failure handling (repro.serving.faults / health)
        "dispatch_failures", "retries", "hedges", "hedge_wins", "timeouts",
        "corrupt_batches", "quarantines", "recoveries", "probes",
        "brownout_shed",
    )

    def __init__(self, *, reservoir: int = 8192, clock=time.perf_counter):
        self.counters: dict[str, int] = {k: 0 for k in self.COUNTERS}
        self._lat = collections.deque(maxlen=reservoir)
        self._clock = clock
        self._t_first: float | None = None
        self._t_last: float | None = None
        self.queue_depth = 0
        self.max_queue_depth = 0
        self.healthy_replicas: int | None = None
        self.total_replicas: int | None = None
        self.brownout_level = 0

    # ------------------------------------------------------------- recording
    def count(self, key: str, n: int = 1) -> None:
        self.counters[key] = self.counters.get(key, 0) + n

    def observe_depth(self, depth: int) -> None:
        self.queue_depth = depth
        self.max_queue_depth = max(self.max_queue_depth, depth)

    def observe_health(self, healthy: int, total: int) -> None:
        self.healthy_replicas = healthy
        self.total_replicas = total

    def observe_brownout(self, level: int) -> None:
        self.brownout_level = level

    def observe_latency(self, seconds: float, *, now: float | None = None) -> None:
        now = self._clock() if now is None else now
        if self._t_first is None:
            self._t_first = now
        self._t_last = now
        self._lat.append(seconds)
        self.count("completed")

    # -------------------------------------------------------------- snapshot
    def latency_percentiles(self) -> dict[str, float]:
        if not self._lat:
            return {f"p{int(p)}_ms": float("nan") for p in PERCENTILES}
        arr = np.asarray(self._lat)
        return {f"p{int(p)}_ms": float(np.percentile(arr, p)) * 1e3
                for p in PERCENTILES}

    def throughput(self) -> float:
        """Completed samples per second over the observed completion window."""
        if self._t_first is None or self._t_last is None:
            return 0.0
        span = self._t_last - self._t_first
        if span <= 0:
            return 0.0
        return self.counters["completed"] / span

    def padding_overhead(self) -> float:
        """Fraction of dispatched engine slots that were padding."""
        total = self.counters["dispatched_samples"]
        if total <= 0:
            return 0.0
        return self.counters["padded_samples"] / total

    def availability(self) -> float:
        """Fraction of admitted requests that completed with a result (the
        complement of shed/abandoned traffic); 1.0 when nothing arrived."""
        reqs = self.counters["requests"]
        if reqs <= 0:
            return 1.0
        return self.counters["completed"] / reqs

    def snapshot(self) -> dict:
        return {
            **self.counters,
            **self.latency_percentiles(),
            "samples_per_s": self.throughput(),
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "padding_overhead": self.padding_overhead(),
            "availability": self.availability(),
            "healthy_replicas": self.healthy_replicas,
            "total_replicas": self.total_replicas,
            "brownout_level": self.brownout_level,
        }
