"""Serving metrics: latency percentiles, throughput, queue depth, padding.

One ``ServingMetrics`` instance is shared by the admission queue, the
continuous batcher, and the replica pool -- and by whatever harvest /
monitoring threads a deployment runs around them, so every mutation takes
the instance lock (a counter bumped from two threads must never lose an
increment).  Latencies live in a :class:`repro.telemetry.LogHistogram`:
bounded memory regardless of uptime (the FINN FIFO rule applied to the
bookkeeping itself), mergeable across instances, and percentiles within
the bucket width (~4.4%) of exact.  A :class:`repro.telemetry.WindowedRate`
tracks recent completion rate alongside the all-time throughput.

``snapshot()`` condenses everything into a plain JSON-safe dict (empty
percentiles are ``None``, never NaN -- ``json.dumps(float("nan"))`` emits
a token no strict JSON parser accepts); ``prometheus()`` renders the same
state in the Prometheus text exposition format.
"""

from __future__ import annotations

import threading
import time

from repro.telemetry.metrics import LogHistogram, WindowedRate, render_prometheus

PERCENTILES = (50.0, 95.0, 99.0)


class ServingMetrics:
    """Thread-safe counters + gauges + a latency histogram with snapshots.

    ``window_s`` sizes the recent-completions rate window.  ``reservoir``
    is accepted for back-compat with the old bounded-reservoir API and
    ignored (the histogram is bounded by construction).
    """

    COUNTERS = (
        "requests", "completed", "rejected", "shed", "flushes",
        "padded_samples", "deadline_misses", "dispatched_samples",
        # failure handling (repro.serving.faults / health)
        "dispatch_failures", "retries", "hedges", "hedge_wins", "timeouts",
        "corrupt_batches", "quarantines", "recoveries", "probes",
        "brownout_shed",
    )

    def __init__(self, *, reservoir: int | None = None,
                 clock=time.perf_counter, window_s: float = 10.0):
        del reservoir  # legacy knob: histogram memory is bounded regardless
        self.counters: dict[str, int] = {k: 0 for k in self.COUNTERS}
        self._lock = threading.Lock()
        self.latency = LogHistogram()
        self._rate = WindowedRate(window_s, clock=clock)
        self._clock = clock
        self._t_first: float | None = None
        self._t_last: float | None = None
        self.queue_depth = 0
        self.max_queue_depth = 0
        self.healthy_replicas: int | None = None
        self.total_replicas: int | None = None
        self.brownout_level = 0

    # ------------------------------------------------------------- recording
    def count(self, key: str, n: int = 1) -> None:
        with self._lock:
            self.counters[key] = self.counters.get(key, 0) + n

    def observe_depth(self, depth: int) -> None:
        with self._lock:
            self.queue_depth = depth
            self.max_queue_depth = max(self.max_queue_depth, depth)

    def observe_health(self, healthy: int, total: int) -> None:
        with self._lock:
            self.healthy_replicas = healthy
            self.total_replicas = total

    def observe_brownout(self, level: int) -> None:
        with self._lock:
            self.brownout_level = level

    def observe_latency(self, seconds: float, *, now: float | None = None) -> None:
        now = self._clock() if now is None else now
        with self._lock:
            if self._t_first is None:
                self._t_first = now
            self._t_last = now
            self.latency.observe(seconds)
            self._rate.add(now=now)
            self.counters["completed"] += 1

    # -------------------------------------------------------------- snapshot
    def latency_percentiles(self) -> dict[str, float | None]:
        """Histogram percentiles in ms; ``None`` (JSON null, not NaN) when
        nothing has completed yet."""
        with self._lock:
            out = {}
            for p in PERCENTILES:
                v = self.latency.percentile(p)
                out[f"p{int(p)}_ms"] = None if v is None else v * 1e3
            return out

    def throughput(self) -> float:
        """Completed samples per second over the observed completion window."""
        with self._lock:
            if self._t_first is None or self._t_last is None:
                return 0.0
            span = self._t_last - self._t_first
            if span <= 0:
                return 0.0
            return self.counters["completed"] / span

    def recent_rate(self, *, now: float | None = None) -> float:
        """Completions per second over the recent sliding window."""
        with self._lock:
            return self._rate.rate(now=now)

    def padding_overhead(self) -> float:
        """Fraction of dispatched engine slots that were padding."""
        with self._lock:
            total = self.counters["dispatched_samples"]
            if total <= 0:
                return 0.0
            return self.counters["padded_samples"] / total

    def availability(self) -> float:
        """Fraction of admitted requests that completed with a result (the
        complement of shed/abandoned traffic); 1.0 when nothing arrived."""
        with self._lock:
            reqs = self.counters["requests"]
            if reqs <= 0:
                return 1.0
            return self.counters["completed"] / reqs

    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self.counters)
        return {
            **counters,
            **self.latency_percentiles(),
            "samples_per_s": self.throughput(),
            "recent_samples_per_s": self.recent_rate(),
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "padding_overhead": self.padding_overhead(),
            "availability": self.availability(),
            "healthy_replicas": self.healthy_replicas,
            "total_replicas": self.total_replicas,
            "brownout_level": self.brownout_level,
        }

    def prometheus(self, *, prefix: str = "repro_serving") -> str:
        """The same state as :meth:`snapshot`, rendered in the Prometheus
        text exposition format (counters ``_total``, latency as a native
        histogram with cumulative ``le`` buckets in seconds)."""
        pct = self.latency_percentiles()
        with self._lock:
            counters = dict(self.counters)
            gauges = {
                "queue_depth": self.queue_depth,
                "max_queue_depth": self.max_queue_depth,
                "brownout_level": self.brownout_level,
            }
            if self.healthy_replicas is not None:
                gauges["healthy_replicas"] = self.healthy_replicas
            if self.total_replicas is not None:
                gauges["total_replicas"] = self.total_replicas
            hist = {"latency_seconds": self.latency}
            text = render_prometheus(counters=counters, gauges={
                **gauges,
                "samples_per_s": self.counters["completed"] /
                    (self._t_last - self._t_first)
                    if self._t_first is not None
                    and self._t_last is not None
                    and self._t_last > self._t_first else 0.0,
                **{f"latency_{k}": v for k, v in pct.items()},
            }, histograms=hist, prefix=prefix)
        return text
