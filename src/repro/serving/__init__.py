"""Production serving subsystem over :class:`repro.core.engine.FusedEngine`.

The paper's dataflow argument made operational: steady-state throughput is
set by the bottleneck stage's initiation interval, small FIFOs absorb
bursts, and nothing is allowed to grow without bound.  The serving layer
honors the same contract at the front door:

* :mod:`repro.serving.queue` -- bounded admission queue with backpressure
  (reject / shed policies), per-request deadlines, and input validation
  against the engine graph's spec,
* :mod:`repro.serving.batcher` -- continuous batcher whose flush policy is
  derived from the dataflow schedule (flush when a bucket fills, when the
  pipeline is idle, or when the oldest request's deadline slack shrinks to
  one engine flush budget),
* :mod:`repro.serving.pool` -- multi-replica pool (params ``device_put``
  onto each local device, least-loaded async dispatch, blocking only at
  result resolution),
* :mod:`repro.serving.metrics` -- p50/p95/p99 latency, throughput,
  queue-depth and padding counters with a snapshot API.

Quickstart::

    from repro.serving import ContinuousBatcher

    batcher = ContinuousBatcher(engine, batch_buckets=(1, 8, 32), slo_s=0.05)
    rid = batcher.submit(x)            # validated, bounded admission
    while batcher.pop_result(rid) is None:
        batcher.poll()                 # harvest + SLO-aware flushing
    print(batcher.metrics.snapshot())  # p99, throughput, padding overhead

The legacy ``repro.launch.serve.EngineServer`` is a thin deprecated shim
over this package.
"""

from repro.serving.batcher import (
    CompletedRequest,
    ContinuousBatcher,
    calibrate_cycle_time,
)
from repro.serving.metrics import ServingMetrics
from repro.serving.pool import PendingBatch, Replica, ReplicaPool
from repro.serving.queue import (
    AdmissionQueue,
    Block,
    Entry,
    InputSpec,
    QueueFull,
)

__all__ = [
    "AdmissionQueue",
    "Block",
    "CompletedRequest",
    "ContinuousBatcher",
    "Entry",
    "InputSpec",
    "PendingBatch",
    "QueueFull",
    "Replica",
    "ReplicaPool",
    "ServingMetrics",
    "calibrate_cycle_time",
]
