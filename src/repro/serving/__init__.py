"""Production serving subsystem over :class:`repro.core.engine.FusedEngine`.

The paper's dataflow argument made operational: steady-state throughput is
set by the bottleneck stage's initiation interval, small FIFOs absorb
bursts, and nothing is allowed to grow without bound.  The serving layer
honors the same contract at the front door:

* :mod:`repro.serving.queue` -- bounded admission queue with backpressure
  (reject / shed policies), per-request deadlines, SLO tiers, and input
  validation against the engine graph's spec,
* :mod:`repro.serving.batcher` -- continuous batcher whose flush policy is
  derived from the dataflow schedule (flush when a bucket fills, when the
  pipeline is idle, or when the oldest request's deadline slack shrinks to
  one engine flush budget),
* :mod:`repro.serving.pool` -- multi-replica pool (params ``device_put``
  onto each local device, least-loaded async dispatch, blocking only at
  result resolution),
* :mod:`repro.serving.metrics` -- thread-safe p50/p95/p99 latency
  (log-bucketed histogram), throughput + windowed rates, queue-depth,
  padding, fault/retry/hedge/quarantine and availability counters with
  JSON ``snapshot()`` and Prometheus text ``prometheus()`` exposition,
* :mod:`repro.serving.faults` -- deterministic seeded fault injection
  (:class:`FaultPlan`) plus the output integrity guard (the chaos-test
  substrate), and
* :mod:`repro.serving.health` -- replica health state machine
  (healthy -> suspect -> quarantined -> recovered via golden canary
  probes), :class:`FaultPolicy` (retries, timeouts, hedging) and the
  graceful-brownout controller.

Quickstart::

    from repro.serving import ContinuousBatcher

    batcher = ContinuousBatcher(engine, batch_buckets=(1, 8, 32), slo_s=0.05)
    rid = batcher.submit(x)            # validated, bounded admission
    while batcher.pop_result(rid) is None:
        batcher.poll()                 # harvest + SLO-aware flushing
    print(batcher.metrics.snapshot())  # p99, throughput, padding overhead

Observability (see docs/observability.md): every component takes
``tracer=None`` (a :class:`repro.telemetry.Tracer`) and the batcher takes
``drift=None`` (a :class:`repro.telemetry.DriftMonitor`); with both wired
a run yields a perfetto-viewable Chrome trace of the full request
lifecycle -- admit, dispatch, resolve, retries/hedges/quarantines as
annotated events -- plus live measured-vs-predicted cycle-model drift per
replica.  ``None`` costs one identity test per site (zero overhead
disabled).

The legacy ``repro.launch.serve.EngineServer`` is a thin deprecated shim
over this package.
"""

from repro.serving.batcher import (
    CompletedRequest,
    ContinuousBatcher,
    calibrate_cycle_time,
)
from repro.serving.faults import (
    DispatchError,
    FaultEvent,
    FaultPlan,
    IntegrityError,
    check_integrity,
    infer_output_range,
)
from repro.serving.health import (
    BEST_EFFORT,
    GOLD,
    TIERS,
    BrownoutController,
    FaultPolicy,
    ReplicaHealth,
)
from repro.serving.metrics import ServingMetrics
from repro.serving.pool import (
    NoHealthyReplicas,
    PendingBatch,
    Replica,
    ReplicaPool,
)
from repro.serving.queue import (
    AdmissionQueue,
    Block,
    Entry,
    InputSpec,
    QueueFull,
)

__all__ = [
    "AdmissionQueue",
    "BEST_EFFORT",
    "Block",
    "BrownoutController",
    "CompletedRequest",
    "ContinuousBatcher",
    "DispatchError",
    "Entry",
    "FaultEvent",
    "FaultPlan",
    "FaultPolicy",
    "GOLD",
    "InputSpec",
    "IntegrityError",
    "NoHealthyReplicas",
    "PendingBatch",
    "QueueFull",
    "Replica",
    "ReplicaHealth",
    "ReplicaPool",
    "ServingMetrics",
    "TIERS",
    "calibrate_cycle_time",
    "check_integrity",
    "infer_output_range",
]
