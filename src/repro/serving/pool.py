"""Multi-replica engine pool: least-loaded async dispatch over local devices.

The paper scales one dataflow build across SLRs/FPGAs by replication; the
runtime analog replicates the fused engine's parameters onto every local
device (``jax.device_put`` once, at pool construction) and dispatches
bucket batches to the least-loaded replica.  JAX dispatch is asynchronous:
``dispatch`` returns as soon as the computation is enqueued on the device,
so the host thread goes straight back to admitting requests -- blocking
happens only at result *resolution* (``PendingBatch.resolve``), and
``PendingBatch.ready`` polls completion without blocking.

Hardened (this layer is where the serving failure model lives):

* every replica carries a :class:`~repro.serving.health.ReplicaHealth`
  state machine; ``pick`` skips quarantined replicas,
* an optional :class:`~repro.serving.faults.FaultPlan` injects dispatch
  exceptions, output corruption, stragglers, hangs and replica death on a
  reproducible schedule (the chaos-test substrate),
* quarantined replicas are re-probed on capped exponential backoff with a
  **golden canary** whose expected output is bit-exact from the engine
  (``maintain``), and
* ``note_result`` feeds resolve latencies into the shared trailing-median
  straggler detector.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import faults as faults_mod
from repro.serving.faults import DispatchError, FaultPlan
from repro.serving.health import QUARANTINED, FaultPolicy, ReplicaHealth
from repro.serving.queue import Entry


@dataclasses.dataclass
class Replica:
    index: int
    device: jax.Device
    params: list  # engine param pytrees, resident on ``device``
    inflight: int = 0
    dispatched: int = 0
    health: ReplicaHealth | None = None


class PendingBatch:
    """One in-flight engine launch: an un-resolved device array + bookkeeping.

    Injected faults ride along: a ``straggle`` withholds readiness for its
    delay, a ``hang`` never becomes ready (only a dispatch timeout or
    ``abandon`` recovers the batch), and a ``corrupt`` deterministically
    corrupts the resolved copy (the device result itself is untouched --
    the injection models a corrupted readback, not a broken build).
    """

    def __init__(self, out: jax.Array, entries: list[Entry], n_valid: int,
                 replica: Replica, plan, t_dispatch: float, *,
                 fault=None, corrupt_rng=None, clock=time.perf_counter):
        self.out = out
        self.entries = entries
        self.n_valid = n_valid  # leading rows that are real samples (rest pad)
        self.replica = replica
        self.plan = plan
        self.t_dispatch = t_dispatch
        self.fault = fault
        self._corrupt_rng = corrupt_rng
        self._clock = clock
        self._resolved: np.ndarray | None = None
        self._abandoned = False

    @property
    def abandoned(self) -> bool:
        return self._abandoned

    def age(self, now: float | None = None) -> float:
        return (self._clock() if now is None else now) - self.t_dispatch

    def ready(self, now: float | None = None) -> bool:
        """True when the device result can be resolved without blocking."""
        if self._resolved is not None:
            return True
        if self._abandoned:
            return False
        if self.fault is not None:
            if self.fault.kind == "hang":
                return False
            if self.fault.kind == "straggle" and self.age(now) < self.fault.delay_s:
                return False
        is_ready = getattr(self.out, "is_ready", None)
        return bool(is_ready()) if is_ready is not None else True

    def resolve(self) -> np.ndarray:
        """Block until done; returns the valid (un-padded) output rows."""
        if self._resolved is None:
            if self._abandoned:
                raise RuntimeError(
                    f"batch abandoned on replica {self.replica.index} "
                    "(timed out / superseded); it cannot be resolved")
            if self.fault is not None and self.fault.kind == "hang":
                raise RuntimeError(
                    f"replica {self.replica.index} hung on this dispatch "
                    "(injected); resolve would block forever -- harvest "
                    "with a timeout instead")
            ys = np.asarray(self.out)[: self.n_valid]
            if self.fault is not None and self.fault.kind == "straggle":
                lag = self.fault.delay_s - self.age()
                if lag > 0:
                    time.sleep(lag)
            if self.fault is not None and self.fault.kind == "corrupt":
                ys = faults_mod.corrupt_array(ys, self._corrupt_rng)
            self._resolved = ys
            self.replica.inflight -= 1
        return self._resolved

    def abandon(self) -> None:
        """Stop tracking this launch (timeout / lost hedge race).  The
        device computation, if real, completes on its own; the replica's
        inflight accounting is released exactly once."""
        if self._resolved is None and not self._abandoned:
            self._abandoned = True
            self.replica.inflight -= 1


class NoHealthyReplicas(RuntimeError):
    """Every replica is quarantined and forced dispatch is disallowed."""


class ReplicaPool:
    """Engine parameters replicated across devices, least-loaded dispatch.

    ``devices`` may repeat a device: replicas are *logical* (the chaos
    benchmark runs a 4-replica pool on one CPU device; a TPU host runs one
    per chip).  ``faults`` injects the reproducible chaos schedule;
    ``policy`` configures the health machine (``FaultPolicy.disabled()``
    turns all of it off -- the pre-hardening pool).
    """

    def __init__(self, engine, devices: list[jax.Device] | None = None, *,
                 clock=time.perf_counter, faults: FaultPlan | None = None,
                 policy: FaultPolicy | None = None, tracer=None):
        devices = list(devices) if devices is not None else jax.local_devices()
        if not devices:
            raise ValueError("need at least one device for the replica pool")
        self.engine = engine
        self._clock = clock
        self.policy = policy if policy is not None else FaultPolicy()
        self.faults = faults
        # repro.telemetry.Tracer or None; every emission is guarded so the
        # disabled (None) pool pays one attribute test, nothing more
        self.tracer = tracer
        self.replicas = [
            Replica(i, d, jax.device_put(engine.params, d),
                    health=ReplicaHealth(self.policy))
            for i, d in enumerate(devices)
        ]
        self.probes = 0
        self.recoveries = 0
        self.quarantines = 0
        # integrity-guard inputs, precomputed once: the canonical output
        # dtype and the interval-arithmetic value bound of the graph
        self.output_range = (faults_mod.infer_output_range(engine.graph)
                             if self.policy.enabled and self.policy.integrity
                             else None)
        self.output_dtype = None
        self._canary: tuple[np.ndarray, np.ndarray] | None = None

    def __len__(self) -> int:
        return len(self.replicas)

    @property
    def total_inflight(self) -> int:
        return sum(r.inflight for r in self.replicas)

    @property
    def idle(self) -> bool:
        return self.total_inflight == 0

    @property
    def healthy_count(self) -> int:
        return sum(1 for r in self.replicas if r.health.state != QUARANTINED)

    @property
    def healthy_frac(self) -> float:
        return self.healthy_count / len(self.replicas)

    # ----------------------------------------------------------------- pick
    def pick(self, exclude: tuple = ()) -> Replica:
        """Least-loaded usable replica.  Quarantined replicas are skipped;
        when *every* candidate is quarantined the least-loaded one is used
        anyway (dispatching somewhere beats deadlocking the queue) unless
        every replica is excluded."""
        candidates = [r for r in self.replicas if r.index not in exclude]
        if not candidates:
            raise NoHealthyReplicas(
                f"no replica available outside exclude={sorted(exclude)}")
        usable = [r for r in candidates if r.health.usable]
        pool = usable if usable else candidates
        # tiebreak on total dispatches: equally-idle replicas round-robin
        # instead of piling onto the lowest index (even wear, and fresh
        # work keeps exercising every replica's health signal)
        return min(pool, key=lambda r: (r.inflight, r.dispatched, r.index))

    # ------------------------------------------------------------- dispatch
    def dispatch(self, xs: np.ndarray, entries: list[Entry],
                 n_valid: int | None = None, *,
                 exclude: tuple = ()) -> PendingBatch:
        """Enqueue one bucket batch on the least-loaded replica (non-blocking).

        Raises :class:`DispatchError` (carrying ``.replica``) on an
        injected or real submit failure; the failure is recorded in the
        replica's health state before raising, so the caller only has to
        retry.
        """
        replica = self.pick(exclude)
        k = replica.dispatched
        fault = None
        if self.faults is not None:
            fault = self.faults.draw(replica.index, k)
            if fault is not None and fault.kind == "die":
                replica.health.dead = True
        replica.dispatched += 1
        if replica.health.dead:
            self._record_failure(replica, "dead")
            raise DispatchError(
                f"replica {replica.index} is dead (injected)",
                replica=replica.index)
        if fault is not None and fault.kind == "error":
            self._record_failure(replica, "dispatch error (injected)")
            raise DispatchError(
                f"injected dispatch failure on replica {replica.index} "
                f"(dispatch #{k})", replica=replica.index)
        try:
            x = jax.device_put(jnp.asarray(xs), replica.device)
            # only pass tracer= when live: duck-typed engines (tests
            # monkeypatch dispatch) need not grow the keyword to stay usable
            if self.tracer is None:
                out, plan = self.engine.dispatch(x, params=replica.params)
            else:
                out, plan = self.engine.dispatch(x, params=replica.params,
                                                 tracer=self.tracer)
        except Exception as e:  # a *real* submit failure
            self._record_failure(replica, f"dispatch raised: {e}")
            raise DispatchError(
                f"dispatch failed on replica {replica.index}: {e}",
                replica=replica.index) from e
        replica.inflight += 1
        corrupt_rng = (self.faults.corruption_rng(replica.index, k)
                       if fault is not None and fault.kind == "corrupt" else None)
        return PendingBatch(out, entries,
                            len(entries) if n_valid is None else n_valid,
                            replica, plan, self._clock(),
                            fault=fault, corrupt_rng=corrupt_rng,
                            clock=self._clock)

    def _record_failure(self, replica: Replica, reason: str) -> None:
        if not self.policy.enabled:
            return
        before = replica.health.state
        replica.health.record_failure(self._clock(), reason)
        if replica.health.state == QUARANTINED and before != QUARANTINED:
            self.quarantines += 1
            if self.tracer is not None:
                self.tracer.instant("quarantine", cat="health",
                                    replica=replica.index, reason=reason)

    # ------------------------------------------------------- health plumbing
    def note_result(self, pending: PendingBatch, latency_s: float,
                    *, ok: bool, reason: str = "") -> None:
        """Feed one resolved launch back into the replica's health state."""
        if not self.policy.enabled:
            return
        replica = pending.replica
        if ok:
            verdict = replica.health.record_success(latency_s)
            if verdict == "quarantine":
                self.quarantine(replica, "persistent straggler")
        else:
            self.quarantine(replica, reason or "bad result")

    def quarantine(self, replica: Replica, reason: str) -> None:
        if not self.policy.enabled:
            return
        if replica.health.state != QUARANTINED:
            self.quarantines += 1
            if self.tracer is not None:
                self.tracer.instant("quarantine", cat="health",
                                    replica=replica.index, reason=reason)
        replica.health.quarantine(self._clock(), reason)

    # --------------------------------------------------------- canary probes
    def _golden(self) -> tuple[np.ndarray, np.ndarray]:
        """(canary input, bit-exact expected output), computed once from
        the engine's resident (reference) parameters."""
        if self._canary is None:
            from repro.core import autotune

            x = np.asarray(autotune.synth_input(self.engine.graph, 1))
            want = np.asarray(jax.block_until_ready(
                self.engine(jnp.asarray(x))))
            self.output_dtype = want.dtype
            self._canary = (x, want)
        return self._canary

    def probe(self, replica: Replica, *, timeout_s: float | None = None,
              now: float | None = None) -> bool:
        """One golden-canary probe of ``replica``: dispatch the canary
        through the regular (fault-injected) path and require a bit-exact
        match with the engine's reference output."""
        timeout_s = (self.policy.probe_timeout_s if timeout_s is None
                     else timeout_s)
        now = self._clock() if now is None else now
        self.probes += 1
        x, want = self._golden()
        try:
            pending = self.dispatch(x, [], n_valid=1,
                                    exclude=tuple(r.index for r in self.replicas
                                                  if r is not replica))
        except (DispatchError, NoHealthyReplicas):
            recovered = bool(replica.health.note_probe(False, self._clock()))
            if self.tracer is not None:
                self.tracer.instant("probe", cat="health",
                                    replica=replica.index, ok=False,
                                    recovered=recovered)
            return recovered
        deadline = self._clock() + timeout_s
        ok = True
        while not pending.ready():
            if self._clock() >= deadline:
                pending.abandon()
                ok = False
                break
            time.sleep(min(1e-4, timeout_s / 10))
        if ok:
            got = pending.resolve()
            ok = bool(np.array_equal(got, want))
        recovered = replica.health.note_probe(ok, self._clock())
        if recovered:
            self.recoveries += 1
        if self.tracer is not None:
            self.tracer.instant("probe", cat="health", replica=replica.index,
                                ok=ok, recovered=recovered)
        return recovered

    def maintain(self, now: float | None = None) -> list[dict]:
        """Probe every quarantined replica whose backoff is due; returns
        the probe outcomes (the batcher folds them into its metrics)."""
        if not self.policy.enabled:
            return []
        now = self._clock() if now is None else now
        events = []
        for r in self.replicas:
            if r.health.due_probe(now):
                recovered = self.probe(r, now=now)
                events.append({"replica": r.index, "recovered": recovered})
        return events

    # -------------------------------------------------------------- warmup
    def warmup(self, batch_sizes) -> None:
        """Precompile the bucket shape grid through the real dispatch path.

        A committed (``device_put``) operand keys the jit cache differently
        from an uncommitted one, so warming must go through the same
        device-placement the serving dispatch uses -- once per (bucket,
        replica device), at startup, exactly like the dry-run's fixed shape
        grid.
        """
        from repro.core import autotune

        for b in sorted(set(batch_sizes)):
            x0 = autotune.synth_input(self.engine.graph, b)
            for r in self.replicas:
                x = jax.device_put(x0, r.device)
                out, _ = self.engine.dispatch(x, params=r.params)
                jax.block_until_ready(out)
        if self.policy.enabled:
            # prime the golden canary too: its reference output runs the
            # engine's blocking path at batch 1, and that compile must land
            # at startup, not inside the first mid-traffic probe
            self._golden()

    def load(self) -> dict[int, int]:
        """Replica index -> total batches dispatched (load-spread probe)."""
        return {r.index: r.dispatched for r in self.replicas}

    def health_snapshot(self) -> dict:
        return {
            "replicas": {r.index: r.health.snapshot() for r in self.replicas},
            "healthy": self.healthy_count,
            "total": len(self.replicas),
            "quarantines": self.quarantines,
            "probes": self.probes,
            "recoveries": self.recoveries,
        }
