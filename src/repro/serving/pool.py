"""Multi-replica engine pool: least-loaded async dispatch over local devices.

The paper scales one dataflow build across SLRs/FPGAs by replication; the
runtime analog replicates the fused engine's parameters onto every local
device (``jax.device_put`` once, at pool construction) and dispatches
bucket batches to the least-loaded replica.  JAX dispatch is asynchronous:
``dispatch`` returns as soon as the computation is enqueued on the device,
so the host thread goes straight back to admitting requests -- blocking
happens only at result *resolution* (``PendingBatch.resolve``), and
``PendingBatch.ready`` polls completion without blocking.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.queue import Entry


@dataclasses.dataclass
class Replica:
    index: int
    device: jax.Device
    params: list  # engine param pytrees, resident on ``device``
    inflight: int = 0
    dispatched: int = 0


class PendingBatch:
    """One in-flight engine launch: an un-resolved device array + bookkeeping."""

    def __init__(self, out: jax.Array, entries: list[Entry], n_valid: int,
                 replica: Replica, plan, t_dispatch: float):
        self.out = out
        self.entries = entries
        self.n_valid = n_valid  # leading rows that are real samples (rest pad)
        self.replica = replica
        self.plan = plan
        self.t_dispatch = t_dispatch
        self._resolved: np.ndarray | None = None

    def ready(self) -> bool:
        """True when the device result can be resolved without blocking."""
        if self._resolved is not None:
            return True
        is_ready = getattr(self.out, "is_ready", None)
        return bool(is_ready()) if is_ready is not None else True

    def resolve(self) -> np.ndarray:
        """Block until done; returns the valid (un-padded) output rows."""
        if self._resolved is None:
            self._resolved = np.asarray(self.out)[: self.n_valid]
            self.replica.inflight -= 1
        return self._resolved


class ReplicaPool:
    """Engine parameters replicated across devices, least-loaded dispatch."""

    def __init__(self, engine, devices: list[jax.Device] | None = None, *,
                 clock=time.perf_counter):
        devices = list(devices) if devices is not None else jax.local_devices()
        if not devices:
            raise ValueError("need at least one device for the replica pool")
        self.engine = engine
        self._clock = clock
        self.replicas = [
            Replica(i, d, jax.device_put(engine.params, d))
            for i, d in enumerate(devices)
        ]

    def __len__(self) -> int:
        return len(self.replicas)

    @property
    def total_inflight(self) -> int:
        return sum(r.inflight for r in self.replicas)

    @property
    def idle(self) -> bool:
        return self.total_inflight == 0

    def pick(self) -> Replica:
        return min(self.replicas, key=lambda r: (r.inflight, r.index))

    def dispatch(self, xs: np.ndarray, entries: list[Entry],
                 n_valid: int | None = None) -> PendingBatch:
        """Enqueue one bucket batch on the least-loaded replica (non-blocking)."""
        replica = self.pick()
        x = jax.device_put(jnp.asarray(xs), replica.device)
        out, plan = self.engine.dispatch(x, params=replica.params)
        replica.inflight += 1
        replica.dispatched += 1
        return PendingBatch(out, entries,
                            len(entries) if n_valid is None else n_valid,
                            replica, plan, self._clock())

    def warmup(self, batch_sizes) -> None:
        """Precompile the bucket shape grid through the real dispatch path.

        A committed (``device_put``) operand keys the jit cache differently
        from an uncommitted one, so warming must go through the same
        device-placement the serving dispatch uses -- once per (bucket,
        replica device), at startup, exactly like the dry-run's fixed shape
        grid.
        """
        from repro.core import autotune

        for b in sorted(set(batch_sizes)):
            x0 = autotune.synth_input(self.engine.graph, b)
            for r in self.replicas:
                x = jax.device_put(x0, r.device)
                out, _ = self.engine.dispatch(x, params=r.params)
                jax.block_until_ready(out)

    def load(self) -> dict[int, int]:
        """Replica index -> total batches dispatched (load-spread probe)."""
        return {r.index: r.dispatched for r in self.replicas}
