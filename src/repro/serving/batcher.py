"""Continuous batcher: SLO-aware flushing derived from the dataflow schedule.

The flush policy is the FINN FIFO-sizing rule applied to wall-clock time
(paper section 5.3): steady-state throughput is set by the bottleneck
stage's initiation interval, small buffers absorb bursts, and a burst is
released downstream as soon as either

* a **bucket fills** -- one full producer burst is ready, ship it,
* the **pipeline is idle** -- holding work while the engine sits empty buys
  nothing (the continuous-batching insight: waiting is only useful when the
  device is busy), or
* the **oldest request's slack runs out** -- the time left to its deadline
  has shrunk to one engine flush budget (``DataflowSchedule.
  steady_state_interval`` converted to seconds via
  ``dataflow.interval_seconds``, times the bucket's microbatch count), so
  deferring any further would miss the SLO.

``ContinuousBatcher`` owns an :class:`~repro.serving.queue.AdmissionQueue`
(bounded, validating, backpressured), a
:class:`~repro.serving.pool.ReplicaPool` (async least-loaded dispatch) and
a :class:`~repro.serving.metrics.ServingMetrics`; ``poll`` advances the
whole machine one non-blocking step and is the only method a serving loop
needs to call.

Hardened against the serving failure model (``fault_policy``):

* a failed dispatch **never loses its batch** -- entries re-enqueue for
  retry (per-request budgets, exponential backoff) or complete as shed,
* every launch has a **dispatch timeout**: a hung replica is quarantined
  and its batch re-dispatched, so ``harvest``/``drain`` cannot block
  forever (and both take an explicit ``timeout`` raising
  :class:`TimeoutError` naming the stuck replica),
* straggling launches can be **hedged** onto a second healthy replica --
  the first bit-exact result wins,
* retries are **deadline-aware**: a request is never retried past its
  deadline; it completes as shed (``CompletedRequest.shed``),
* an **integrity guard** checks every resolved batch (dtype / finite /
  reachable value range); a corrupt batch quarantines its replica and
  re-executes on a healthy one -- no corrupted result is ever delivered,
* a **brownout controller** sheds best-effort-tier traffic first and
  shrinks the active bucket grid under sustained replica loss or
  overload, keeping gold-tier latency bounded.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import jax
import numpy as np

from repro.core import dataflow
from repro.serving import faults as faults_mod
from repro.serving.faults import DispatchError
from repro.serving.health import (
    BEST_EFFORT,
    GOLD,
    BrownoutController,
    FaultPolicy,
)
from repro.serving.metrics import ServingMetrics
from repro.serving.pool import NoHealthyReplicas, PendingBatch, ReplicaPool
from repro.serving.queue import AdmissionQueue, Entry, InputSpec, QueueFull

_TICK_S = 2e-4  # blocking-harvest poll tick


@dataclasses.dataclass(frozen=True)
class CompletedRequest:
    """A finished request: output row + the timestamps the SLO math needs.

    A request dropped by the queue's shed policy also resolves here, with
    ``out is None`` (``shed`` True) -- so a ``pop_result``/``poll`` wait
    loop always terminates, it never spins on a rid that left the system.
    The same contract covers failure handling: a request whose retry
    budget or deadline ran out completes as shed, never silently vanishes.
    """

    rid: int
    out: np.ndarray | None
    t_submit: float
    t_done: float
    deadline: float

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def missed_deadline(self) -> bool:
        return self.t_done > self.deadline

    @property
    def shed(self) -> bool:
        return self.out is None


class _Flight:
    """One logical launch: its entries + sample rows, the primary pending
    batch, and (optionally) a hedged duplicate racing it."""

    __slots__ = ("entries", "xs", "primary", "hedge")

    def __init__(self, entries: list[Entry], xs: np.ndarray,
                 primary: PendingBatch):
        self.entries = entries
        self.xs = xs  # unpadded (len(entries), *spec.shape) rows
        self.primary = primary
        self.hedge: PendingBatch | None = None

    def pendings(self):
        return [p for p in (self.primary, self.hedge) if p is not None]


def calibrate_cycle_time(engine, *, batch: int = 128, reps: int = 3,
                         cache=None, device: str | None = None) -> dict:
    """Measure the engine's realized wall-clock seconds per schedule cycle.

    The analytic schedule counts cycles; serving deadlines are seconds.  One
    timed run of the fused engine divides measured time by the plan's
    ``n_micro * steady_state_interval`` to get the device's realized cycle
    time, recorded under :func:`repro.core.autotune.cycle_time_key` so
    ``dataflow.interval_seconds`` (and every batcher built afterwards) uses
    the measurement instead of the nominal clock.
    """
    from repro.core import autotune

    x = autotune.synth_input(engine.graph, batch)
    jax.block_until_ready(engine(x))  # compile outside the timed region
    ts = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(engine(x))
        ts.append(time.perf_counter() - t0)
    plan = engine.plan(batch)
    cycles = max(1, plan.n_micro * max(plan.interval_cycles, 1))
    entry = {
        "s_per_cycle": float(min(ts)) / cycles,
        "batch": int(batch),
        "n_micro": int(plan.n_micro),
        "measured_s": float(min(ts)),
    }
    if cache is not None:
        cache.put(autotune.cycle_time_key(device), entry)
    return entry


class ContinuousBatcher:
    """Continuous batching front-end over one :class:`FusedEngine`.

    Parameters
    ----------
    batch_buckets: the padded jit shapes (same contract as the legacy
        ``EngineServer``): a launch pads up to the smallest bucket holding
        it, so the jit cache stays bounded under any traffic pattern.
    slo_s: default per-request latency budget; ``submit(deadline=...)``
        overrides per request.  ``None`` disables deadline-triggered
        flushing (bucket-fill and idle-greedy still apply).
    queue_capacity / policy: admission bound and overflow behavior
        (``"reject"`` raises :class:`QueueFull`, ``"shed"`` drops the
        oldest).  Defaults to 8 max-size bursts -- the decoupling-FIFO
        bound; a deeper queue only hides latency the SLO already lost.
    interval_s: seconds per steady-state interval; defaults to
        ``dataflow.interval_seconds`` (measured cycle time when the
        autotune ``cache`` holds one, nominal clock otherwise).
    greedy_when_idle: flush a partial bucket whenever no replica has work
        in flight (set False to batch strictly by deadline/bucket -- the
        legacy manual-flush behavior).
    fault_policy: failure-handling knobs (:class:`FaultPolicy`); the
        default enables retries, dispatch timeouts, the integrity guard
        and brownout with conservative settings (zero overhead while
        replicas are healthy).  ``FaultPolicy.disabled()`` reproduces the
        pre-hardening behavior.
    faults: optional :class:`~repro.serving.faults.FaultPlan` injected
        into the pool (chaos testing); ignored when ``pool`` is given.
    tracer: optional :class:`repro.telemetry.Tracer`.  Records the full
        request lifecycle -- an async ``request`` interval per rid from
        admission to resolution, ``dispatch``/``resolve`` duration spans,
        and ``retry``/``hedge``/``timeout``/``corrupt_batch`` instants
        (quarantine/probe/brownout instants come from the pool and the
        brownout controller, which share this tracer when the batcher
        constructs them).  ``None`` (the default) costs one identity test
        per site -- the zero-overhead-when-disabled contract.
    drift: optional :class:`repro.telemetry.DriftMonitor`.  Every resolved
        launch contributes a measured-vs-predicted observation keyed
        ``replica:N`` (predicted = the launch plan's ``n_micro`` x
        ``interval_s``, the same cycle-model arithmetic the flush budgets
        use); hedged-away, abandoned and timed-out launches contribute
        *censored* lower bounds, so a straggling replica is flagged even
        when hedging hides its completions.
    """

    def __init__(self, engine, *, batch_buckets: tuple[int, ...] = (1, 8, 32, 128),
                 slo_s: float | None = None, queue: AdmissionQueue | None = None,
                 pool: ReplicaPool | None = None, metrics: ServingMetrics | None = None,
                 cache=None, interval_s: float | None = None,
                 greedy_when_idle: bool = True, safety: float = 2.0,
                 queue_capacity: int | None = None, policy: str = "reject",
                 result_capacity: int = 8192, clock=time.perf_counter,
                 fault_policy: FaultPolicy | None = None,
                 faults=None, tracer=None, drift=None):
        if not batch_buckets or any(b <= 0 for b in batch_buckets):
            raise ValueError(f"need positive bucket sizes, got {batch_buckets}")
        self.engine = engine
        self.buckets = tuple(sorted(set(batch_buckets)))
        self.spec = InputSpec.from_graph(engine.graph)
        self._clock = clock
        self.tracer = tracer
        self.drift = drift
        self.metrics = metrics if metrics is not None else ServingMetrics(clock=clock)
        if queue_capacity is None:
            queue_capacity = 8 * self.buckets[-1]
        self.queue = queue if queue is not None else AdmissionQueue(
            self.spec, capacity=queue_capacity, policy=policy,
            default_slo_s=slo_s, clock=clock, tracer=tracer)
        self.fault_policy = fault_policy if fault_policy is not None else FaultPolicy()
        self.pool = pool if pool is not None else ReplicaPool(
            engine, clock=clock, faults=faults, policy=self.fault_policy,
            tracer=tracer)
        if pool is not None and tracer is not None and pool.tracer is None:
            # a caller-built pool joins the batcher's trace unless it
            # already carries its own tracer
            pool.tracer = tracer
        self._brownout = BrownoutController(self.fault_policy, tracer=tracer)
        self.greedy_when_idle = greedy_when_idle
        if interval_s is None:
            interval_s = dataflow.interval_seconds(engine.schedule, cache=cache)
        self.interval_s = float(interval_s)
        # flush budget per bucket: the wall-clock the engine needs to stream
        # that bucket (n_micro bursts at one interval each), padded by a
        # safety factor for dispatch overhead -- when a request's deadline
        # slack shrinks to this, the batch must leave NOW to meet its SLO.
        self.budgets = {b: engine.plan(b).n_micro * self.interval_s * safety
                        for b in self.buckets}
        self._inflight: list[_Flight] = []
        # retry buffer: (not_before, entries, xs) batches awaiting
        # re-dispatch after a failed / timed-out / corrupted launch
        self._retry: collections.deque[tuple[float, list[Entry], np.ndarray]] = (
            collections.deque())
        # bounded like every other buffer in the system: results a client
        # never collects evict oldest-first once result_capacity is reached
        # (the abandoned-rid leak guard; metrics' reservoir bounds the same
        # way), so a long-running server's memory stays flat
        self.result_capacity = result_capacity
        self.results: dict[int, CompletedRequest] = {}
        self.shed: list[int] = []
        self._depth_emitted: int | None = None  # last queue_depth counter sample

    def warmup(self) -> "ContinuousBatcher":
        """Precompile every bucket shape on every replica (startup cost,
        never paid inside the serving loop)."""
        self.pool.warmup(self.buckets)
        return self

    # ------------------------------------------------------------ admission
    def submit(self, x, *, deadline: float | None = None,
               now: float | None = None, tier: str = GOLD) -> int:
        """Validate + enqueue one sample; returns its request id."""
        if tier == BEST_EFFORT and self._brownout.shedding_best_effort:
            return self._shed_at_door(1, deadline, now)[0]
        try:
            rid = self.queue.admit(x, deadline=deadline, now=now, tier=tier)
        except QueueFull:
            self.metrics.count("rejected")
            if self.tracer is not None:
                self.tracer.instant("reject", cat="request", tier=tier)
            raise
        self.metrics.count("requests")
        if self.tracer is not None:
            self.tracer.begin_async("request", rid, cat="request", tier=tier)
        self._note_shed(now)
        self.metrics.observe_depth(self.queue.depth)
        return rid

    def submit_batch(self, xs, *, deadline: float | None = None,
                     now: float | None = None, tier: str = GOLD) -> list[int]:
        """Enqueue a (B, *spec.shape) batch as one block; per-sample rids."""
        if tier == BEST_EFFORT and self._brownout.shedding_best_effort:
            return self._shed_at_door(np.asarray(xs).shape[0], deadline, now)
        try:
            rids = self.queue.admit_batch(xs, deadline=deadline, now=now,
                                          tier=tier)
        except QueueFull:
            self.metrics.count("rejected", np.asarray(xs).shape[0])
            if self.tracer is not None:
                self.tracer.instant("reject", cat="request", tier=tier,
                                    n=int(np.asarray(xs).shape[0]))
            raise
        self.metrics.count("requests", len(rids))
        if self.tracer is not None:
            for rid in rids:
                self.tracer.begin_async("request", rid, cat="request",
                                        tier=tier)
        self._note_shed(now)
        self.metrics.observe_depth(self.queue.depth)
        return rids

    def _shed_at_door(self, n: int, deadline: float | None,
                      now: float | None) -> list[int]:
        """Brownout: best-effort arrivals get real rids but resolve as shed
        immediately (admission tiering -- gold capacity is protected)."""
        now = self._clock() if now is None else now
        rids = self.queue.take_rids(n)
        dl = deadline if deadline is not None else np.inf
        for rid in rids:
            if self.tracer is not None:
                self.tracer.begin_async("request", rid, cat="request",
                                        tier=BEST_EFFORT, t=now)
            self._record(CompletedRequest(rid, None, now, now, dl))
        self.shed.extend(rids)
        self.metrics.count("requests", n)
        self.metrics.count("shed", n)
        self.metrics.count("brownout_shed", n)
        return rids

    def _note_shed(self, now: float | None = None) -> None:
        dropped = self.queue.drain_shed()
        if dropped:
            now = self._clock() if now is None else now
            for e in dropped:
                # a shed request resolves with out=None so result waiters
                # terminate instead of spinning on a rid that left the system
                self._record(CompletedRequest(
                    e.rid, None, e.t_submit, now, e.deadline))
            self.shed.extend(e.rid for e in dropped)
            self.metrics.count("shed", len(dropped))

    # -------------------------------------------------------------- buckets
    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(
            f"group of {n} exceeds the largest bucket {self.buckets[-1]}; "
            "oversized backlogs split across max-size bucket launches"
        )

    @property
    def active_buckets(self) -> tuple[int, ...]:
        """The bucket grid launches currently size against.  Under severe
        brownout the largest bucket is retired, so each launch is smaller
        and the per-flush latency bound tighter (gold p99 protection)."""
        if self._brownout.shrink_buckets and len(self.buckets) > 1:
            return self.buckets[:-1]
        return self.buckets

    # ------------------------------------------------------------- dispatch
    def _pad(self, xs: np.ndarray, n: int) -> np.ndarray:
        bucket = self.bucket_for(n)
        pad = bucket - n
        if pad:
            xs = np.concatenate([xs, np.zeros((pad, *xs.shape[1:]), xs.dtype)])
        return xs

    def _dispatch(self, entries: list[Entry], xs: np.ndarray,
                  now: float | None = None) -> _Flight | None:
        """One launch attempt; on dispatch failure the batch re-enqueues
        for retry (or sheds) -- entries are never dropped."""
        bucket = self.bucket_for(len(entries))
        padded = self._pad(xs, len(entries))
        try:
            if self.tracer is None:
                pending = self.pool.dispatch(padded, entries,
                                             n_valid=len(entries))
            else:
                with self.tracer.span("dispatch", cat="serving",
                                      bucket=bucket, n=len(entries)) as sp:
                    pending = self.pool.dispatch(padded, entries,
                                                 n_valid=len(entries))
                    sp.args["replica"] = pending.replica.index
        except (DispatchError, NoHealthyReplicas) as e:
            self.metrics.count("dispatch_failures")
            if self.tracer is not None:
                self.tracer.instant("dispatch_failure", cat="serving",
                                    bucket=bucket, n=len(entries),
                                    replica=getattr(e, "replica", None))
            self._requeue(entries, xs, self._clock() if now is None else now)
            return None
        flight = _Flight(entries, xs, pending)
        self._inflight.append(flight)
        self.metrics.count("flushes")
        self.metrics.count("padded_samples", bucket - len(entries))
        self.metrics.count("dispatched_samples", bucket)
        self.metrics.observe_depth(self.queue.depth)
        return flight

    def _launch(self, n: int, now: float | None = None) -> _Flight | None:
        entries, xs = self.queue.pop(n)
        if not entries:
            return None
        return self._dispatch(entries, xs, now)

    def _requeue(self, entries: list[Entry], xs: np.ndarray,
                 now: float) -> None:
        """Failed-launch recovery: bump each entry's attempt count, shed
        what is out of budget or past deadline, buffer the rest for a
        backed-off re-dispatch."""
        policy = self.fault_policy
        keep_entries: list[Entry] = []
        keep_rows: list[int] = []
        for i, e in enumerate(entries):
            e = dataclasses.replace(e, attempts=e.attempts + 1)
            # deadline-aware: a retry that cannot land before the request's
            # deadline is pointless -- complete as shed instead
            if (e.attempts > policy.max_retries or now >= e.deadline):
                self._record(CompletedRequest(
                    e.rid, None, e.t_submit, now, e.deadline))
                self.shed.append(e.rid)
                self.metrics.count("shed")
            else:
                keep_entries.append(e)
                keep_rows.append(i)
        if not keep_entries:
            return
        attempts = min(e.attempts for e in keep_entries)
        backoff = policy.retry_backoff_s * (2 ** (attempts - 1))
        self._retry.append((now + backoff, keep_entries, xs[keep_rows]))
        self.metrics.count("retries", len(keep_entries))
        if self.tracer is not None:
            self.tracer.instant("retry", cat="serving", n=len(keep_entries),
                                attempts=attempts, backoff_s=backoff)

    def _launch_retries(self, now: float) -> None:
        """Re-dispatch every retry batch whose backoff has elapsed."""
        n = len(self._retry)
        for _ in range(n):
            not_before, entries, xs = self._retry.popleft()
            if now >= not_before:
                self._dispatch(entries, xs, now)
            else:
                self._retry.append((not_before, entries, xs))

    # -------------------------------------------------------------- harvest
    def _complete(self, flight: _Flight, ys: np.ndarray, now: float) -> list[int]:
        done = []
        for entry, y in zip(flight.entries, ys):
            self._record(CompletedRequest(
                entry.rid, y, entry.t_submit, now, entry.deadline))
            self.metrics.observe_latency(now - entry.t_submit, now=now)
            if now > entry.deadline:
                self.metrics.count("deadline_misses")
            done.append(entry.rid)
        return done

    def _abandon_loser(self, loser: PendingBatch, now: float) -> None:
        """Drop the losing side of a hedge race; if it had already blown
        the dispatch timeout (a hang the hedge papered over), quarantine
        its replica too."""
        t = self.fault_policy.dispatch_timeout_s
        if (self.fault_policy.enabled and t is not None
                and loser.age(now) > t):
            self.pool.quarantine(loser.replica, "timed out (lost hedge race)")
        # the loser's true duration is unobservable from here on; its age is
        # a censored lower bound the drift monitor can still learn from
        self._drift_censored(loser, now)
        loser.abandon()

    # ------------------------------------------------------- drift plumbing
    def _predicted_s(self, pending: PendingBatch) -> float:
        """Cycle-model prediction for one launch: the plan's microbatch
        count times the calibrated steady-state interval -- the same
        arithmetic the flush budgets use (without the safety factor)."""
        return max(pending.plan.n_micro, 1) * self.interval_s

    def _drift_censored(self, pending: PendingBatch, now: float) -> None:
        if self.drift is not None:
            self.drift.observe(f"replica:{pending.replica.index}",
                               pending.age(now),
                               predicted_s=self._predicted_s(pending),
                               censored=True)

    def _maybe_hedge(self, flight: _Flight, now: float) -> None:
        if flight.hedge is not None or len(self.pool) < 2:
            return
        delay = self.fault_policy.hedge_delay(
            flight.primary.replica.health.latency.ewma)
        if delay is None or flight.primary.age(now) <= delay:
            return
        try:
            flight.hedge = self.pool.dispatch(
                self._pad(flight.xs, len(flight.entries)), flight.entries,
                n_valid=len(flight.entries),
                exclude=(flight.primary.replica.index,))
            self.metrics.count("hedges")
            if self.tracer is not None:
                self.tracer.instant(
                    "hedge", cat="serving",
                    primary=flight.primary.replica.index,
                    hedge=flight.hedge.replica.index,
                    primary_age_s=flight.primary.age(now))
            # hedge-worthiness itself is drift evidence: the primary has
            # already run ``delay`` without resolving, a censored bound
            self._drift_censored(flight.primary, now)
        except (DispatchError, NoHealthyReplicas):
            self.metrics.count("dispatch_failures")

    def _check(self, ys: np.ndarray) -> str | None:
        if not (self.fault_policy.enabled and self.fault_policy.integrity):
            return None
        return faults_mod.check_integrity(
            ys, dtype=self.pool.output_dtype,
            value_range=self.pool.output_range)

    def _harvest_once(self, done: list[int], now: float) -> bool:
        """One pass over the in-flight launches; returns True if any
        flight made progress (resolved, timed out, or was requeued)."""
        policy = self.fault_policy
        timeout = policy.dispatch_timeout_s if policy.enabled else None
        progressed = False
        still: list[_Flight] = []
        for flight in self._inflight:
            resolved = False
            # first ready result wins the (possibly hedged) race
            for pending in flight.pendings():
                if not pending.ready(now):
                    continue
                if self.tracer is None:
                    ys = pending.resolve()
                else:
                    with self.tracer.span(
                            "resolve", cat="serving",
                            replica=pending.replica.index,
                            n=len(flight.entries),
                            hedged=pending is flight.hedge):
                        ys = pending.resolve()
                latency = now - pending.t_dispatch
                if self.drift is not None:
                    self.drift.observe(f"replica:{pending.replica.index}",
                                       latency,
                                       predicted_s=self._predicted_s(pending))
                reason = self._check(ys)
                if reason is None:
                    self.pool.note_result(pending, latency, ok=True)
                    if pending is flight.hedge:
                        self.metrics.count("hedge_wins")
                    for other in flight.pendings():
                        if other is not pending:
                            self._abandon_loser(other, now)
                    done.extend(self._complete(flight, ys, now))
                    resolved = progressed = True
                    break
                # corrupted batch: quarantine the replica, never deliver
                self.metrics.count("corrupt_batches")
                if self.tracer is not None:
                    self.tracer.instant("corrupt_batch", cat="serving",
                                        replica=pending.replica.index,
                                        reason=reason)
                self.pool.note_result(pending, latency, ok=False,
                                      reason=f"integrity: {reason}")
                progressed = True
                if pending is flight.primary and flight.hedge is not None:
                    flight.primary, flight.hedge = flight.hedge, None
                elif pending is flight.hedge:
                    flight.hedge = None
                else:
                    # no twin racing: re-execute on a healthy replica
                    self._requeue(flight.entries, flight.xs, now)
                    resolved = True
                break
            if resolved:
                continue
            # dispatch timeout: a hung launch quarantines its replica and
            # the batch re-dispatches -- harvest can never block forever
            if timeout is not None and flight.pendings() and all(
                    p.age(now) > timeout for p in flight.pendings()):
                for p in flight.pendings():
                    self.pool.quarantine(
                        p.replica,
                        f"dispatch timed out after {timeout:.3g}s")
                    # the hang's duration is unbounded; its age at timeout
                    # is the censored lower bound we get to keep
                    self._drift_censored(p, now)
                    p.abandon()
                self.metrics.count("timeouts")
                if self.tracer is not None:
                    self.tracer.instant(
                        "timeout", cat="serving", timeout_s=timeout,
                        replicas=[p.replica.index
                                  for p in flight.pendings()])
                self._requeue(flight.entries, flight.xs, now)
                progressed = True
                continue
            self._maybe_hedge(flight, now)
            still.append(flight)
        self._inflight = still
        return progressed

    def harvest(self, *, block: bool = False, timeout: float | None = None,
                now: float | None = None) -> list[int]:
        """Collect finished launches; non-blocking unless ``block``.

        ``timeout`` (with ``block=True``) bounds the wait: expiry raises
        :class:`TimeoutError` naming the replica(s) still holding work --
        the un-hardened failure mode this replaces was an unbounded block
        on a hung replica.
        """
        done: list[int] = []
        t_end = None if timeout is None else self._clock() + timeout
        while True:
            # blocking waits must advance real time even under a caller-
            # supplied (fake) now, or an un-ready flight would spin forever
            t = now if (now is not None and not block) else self._clock()
            self._harvest_once(done, t)
            if not block or not self._inflight:
                return done
            if t_end is not None and self._clock() >= t_end:
                stuck = sorted({p.replica.index for f in self._inflight
                                for p in f.pendings()})
                raise TimeoutError(
                    f"harvest timed out after {timeout:.3g}s with "
                    f"{len(self._inflight)} launch(es) still un-resolved on "
                    f"replica(s) {stuck} -- likely hung; quarantine via "
                    f"FaultPolicy.dispatch_timeout_s recovers automatically")
            time.sleep(_TICK_S)

    def poll(self, now: float | None = None) -> list[int]:
        """One non-blocking serving step: harvest, maintain health, then
        flush what's due.

        Full buckets always ship; a partial bucket ships when every replica
        is idle (``greedy_when_idle``) or when the oldest request's deadline
        slack has shrunk to the bucket's flush budget.  Quarantined
        replicas get their due canary probes, ripe retry batches re-launch,
        and the brownout controller advances.  Returns the rids completed
        this step (their results are in :attr:`results`).
        """
        now = self._clock() if now is None else now
        done = self.harvest(now=now)
        self._note_shed(now)
        self._maintain(now)
        self._launch_retries(now)
        top = self.active_buckets[-1]
        while self.queue.depth >= top:
            self._launch(top, now)
        depth = self.queue.depth
        if depth:
            # the tightest deadline anywhere in the queue, not the FIFO
            # head's: a later arrival may carry an urgent override, and the
            # launch drains the whole (FIFO) backlog up to it anyway
            slack = self.queue.min_deadline() - now
            if ((self.greedy_when_idle and self.pool.idle)
                    or slack <= self.budgets[self.bucket_for(min(depth, top))]):
                self._launch(min(depth, top), now)
        return done

    def _maintain(self, now: float) -> None:
        """Health upkeep: canary probes for due quarantined replicas, pool
        counter sync, and one brownout-controller tick."""
        if self.tracer is not None:
            # change-triggered counter track (not per-tick: a busy poll loop
            # would otherwise flood the bounded trace buffer with no-ops)
            depth = self.queue.depth
            if depth != self._depth_emitted:
                self.tracer.counter("queue_depth", depth, cat="serving")
                self._depth_emitted = depth
        if not self.fault_policy.enabled:
            return
        self.pool.maintain(now)
        # the pool is the single source of truth for its own lifecycle
        # counters; mirror them instead of double-counting
        self.metrics.counters["quarantines"] = self.pool.quarantines
        self.metrics.counters["probes"] = self.pool.probes
        self.metrics.counters["recoveries"] = self.pool.recoveries
        self.metrics.observe_health(self.pool.healthy_count, len(self.pool))
        before = self._brownout.level
        level = self._brownout.update(
            healthy_frac=self.pool.healthy_frac,
            depth_frac=self.queue.depth / self.queue.capacity, now=now)
        self.metrics.observe_brownout(level)
        if level >= 1 and before < 1:
            # entering brownout: queued best-effort work goes first
            dropped = self.queue.shed_tier(BEST_EFFORT)
            if dropped:
                self.metrics.count("brownout_shed", dropped)
                self._note_shed(now)

    def flush_all(self) -> None:
        """Launch every queued request immediately (bucket-split)."""
        while self.queue.depth:
            if self._launch(min(self.queue.depth, self.buckets[-1])) is None:
                break  # dispatch failed; entries moved to the retry buffer

    def drain(self, timeout: float | None = None) -> list[int]:
        """Flush and resolve everything outstanding (blocking).

        ``timeout`` bounds the whole drain; expiry raises
        :class:`TimeoutError` naming any stuck replica.  Retry backoffs
        are honored (the drain sleeps until the next batch is ripe).
        """
        done: list[int] = []
        t_end = None if timeout is None else self._clock() + timeout
        while self.queue.depth or self._inflight or self._retry:
            now = self._clock()
            if t_end is not None and now >= t_end:
                stuck = sorted({p.replica.index for f in self._inflight
                                for p in f.pendings()})
                raise TimeoutError(
                    f"drain timed out after {timeout:.3g}s with "
                    f"{self.outstanding} request(s) outstanding"
                    + (f" on replica(s) {stuck}" if stuck else ""))
            self._launch_retries(now)
            self.flush_all()
            if self._inflight:
                remaining = None if t_end is None else max(t_end - self._clock(), 1e-9)
                done.extend(self.harvest(block=True, timeout=remaining))
            self._note_shed()
            self._maintain(self._clock())
            if self._retry and not self._inflight and not self.queue.depth:
                ripe_at = min(nb for nb, _, _ in self._retry)
                wait = ripe_at - self._clock()
                if wait > 0:
                    time.sleep(min(wait, _TICK_S * 10))
        self._note_shed()
        return done

    # --------------------------------------------------------------- results
    def _record(self, req: CompletedRequest) -> None:
        if self.tracer is not None:
            self.tracer.end_async("request", req.rid, cat="request",
                                  t=req.t_done, shed=req.shed,
                                  missed_deadline=bool(req.missed_deadline))
        self.results[req.rid] = req
        while len(self.results) > self.result_capacity:
            self.results.pop(next(iter(self.results)))  # evict oldest

    @property
    def outstanding(self) -> int:
        """Samples admitted but not yet resolved (queued + in flight +
        awaiting retry)."""
        return (self.queue.depth
                + sum(len(f.entries) for f in self._inflight)
                + sum(len(e) for _, e, _ in self._retry))

    def pop_result(self, rid: int) -> CompletedRequest | None:
        return self.results.pop(rid, None)
