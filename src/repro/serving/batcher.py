"""Continuous batcher: SLO-aware flushing derived from the dataflow schedule.

The flush policy is the FINN FIFO-sizing rule applied to wall-clock time
(paper section 5.3): steady-state throughput is set by the bottleneck
stage's initiation interval, small buffers absorb bursts, and a burst is
released downstream as soon as either

* a **bucket fills** -- one full producer burst is ready, ship it,
* the **pipeline is idle** -- holding work while the engine sits empty buys
  nothing (the continuous-batching insight: waiting is only useful when the
  device is busy), or
* the **oldest request's slack runs out** -- the time left to its deadline
  has shrunk to one engine flush budget (``DataflowSchedule.
  steady_state_interval`` converted to seconds via
  ``dataflow.interval_seconds``, times the bucket's microbatch count), so
  deferring any further would miss the SLO.

``ContinuousBatcher`` owns an :class:`~repro.serving.queue.AdmissionQueue`
(bounded, validating, backpressured), a
:class:`~repro.serving.pool.ReplicaPool` (async least-loaded dispatch) and
a :class:`~repro.serving.metrics.ServingMetrics`; ``poll`` advances the
whole machine one non-blocking step and is the only method a serving loop
needs to call.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core import dataflow
from repro.serving.metrics import ServingMetrics
from repro.serving.pool import PendingBatch, ReplicaPool
from repro.serving.queue import AdmissionQueue, InputSpec, QueueFull


@dataclasses.dataclass(frozen=True)
class CompletedRequest:
    """A finished request: output row + the timestamps the SLO math needs.

    A request dropped by the queue's shed policy also resolves here, with
    ``out is None`` (``shed`` True) -- so a ``pop_result``/``poll`` wait
    loop always terminates, it never spins on a rid that left the system.
    """

    rid: int
    out: np.ndarray | None
    t_submit: float
    t_done: float
    deadline: float

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def missed_deadline(self) -> bool:
        return self.t_done > self.deadline

    @property
    def shed(self) -> bool:
        return self.out is None


def calibrate_cycle_time(engine, *, batch: int = 128, reps: int = 3,
                         cache=None, device: str | None = None) -> dict:
    """Measure the engine's realized wall-clock seconds per schedule cycle.

    The analytic schedule counts cycles; serving deadlines are seconds.  One
    timed run of the fused engine divides measured time by the plan's
    ``n_micro * steady_state_interval`` to get the device's realized cycle
    time, recorded under :func:`repro.core.autotune.cycle_time_key` so
    ``dataflow.interval_seconds`` (and every batcher built afterwards) uses
    the measurement instead of the nominal clock.
    """
    from repro.core import autotune

    x = autotune.synth_input(engine.graph, batch)
    jax.block_until_ready(engine(x))  # compile outside the timed region
    ts = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(engine(x))
        ts.append(time.perf_counter() - t0)
    plan = engine.plan(batch)
    cycles = max(1, plan.n_micro * max(plan.interval_cycles, 1))
    entry = {
        "s_per_cycle": float(min(ts)) / cycles,
        "batch": int(batch),
        "n_micro": int(plan.n_micro),
        "measured_s": float(min(ts)),
    }
    if cache is not None:
        cache.put(autotune.cycle_time_key(device), entry)
    return entry


class ContinuousBatcher:
    """Continuous batching front-end over one :class:`FusedEngine`.

    Parameters
    ----------
    batch_buckets: the padded jit shapes (same contract as the legacy
        ``EngineServer``): a launch pads up to the smallest bucket holding
        it, so the jit cache stays bounded under any traffic pattern.
    slo_s: default per-request latency budget; ``submit(deadline=...)``
        overrides per request.  ``None`` disables deadline-triggered
        flushing (bucket-fill and idle-greedy still apply).
    queue_capacity / policy: admission bound and overflow behavior
        (``"reject"`` raises :class:`QueueFull`, ``"shed"`` drops the
        oldest).  Defaults to 8 max-size bursts -- the decoupling-FIFO
        bound; a deeper queue only hides latency the SLO already lost.
    interval_s: seconds per steady-state interval; defaults to
        ``dataflow.interval_seconds`` (measured cycle time when the
        autotune ``cache`` holds one, nominal clock otherwise).
    greedy_when_idle: flush a partial bucket whenever no replica has work
        in flight (set False to batch strictly by deadline/bucket -- the
        legacy manual-flush behavior).
    """

    def __init__(self, engine, *, batch_buckets: tuple[int, ...] = (1, 8, 32, 128),
                 slo_s: float | None = None, queue: AdmissionQueue | None = None,
                 pool: ReplicaPool | None = None, metrics: ServingMetrics | None = None,
                 cache=None, interval_s: float | None = None,
                 greedy_when_idle: bool = True, safety: float = 2.0,
                 queue_capacity: int | None = None, policy: str = "reject",
                 result_capacity: int = 8192, clock=time.perf_counter):
        if not batch_buckets or any(b <= 0 for b in batch_buckets):
            raise ValueError(f"need positive bucket sizes, got {batch_buckets}")
        self.engine = engine
        self.buckets = tuple(sorted(set(batch_buckets)))
        self.spec = InputSpec.from_graph(engine.graph)
        self._clock = clock
        self.metrics = metrics if metrics is not None else ServingMetrics(clock=clock)
        if queue_capacity is None:
            queue_capacity = 8 * self.buckets[-1]
        self.queue = queue if queue is not None else AdmissionQueue(
            self.spec, capacity=queue_capacity, policy=policy,
            default_slo_s=slo_s, clock=clock)
        self.pool = pool if pool is not None else ReplicaPool(engine, clock=clock)
        self.greedy_when_idle = greedy_when_idle
        if interval_s is None:
            interval_s = dataflow.interval_seconds(engine.schedule, cache=cache)
        self.interval_s = float(interval_s)
        # flush budget per bucket: the wall-clock the engine needs to stream
        # that bucket (n_micro bursts at one interval each), padded by a
        # safety factor for dispatch overhead -- when a request's deadline
        # slack shrinks to this, the batch must leave NOW to meet its SLO.
        self.budgets = {b: engine.plan(b).n_micro * self.interval_s * safety
                        for b in self.buckets}
        self._inflight: list[PendingBatch] = []
        # bounded like every other buffer in the system: results a client
        # never collects evict oldest-first once result_capacity is reached
        # (the abandoned-rid leak guard; metrics' reservoir bounds the same
        # way), so a long-running server's memory stays flat
        self.result_capacity = result_capacity
        self.results: dict[int, CompletedRequest] = {}
        self.shed: list[int] = []

    def warmup(self) -> "ContinuousBatcher":
        """Precompile every bucket shape on every replica (startup cost,
        never paid inside the serving loop)."""
        self.pool.warmup(self.buckets)
        return self

    # ------------------------------------------------------------ admission
    def submit(self, x, *, deadline: float | None = None,
               now: float | None = None) -> int:
        """Validate + enqueue one sample; returns its request id."""
        try:
            rid = self.queue.admit(x, deadline=deadline, now=now)
        except QueueFull:
            self.metrics.count("rejected")
            raise
        self.metrics.count("requests")
        self._note_shed(now)
        self.metrics.observe_depth(self.queue.depth)
        return rid

    def submit_batch(self, xs, *, deadline: float | None = None,
                     now: float | None = None) -> list[int]:
        """Enqueue a (B, *spec.shape) batch as one block; per-sample rids."""
        try:
            rids = self.queue.admit_batch(xs, deadline=deadline, now=now)
        except QueueFull:
            self.metrics.count("rejected", np.asarray(xs).shape[0])
            raise
        self.metrics.count("requests", len(rids))
        self._note_shed(now)
        self.metrics.observe_depth(self.queue.depth)
        return rids

    def _note_shed(self, now: float | None = None) -> None:
        dropped = self.queue.drain_shed()
        if dropped:
            now = self._clock() if now is None else now
            for e in dropped:
                # a shed request resolves with out=None so result waiters
                # terminate instead of spinning on a rid that left the system
                self._record(CompletedRequest(
                    e.rid, None, e.t_submit, now, e.deadline))
            self.shed.extend(e.rid for e in dropped)
            self.metrics.count("shed", len(dropped))

    # -------------------------------------------------------------- buckets
    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(
            f"group of {n} exceeds the largest bucket {self.buckets[-1]}; "
            "oversized backlogs split across max-size bucket launches"
        )

    # ------------------------------------------------------------- dispatch
    def _launch(self, n: int) -> PendingBatch:
        entries, xs = self.queue.pop(n)
        bucket = self.bucket_for(len(entries))
        pad = bucket - len(entries)
        if pad:
            xs = np.concatenate(
                [xs, np.zeros((pad, *xs.shape[1:]), xs.dtype)])
        pending = self.pool.dispatch(xs, entries, n_valid=len(entries))
        self._inflight.append(pending)
        self.metrics.count("flushes")
        self.metrics.count("padded_samples", pad)
        self.metrics.count("dispatched_samples", bucket)
        self.metrics.observe_depth(self.queue.depth)
        return pending

    def harvest(self, *, block: bool = False,
                now: float | None = None) -> list[int]:
        """Collect finished launches; non-blocking unless ``block``."""
        done: list[int] = []
        still: list[PendingBatch] = []
        for pending in self._inflight:
            if not (block or pending.ready()):
                still.append(pending)
                continue
            ys = pending.resolve()  # blocks only if not already ready
            t_done = self._clock() if now is None else now
            for entry, y in zip(pending.entries, ys):
                self._record(CompletedRequest(
                    entry.rid, y, entry.t_submit, t_done, entry.deadline))
                self.metrics.observe_latency(t_done - entry.t_submit, now=t_done)
                if t_done > entry.deadline:
                    self.metrics.count("deadline_misses")
                done.append(entry.rid)
        self._inflight = still
        return done

    def poll(self, now: float | None = None) -> list[int]:
        """One non-blocking serving step: harvest, then flush what's due.

        Full buckets always ship; a partial bucket ships when every replica
        is idle (``greedy_when_idle``) or when the oldest request's deadline
        slack has shrunk to the bucket's flush budget.  Returns the rids
        completed this step (their results are in :attr:`results`).
        """
        now = self._clock() if now is None else now
        done = self.harvest(now=now)
        self._note_shed(now)
        while self.queue.depth >= self.buckets[-1]:
            self._launch(self.buckets[-1])
        depth = self.queue.depth
        if depth:
            # the tightest deadline anywhere in the queue, not the FIFO
            # head's: a later arrival may carry an urgent override, and the
            # launch drains the whole (FIFO) backlog up to it anyway
            slack = self.queue.min_deadline() - now
            if ((self.greedy_when_idle and self.pool.idle)
                    or slack <= self.budgets[self.bucket_for(depth)]):
                self._launch(depth)
        return done

    def flush_all(self) -> None:
        """Launch every queued request immediately (bucket-split)."""
        while self.queue.depth:
            self._launch(min(self.queue.depth, self.buckets[-1]))

    def drain(self) -> list[int]:
        """Flush and resolve everything outstanding (blocking)."""
        done: list[int] = []
        while self.queue.depth or self._inflight:
            self.flush_all()
            done.extend(self.harvest(block=True))
        self._note_shed()
        return done

    # --------------------------------------------------------------- results
    def _record(self, req: CompletedRequest) -> None:
        self.results[req.rid] = req
        while len(self.results) > self.result_capacity:
            self.results.pop(next(iter(self.results)))  # evict oldest

    @property
    def outstanding(self) -> int:
        """Samples admitted but not yet resolved (queued + in flight)."""
        return self.queue.depth + sum(p.n_valid for p in self._inflight)

    def pop_result(self, rid: int) -> CompletedRequest | None:
        return self.results.pop(rid, None)
