"""Bounded admission queue with backpressure (the serving FIFO).

The paper's dataflow contract (section 5.3.2) is that a small FIFO absorbs
producer bursts while the consumer drains at the steady-state interval --
and that the FIFO must be *bounded*: a queue that can grow without limit
just moves the stall somewhere invisible.  ``AdmissionQueue`` is that FIFO
at the serving front door:

* **bounded** -- ``capacity`` samples; overflow either rejects the new
  arrival (``policy="reject"``, backpressure to the client) or sheds the
  oldest queued samples (``policy="shed"``, bounded staleness),
* **typed** -- every sample is validated against the engine graph's input
  spec at admission, so a malformed request fails with a clear error at
  ``submit`` time instead of a cryptic ``np.stack`` shape error mid-flush,
* **block-structured** -- a multi-sample submission is stored as ONE block
  (no per-sample array copies); request ids stay per-sample and blocks are
  sliced lazily when the batcher pops work.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time

import numpy as np

from repro.core.ir import Graph


class QueueFull(RuntimeError):
    """Raised by ``policy="reject"`` when admission would exceed capacity."""


# SLO tiers (mirrored from repro.serving.health to avoid a circular import)
TIERS = ("gold", "best_effort")


@dataclasses.dataclass(frozen=True)
class InputSpec:
    """Per-sample input contract of an engine graph (shape minus batch dim).

    ``DTYPE`` is the one canonical activation dtype (the graph-input
    convention everywhere else in the repo): admitting a single dtype keeps
    the jit cache bounded at one executable per bucket -- mixed integer
    dtypes would each compile their own shape grid and defeat ``warmup``.
    """

    shape: tuple[int, ...]
    bits: int

    DTYPE = np.int32

    @classmethod
    def from_graph(cls, graph: Graph) -> "InputSpec":
        heads = [n for n in graph if n.op == "input"]
        if len(heads) != 1:
            raise ValueError(
                f"graph must have exactly one input node, found {len(heads)}")
        head = heads[0]
        return cls(tuple(head.attrs["shape"]), int(head.attrs.get("bits", 1)))

    def validate_batch(self, xs) -> np.ndarray:
        """Check a (B, *shape) integer batch.

        Returned as-is (no copy) when already canonical ``DTYPE``; other
        integer dtypes are converted (one copy) so every admitted block
        shares the single jit-cache dtype.  Non-integer dtypes are errors.
        """
        xs = np.asarray(xs)
        if xs.ndim != len(self.shape) + 1 or xs.shape[1:] != self.shape:
            raise ValueError(
                f"request shape {xs.shape[1:]} does not match the engine "
                f"input spec {self.shape} (batch of {xs.shape[0] if xs.ndim else '?'})"
            )
        if not np.issubdtype(xs.dtype, np.integer):
            raise ValueError(
                f"request dtype {xs.dtype} is not an integer type; the "
                f"engine consumes {self.bits}-bit integer activations"
            )
        if xs.dtype != self.DTYPE:
            xs = xs.astype(self.DTYPE)
        return xs

    def validate_sample(self, x) -> np.ndarray:
        x = np.asarray(x)
        if x.shape != self.shape:
            raise ValueError(
                f"request shape {x.shape} does not match the engine input "
                f"spec {self.shape}"
            )
        return self.validate_batch(x[None])


@dataclasses.dataclass
class Block:
    """One admitted submission: contiguous rids over a shared sample array."""

    rids: range
    xs: np.ndarray  # (len(rids), *spec.shape) -- a view of the caller's batch
    t_submit: float
    deadline: float
    tier: str = "gold"  # SLO tier: "gold" | "best_effort" (brownout sheds the latter first)

    def __len__(self) -> int:
        return len(self.rids)

    def split(self, n: int) -> tuple["Block", "Block"]:
        """Head block of ``n`` samples + the remainder (views, no copies)."""
        head = Block(self.rids[:n], self.xs[:n], self.t_submit, self.deadline,
                     self.tier)
        tail = Block(self.rids[n:], self.xs[n:], self.t_submit, self.deadline,
                     self.tier)
        return head, tail

    def entries(self) -> list["Entry"]:
        return [Entry(r, self.t_submit, self.deadline, self.tier)
                for r in self.rids]


@dataclasses.dataclass(frozen=True)
class Entry:
    """One popped request: what the batcher needs to track a sample.

    ``attempts`` counts completed dispatch attempts (the retry machinery
    bumps it via ``dataclasses.replace`` on every re-dispatch)."""

    rid: int
    t_submit: float
    deadline: float
    tier: str = "gold"
    attempts: int = 0


class AdmissionQueue:
    """Bounded FIFO of request blocks with per-request deadlines.

    ``admit``/``admit_batch`` validate against ``spec`` and apply the
    overflow policy; ``pop`` hands the batcher up to ``n`` samples as
    ``(entries, xs)`` with ``xs`` concatenated once (the only copy on the
    admission path, and one the padded bucket launch needs anyway).
    """

    POLICIES = ("reject", "shed")

    def __init__(self, spec: InputSpec, *, capacity: int = 1024,
                 policy: str = "reject", default_slo_s: float | None = None,
                 clock=time.perf_counter, tracer=None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        if policy not in self.POLICIES:
            raise ValueError(f"policy must be one of {self.POLICIES}, got {policy!r}")
        self.spec = spec
        self.capacity = capacity
        self.policy = policy
        self.default_slo_s = default_slo_s
        self._clock = clock
        # repro.telemetry.Tracer or None (zero overhead when None): the
        # queue annotates the timeline where IT drops work -- overflow
        # eviction and tier sheds -- since those never reach a dispatch span
        self.tracer = tracer
        self._blocks: collections.deque[Block] = collections.deque()
        self._depth = 0
        self._next_rid = 0
        self.shed_entries: list[Entry] = []
        # running min over block deadlines: O(1) on admit, invalidated on
        # removal and recomputed lazily -- the batcher polls min_deadline()
        # on its hot loop, which must not scan every block per tick
        self._min_dl = math.inf
        self._min_dirty = False

    # ------------------------------------------------------------ admission
    @property
    def depth(self) -> int:
        return self._depth

    def __len__(self) -> int:
        return self._depth

    def _deadline(self, now: float, deadline: float | None) -> float:
        if deadline is not None:
            return deadline
        if self.default_slo_s is None:
            return math.inf
        return now + self.default_slo_s

    def _make_room(self, n: int) -> None:
        if n > self.capacity:
            raise ValueError(
                f"batch of {n} samples exceeds the queue capacity "
                f"{self.capacity}; split the submission"
            )
        if self._depth + n <= self.capacity:
            return
        if self.policy == "reject":
            raise QueueFull(
                f"admission queue full ({self._depth}/{self.capacity} "
                f"samples pending); retry after a flush or raise capacity"
            )
        while self._depth + n > self.capacity and self._blocks:
            oldest = self._blocks[0]
            drop = min(len(oldest), self._depth + n - self.capacity)
            head, tail = oldest.split(drop)
            self.shed_entries.extend(head.entries())
            self._depth -= drop
            self._min_dirty = True
            if self.tracer is not None:
                self.tracer.instant("queue.evict", cat="serving", n=drop,
                                    rids=[head.rids[0], head.rids[-1]])
            if len(tail):
                self._blocks[0] = tail
            else:
                self._blocks.popleft()

    def _admit_block(self, xs: np.ndarray, deadline: float | None,
                     now: float | None, tier: str) -> list[int]:
        """Append one already-validated block (single validation pass)."""
        if tier not in TIERS:
            raise ValueError(f"tier must be one of {TIERS}, got {tier!r}")
        now = self._clock() if now is None else now
        self._make_room(len(xs))
        rids = range(self._next_rid, self._next_rid + len(xs))
        self._next_rid += len(xs)
        block = Block(rids, xs, now, self._deadline(now, deadline), tier)
        self._blocks.append(block)
        self._depth += len(xs)
        if not self._min_dirty:
            self._min_dl = min(self._min_dl, block.deadline)
        return list(rids)

    def admit_batch(self, xs, *, deadline: float | None = None,
                    now: float | None = None, tier: str = "gold") -> list[int]:
        """Admit a (B, *shape) batch as ONE block; returns per-sample rids."""
        return self._admit_block(self.spec.validate_batch(xs), deadline, now, tier)

    def admit(self, x, *, deadline: float | None = None,
              now: float | None = None, tier: str = "gold") -> int:
        """Admit one sample (shape = the engine input spec); returns its rid."""
        return self._admit_block(self.spec.validate_sample(x), deadline, now, tier)[0]

    def take_rids(self, n: int) -> list[int]:
        """Allocate ``n`` request ids without enqueueing anything -- the
        brownout path sheds best-effort arrivals at the front door but must
        still hand the caller real rids so its waiters terminate."""
        rids = list(range(self._next_rid, self._next_rid + n))
        self._next_rid += n
        return rids

    def shed_tier(self, tier: str) -> int:
        """Drop every queued block of ``tier`` (brownout: best-effort goes
        first); their entries land in ``shed_entries``.  Returns the count."""
        dropped = 0
        kept: collections.deque[Block] = collections.deque()
        for block in self._blocks:
            if block.tier == tier:
                self.shed_entries.extend(block.entries())
                self._depth -= len(block)
                dropped += len(block)
            else:
                kept.append(block)
        if dropped:
            self._blocks = kept
            self._min_dirty = True
            if self.tracer is not None:
                self.tracer.instant("queue.shed_tier", cat="serving",
                                    tier=tier, n=dropped)
        return dropped

    # ------------------------------------------------------------------ pop
    def oldest_deadline(self) -> float:
        return self._blocks[0].deadline if self._blocks else math.inf

    def min_deadline(self) -> float:
        """Tightest deadline anywhere in the queue -- the one the batcher's
        slack rule must honor (a later arrival may carry an earlier deadline
        than the FIFO head, e.g. a default-SLO head plus an urgent
        override).  Amortized O(1): the running min is maintained on admit
        and recomputed only after removals invalidated it."""
        if not self._blocks:
            self._min_dl, self._min_dirty = math.inf, False
            return math.inf
        if self._min_dirty:
            self._min_dl = min(b.deadline for b in self._blocks)
            self._min_dirty = False
        return self._min_dl

    def oldest_age(self, now: float | None = None) -> float:
        if not self._blocks:
            return 0.0
        now = self._clock() if now is None else now
        return now - self._blocks[0].t_submit

    def pop(self, n: int) -> tuple[list[Entry], np.ndarray]:
        """Dequeue up to ``n`` samples in FIFO order.

        Returns per-sample entries plus their activations concatenated into
        one ``(len(entries), *spec.shape)`` array.
        """
        entries: list[Entry] = []
        parts: list[np.ndarray] = []
        while self._blocks and len(entries) < n:
            block = self._blocks.popleft()
            take = min(len(block), n - len(entries))
            head, tail = block.split(take)
            entries.extend(head.entries())
            parts.append(head.xs)
            self._depth -= take
            self._min_dirty = True
            if len(tail):
                self._blocks.appendleft(tail)
        if not entries:
            return [], np.empty((0, *self.spec.shape))
        xs = parts[0] if len(parts) == 1 else np.concatenate(parts)
        return entries, xs

    def pending_rids(self) -> list[int]:
        """Rids still queued, FIFO order (legacy ``EngineServer._pending``)."""
        return [r for block in self._blocks for r in block.rids]

    def drain_shed(self) -> list[Entry]:
        """Entries dropped by the shed policy since the last call."""
        out, self.shed_entries = self.shed_entries, []
        return out
