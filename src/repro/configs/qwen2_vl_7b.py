"""Qwen2-VL 7B backbone: GQA + M-RoPE, dynamic-resolution frontend stubbed
[arXiv:2409.12191]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    activation="swiglu",
    frontend="patch",
    subquadratic=False,
)

REDUCED = CONFIG.replace(
    name="qwen2-vl-7b-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=32, mrope_sections=(4, 6, 6), d_ff=128,
    vocab_size=256,
)
