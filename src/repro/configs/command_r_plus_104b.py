"""Command R+ 104B: dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-plus]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
    rope_theta=75e6,
    activation="swiglu",
    norm="layernorm",  # Cohere uses LayerNorm (no bias folded into scale here)
    subquadratic=False,
)

REDUCED = CONFIG.replace(
    name="command-r-plus-104b-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
)
