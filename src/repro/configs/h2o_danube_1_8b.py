"""H2O-Danube 1.8B: llama+mistral mix with sliding-window attention
[arXiv:2401.16818]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    head_dim=80,
    attn_type="swa",
    window=4096,
    rope_theta=1e4,
    activation="swiglu",
    subquadratic=True,  # SWA => sub-quadratic => long_500k runs
)

REDUCED = CONFIG.replace(
    name="h2o-danube-1.8b-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, window=32,
)
