"""Mamba2-780M: attention-free SSD (state-space duality) [arXiv:2405.21060]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,  # attention-free; placeholders
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    rope=False,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_groups=1,
    ssm_conv=4,
    tie_embeddings=True,
    subquadratic=True,  # attn-free => long_500k runs
)

REDUCED = CONFIG.replace(
    name="mamba2-780m-reduced", num_layers=2, d_model=64, ssm_state=16,
    ssm_headdim=16, vocab_size=256, ssd_chunk=16,
)
