"""Skip-connection variant of the NID MLP: the DAG-IR proof workload.

The Table 6 use case re-shaped as a residual network: the trunk embeds
the 600-feature input to 64 channels, a branch stacks a second quantized
64x64 layer, and an elementwise ``add`` joins the branch back onto the
trunk activation (FINN's streaming elementwise-binary node) before the
1-output head.  Topology::

    in -> fc0/bn0/act0 --+--> fc1/bn1/act1 --+
                         |                   +--> res(add) -> fc2
                         +-------------------+

The graph cannot be expressed as a chain: ``act0`` fans out to both the
branch and the join, and ``res`` has two input streams.  Everything else
(2-bit weights/activations, folding per Table 6) matches ``nid_mlp`` so
the committed autotune schedules there cover these stage shapes too.
"""

import numpy as np

from repro.core.folding import Folding
from repro.core.ir import Graph, Node

# (in_features K, out_features N, PE, SIMD) per linear layer
LAYERS = [
    (600, 64, 64, 50),   # fc0: trunk embedding
    (64, 64, 16, 32),    # fc1: the residual branch
    (64, 1, 1, 8),       # fc2: head after the join
]
WEIGHT_BITS = 2
INPUT_BITS = 2


def foldings() -> list[Folding]:
    return [Folding(pe, simd) for (_, _, pe, simd) in LAYERS]


def build_graph(seed: int = 0) -> Graph:
    """The residual MLP as a RAW IR DAG (linear + bn + quant_act with
    random trained-like weights, explicit ``inputs`` edges) --
    ``repro.build.build`` does the lowering."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)

    def linear(name: str, k: int, n: int, src: str) -> Node:
        w = (rng.normal(0, 1, (n, k)) / np.sqrt(k)).astype(np.float32)
        return Node("linear", name, {}, {"w": jnp.asarray(w)}, inputs=(src,))

    def bn(name: str, n: int, src: str) -> Node:
        return Node("batchnorm", name, {}, {
            "gamma": jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32)),
            "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
            "mean": jnp.asarray(rng.normal(0, 1, n).astype(np.float32)),
            "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
        }, inputs=(src,))

    def qact(name: str, src: str) -> Node:
        return Node("quant_act", name, {"bits": INPUT_BITS, "act_scale": 1.0},
                    inputs=(src,))

    (k0, n0, _, _), (k1, n1, _, _), (k2, n2, _, _) = LAYERS
    return Graph([
        Node("input", "in", {"shape": (k0,), "bits": INPUT_BITS}),
        # trunk: embed to 64 channels, quantize
        linear("fc0", k0, n0, "in"), bn("bn0", n0, "fc0"), qact("act0", "bn0"),
        # branch off act0: one more quantized 64x64 layer
        linear("fc1", k1, n1, "act0"), bn("bn1", n1, "fc1"), qact("act1", "bn1"),
        # fan-in: act1 + act0 (streaming elementwise add, equal shapes)
        Node("add", "res", {"scales": (1, 1)}, inputs=("act1", "act0")),
        # head consumes the joined stream
        linear("fc2", k2, n2, "res"),
    ])


# The lowered stage shapes (64x600 thresh, 64x64 thresh, 1x64 scale) are
# exactly the nid_mlp ones, so its committed TUNED_SCHEDULES cover this
# config through ``autotune.default_cache()`` -- no separate entries.
