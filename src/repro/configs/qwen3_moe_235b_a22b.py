"""Qwen3-MoE 235B-A22B: 128 experts top-8, QK-norm
[hf:Qwen/Qwen3-235B-A22B]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    activation="swiglu",
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=1536,
    subquadratic=False,
)

REDUCED = CONFIG.replace(
    name="qwen3-moe-235b-a22b-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=64, moe_d_ff=64, vocab_size=256,
    num_experts=8, num_experts_per_tok=2, moe_group_size=64,
)
