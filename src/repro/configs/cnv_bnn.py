"""CNV: the FINN BNN convnet topology (BNN-PYNQ's CIFAR-10 network).

The paper's MVU always sits behind the SWU for conv layers (Fig. 1); CNV is
the canonical FINN workload exercising that pairing: six 3x3 conv layers
(64, 64, 128, 128, 256, 256 channels, no padding) with 2x2 max-pools after
conv pairs, then three dense layers (512, 512, 10) -- all with fused
BN + quantized activations between compute layers.

``build_graph`` emits the unlowered IR chain with trained-like random
parameters; ``QUICK`` is a channel/image-scaled variant small enough for CI
smoke runs (same shape of topology: >=2 conv + pool + dense).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.ir import Graph, Node


@dataclasses.dataclass(frozen=True)
class CNVSpec:
    image: int  # input is (image, image, 3)
    channels: tuple[int, ...]  # conv channels, 3x3 / stride 1 / pad 0 each
    pool_after: tuple[int, ...]  # conv indices followed by a 2x2 max-pool
    fc: tuple[int, ...]  # dense widths; the last one is the classifier head
    weight_bits: int = 1
    act_bits: int = 1


# The full FINN CNV: 32x32x3 -> 1x1x256 through the conv stack, then the
# 512-512-10 classifier.
FULL = CNVSpec(
    image=32,
    channels=(64, 64, 128, 128, 256, 256),
    pool_after=(1, 3),
    fc=(512, 512, 10),
)

# CI-sized CNV: same topology shape at 1/8 the channels on 16x16 inputs.
QUICK = CNVSpec(
    image=16,
    channels=(8, 8, 16, 16),
    pool_after=(1,),
    fc=(64, 10),
)


# Committed autotune results (repro.core.autotune) for the QUICK CNV in
# xnor mode on the CPU interpret-mode host (device key "cpu"): winners of
# the empirical tile-schedule search, consumed by
# ``FusedEngine(tune="cache")`` with zero measurement at load time.  The
# conv entries were measured in the engine's streaming regime (single-image
# microbatches).  Regenerate with
# ``python -m benchmarks.autotune_gain --config cnv --retune``.
TUNED_SCHEDULES = {
    "cpu|conv3s1p0@16x16x3|xnor|n8|k27|thresh|px196": {
        "backend": "pallas", "block_m": 32, "block_n": 8,
        "rows_per_tile": 3, "epilogue": "thresh", "n_pixels": 196,
        "predicted_cycles": 196, "speedup": 1.30,
    },
    "cpu|conv3s1p0@14x14x8|xnor|n8|k72|thresh|px144": {
        "backend": "pallas", "block_m": 256, "block_n": 8,
        "rows_per_tile": 12, "epilogue": "thresh", "n_pixels": 144,
        "predicted_cycles": 144, "speedup": 1.32,
    },
    "cpu|conv3s1p0@6x6x8|xnor|n16|k72|thresh|px16": {
        "backend": "pallas", "block_m": 32, "block_n": 128,
        "rows_per_tile": 4, "epilogue": "thresh", "n_pixels": 16,
        "predicted_cycles": 16, "speedup": 1.51,
    },
    "cpu|conv3s1p0@4x4x16|xnor|n16|k144|thresh|px4": {
        "backend": "pallas", "block_m": 128, "block_n": 16,
        "epilogue": "thresh", "n_pixels": 4,
        "predicted_cycles": 8, "speedup": 1.0,
    },
    # dense xnor stages run the natively bit-packed Pallas XNOR/popcount
    # kernel -- ``"packed": True`` records the datapath the winner ran on
    # (keys are shape-scoped, so the n64|k64 entry is shared with the
    # binarized NID-MLP variant and must stay identical in both configs)
    "cpu|mvu|xnor|n64|k64|thresh|px1": {
        "backend": "pallas", "block_m": 256, "block_n": 64, "block_k": 128,
        "block_kw": 2, "epilogue": "thresh", "n_pixels": 1,
        "packed": True, "predicted_cycles": 1, "speedup": 1.45,
    },
    "cpu|mvu|xnor|n10|k64|scale|px1": {
        "backend": "pallas", "block_m": 256, "block_n": 128, "block_k": 128,
        "block_kw": 2, "epilogue": "scale", "n_pixels": 1,
        "packed": True, "predicted_cycles": 1, "speedup": 1.13,
    },
    "engine|cpu|8ea0ac6c37bc": {
        "microbatch": 1, "batch": 128, "speedup": 1.0,
    },
}


def _bn(rng, name: str, n: int) -> Node:
    return Node("batchnorm", name, {}, {
        "gamma": jnp.asarray(rng.uniform(-1.5, 1.5, n).astype(np.float32)),
        "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
        "mean": jnp.asarray(rng.normal(0, 1, n).astype(np.float32)),
        "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
    })


def build_graph(spec: CNVSpec = QUICK, *, seed: int = 0) -> Graph:
    """CNV as an IR chain with trained-like random weights.

    Every conv/dense layer (except the classifier head) is followed by
    batchnorm + quant_act, the pattern ``lowering.streamline`` /
    ``lowering.fuse_epilogues`` folds into MVU threshold epilogues.
    """
    rng = np.random.default_rng(seed)
    bits = spec.act_bits
    g: Graph = [Node("input", "in",
                     {"shape": (spec.image, spec.image, 3), "bits": bits})]
    size, cin = spec.image, 3
    for i, cout in enumerate(spec.channels):
        w = rng.normal(0, 0.5, (3, 3, cin, cout)).astype(np.float32)
        g.append(Node("conv", f"conv{i}", {"kernel": 3, "stride": 1, "pad": 0},
                      {"w": jnp.asarray(w)}))
        g.append(_bn(rng, f"bn_c{i}", cout))
        g.append(Node("quant_act", f"act_c{i}", {"bits": bits, "act_scale": 1.0}))
        size, cin = size - 2, cout
        if i in spec.pool_after:
            g.append(Node("maxpool", f"pool{i}", {"size": 2}))
            size //= 2
    g.append(Node("flatten", "flatten", {}))
    k = size * size * cin
    for i, n in enumerate(spec.fc):
        w = (rng.normal(0, 1, (n, k)) / np.sqrt(k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(spec.fc) - 1:
            g.append(_bn(rng, f"bn_f{i}", n))
            g.append(Node("quant_act", f"act_f{i}", {"bits": bits, "act_scale": 1.0}))
        k = n
    return g
