"""Whisper-tiny backbone: encoder-decoder, conv frontend stubbed
[arXiv:2212.04356].  RoPE stands in for Whisper's sinusoidal/learned
positions (backbone-structural equivalence, see DESIGN.md)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,  # decoder layers
    enc_layers=4,
    encdec=True,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    activation="gelu",
    norm="layernorm",
    frontend="audio",
    subquadratic=False,
)

REDUCED = CONFIG.replace(
    name="whisper-tiny-reduced", num_layers=2, enc_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
)
