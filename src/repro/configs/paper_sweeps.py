"""Table 2: layer and implementation parameters for the paper's analysis.

A star in the paper marks the swept parameter; here each configuration is a
dict of constants plus the name + range of the swept axis.  SIMD types
sweep over the three datapaths of Fig. 4 in every configuration.
"""

CONFIGURATIONS = {
    1: dict(sweep="ifm_ch", values=[2, 4, 8, 16, 32, 64],
            ifm_dim=32, ofm_ch=64, kernel=4, pe=2, simd=2),
    2: dict(sweep="ifm_dim", values=[4, 8, 16],
            ifm_ch=64, ofm_ch=64, kernel=4, pe=32, simd=32),
    3: dict(sweep="ofm_ch", values=[2, 4, 8, 16, 32, 64],
            ifm_ch=64, ifm_dim=32, kernel=4, pe=2, simd=2),
    4: dict(sweep="kernel", values=[3, 5, 7, 9],
            ifm_ch=64, ifm_dim=32, ofm_ch=64, pe=32, simd=32),
    5: dict(sweep="pe", values=[2, 4, 8, 16, 32, 64],
            ifm_ch=64, ifm_dim=8, ofm_ch=64, kernel=4, simd=64),
    6: dict(sweep="simd", values=[2, 4, 8, 16, 32, 64],
            ifm_ch=64, ifm_dim=8, ofm_ch=64, kernel=4, pe=64),
}

# Table 3: larger designs with increasing IFM channels (PE = SIMD = 16)
LARGE_CONFIGS = [
    dict(ifm_ch=16, ifm_dim=16, ofm_ch=16, kernel=4, pe=16, simd=16),
    dict(ifm_ch=32, ifm_dim=16, ofm_ch=16, kernel=4, pe=16, simd=16),
    dict(ifm_ch=64, ifm_dim=16, ofm_ch=16, kernel=4, pe=16, simd=16),
]

SIMD_TYPES = ("xnor", "binary", "standard")


def mvu_shape(c: dict) -> tuple[int, int, int]:
    """(N, K, n_pixels) of the MVU behind a conv with these parameters."""
    k = c["kernel"] ** 2 * c["ifm_ch"]
    n = c["ofm_ch"]
    od = c["ifm_dim"] - c["kernel"] + 1  # stride 1, no pad (paper setup)
    return n, k, max(od, 1) ** 2


def expand(cfg_id: int):
    """Yield (params_dict, swept_value) rows for one configuration."""
    c = CONFIGURATIONS[cfg_id]
    base = {k: v for k, v in c.items() if k not in ("sweep", "values")}
    for v in c["values"]:
        row = dict(base)
        row[c["sweep"]] = v
        row.setdefault("ifm_ch", 64)
        row.setdefault("ifm_dim", 32)
        row.setdefault("ofm_ch", 64)
        row.setdefault("kernel", 4)
        row.setdefault("pe", 2)
        row.setdefault("simd", 2)
        yield row, v
