"""ModelConfig: one dataclass describes every assigned architecture.

families: dense | moe | ssm | hybrid | vlm | audio
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention
    attn_type: str = "full"  # full | swa
    window: int | None = None
    attn_q_chunk: int = 2048  # query-chunked exact attention; 0 = naive
    kv_quant: bool = False  # int8 KV cache with per-(token,head) scales
    rope: bool = True
    rope_theta: float = 1e4
    mrope: bool = False
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    qk_norm: bool = False

    # ffn
    activation: str = "swiglu"

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int | None = None
    moe_group_size: int = 512
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssd_chunk: int = 128

    # hybrid interleave (Jamba): one attn layer per `attn_period` layers,
    # MoE FFN on odd in-group indices (16e top-2), dense FFN elsewhere.
    attn_period: int = 0  # 0 = not hybrid

    # encoder-decoder (Whisper)
    encdec: bool = False
    enc_layers: int = 0

    # frontend stubs
    frontend: str | None = None  # "patch" (vlm) | "audio" (whisper)

    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    linear_backend: str = "dense"  # dense | mvu_w8a8 | mvu_w4a8 | mvu_w4a4 | mvu_binary
    remat: bool = True
    dtype: str = "bfloat16"
    scan_unroll: bool = False  # unroll layer scans (dry-run cost extrapolation)
    seq_sharded_acts: bool = False  # Megatron-SP: shard residual stream seq over "model"

    # long-context applicability (sub-quadratic path available?)
    subquadratic: bool = False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def is_hybrid(self) -> bool:
        return self.attn_period > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def param_count(self) -> int:
        """Approximate total parameters (for 6ND MODEL_FLOPS accounting)."""
        d, hd = self.d_model, self.head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d

        def ffn_params(ff):
            mats = 3 if self.activation in ("swiglu", "geglu") else 2
            return mats * d * ff

        if self.family == "ssm":
            from repro.models.ssm import ssm_dims

            d_inner, nheads, conv_dim = ssm_dims(self)
            per = (
                d * (2 * d_inner + 2 * self.ssm_groups * self.ssm_state + nheads)
                + self.ssm_conv * conv_dim
                + d_inner * d
                + 3 * nheads
                + d_inner
            )
            layers = self.num_layers * per
        elif self.is_hybrid:
            from repro.models.ssm import ssm_dims

            d_inner, nheads, conv_dim = ssm_dims(self)
            ssm_per = (
                d * (2 * d_inner + 2 * self.ssm_groups * self.ssm_state + nheads)
                + self.ssm_conv * conv_dim + d_inner * d + 3 * nheads + d_inner
            )
            n_attn = self.num_layers // self.attn_period
            n_ssm = self.num_layers - n_attn
            n_moe = self.num_layers // 2
            n_dense = self.num_layers - n_moe
            layers = (
                n_attn * attn
                + n_ssm * ssm_per
                + n_moe * (self.num_experts * ffn_params(self.moe_d_ff) + d * self.num_experts)
                + n_dense * ffn_params(self.d_ff)
            )
        elif self.is_moe:
            layers = self.num_layers * (
                attn + self.num_experts * ffn_params(self.moe_d_ff) + d * self.num_experts
            )
        else:
            enc = self.enc_layers if self.encdec else 0
            layers = (self.num_layers + enc) * (attn + ffn_params(self.d_ff))
            if self.encdec:  # cross-attention per decoder layer
                layers += self.num_layers * attn
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(layers + embed)

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE top-k counting) for 6*N_active*D."""
        if not (self.is_moe or self.is_hybrid):
            return self.param_count
        d = self.d_model

        def ffn_params(ff):
            mats = 3 if self.activation in ("swiglu", "geglu") else 2
            return mats * d * ff

        full = self.param_count
        if self.is_hybrid:
            n_moe = self.num_layers // 2
        else:
            n_moe = self.num_layers
        inactive = n_moe * (self.num_experts - self.num_experts_per_tok) * ffn_params(self.moe_d_ff)
        return int(full - inactive)
