"""Granite-MoE 3B-a800m: 40 experts top-8, fine-grained d_ff=512
[hf:ibm-granite/granite-3.0-3b-a800m-base]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    activation="swiglu",
    num_experts=40,
    num_experts_per_tok=8,
    moe_d_ff=512,
    subquadratic=False,
)

REDUCED = CONFIG.replace(
    name="granite-moe-3b-a800m-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=64, moe_d_ff=64, vocab_size=256,
    num_experts=8, num_experts_per_tok=2, moe_group_size=64,
)
