"""The paper's real-world use case (Table 6): 4-layer MLP for network
intrusion detection on UNSW-NB15, 2-bit weights and activations.

Layers (IFMch -> OFMch, PE, SIMD): 600->64 (64,50), 64->64 (16,32),
64->64 (16,32), 64->1 (1,8).
"""

from repro.core.folding import Folding

# (in_features K, out_features N, PE, SIMD) per layer, from Table 6
LAYERS = [
    (600, 64, 64, 50),
    (64, 64, 16, 32),
    (64, 64, 16, 32),
    (64, 1, 1, 8),
]
WEIGHT_BITS = 2
INPUT_BITS = 2


def foldings() -> list[Folding]:
    return [Folding(pe, simd) for (_, _, pe, simd) in LAYERS]
