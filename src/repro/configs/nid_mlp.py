"""The paper's real-world use case (Table 6): 4-layer MLP for network
intrusion detection on UNSW-NB15, 2-bit weights and activations.

Layers (IFMch -> OFMch, PE, SIMD): 600->64 (64,50), 64->64 (16,32),
64->64 (16,32), 64->1 (1,8).
"""

import numpy as np

from repro.core.folding import Folding
from repro.core.ir import Graph, Node

# (in_features K, out_features N, PE, SIMD) per layer, from Table 6
LAYERS = [
    (600, 64, 64, 50),
    (64, 64, 16, 32),
    (64, 64, 16, 32),
    (64, 1, 1, 8),
]
WEIGHT_BITS = 2
INPUT_BITS = 2


def foldings() -> list[Folding]:
    return [Folding(pe, simd) for (_, _, pe, simd) in LAYERS]


def build_graph(seed: int = 0) -> Graph:
    """Table 6 MLP as a RAW IR chain (linear + bn + quant_act with random
    trained-like weights) -- ``repro.build.build`` does the lowering.  The
    benchmarks, examples, and the design-space explorer all share this one
    definition of the workload."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    dims = [k for (k, _, _, _) in LAYERS] + [LAYERS[-1][1]]
    g: Graph = [Node("input", "in", {"shape": (dims[0],), "bits": INPUT_BITS})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = (rng.normal(0, 1, (n, k)) / np.sqrt(k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(dims) - 2:
            g.append(Node("batchnorm", f"bn{i}", {}, {
                "gamma": jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32)),
                "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
                "mean": jnp.asarray(rng.normal(0, 1, n).astype(np.float32)),
                "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
            }))
            g.append(Node("quant_act", f"act{i}",
                          {"bits": INPUT_BITS, "act_scale": 1.0}))
    return g


# Committed autotune results (repro.core.autotune): winners of the empirical
# design-space search over Pallas tile schedules on the CPU interpret-mode
# host (device key "cpu"), consumed by ``FusedEngine(tune="cache")`` with
# zero measurement at load time.  The engine-level entry pins the tuned
# microbatch tile for the NID stage chain.  Regenerate with
# ``python -m benchmarks.autotune_gain --config nid_mlp --retune``.
TUNED_SCHEDULES = {
    "cpu|mvu|standard|n64|k600|thresh|px1": {
        "backend": "pallas", "block_m": 256, "block_n": 64, "block_k": 300,
        "block_kw": 8, "epilogue": "thresh", "n_pixels": 1,
        "predicted_cycles": 2, "speedup": 1.64,
    },
    "cpu|mvu|standard|n64|k64|thresh|px1": {
        "backend": "pallas", "block_m": 256, "block_n": 64, "block_k": 64,
        "block_kw": 8, "epilogue": "thresh", "n_pixels": 1,
        "predicted_cycles": 1, "speedup": 2.16,
    },
    "cpu|mvu|standard|n1|k64|scale|px1": {
        "backend": "pallas", "block_m": 256, "block_n": 8, "block_k": 64,
        "block_kw": 8, "epilogue": "scale", "n_pixels": 1,
        "predicted_cycles": 1, "speedup": 1.88,
    },
    "engine|cpu|b155d7a42584": {
        "microbatch": 256, "batch": 1024, "speedup": 1.0,
    },
    # Binarized (mode="xnor") variant of the same chain: the empirical
    # search picks the bit-packed XNOR/popcount datapath (``"packed":
    # True``) on every layer -- the blocked-popcount XLA path on the wide
    # layers, the natively-packed Pallas kernel on the square ones.  The
    # canonical unpack+matmul schedule loses 5-30x on this host.
    # Regenerate with ``python -m benchmarks.packed_gain --retune``.
    "cpu|mvu|xnor|n64|k600|thresh|px1": {
        "backend": "xla", "block_m": 128, "block_n": 64, "block_k": 128,
        "block_kw": 3, "epilogue": "thresh", "n_pixels": 1,
        "packed": True, "predicted_cycles": 5, "speedup": 2.57,
    },
    # shared shape with cnv_bnn's fc1 (same key): keep both copies identical
    "cpu|mvu|xnor|n64|k64|thresh|px1": {
        "backend": "pallas", "block_m": 256, "block_n": 64, "block_k": 128,
        "block_kw": 2, "epilogue": "thresh", "n_pixels": 1,
        "packed": True, "predicted_cycles": 1, "speedup": 1.45,
    },
    "cpu|mvu|xnor|n1|k64|scale|px1": {
        "backend": "xla", "block_m": 128, "block_n": 8, "block_k": 128,
        "block_kw": 1, "epilogue": "scale", "n_pixels": 1,
        "packed": True, "predicted_cycles": 4, "speedup": 2.19,
    },
}
