"""The paper's real-world use case (Table 6): 4-layer MLP for network
intrusion detection on UNSW-NB15, 2-bit weights and activations.

Layers (IFMch -> OFMch, PE, SIMD): 600->64 (64,50), 64->64 (16,32),
64->64 (16,32), 64->1 (1,8).
"""

from repro.core.folding import Folding

# (in_features K, out_features N, PE, SIMD) per layer, from Table 6
LAYERS = [
    (600, 64, 64, 50),
    (64, 64, 16, 32),
    (64, 64, 16, 32),
    (64, 1, 1, 8),
]
WEIGHT_BITS = 2
INPUT_BITS = 2


def foldings() -> list[Folding]:
    return [Folding(pe, simd) for (_, _, pe, simd) in LAYERS]


# Committed autotune results (repro.core.autotune): winners of the empirical
# design-space search over Pallas tile schedules on the CPU interpret-mode
# host (device key "cpu"), consumed by ``FusedEngine(tune="cache")`` with
# zero measurement at load time.  The engine-level entry pins the tuned
# microbatch tile for the NID stage chain.  Regenerate with
# ``python -m benchmarks.autotune_gain --config nid_mlp --retune``.
TUNED_SCHEDULES = {
    "cpu|mvu|standard|n64|k600|thresh|px1": {
        "backend": "pallas", "block_m": 256, "block_n": 64, "block_k": 300,
        "block_kw": 8, "epilogue": "thresh", "n_pixels": 1,
        "predicted_cycles": 2, "speedup": 1.64,
    },
    "cpu|mvu|standard|n64|k64|thresh|px1": {
        "backend": "pallas", "block_m": 256, "block_n": 64, "block_k": 64,
        "block_kw": 8, "epilogue": "thresh", "n_pixels": 1,
        "predicted_cycles": 1, "speedup": 2.16,
    },
    "cpu|mvu|standard|n1|k64|scale|px1": {
        "backend": "pallas", "block_m": 256, "block_n": 8, "block_k": 64,
        "block_kw": 8, "epilogue": "scale", "n_pixels": 1,
        "predicted_cycles": 1, "speedup": 1.88,
    },
    "engine|cpu|b155d7a42584": {
        "microbatch": 256, "batch": 1024, "speedup": 1.0,
    },
}
