"""Config registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCH_IDS = [
    "yi-9b",
    "command-r-plus-104b",
    "nemotron-4-15b",
    "h2o-danube-1.8b",
    "qwen2-vl-7b",
    "granite-moe-3b-a800m",
    "qwen3-moe-235b-a22b",
    "mamba2-780m",
    "jamba-1.5-large-398b",
    "whisper-tiny",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_reduced(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[name]).REDUCED


__all__ = ["ARCH_IDS", "ModelConfig", "get_config", "get_reduced"]
