"""Jamba-1.5-Large 398B: Mamba+attention 1:7 interleave with MoE 16e top-2
[arXiv:2403.19887].  SSD layers stand in for Jamba's Mamba-1 blocks (see
DESIGN.md hardware-adaptation notes)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    rope=False,  # Jamba uses no positional encoding in attention
    activation="swiglu",
    attn_period=8,  # one attention layer per 8 (1:7)
    num_experts=16,
    num_experts_per_tok=2,
    moe_d_ff=24576,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=128,
    ssm_groups=1,
    ssm_conv=4,
    subquadratic=True,  # hybrid => long_500k runs
)

REDUCED = CONFIG.replace(
    name="jamba-1.5-large-398b-reduced", num_layers=4, attn_period=4,
    d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    moe_d_ff=128, vocab_size=256, num_experts=4, num_experts_per_tok=2,
    ssm_state=16, ssm_headdim=16, ssd_chunk=16, moe_group_size=64,
)
