"""Nemotron-4 15B: dense GQA with squared-ReLU MLP [arXiv:2402.16819]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    head_dim=128,
    rope_theta=1e4,
    activation="squared_relu",
    norm="layernorm",
    subquadratic=False,
)

REDUCED = CONFIG.replace(
    name="nemotron-4-15b-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
)
