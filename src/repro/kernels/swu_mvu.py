"""Fused SWU+MVU conv kernel (FINN Fig. 1 without the im2col matrix).

In FINN the Sliding Window Unit lowers convolution to an interleaved GEMM
*stream*: a line buffer holds the last ``Kd`` input rows and feeds the MVU
one K = Kd^2*C window per output pixel, so the (P, K) im2col matrix never
exists in memory.  ``repro.core.swu.sliding_window`` is the host-side analog
that *does* materialize it -- exactly the (B, OH*OW, Kd^2*C) HBM blow-up the
RTL avoids.

This kernel family restores the line-buffer discipline on TPU: the input
image stays in its natural (B, H, W, C) layout in HBM, and each grid step
gathers the sliding windows for one tile of output rows *inside the kernel*
(static strided slices over the ``Kd`` resident kernel rows -- the line
buffer), multiplies against one PE block of the packed weight matrix, and
runs the fused multi-threshold epilogue.  The (ky, kx, c) feature order
matches :func:`repro.core.swu.pack_conv_weights`, so the same packed weights
serve both paths.

Grid = (B, row tiles, NF); every step is independent (full-K dot per step),
mirroring one pass of the FINN SWU/MVU pair over ``rt`` output rows:

    A tile   (rt*OW, K)  gathered from the Kd-row line buffer per output row
    W block  (PE=bn, K)  weight stream, one NF row group per step
    epilogue thresholds / scale / raw int32 accumulator (shared MVTU code)

All three weight codings run through the MXU via the usual identities
(cf. ``mvu_binary``/``ops.xnor_mxu``):

    standard  acc = A . W^T                          (int8 x int8 -> int32)
    binary    acc = 2*(A . W01^T) - sum_k A          ({0,1}-coded +/-1 rows)
    xnor      acc = 4*(A01 . W01^T) - 2*sum_k A01
                    - 2*sum_k W01 + K                (1-bit x 1-bit, bipolar)

The xnor identity needs no pad-bit correction: the gather builds A with
exactly K true synapses, unlike the packed-word datapath.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.swu import out_dim
from repro.kernels._common import CompilerParams, epilogue_value, pad_to

MODES = ("standard", "binary", "xnor")


def conv_rows_per_tile(oh: int, ow: int, block_m: int) -> int:
    """Output rows gathered per grid step: the MXU sees ~block_m pixels."""
    return max(1, min(oh, -(-block_m // ow)))


def conv_vmem_bytes(
    h: int, w: int, c: int, n: int, k: int,
    *,
    kernel: int, stride: int, pad: int,
    block_m: int, block_n: int, n_thresh: int = 0,
) -> int:
    """VMEM working set of one ``conv_mvu_pallas`` grid step, in bytes.

    Mirrors the kernel's actual residency: the whole padded image tile
    (line-buffer source), the gathered (rt*OW, K) window tile, one PE block
    of the weight matrix, the int32 output tile, and the threshold table.
    The autotuner prunes candidate schedules against this before timing.
    """
    oh = out_dim(h, kernel, stride, pad)
    ow = out_dim(w, kernel, stride, pad)
    rt = conv_rows_per_tile(oh, ow, block_m)
    n_tiles = -(-oh // rt)
    need_h = (n_tiles * rt - 1) * stride + kernel
    hp = h + pad + max(pad, need_h - h - pad)  # same padding rule as the kernel
    wp = w + 2 * pad
    image = hp * wp * c  # int8 line-buffer source, resident per grid step
    a_tile = rt * ow * k  # int8 gathered windows
    w_tile = block_n * k  # int8 PE block, full K
    out_tile = rt * ow * block_n * 4
    thr = block_n * n_thresh * 4
    return int(image + a_tile + w_tile + out_tile + thr)


def _kernel(*refs, kernel: int, stride: int, ow: int, rt: int, k: int,
            mode: str, has_thresh: bool, has_scale: bool):
    if has_thresh:
        x_ref, w_ref, t_ref, o_ref = refs
        s_ref = None
    elif has_scale:
        x_ref, w_ref, s_ref, o_ref = refs
        t_ref = None
    else:
        x_ref, w_ref, o_ref = refs
        t_ref = s_ref = None

    t = pl.program_id(1)

    # Line-buffer gather: for each output row in the tile, only the Kd
    # resident kernel rows are touched; each kx tap is a static strided
    # slice, so no im2col matrix ever exists outside this kernel.
    tiles = []
    for r in range(rt):  # static unroll over the row tile
        row0 = (t * rt + r) * stride
        win = x_ref[0, pl.ds(row0, kernel)]  # (Kd, Wp, C) -- the line buffer
        taps = [
            win[:, kx : kx + stride * ow : stride, :]  # (Kd, OW, C) per kx
            for kx in range(kernel)
        ]
        a = jnp.stack(taps, axis=1)  # (ky, kx, OW, C)
        tiles.append(jnp.transpose(a, (2, 0, 1, 3)).reshape(ow, k))
    a_tile = jnp.concatenate(tiles, axis=0).astype(jnp.int8)  # (rt*OW, K)

    w_blk = w_ref[...]  # (bn, K) int8
    dot = jax.lax.dot_general(
        a_tile, w_blk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    if mode == "standard":
        acc = dot
    elif mode == "binary":
        rowsum = jnp.sum(a_tile.astype(jnp.int32), axis=1, keepdims=True)
        acc = 2 * dot - rowsum
    else:  # xnor: both operands {0,1}-coded +/-1
        rowsum = jnp.sum(a_tile.astype(jnp.int32), axis=1, keepdims=True)
        colsum = jnp.sum(w_blk.astype(jnp.int32), axis=1)[None, :]
        acc = 4 * dot - 2 * rowsum - 2 * colsum + k

    o_ref[...] = epilogue_value(acc, t_ref, s_ref)[None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "kernel", "stride", "pad", "mode", "block_n", "rows_per_tile",
        "block_m", "interpret",
    ),
)
def conv_mvu_pallas(
    x: jax.Array,
    w: jax.Array,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
    *,
    kernel: int,
    stride: int = 1,
    pad: int = 0,
    mode: str = "standard",
    block_n: int = 128,
    block_m: int = 128,
    rows_per_tile: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """out[B, OH*OW, N] = epilogue(SWU(x) . W^T), without materializing SWU(x).

    x: (B, H, W, C) int8 activations (standard/binary) or {0,1} bits (xnor)
    w: (N, K = Kd^2*C) int8 packed in (ky, kx, c) order; binary/xnor rows are
       {0,1}-coded +/-1 (``packing.bipolar_to_bits``)
    thresholds: optional (N, T) int32  -> int32 activations in [0, T]
    out_scale: optional (N,) float32   -> float32 dequantized output
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if thresholds is not None and out_scale is not None:
        raise ValueError("thresholds and out_scale are mutually exclusive")
    b, h, wdim, c = x.shape
    n, k = w.shape
    assert k == kernel * kernel * c, (w.shape, kernel, c)
    oh = out_dim(h, kernel, stride, pad)
    ow = out_dim(wdim, kernel, stride, pad)

    # Output-row tiling: rt rows per grid step so the MXU sees M ~ block_m
    # pixels; OH pads up to a whole number of tiles (garbage rows sliced off).
    rt = rows_per_tile or conv_rows_per_tile(oh, ow, block_m)
    n_tiles = -(-oh // rt)
    need_h = (n_tiles * rt - 1) * stride + kernel
    x_p = jnp.pad(
        x.astype(jnp.int8),
        ((0, 0), (pad, max(pad, need_h - h - pad)), (pad, pad), (0, 0)),
    )
    hp, wp = x_p.shape[1], x_p.shape[2]
    w_p = pad_to(w.astype(jnp.int8), 0, block_n)
    np_ = w_p.shape[0]
    grid = (b, n_tiles, np_ // block_n)

    in_specs = [
        pl.BlockSpec((1, hp, wp, c), lambda bi, ti, ni: (bi, 0, 0, 0)),
        pl.BlockSpec((block_n, k), lambda bi, ti, ni: (ni, 0)),
    ]
    operands = [x_p, w_p]
    has_thresh = thresholds is not None
    has_scale = out_scale is not None
    if has_thresh:
        t_p = pad_to(thresholds.astype(jnp.int32), 0, block_n)
        nt = t_p.shape[1]
        in_specs.append(pl.BlockSpec((block_n, nt), lambda bi, ti, ni: (ni, 0)))
        operands.append(t_p)
        out_dtype = jnp.int32
    elif has_scale:
        s_p = pad_to(out_scale.reshape(-1, 1).astype(jnp.float32), 0, block_n, value=1)
        in_specs.append(pl.BlockSpec((block_n, 1), lambda bi, ti, ni: (ni, 0)))
        operands.append(s_p)
        out_dtype = jnp.float32
    else:
        out_dtype = jnp.int32

    out = pl.pallas_call(
        functools.partial(
            _kernel, kernel=kernel, stride=stride, ow=ow, rt=rt, k=k,
            mode=mode, has_thresh=has_thresh, has_scale=has_scale,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rt * ow, block_n), lambda bi, ti, ni: (bi, ti, ni)),
        out_shape=jax.ShapeDtypeStruct((b, n_tiles * rt * ow, np_), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name=f"conv_mvu_{mode}",
    )(*operands)
    return out[:, : oh * ow, :n]
