"""Public jit'd entry points for the MVU kernels.

``mvu(...)`` dispatches on the SIMD-lane datapath (paper Fig. 4):

    mode="xnor"     1-bit x 1-bit, bit-packed XNOR+popcount   (Fig. 4a)
    mode="binary"   {+-1} weights x n-bit inputs               (Fig. 4b)
    mode="standard" arbitrary-precision integer lanes          (Fig. 4c)

Each mode has two backends:
    backend="pallas"  hand-scheduled kernel (the paper's RTL analog)
    backend="xla"     pure-jnp reference compiled by XLA (the HLS analog)

On non-TPU hosts the Pallas backend runs in interpret mode (CPU validation);
the TPU is the deployment target.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import mvu_packed as packed_kernels
from repro.kernels import packing, ref
from repro.kernels._common import default_interpret
from repro.kernels.mvu_binary import mvu_binary_pallas
from repro.kernels.mvu_int import mvu_int_pallas
from repro.kernels.mvu_xnor import mvu_xnor_pallas
from repro.kernels.swu_mvu import conv_mvu_pallas

MODES = ("xnor", "binary", "standard")
BACKENDS = ("pallas", "xla")


def xnor_mxu(
    a_packed: jax.Array,
    w_packed: jax.Array,
    k_bits: int,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
) -> jax.Array:
    """Beyond-paper XNOR variant: unpack to +/-1 int8 and use the MXU.

    On FPGA the bit-serial datapath wins because LUTs are the scarce
    resource; on TPU the MXU's int8 path delivers 394 TOP/s vs the VPU's
    ~4 TOP/s, so paying an 8x unpack blow-up in VMEM traffic can still win
    by >10x on compute.  Benchmarked against the faithful datapath in
    EXPERIMENTS.md section Perf.
    """
    a = packing.bits_to_bipolar(packing.unpack_bits(a_packed, k_bits)).astype(jnp.int8)
    w = packing.bits_to_bipolar(packing.unpack_bits(w_packed, k_bits)).astype(jnp.int8)
    return mvu_int_pallas(a, w, thresholds, out_scale, interpret=default_interpret())


def mvu_layer_fn(mode: str = "standard", *, backend: str = "pallas", **blocks):
    """Stage callable for the streaming executors: ``fn(params, x) -> y``.

    ``params`` is a dict with ``"w"`` (N, K) plus optionally ``"t"``
    (thresholds) or ``"s"`` (out_scale) — the stackable form used by
    ``repro.core.engine.FusedEngine.as_pipeline`` to run one MVU per
    pipeline stage through ``repro.distributed.pipeline.pipeline_apply``.
    """

    def fn(params, x):
        return mvu(
            x,
            params["w"],
            mode,
            thresholds=params.get("t"),
            out_scale=params.get("s"),
            backend=backend,
            **blocks,
        )

    return fn


def conv_mvu(
    x: jax.Array,
    w: jax.Array,
    *,
    kernel: int,
    stride: int = 1,
    pad: int = 0,
    mode: str = "standard",
    k_bits: int | None = None,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
    backend: str = "pallas",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    block_kw: int = 8,
    rows_per_tile: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused SWU+MVU convolution: epilogue(SWU(x) . W^T) -> (B, OH*OW, N).

    x: (B, H, W, C) integer activations ({0,1} bits for xnor); w: (N, Kd^2*C)
    in (ky, kx, c) order -- ``standard`` integer rows, ``binary`` {0,1}-coded
    +/-1 rows, ``xnor`` bit-packed (N, Wd) uint32 rows (``k_bits`` = Kd^2*C,
    unpacked on the fly; the fused gather needs the true synapse axis).

    backend="pallas" streams sliding windows through the line-buffer kernel
    (no im2col in HBM); backend="xla" is the materializing reference.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if interpret is None:
        interpret = default_interpret()
    if mode == "xnor":
        assert k_bits is not None, "xnor mode requires k_bits"
        w = packing.unpack_bits(w, k_bits).astype(jnp.int8)  # (N, K) {0,1}

    if backend == "xla":
        return ref.conv_mvu_ref(
            x, w, kernel=kernel, stride=stride, pad=pad, mode=mode,
            thresholds=thresholds, out_scale=out_scale,
        )
    del block_k, block_kw  # the fused gather keeps full K resident
    return conv_mvu_pallas(
        x, w, thresholds, out_scale,
        kernel=kernel, stride=stride, pad=pad, mode=mode,
        block_m=block_m, block_n=block_n, rows_per_tile=rows_per_tile,
        interpret=interpret,
    )


def mvu(
    a: jax.Array,
    w: jax.Array,
    mode: str = "standard",
    *,
    k_bits: int | None = None,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
    backend: str = "pallas",
    packed: bool = False,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    block_kw: int = 8,
    rows_per_tile: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Matrix-vector(-batch) compute: epilogue(A . W^T).

    Shapes: standard/binary: a (M, K), w (N, K). xnor: packed a (M, Wd)
    uint32, w (N, Wd) uint32 with ``k_bits`` true synapses.

    ``packed=True`` selects the bit-packed datapath (kernels/mvu_packed.py):
    ``w`` is then the mode's packed storage form -- uint32 bitplanes for
    binary, uint8 2-bit lanes for standard, the usual packed words for xnor
    -- and ``k_bits`` carries the true K for every mode.

    ``rows_per_tile`` is accepted for uniform block plumbing with
    :func:`conv_mvu` (tuned schedules pass one kwargs set to either entry
    point); the dense kernels have no row tiling and ignore it, just as the
    conv path ignores ``block_k``/``block_kw``.
    """
    del rows_per_tile
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if interpret is None:
        interpret = default_interpret()

    if packed:
        assert k_bits is not None, "packed mvu requires k_bits"
        return packed_kernels.mvu_packed(
            a, w, mode, k_bits, thresholds, out_scale,
            backend=backend, block_m=block_m, block_n=block_n,
            block_k=block_k, block_kw=block_kw, interpret=interpret,
        )

    if backend == "xla":
        if mode == "xnor":
            assert k_bits is not None
            return ref.mvu_xnor_ref(a, w, k_bits, thresholds, out_scale)
        if mode == "binary":
            return ref.mvu_binary_ref(a, w, thresholds, out_scale)
        return ref.mvu_int_ref(a, w, thresholds, out_scale)

    if mode == "xnor":
        assert k_bits is not None, "xnor mode requires k_bits"
        return mvu_xnor_pallas(
            a, w, k_bits, thresholds, out_scale,
            block_m=block_m, block_n=block_n, block_kw=block_kw,
            interpret=interpret,
        )
    if mode == "binary":
        return mvu_binary_pallas(
            a, w, thresholds, out_scale,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )
    return mvu_int_pallas(
        a, w, thresholds, out_scale,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
