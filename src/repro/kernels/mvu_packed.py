"""Packed-datapath MVU kernel family (paper Fig. 4a economics in storage).

The RTL MVU wins on resources because synapses live bit-packed in the PE
weight memories and the datapath consumes them without ever widening to
canonical operands.  This module is that datapath on TPU: every kernel takes
*packed* weight storage -- uint32 bitplanes for 1-bit codings
(:func:`packing.pack_bits`), 4x 2-bit two's-complement lanes per byte for
2-bit weights (:func:`packing.pack_int2`) -- and computes the exact same
integers as ``kernels/ref.py`` via the pack-domain identities:

    xnor    dot = 2 * popcount(~(a ^ w)) - pad_correction(K)   (Fig. 4a)
    binary  dot = 2 * (x . w01) - rowsum(x)                    (Fig. 4b)
    2-bit   dot = x . sign_extend(w2)                          (Fig. 4c)

Pallas kernels unpack one weight tile at a time inside VMEM, so HBM traffic
and the weight-resident footprint shrink by the packing factor (32x bits,
4x lanes) while the MXU/VPU still sees full-rate operands.  The XLA paths
are the compiled fallbacks the autotuner races against them; the blocked
XNOR popcount path in particular is memory-bandwidth-bound and beats the
unpack-then-matmul reference by a wide margin on large N*K layers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._common import (
    CompilerParams,
    default_interpret,
    epilogue_write,
    pad_to,
    std_grid,
)
from repro.kernels import packing, ref
from repro.kernels.packing import INT2_PER_BYTE, WORD_BITS, pad_correction


# --------------------------------------------------------------- xnor / xla
@functools.partial(jax.jit, static_argnames=("k_bits", "block_n"))
def mvu_xnor_popcount_xla(
    a_packed: jax.Array,
    w_packed: jax.Array,
    k_bits: int,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
    *,
    block_n: int | None = None,
) -> jax.Array:
    """Blocked XNOR+popcount entirely in the packed domain (no unpack).

    a_packed: (M, Wd) uint32, w_packed: (N, Wd) uint32.  The (M, bn, Wd)
    xnor intermediate is tiled over N (``block_n`` words of output columns
    per step, default sized so the tile stays ~4 MiB) and reduced with the
    hardware popcount -- the compiled analog of the paper's LUT popcount
    tree, and the memory-bandwidth-bound fast path on large N*K layers.
    """
    if thresholds is not None and out_scale is not None:
        raise ValueError("thresholds and out_scale are mutually exclusive")
    m, wd = a_packed.shape
    n, wd2 = w_packed.shape
    assert wd == wd2
    nb = block_n or max(1, (1 << 22) // max(1, m * max(wd, 1)))
    nb = min(n, nb)
    w_p = pad_to(w_packed, 0, nb)

    def chunk(wc):  # (nb, Wd) -> (M, nb) popcounts
        x = ~(a_packed[:, None, :] ^ wc[None, :, :])
        return jnp.sum(packing.popcount(x), axis=-1, dtype=jnp.int32)

    pcs = jax.lax.map(chunk, w_p.reshape(-1, nb, wd))  # (n/nb, M, nb)
    pc = jnp.moveaxis(pcs, 0, 1).reshape(m, -1)[:, :n]
    dot = 2 * pc - pad_correction(k_bits, wd * WORD_BITS)
    return ref._epilogue(dot, thresholds, out_scale)


# ----------------------------------------------------------- binary / pallas
def _binary_kernel(*refs, block_kw: int, has_thresh: bool, has_scale: bool):
    if has_thresh:
        a_ref, w_ref, t_ref, o_ref, acc_ref = refs
        s_ref = None
    elif has_scale:
        a_ref, w_ref, s_ref, o_ref, acc_ref = refs
        t_ref = None
    else:
        a_ref, w_ref, o_ref, acc_ref = refs
        t_ref = s_ref = None

    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bk = block_kw * WORD_BITS
    a_blk = a_ref[:, pl.ds(k * bk, bk)]  # (bm, bkw*32) int8
    w_blk = w_ref[...]  # (bn, bkw) uint32 bitplanes
    # in-VMEM unpack of one weight tile: (bn, bkw, 32) bits -> (bn, bkw*32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, WORD_BITS), 2)
    w01 = ((w_blk[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.int8)
    w01 = w01.reshape(w_blk.shape[0], bk)
    dot = jax.lax.dot_general(
        a_blk, w01, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )
    # 2*(x.w01) - sum(x): zero-padded activation columns contribute 0 to both
    # terms, so garbage pad bits in the weight words are harmless.
    rowsum = jnp.sum(a_blk.astype(jnp.int32), axis=1, keepdims=True)
    acc_ref[...] += 2 * dot - rowsum

    @pl.when(k == nk - 1)
    def _done():
        epilogue_write(o_ref, acc_ref[...], t_ref, s_ref)


@functools.partial(
    jax.jit,
    static_argnames=("k_bits", "block_m", "block_n", "block_kw", "interpret"),
)
def mvu_binary_packed_pallas(
    a: jax.Array,
    w_packed: jax.Array,
    k_bits: int,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_kw: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """out[M,N] = epilogue(A[M,K] . (2*W01[N,K]-1)^T) from bitplane weights.

    a: (M, K) int8 activations; w_packed: (N, ceil(K/32)) uint32 bitplanes
    of the {0,1} weight coding (:func:`packing.pack_bits`).  The weight
    tile is unpacked inside the kernel, so the HBM-resident weights stay
    32x smaller than the canonical int8 form.
    """
    if thresholds is not None and out_scale is not None:
        raise ValueError("thresholds and out_scale are mutually exclusive")
    m, k = a.shape
    n, wd = w_packed.shape
    assert k == k_bits and wd * WORD_BITS >= k

    w_p = pad_to(pad_to(w_packed, 0, block_n), 1, block_kw)
    np_, wdp = w_p.shape
    # activations padded out to the full unpacked span of the padded words
    a_p = pad_to(pad_to(a.astype(jnp.int8), 0, block_m), 1, wdp * WORD_BITS)
    mp, _ = a_p.shape
    grid = std_grid(mp, np_, wdp, block_m, block_n, block_kw)

    in_specs = [
        pl.BlockSpec((block_m, wdp * WORD_BITS), lambda mi, ni, ki: (mi, 0)),
        pl.BlockSpec((block_n, block_kw), lambda mi, ni, ki: (ni, ki)),
    ]
    operands = [a_p, w_p]
    has_thresh = thresholds is not None
    has_scale = out_scale is not None
    if has_thresh:
        t_p = pad_to(thresholds.astype(jnp.int32), 0, block_n)
        nt = t_p.shape[1]
        in_specs.append(pl.BlockSpec((block_n, nt), lambda mi, ni, ki: (ni, 0)))
        operands.append(t_p)
        out_dtype = jnp.int32
    elif has_scale:
        s_p = pad_to(out_scale.reshape(-1, 1).astype(jnp.float32), 0, block_n, value=1)
        in_specs.append(pl.BlockSpec((block_n, 1), lambda mi, ni, ki: (ni, 0)))
        operands.append(s_p)
        out_dtype = jnp.float32
    else:
        out_dtype = jnp.int32

    out = pl.pallas_call(
        functools.partial(
            _binary_kernel,
            block_kw=block_kw,
            has_thresh=has_thresh,
            has_scale=has_scale,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="mvu_binary_packed",
    )(*operands)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("k_bits",))
def mvu_binary_packed_xla(
    a: jax.Array,
    w_packed: jax.Array,
    k_bits: int,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
) -> jax.Array:
    """Compiled fallback: unpack the bitplanes once, then the Fig. 4b oracle."""
    w_bits = packing.unpack_bits(w_packed, k_bits)
    return ref.mvu_binary_ref(a, w_bits, thresholds, out_scale)


# ------------------------------------------------------------- 2-bit / pallas
def _int2_kernel(*refs, block_kb: int, has_thresh: bool, has_scale: bool):
    if has_thresh:
        a_ref, w_ref, t_ref, o_ref, acc_ref = refs
        s_ref = None
    elif has_scale:
        a_ref, w_ref, s_ref, o_ref, acc_ref = refs
        t_ref = None
    else:
        a_ref, w_ref, o_ref, acc_ref = refs
        t_ref = s_ref = None

    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bk = block_kb * INT2_PER_BYTE
    a_blk = a_ref[:, pl.ds(k * bk, bk)]  # (bm, bkb*4) int8
    w_blk = w_ref[...]  # (bn, bkb) uint8 2-bit lanes
    # in-VMEM sign-extending unpack: (bn, bkb, 4) fields -> (bn, bkb*4)
    shifts = 2 * jax.lax.broadcasted_iota(jnp.uint8, (1, 1, INT2_PER_BYTE), 2)
    fields = ((w_blk[:, :, None] >> shifts) & jnp.uint8(0x3)).astype(jnp.int8)
    w2 = jnp.where(fields >= 2, fields - 4, fields).reshape(w_blk.shape[0], bk)
    acc_ref[...] += jax.lax.dot_general(
        a_blk, w2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(k == nk - 1)
    def _done():
        epilogue_write(o_ref, acc_ref[...], t_ref, s_ref)


@functools.partial(
    jax.jit,
    static_argnames=("k_bits", "block_m", "block_n", "block_k", "interpret"),
)
def mvu_int2_packed_pallas(
    a: jax.Array,
    w_packed: jax.Array,
    k_bits: int,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """out[M,N] = epilogue(A[M,K] . W2[N,K]^T) from 2-bit lane weights.

    a: (M, K) int8 activations; w_packed: (N, ceil(K/4)) uint8 holding four
    signed 2-bit two's-complement lanes per byte (:func:`packing.pack_int2`).
    ``block_k`` counts synapse lanes (must be a multiple of 4); padded lanes
    decode to weight 0 and contribute nothing.
    """
    if thresholds is not None and out_scale is not None:
        raise ValueError("thresholds and out_scale are mutually exclusive")
    if block_k % INT2_PER_BYTE:
        raise ValueError(f"block_k must be a multiple of {INT2_PER_BYTE}")
    m, k = a.shape
    n, bd = w_packed.shape
    assert k == k_bits and bd * INT2_PER_BYTE >= k
    block_kb = block_k // INT2_PER_BYTE

    w_p = pad_to(pad_to(w_packed, 0, block_n), 1, block_kb)
    np_, bdp = w_p.shape
    a_p = pad_to(pad_to(a.astype(jnp.int8), 0, block_m), 1, bdp * INT2_PER_BYTE)
    mp, _ = a_p.shape
    grid = std_grid(mp, np_, bdp, block_m, block_n, block_kb)

    in_specs = [
        pl.BlockSpec((block_m, bdp * INT2_PER_BYTE), lambda mi, ni, ki: (mi, 0)),
        pl.BlockSpec((block_n, block_kb), lambda mi, ni, ki: (ni, ki)),
    ]
    operands = [a_p, w_p]
    has_thresh = thresholds is not None
    has_scale = out_scale is not None
    if has_thresh:
        t_p = pad_to(thresholds.astype(jnp.int32), 0, block_n)
        nt = t_p.shape[1]
        in_specs.append(pl.BlockSpec((block_n, nt), lambda mi, ni, ki: (ni, 0)))
        operands.append(t_p)
        out_dtype = jnp.int32
    elif has_scale:
        s_p = pad_to(out_scale.reshape(-1, 1).astype(jnp.float32), 0, block_n, value=1)
        in_specs.append(pl.BlockSpec((block_n, 1), lambda mi, ni, ki: (ni, 0)))
        operands.append(s_p)
        out_dtype = jnp.float32
    else:
        out_dtype = jnp.int32

    out = pl.pallas_call(
        functools.partial(
            _int2_kernel,
            block_kb=block_kb,
            has_thresh=has_thresh,
            has_scale=has_scale,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="mvu_int2_packed",
    )(*operands)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("k_bits",))
def mvu_int2_packed_xla(
    a: jax.Array,
    w_packed: jax.Array,
    k_bits: int,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
) -> jax.Array:
    """Compiled fallback: sign-extend the 2-bit lanes, then the int oracle."""
    w = packing.unpack_int2(w_packed, k_bits)
    return ref.mvu_int_ref(a, w, thresholds, out_scale)


def pack_mvu_weights(w: jax.Array, mode: str) -> jax.Array:
    """Canonical (N, K) weights -> the mode's packed storage form.

    xnor weights arrive already bit-packed (the pack is a no-op); binary
    {0,1} rows become uint32 bitplanes; standard rows (must fit signed
    2-bit, i.e. values in [-2, 1]) become uint8 2-bit lanes.
    """
    if mode == "xnor":
        return w
    if mode == "binary":
        return packing.pack_bits(w.astype(jnp.int32))
    lo, hi = int(jnp.min(w)), int(jnp.max(w))
    if lo < -2 or hi > 1:
        raise ValueError(
            f"standard-mode packing needs signed 2-bit weights in [-2, 1]; "
            f"got range [{lo}, {hi}]")
    return packing.pack_int2(w.astype(jnp.int32))


def packed_weight_bytes(n: int, k: int, mode: str, weight_bits: int) -> int:
    """HBM-resident bytes of the packed (N, K) weight matrix for ``mode``."""
    if mode in ("xnor", "binary"):
        return n * packing.num_words(k) * 4
    del weight_bits  # standard packing is the 2-bit lane format
    return n * packing.num_int2_bytes(k)


def mvu_packed(
    a: jax.Array,
    w_packed: jax.Array,
    mode: str,
    k_bits: int,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
    *,
    backend: str = "pallas",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    block_kw: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Dispatch over the packed kernel family (mirror of ``ops.mvu``)."""
    if interpret is None:
        interpret = default_interpret()
    if mode == "xnor":
        if backend == "xla":
            return mvu_xnor_popcount_xla(
                a, w_packed, k_bits, thresholds, out_scale)
        from repro.kernels.mvu_xnor import mvu_xnor_pallas

        # the Fig. 4a Pallas kernel is natively packed -- same datapath
        return mvu_xnor_pallas(
            a, w_packed, k_bits, thresholds, out_scale,
            block_m=block_m, block_n=block_n, block_kw=block_kw,
            interpret=interpret,
        )
    if mode == "binary":
        if backend == "xla":
            return mvu_binary_packed_xla(a, w_packed, k_bits, thresholds, out_scale)
        return mvu_binary_packed_pallas(
            a, w_packed, k_bits, thresholds, out_scale,
            block_m=block_m, block_n=block_n, block_kw=block_kw,
            interpret=interpret,
        )
    if backend == "xla":
        return mvu_int2_packed_xla(a, w_packed, k_bits, thresholds, out_scale)
    return mvu_int2_packed_pallas(
        a, w_packed, k_bits, thresholds, out_scale,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
