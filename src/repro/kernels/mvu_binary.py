"""Binary-weight MVU (paper Fig. 4b): {0,1}-coded +/-1 weights, n-bit inputs.

The FPGA datapath selects +x or -x per synapse and feeds an adder tree.  On
TPU we use the algebraic identity

    sum_k x_k * (2 w_k - 1)  =  2 * (x . w01) - sum_k x_k

so the select/add tree becomes one 0/1 int8 MXU matmul plus a per-row input
sum correction -- the MXU *is* the compressor tree (cf. Preusser [36]).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._common import CompilerParams, epilogue_write, pad_to, std_grid


def _kernel(*refs, block_k: int, has_thresh: bool, has_scale: bool):
    if has_thresh:
        a_ref, w_ref, t_ref, o_ref, acc_ref = refs
        s_ref = None
    elif has_scale:
        a_ref, w_ref, s_ref, o_ref, acc_ref = refs
        t_ref = None
    else:
        a_ref, w_ref, o_ref, acc_ref = refs
        t_ref = s_ref = None

    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_blk = a_ref[:, pl.ds(k * block_k, block_k)]  # (bm, bk) int8
    w_blk = w_ref[...]  # (bn, bk) int8 in {0,1}
    dot = jax.lax.dot_general(
        a_blk, w_blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )
    # per-block correction: 2*(x.w01) - sum(x); zero-padded K chunks add 0.
    rowsum = jnp.sum(a_blk.astype(jnp.int32), axis=1, keepdims=True)
    acc_ref[...] += 2 * dot - rowsum

    @pl.when(k == nk - 1)
    def _done():
        epilogue_write(o_ref, acc_ref[...], t_ref, s_ref)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def mvu_binary_pallas(
    a: jax.Array,
    w_bits: jax.Array,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """out[M,N] = epilogue(A[M,K] . (2*W01[N,K]-1)^T).

    a: (M, K) int8 activations; w_bits: (N, K) int8 in {0,1}.
    """
    if thresholds is not None and out_scale is not None:
        raise ValueError("thresholds and out_scale are mutually exclusive")
    m, k = a.shape
    n, k2 = w_bits.shape
    assert k == k2

    a_p = pad_to(pad_to(a, 0, block_m), 1, block_k)
    w_p = pad_to(pad_to(w_bits.astype(jnp.int8), 0, block_n), 1, block_k)
    mp, kp = a_p.shape
    np_, _ = w_p.shape
    grid = std_grid(mp, np_, kp, block_m, block_n, block_k)

    in_specs = [
        pl.BlockSpec((block_m, kp), lambda mi, ni, ki: (mi, 0)),
        pl.BlockSpec((block_n, block_k), lambda mi, ni, ki: (ni, ki)),
    ]
    operands = [a_p, w_p]
    has_thresh = thresholds is not None
    has_scale = out_scale is not None
    if has_thresh:
        t_p = pad_to(thresholds.astype(jnp.int32), 0, block_n)
        nt = t_p.shape[1]
        in_specs.append(pl.BlockSpec((block_n, nt), lambda mi, ni, ki: (ni, 0)))
        operands.append(t_p)
        out_dtype = jnp.int32
    elif has_scale:
        s_p = pad_to(out_scale.reshape(-1, 1).astype(jnp.float32), 0, block_n, value=1)
        in_specs.append(pl.BlockSpec((block_n, 1), lambda mi, ni, ki: (ni, 0)))
        operands.append(s_p)
        out_dtype = jnp.float32
    else:
        out_dtype = jnp.int32

    out = pl.pallas_call(
        functools.partial(
            _kernel, block_k=block_k, has_thresh=has_thresh, has_scale=has_scale
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="mvu_binary",
    )(*operands)
    return out[:m, :n]
