"""Pure-jnp oracles for every MVU kernel (the "golden model").

All reference paths are written in the mathematically transparent form
(unpack -> integer matmul -> epilogue) so the Pallas kernels can be checked
for *exact* integer equality.

Shapes follow the paper's GEMM view (Fig. 1):
  activations A: (M, K)   -- M output pixels, K = Kd^2 * I_c synapses
  weights     W: (N, K)   -- N = O_c output channels (one row per neuron)
  output        : (M, N)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.thresholds import apply_thresholds
from repro.kernels import packing


def _epilogue(
    acc: jax.Array,
    thresholds: jax.Array | None,
    out_scale: jax.Array | None,
) -> jax.Array:
    if thresholds is not None:
        return apply_thresholds(acc, thresholds)
    if out_scale is not None:
        return acc.astype(jnp.float32) * out_scale
    return acc


def mvu_xnor_ref(
    a_packed: jax.Array,
    w_packed: jax.Array,
    k_bits: int,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
) -> jax.Array:
    """XNOR-popcount MVU oracle on *packed* operands.

    a_packed: (M, Wd) uint32, w_packed: (N, Wd) uint32; both packed with
    :func:`packing.pack_bits` (zero pad bits).  Implements the bipolar dot
    product over the true K = ``k_bits`` synapses.
    """
    m, wd = a_packed.shape
    a_bits = packing.unpack_bits(a_packed, k_bits)  # (M, K) {0,1}
    w_bits = packing.unpack_bits(w_packed, k_bits)  # (N, K)
    a = packing.bits_to_bipolar(a_bits)
    w = packing.bits_to_bipolar(w_bits)
    acc = jax.lax.dot_general(
        a, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )
    return _epilogue(acc, thresholds, out_scale)


def mvu_binary_ref(
    a: jax.Array,
    w_bits: jax.Array,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
) -> jax.Array:
    """Binary-weight MVU oracle: a (M, K) int, w_bits (N, K) in {0,1} ~ {-1,+1}."""
    w = packing.bits_to_bipolar(w_bits.astype(jnp.int32))
    acc = jax.lax.dot_general(
        a.astype(jnp.int32), w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return _epilogue(acc, thresholds, out_scale)


def mvu_int_ref(
    a: jax.Array,
    w: jax.Array,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
) -> jax.Array:
    """Standard (arbitrary-precision) MVU oracle: int x int -> int32 matmul."""
    acc = jax.lax.dot_general(
        a.astype(jnp.int32), w.astype(jnp.int32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return _epilogue(acc, thresholds, out_scale)
