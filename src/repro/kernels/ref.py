"""Pure-jnp oracles for every MVU kernel (the "golden model").

All reference paths are written in the mathematically transparent form
(unpack -> integer matmul -> epilogue) so the Pallas kernels can be checked
for *exact* integer equality.

Shapes follow the paper's GEMM view (Fig. 1):
  activations A: (M, K)   -- M output pixels, K = Kd^2 * I_c synapses
  weights     W: (N, K)   -- N = O_c output channels (one row per neuron)
  output        : (M, N)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.thresholds import apply_thresholds
from repro.kernels import packing


def _epilogue(
    acc: jax.Array,
    thresholds: jax.Array | None,
    out_scale: jax.Array | None,
) -> jax.Array:
    if thresholds is not None:
        return apply_thresholds(acc, thresholds)
    if out_scale is not None:
        return acc.astype(jnp.float32) * out_scale
    return acc


def mvu_xnor_ref(
    a_packed: jax.Array,
    w_packed: jax.Array,
    k_bits: int,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
) -> jax.Array:
    """XNOR-popcount MVU oracle on *packed* operands.

    a_packed: (M, Wd) uint32, w_packed: (N, Wd) uint32; both packed with
    :func:`packing.pack_bits` (zero pad bits).  Implements the bipolar dot
    product over the true K = ``k_bits`` synapses.
    """
    m, wd = a_packed.shape
    a_bits = packing.unpack_bits(a_packed, k_bits)  # (M, K) {0,1}
    w_bits = packing.unpack_bits(w_packed, k_bits)  # (N, K)
    a = packing.bits_to_bipolar(a_bits)
    w = packing.bits_to_bipolar(w_bits)
    acc = jax.lax.dot_general(
        a, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )
    return _epilogue(acc, thresholds, out_scale)


def mvu_binary_ref(
    a: jax.Array,
    w_bits: jax.Array,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
) -> jax.Array:
    """Binary-weight MVU oracle: a (M, K) int, w_bits (N, K) in {0,1} ~ {-1,+1}."""
    w = packing.bits_to_bipolar(w_bits.astype(jnp.int32))
    acc = jax.lax.dot_general(
        a.astype(jnp.int32), w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return _epilogue(acc, thresholds, out_scale)


def conv_mvu_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    kernel: int,
    stride: int = 1,
    pad: int = 0,
    mode: str = "standard",
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
) -> jax.Array:
    """Fused-conv oracle: materialized SWU + the mode's MVU reference.

    x: (B, H, W, C) ints ({0,1} bits for binary/xnor weights' activations as
    appropriate); w: (N, Kd^2*C) in (ky, kx, c) order.  This is the "HLS"
    path -- it pays the im2col blow-up the Pallas kernel avoids.
    """
    from repro.core import swu as swu_mod

    b = x.shape[0]
    cols = swu_mod.sliding_window(x, kernel, stride, pad)  # (B, P, K)
    a = cols.reshape(-1, cols.shape[-1])
    if mode == "xnor":
        acc = jax.lax.dot_general(
            packing.bits_to_bipolar(a.astype(jnp.int32)),
            packing.bits_to_bipolar(w.astype(jnp.int32)),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32,
        )
        out = _epilogue(acc, thresholds, out_scale)
    elif mode == "binary":
        out = mvu_binary_ref(a, w, thresholds, out_scale)
    else:
        out = mvu_int_ref(a, w, thresholds, out_scale)
    return out.reshape(b, cols.shape[1], -1)


def mvu_int_ref(
    a: jax.Array,
    w: jax.Array,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
) -> jax.Array:
    """Standard (arbitrary-precision) MVU oracle: int x int -> int32 matmul."""
    acc = jax.lax.dot_general(
        a.astype(jnp.int32), w.astype(jnp.int32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return _epilogue(acc, thresholds, out_scale)
