"""XNOR-popcount MVU (paper Fig. 4a): 1-bit weights x 1-bit activations.

Faithful TPU port of the bit-serial FPGA datapath: 32 synapses are packed
per uint32 "wire bundle" and each grid step computes, on the VPU,

    acc[m, n] += sum_w popcount(~(a[m, w] ^ w[n, w]))

with the bipolar dot product recovered in the epilogue as

    dot = 2*acc - Kp - n_pad      (Kp = padded bits, n_pad = Kp - K)

since every zero pad bit in *both* operands contributes one spurious
popcount.  SIMD = 32 * block_kw synapses per step.

A beyond-paper MXU alternative (unpack to +/-1 int8 and matmul) lives in
ops.py as ``xnor_mxu`` -- benchmarked against this one in EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._common import (
    CompilerParams,
    epilogue_write,
    pad_to,
    std_grid,
    swar_popcount,
)
from repro.kernels.packing import WORD_BITS, pad_correction


def _kernel(*refs, block_kw: int, correction: int,
            has_thresh: bool, has_scale: bool):
    if has_thresh:
        a_ref, w_ref, t_ref, o_ref, acc_ref = refs
        s_ref = None
    elif has_scale:
        a_ref, w_ref, s_ref, o_ref, acc_ref = refs
        t_ref = None
    else:
        a_ref, w_ref, o_ref, acc_ref = refs
        t_ref = s_ref = None

    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_blk = a_ref[:, pl.ds(k * block_kw, block_kw)]  # (bm, bkw) uint32
    w_blk = w_ref[...]  # (bn, bkw) uint32
    # (bm, bn, bkw) xnor + popcount, reduced over the word axis on the VPU.
    xnor = ~(a_blk[:, None, :] ^ w_blk[None, :, :])
    acc_ref[...] += jnp.sum(swar_popcount(xnor), axis=-1, dtype=jnp.int32)

    @pl.when(k == nk - 1)
    def _done():
        # bipolar dot over the true K bits (packing.pad_correction)
        dot = 2 * acc_ref[...] - correction
        epilogue_write(o_ref, dot, t_ref, s_ref)


@functools.partial(
    jax.jit,
    static_argnames=("k_bits", "block_m", "block_n", "block_kw", "interpret"),
)
def mvu_xnor_pallas(
    a_packed: jax.Array,
    w_packed: jax.Array,
    k_bits: int,
    thresholds: jax.Array | None = None,
    out_scale: jax.Array | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_kw: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Bipolar out[M,N] from packed a (M, Wd) uint32 and w (N, Wd) uint32."""
    if thresholds is not None and out_scale is not None:
        raise ValueError("thresholds and out_scale are mutually exclusive")
    m, wd = a_packed.shape
    n, wd2 = w_packed.shape
    assert wd == wd2

    a_p = pad_to(pad_to(a_packed, 0, block_m), 1, block_kw)
    w_p = pad_to(pad_to(w_packed, 0, block_n), 1, block_kw)
    mp, wdp = a_p.shape
    np_, _ = w_p.shape
    kp_bits = wdp * WORD_BITS
    grid = std_grid(mp, np_, wdp, block_m, block_n, block_kw)

    in_specs = [
        pl.BlockSpec((block_m, wdp), lambda mi, ni, ki: (mi, 0)),
        pl.BlockSpec((block_n, block_kw), lambda mi, ni, ki: (ni, ki)),
    ]
    operands = [a_p, w_p]
    has_thresh = thresholds is not None
    has_scale = out_scale is not None
    if has_thresh:
        t_p = pad_to(thresholds.astype(jnp.int32), 0, block_n)
        nt = t_p.shape[1]
        in_specs.append(pl.BlockSpec((block_n, nt), lambda mi, ni, ki: (ni, 0)))
        operands.append(t_p)
        out_dtype = jnp.int32
    elif has_scale:
        s_p = pad_to(out_scale.reshape(-1, 1).astype(jnp.float32), 0, block_n, value=1)
        in_specs.append(pl.BlockSpec((block_n, 1), lambda mi, ni, ki: (ni, 0)))
        operands.append(s_p)
        out_dtype = jnp.float32
    else:
        out_dtype = jnp.int32

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            block_kw=block_kw,
            correction=pad_correction(k_bits, kp_bits),
            has_thresh=has_thresh,
            has_scale=has_scale,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="mvu_xnor",
    )(*operands)
    return out[:m, :n]
