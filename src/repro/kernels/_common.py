"""Shared pieces of the MVU Pallas kernels.

The folded schedule (DESIGN.md §4) is identical for all three SIMD
datapaths; only the inner dot-product step differs:

    grid = (M/bm, N/bn, K/bk)            # (pixel tiles, NF, SF)
    A block (bm, K)  @ index (m, 0)      # "input buffer": full-K resident,
                                         #  re-used across the whole NF loop
    W block (bn, bk) @ index (n, k)      # weight stream (PE memories)
    acc scratch (bm, bn) int32 in VMEM   # PE accumulators
    epilogue at k == SF-1                # thresholds / scale / raw acc

PE = bn rows in parallel, SIMD = bk synapses per grid step (x32 for the
bit-packed datapath). II = 1 grid step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x; resolve
# whichever this jax ships so the kernels stay version-agnostic.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def swar_popcount(x: jax.Array) -> jax.Array:
    """Branch-free SWAR popcount on uint32 (the LUT-fabric popcount analog)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def epilogue_value(acc, t_ref, s_ref):
    """MVTU epilogue as a value: thresholds > scale > raw accumulator."""
    if t_ref is not None:
        # act = sum_t (acc >= T[c, t]) -- the multi-threshold unit.
        thr = t_ref[...]  # (bn, T) int32
        return jnp.sum(acc[:, :, None] >= thr[None, :, :], axis=-1, dtype=jnp.int32)
    if s_ref is not None:
        return acc.astype(jnp.float32) * s_ref[...].reshape(1, -1)
    return acc


def epilogue_write(o_ref, acc, t_ref, s_ref) -> None:
    """Write the MVTU epilogue: thresholds > scale > raw accumulator."""
    o_ref[...] = epilogue_value(acc, t_ref, s_ref)


def pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


def default_interpret() -> bool:
    """Pallas kernels target TPU; everywhere else we validate via interpret."""
    return jax.default_backend() != "tpu"


def std_grid(m: int, n: int, k: int, bm: int, bn: int, bk: int):
    return (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
