"""Bit-packing helpers for the XNOR-popcount MVU datapath.

FINN's 1-bit SIMD lanes consume one synapse per wire; on TPU the natural
"wire bundle" is a 32-bit VPU lane. We pack 32 binary synapses per uint32
word, LSB-first, so one packed word corresponds to SIMD=32 FINN lanes.

Bipolar convention (paper Fig. 4a): a stored bit b encodes the value
(2b - 1) in {-1, +1}.  For two packed operands the dot product over K bits is

    dot = 2 * popcount(~(a ^ w)) - K          (XNOR + popcount)

Padding: packing pads K up to a multiple of 32 with zero bits.  Zero pads in
*both* operands each contribute xnor(0,0)=1 to the popcount; the combined
padded-K and per-pad-bit correction is :func:`pad_correction`, so

    dot = 2 * popcount(~(a ^ w)) - pad_correction(K)

holds for any K, divisor of 32 or not (see kernels/ref.py, mvu_packed.py).

2-bit weights use the sibling lane format (:func:`pack_int2`): four signed
2-bit two's-complement fields per uint8 byte, LSB-first -- the int8 analog of
the paper's SIMD-lane weight memory for WEIGHT_BITS=2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32
INT2_PER_BYTE = 4


def padded_bits(k: int) -> int:
    """K rounded up to a whole number of 32-bit words (0 stays 0)."""
    if k < 0:
        raise ValueError(f"bit count must be non-negative, got {k}")
    return ((k + WORD_BITS - 1) // WORD_BITS) * WORD_BITS


def num_words(k: int) -> int:
    return padded_bits(k) // WORD_BITS


def pad_correction(k: int, kp: int | None = None) -> int:
    """The constant subtracted in the padded XNOR-popcount identity.

    With both operands zero-padded from K up to ``kp`` total bits (default
    ``padded_bits(K)``; kernels pass their block-padded width), each pad bit
    contributes xnor(0,0)=1 to the popcount on top of the bipolar -K offset,
    so

        dot = 2 * popcount(~(a ^ w)) - pad_correction(K, Kp)
            = 2 * popcount(~(a ^ w)) - (Kp + (Kp - K))

    For K a whole word multiple with no block padding this degrades to the
    textbook ``2*pc - K``.
    """
    if kp is None:
        kp = padded_bits(k)
    if kp < k:
        raise ValueError(f"padded width {kp} is smaller than bit count {k}")
    return kp + (kp - k)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack {0,1} integer array along the last axis into uint32 words.

    (..., K) -> (..., ceil(K/32)), LSB-first within each word.  Each value
    is masked to its LSB first: a multi-bit value (e.g. a 2-bit activation
    fed to a 1-bit layer) would otherwise leak into the neighboring bit
    position -- and into the pad bits of the last word, where it silently
    breaks the XNOR/popcount pad-correction identity.
    """
    k = bits.shape[-1]
    kp = padded_bits(k)
    if kp != k:
        pad = [(0, 0)] * (bits.ndim - 1) + [(0, kp - k)]
        bits = jnp.pad(bits, pad)
    bits = (bits.astype(jnp.uint32) & jnp.uint32(1)).reshape(
        *bits.shape[:-1], kp // WORD_BITS, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, count: int) -> jax.Array:
    """Inverse of :func:`pack_bits`: (..., W) uint32 -> (..., count) int32 in {0,1}.

    ``count`` greater than the packed width (W*32) raises instead of silently
    truncating to the available bits -- a caller passing the wrong K would
    otherwise compute a plausible-looking dot over a shorter reduction.
    """
    if count < 0:
        raise ValueError(f"bit count must be non-negative, got {count}")
    width = words.shape[-1] * WORD_BITS
    if count > width:
        raise ValueError(
            f"cannot unpack {count} bits from {words.shape[-1]} words "
            f"({width} bits packed)")
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*words.shape[:-1], width)
    return bits[..., :count].astype(jnp.int32)


def popcount(x: jax.Array) -> jax.Array:
    """Per-element number of set bits (int32 result)."""
    return jax.lax.population_count(x).astype(jnp.int32)


def bipolar_to_bits(x: jax.Array) -> jax.Array:
    """Map {-1,+1} (or any sign) to the stored-bit convention {0,1}."""
    return (x > 0).astype(jnp.int32)


def bits_to_bipolar(b: jax.Array) -> jax.Array:
    return (2 * b - 1).astype(jnp.int32)


# ------------------------------------------------------------------ 2-bit lanes
def padded_int2(k: int) -> int:
    """K rounded up to a whole number of 4-field bytes (0 stays 0)."""
    if k < 0:
        raise ValueError(f"lane count must be non-negative, got {k}")
    return ((k + INT2_PER_BYTE - 1) // INT2_PER_BYTE) * INT2_PER_BYTE


def num_int2_bytes(k: int) -> int:
    return padded_int2(k) // INT2_PER_BYTE


def pack_int2(values: jax.Array) -> jax.Array:
    """Pack signed 2-bit integers in [-2, 1] along the last axis into uint8.

    (..., K) -> (..., ceil(K/4)); each byte holds four two's-complement 2-bit
    fields, LSB-first.  Zero pads decode back to weight 0, so padded lanes
    contribute nothing to a dot product.
    """
    k = values.shape[-1]
    kp = padded_int2(k)
    if kp != k:
        pad = [(0, 0)] * (values.ndim - 1) + [(0, kp - k)]
        values = jnp.pad(values, pad)
    fields = (values.astype(jnp.int32) & 0x3).astype(jnp.uint8)
    fields = fields.reshape(*fields.shape[:-1], kp // INT2_PER_BYTE, INT2_PER_BYTE)
    shifts = jnp.arange(0, 2 * INT2_PER_BYTE, 2, dtype=jnp.uint8)
    return jnp.sum(fields << shifts, axis=-1, dtype=jnp.uint32).astype(jnp.uint8)


def unpack_int2(bytes_: jax.Array, count: int) -> jax.Array:
    """Inverse of :func:`pack_int2`: (..., B) uint8 -> (..., count) int32 in [-2, 1].

    Like :func:`unpack_bits`, ``count`` beyond the packed width raises.
    """
    if count < 0:
        raise ValueError(f"lane count must be non-negative, got {count}")
    width = bytes_.shape[-1] * INT2_PER_BYTE
    if count > width:
        raise ValueError(
            f"cannot unpack {count} lanes from {bytes_.shape[-1]} bytes "
            f"({width} lanes packed)")
    shifts = jnp.arange(0, 2 * INT2_PER_BYTE, 2, dtype=jnp.uint8)
    fields = (bytes_[..., None] >> shifts) & jnp.uint8(0x3)
    fields = fields.reshape(*bytes_.shape[:-1], width).astype(jnp.int32)
    # sign-extend the 2-bit two's-complement field: 0b10 -> -2, 0b11 -> -1
    signed = jnp.where(fields >= 2, fields - 4, fields)
    return signed[..., :count]
