"""Bit-packing helpers for the XNOR-popcount MVU datapath.

FINN's 1-bit SIMD lanes consume one synapse per wire; on TPU the natural
"wire bundle" is a 32-bit VPU lane. We pack 32 binary synapses per uint32
word, LSB-first, so one packed word corresponds to SIMD=32 FINN lanes.

Bipolar convention (paper Fig. 4a): a stored bit b encodes the value
(2b - 1) in {-1, +1}.  For two packed operands the dot product over K bits is

    dot = 2 * popcount(~(a ^ w)) - K          (XNOR + popcount)

Padding: packing pads K up to a multiple of 32 with zero bits.  Zero pads in
*both* operands each contribute xnor(0,0)=1 to the popcount, so the identity
above must use the *padded* K and subtract one extra per pad bit; callers use
:func:`padded_bits` / keep the true K around (see kernels/ref.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32


def padded_bits(k: int) -> int:
    """K rounded up to a whole number of 32-bit words."""
    return ((k + WORD_BITS - 1) // WORD_BITS) * WORD_BITS


def num_words(k: int) -> int:
    return padded_bits(k) // WORD_BITS


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack {0,1} integer array along the last axis into uint32 words.

    (..., K) -> (..., ceil(K/32)), LSB-first within each word.
    """
    k = bits.shape[-1]
    kp = padded_bits(k)
    if kp != k:
        pad = [(0, 0)] * (bits.ndim - 1) + [(0, kp - k)]
        bits = jnp.pad(bits, pad)
    bits = bits.astype(jnp.uint32).reshape(*bits.shape[:-1], kp // WORD_BITS, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, count: int) -> jax.Array:
    """Inverse of :func:`pack_bits`: (..., W) uint32 -> (..., count) int32 in {0,1}."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*words.shape[:-1], words.shape[-1] * WORD_BITS)
    return bits[..., :count].astype(jnp.int32)


def popcount(x: jax.Array) -> jax.Array:
    """Per-element number of set bits (int32 result)."""
    return jax.lax.population_count(x).astype(jnp.int32)


def bipolar_to_bits(x: jax.Array) -> jax.Array:
    """Map {-1,+1} (or any sign) to the stored-bit convention {0,1}."""
    return (x > 0).astype(jnp.int32)


def bits_to_bipolar(b: jax.Array) -> jax.Array:
    return (2 * b - 1).astype(jnp.int32)
