"""``repro.explore``: cached design-space exploration with paper-figure parity.

The paper sweeps every Table 2 configuration across PE/SIMD foldings and
reads resource, timing, and synthesis-time curves off the reports; this
package is that experimental loop over the ``repro.build`` pipeline --
sweep grid, Pareto frontier, whole-sweep resource-model calibration, and
the cold/warm autotune-cache phase (the synthesis-time-cache analog).

    PYTHONPATH=src python -m repro.explore --config nid_mlp --quick

writes ``experiments/explore/nid_mlp_quick_explore.json``; the committed
copy is what ``scripts/make_experiments.py`` renders and the regression
gate checks.
"""

from repro.explore.explorer import (
    PARETO_MAXIMIZE,
    PARETO_MINIMIZE,
    ExploreConfig,
    explore,
    load_record,
    save_record,
)
from repro.explore.grid import (
    LayerShape,
    SweepPoint,
    clamp_folding,
    layer_shapes,
    sweep_grid,
)
from repro.explore.pareto import dominates, pareto_front

__all__ = [
    "ExploreConfig",
    "LayerShape",
    "PARETO_MAXIMIZE",
    "PARETO_MINIMIZE",
    "SweepPoint",
    "clamp_folding",
    "dominates",
    "explore",
    "layer_shapes",
    "load_record",
    "pareto_front",
    "save_record",
    "sweep_grid",
]
