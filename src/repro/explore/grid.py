"""Sweep-grid construction: the PE x SIMD design space as build points.

The paper's experimental core is a grid: every configuration of Table 2 is
re-synthesized across PE and SIMD values and the resource/timing curves are
read off the sweep.  Our design dimension is the same folding algebra
(``core.folding``), so a sweep point is simply *one legal folding per MVU
stage* -- which :func:`repro.build.build` accepts verbatim as its
``folding=[Folding, ...]`` override.  This module turns (pe_target,
simd_target) grid coordinates into those per-stage folding lists:

* targets are clamped per layer to the largest legal divisor (PE | N,
  SIMD | K -- the paper keeps divisibility by construction, we enforce it),
* points whose *realized* foldings coincide are deduplicated (a 64-wide
  target and a 128-wide target collapse onto the same design when every
  layer tops out at 64),
* the default target axes are powers of two up to the largest layer
  dimension, so small and large designs both appear (the paper's Figs 8-15
  x-axes).
"""

from __future__ import annotations

import dataclasses

from repro.core import ir
from repro.core.folding import Folding, divisors
from repro.core.ir import Graph


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One MVU stage of the lowered chain, as the grid sees it."""

    name: str
    n: int
    k: int
    n_pixels: int


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One design point: grid coordinates + the realized per-stage foldings.

    ``packed`` is the weight-storage coordinate of the joint folding x
    packing space: True builds the point with ``pack="always"`` (bit-packed
    weights + packed datapath on every packable stage), False with
    ``pack="never"`` (canonical storage).
    """

    point_id: str
    pe_target: int
    simd_target: int
    foldings: tuple[Folding, ...]
    packed: bool = False

    def as_dict(self) -> dict:
        return {
            "point_id": self.point_id,
            "pe_target": self.pe_target,
            "simd_target": self.simd_target,
            "foldings": [[f.pe, f.simd] for f in self.foldings],
            "packed": self.packed,
        }


def layer_shapes(graph: Graph) -> list[LayerShape]:
    """The (N, K, n_pixels) of every MVU stage of a *lowered* graph, in
    dataflow (topological) order."""
    shapes: list[LayerShape] = []
    for node, _, out_shape in ir.io_shapes(graph):
        if node.op not in ("mvu", "conv_mvu"):
            continue
        cfg = node.attrs["config"]
        shapes.append(LayerShape(node.name, cfg.out_features,
                                 cfg.in_features, ir.n_pixels(out_shape)))
    return shapes


def clamp_folding(n: int, k: int, pe_target: int, simd_target: int) -> Folding:
    """Largest legal folding at or under the targets (PE | N, SIMD | K)."""
    pe = max(d for d in divisors(n) if d <= max(pe_target, 1))
    simd = max(d for d in divisors(k) if d <= max(simd_target, 1))
    return Folding(pe, simd)


def _pow2_axis(limit: int) -> tuple[int, ...]:
    vals = [1]
    while vals[-1] < limit:
        vals.append(vals[-1] * 4)
    return tuple(vals)


def sweep_grid(
    shapes: list[LayerShape],
    pe_targets: tuple[int, ...] | None = None,
    simd_targets: tuple[int, ...] | None = None,
    packings: tuple[bool, ...] = (False,),
) -> list[SweepPoint]:
    """The deduplicated design grid for one workload.

    Every (pe_target, simd_target) pair becomes a point whose per-stage
    foldings are the targets clamped to each layer's divisors; pairs that
    realize identical folding lists are merged (the first grid coordinate
    wins, so point ids stay stable as axes grow).  ``packings`` crosses the
    weight-storage axis into the grid: each realized folding appears once
    per packing, so ``(False, True)`` sweeps the joint folding x packing
    space (packed point ids carry a ``_packed`` suffix).
    """
    if not shapes:
        raise ValueError("sweep_grid needs at least one MVU layer shape")
    if pe_targets is None:
        pe_targets = _pow2_axis(max(s.n for s in shapes))
    if simd_targets is None:
        simd_targets = _pow2_axis(max(s.k for s in shapes))
    points: list[SweepPoint] = []
    seen: set[tuple] = set()
    for pe_t in pe_targets:
        for simd_t in simd_targets:
            folds = tuple(clamp_folding(s.n, s.k, pe_t, simd_t)
                          for s in shapes)
            for packed in packings:
                key = (tuple((f.pe, f.simd) for f in folds), bool(packed))
                if key in seen:
                    continue
                seen.add(key)
                suffix = "_packed" if packed else ""
                points.append(SweepPoint(f"pe{pe_t}_simd{simd_t}{suffix}",
                                         int(pe_t), int(simd_t), folds,
                                         packed=bool(packed)))
    return points
