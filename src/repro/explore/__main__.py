"""CLI: ``python -m repro.explore --config nid_mlp --quick``.

Runs the design-space sweep and prints the headline numbers; the full
record lands in ``--out-dir`` (default ``experiments/explore/``).
"""

from __future__ import annotations

import argparse
import json

from repro.explore.explorer import ExploreConfig, explore


def main(argv: list[str] | None = None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="nid_mlp",
                    choices=("nid_mlp", "cnv_quick"))
    ap.add_argument("--quick", action="store_true",
                    help="3x3 corner grid + fast autotune phase (CI smoke)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default="experiments/explore")
    ap.add_argument("--no-cache-phase", action="store_true",
                    help="skip the cold/warm autotune comparison")
    args = ap.parse_args(argv)

    batch = args.batch if args.batch is not None else (256 if args.quick else 1024)
    cfg = ExploreConfig(
        config=args.config, quick=args.quick, batch=batch, reps=args.reps,
        seed=args.seed, out_dir=args.out_dir,
        cache_phase=not args.no_cache_phase)
    rec = explore(cfg)

    front = {p["point_id"]: p for p in rec["points"] if p["pareto"]}
    print(json.dumps({
        "name": rec["name"],
        "n_points": rec["n_points"],
        "pareto_front": rec["pareto_front"],
        "bit_exact": rec["bit_exact"],
        "s_per_cycle": rec["calibration"].get("s_per_cycle"),
        "model_error_p90": rec.get("model_error_p90"),
        "cache_speedup": rec.get("cache_speedup"),
        "path": rec.get("path"),
    }, indent=2))
    for pid, p in front.items():
        print(f"# pareto {pid}: {p['samples_per_s']:.0f} samples/s, "
              f"lut={p['lut_bytes']} ff={p['ff_bytes']} bram={p['bram_bytes']}")
    if rec.get("cache"):
        c = rec["cache"]
        print(f"# autotune cache: cold {c['cold_wall_s']:.2f}s -> warm "
              f"{c['warm_wall_s']:.2f}s ({c['cache_speedup']:.1f}x, "
              f"{c['warm_hits']} hits / {c['warm_misses']} misses)")
    return rec


if __name__ == "__main__":
    main()
