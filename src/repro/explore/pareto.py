"""Pareto-frontier extraction over sweep records.

The paper's design-space story is a trade-off curve: more PE/SIMD buys
throughput, costs LUT/FF/BRAM (Figs 8-15).  The explorer reports the same
curve as the set of non-dominated sweep points -- maximize throughput,
minimize every resource analog.  Generic over plain dicts so benchmarks
and tests can reuse it on any record shape.
"""

from __future__ import annotations

from collections.abc import Sequence


def dominates(a: dict, b: dict, *, maximize: Sequence[str],
              minimize: Sequence[str]) -> bool:
    """True iff ``a`` is at least as good as ``b`` on every objective and
    strictly better on at least one.  Missing keys count as worst-case."""
    at_least = True
    strictly = False
    for key in maximize:
        av = a.get(key, float("-inf"))
        bv = b.get(key, float("-inf"))
        if av < bv:
            at_least = False
            break
        if av > bv:
            strictly = True
    if at_least:
        for key in minimize:
            av = a.get(key, float("inf"))
            bv = b.get(key, float("inf"))
            if av > bv:
                at_least = False
                break
            if av < bv:
                strictly = True
    return at_least and strictly


def pareto_front(points: Sequence[dict], *, maximize: Sequence[str],
                 minimize: Sequence[str] = ()) -> list[int]:
    """Indices of the non-dominated points, in input order.

    Duplicate objective vectors all survive (none strictly dominates the
    other), which keeps deduplication the grid's job, not the frontier's.
    """
    out: list[int] = []
    for i, p in enumerate(points):
        if not any(
            dominates(q, p, maximize=maximize, minimize=minimize)
            for j, q in enumerate(points) if j != i
        ):
            out.append(i)
    return out
