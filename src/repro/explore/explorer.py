"""Cached design-space explorer over the ``repro.build`` pipeline.

The paper's method is a sweep: synthesize every folding of every
configuration, read resources and timing off the reports, and lean on
out-of-context synthesis caching to make re-sweeps cheap.  ``explore``
is that loop for our stack:

1. **Sweep** -- one :class:`~repro.build.BuildConfig` per grid point
   (``grid.sweep_grid``), each built with ``tune="off"`` so the *folding*
   stays the design axis (autotuned block schedules would overwrite the
   very dimension being swept) and ``verify`` on, so every point is
   bit-exact against the reference interpreter by construction.
2. **Measure** -- per point the fused engine is timed end-to-end and every
   MVU stage is timed stand-alone, giving measured seconds next to the
   resource model's analytic cycle counts.
3. **Pareto** -- the throughput-vs-LUT/FF/BRAM-analog frontier
   (``pareto.pareto_front``), the paper's Figs 8-15 trade-off curve.
4. **Calibrate** -- one least-squares cycle time over *all* (point, node)
   pairs (``resource_model.fit_cycle_time``) and the per-node model-error
   distribution, i.e. how well the analytic model predicts measured time
   across the whole design space, not just the bottleneck.
5. **Cache** -- a cold ``tune="auto"`` build against an empty
   :class:`~repro.core.autotune.ScheduleCache` vs a warm ``tune="cache"``
   rebuild from the filled one; the wall-clock ratio is the software
   analog of the paper's ~10x synthesis-time saving from caching.

The result dict round-trips through JSON under ``experiments/explore/``
and is the single committed artifact the EXPERIMENTS.md figures render
from (``scripts/make_experiments.py``) and the regression gate checks
(``cache_speedup`` floor, ``model_error_p90`` ceiling).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.build import build
from repro.core import autotune, resource_model
from repro.core.dataflow import node_runner
from repro.core.ir import Graph
from repro.explore.grid import SweepPoint, layer_shapes, sweep_grid
from repro.explore.pareto import pareto_front

# Frontier objectives: throughput up, every paper resource analog down --
# including HBM-resident weight bytes, the axis the packing coordinate
# trades (bit-packed storage shrinks it 4-8x at equal folding).
PARETO_MAXIMIZE = ("samples_per_s",)
PARETO_MINIMIZE = ("lut_bytes", "ff_bytes", "bram_bytes", "weight_bytes")


@dataclasses.dataclass
class ExploreConfig:
    """One sweep recipe.  ``config`` names a packaged workload
    (``nid_mlp`` / ``cnv_quick``); tests pass an explicit ``graph`` +
    ``build_overrides`` instead."""

    config: str = "nid_mlp"
    quick: bool = False
    pe_targets: tuple[int, ...] | None = None
    simd_targets: tuple[int, ...] | None = None
    batch: int = 1024
    reps: int = 3
    seed: int = 0
    out_dir: str | None = "experiments/explore"
    name: str | None = None
    # weight-storage axis crossed into the grid: default sweeps both the
    # canonical and the bit-packed storage form of every folding point
    packings: tuple[bool, ...] = (False, True)
    # explicit workload (overrides ``config``)
    graph: Graph | None = None
    build_overrides: dict = dataclasses.field(default_factory=dict)
    baseline_folding: object = "balance"
    # cold/warm autotune phase (the synthesis-time-cache analog)
    cache_phase: bool = True
    tune_kwargs: dict | None = None
    verify: str = "all"


QUICK_GRID = {
    # quick axes still span the small/medium/wide corners so the frontier
    # and the calibration fit see a real spread, at ~9 builds
    "pe_targets": (1, 8, 64),
    "simd_targets": (8, 64, 600),
}
QUICK_TUNE_KWARGS = {"reps": 1, "max_measure": 2, "sample_m": 128}


def _workload(cfg: ExploreConfig):
    """Resolve (graph, build kwargs, name, baseline folding, input maker)."""
    if cfg.graph is not None:
        return (cfg.graph, dict(cfg.build_overrides), cfg.name or "custom",
                cfg.baseline_folding)
    if cfg.config == "nid_mlp":
        from repro.configs import nid_mlp

        # the paper's Table 6 NID config is 2-bit weights -- which also
        # makes every stage packable (int2 lanes), so the packing axis of
        # the sweep is exercised on the committed workload
        kw = dict(mode="standard", weight_bits=nid_mlp.WEIGHT_BITS,
                  act_bits=nid_mlp.INPUT_BITS)
        kw.update(cfg.build_overrides)
        return (nid_mlp.build_graph(cfg.seed), kw,
                cfg.name or "nid_mlp", nid_mlp.foldings())
    if cfg.config == "cnv_quick":
        from repro.configs import cnv_bnn

        kw = dict(mode="xnor", weight_bits=1, act_bits=1)
        kw.update(cfg.build_overrides)
        return (cnv_bnn.build_graph(cnv_bnn.QUICK, cfg.seed), kw,
                cfg.name or "cnv_quick", "balance")
    raise ValueError(f"unknown explore config {cfg.config!r} "
                     "(expected nid_mlp or cnv_quick, or pass graph=)")


def _probe_input(graph: Graph, batch: int, seed: int):
    """A deterministic integer batch shaped for the chain's input node."""
    return autotune.synth_input(graph, batch, seed=seed)


def _time_median(fn, *args, reps: int, warmup: int = 1) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _measure_point(acc, x, *, reps: int) -> dict:
    """Engine throughput + per-MVU-stage stand-alone timings for one build."""
    import jax

    engine = acc.engine
    batch = int(x.shape[0])
    want = np.asarray(acc.interpret(x))
    got = np.asarray(engine(x))
    bit_exact = bool(np.array_equal(got, want))
    engine_s = _time_median(engine, x, reps=reps)

    node_times: dict[str, float] = {}
    cur = x
    for node in acc.graph:
        params, fn = node_runner(node)
        if node.op in ("mvu", "conv_mvu"):
            timed = jax.jit(fn)
            node_times[node.name] = _time_median(
                timed, params, cur, reps=reps) / batch
        cur = fn(params, cur)
    return {
        "bit_exact": bit_exact,
        "engine_s": engine_s,
        "samples_per_s": batch / engine_s,
        "node_seconds": node_times,  # measured seconds per sample, per stage
    }


def _point_record(pt: SweepPoint, acc, measured: dict) -> dict:
    rep = acc.report
    nodes = []
    for nr in rep.nodes:
        sec = measured["node_seconds"].get(nr.name)
        nodes.append({
            "name": nr.name, "op": nr.op, "n": nr.n, "k": nr.k,
            "pe": nr.pe, "simd": nr.simd, "n_pixels": nr.n_pixels,
            "cycles": nr.cycles, "lut_bytes": nr.lut_bytes,
            "ff_bytes": nr.ff_bytes, "bram_bytes": nr.bram_bytes,
            "packed": nr.packed, "weight_bytes": nr.weight_bytes,
            "canonical_weight_bytes": nr.canonical_weight_bytes,
            "measured_s": sec,
        })
    return {
        **pt.as_dict(),
        "interval_cycles": rep.schedule.get("interval_cycles"),
        "latency_cycles": rep.schedule.get("latency_cycles"),
        "bottleneck": rep.schedule.get("bottleneck"),
        "lut_bytes": sum(n["lut_bytes"] for n in nodes),
        "ff_bytes": sum(n["ff_bytes"] for n in nodes),
        "bram_bytes": sum(n["bram_bytes"] for n in nodes),
        "weight_bytes": sum(n["weight_bytes"] for n in nodes),
        "pe_simd_product": sum(f[0] * f[1] for f in pt.as_dict()["foldings"]),
        "samples_per_s": measured["samples_per_s"],
        "engine_us": measured["engine_s"] * 1e6,
        "bit_exact": measured["bit_exact"],
        "build_wall_s": rep.total_wall_s,
        "nodes": nodes,
    }


def _calibrate(points: list[dict]) -> dict:
    """Fit one cycle time across every (point, node) pair and attribute the
    per-node model errors back into the point records (mutates ``points``)."""
    cycles, seconds, owners = [], [], []
    for rec in points:
        for node in rec["nodes"]:
            if node["measured_s"] is None:
                continue
            cycles.append(node["cycles"])
            seconds.append(node["measured_s"])
            owners.append(node)
    if not cycles:
        return {}
    s_per_cycle = resource_model.fit_cycle_time(cycles, seconds)
    errors = resource_model.cycle_model_errors(
        cycles, seconds, s_per_cycle=s_per_cycle)
    per_node: dict[str, list[float]] = {}
    for node, err in zip(owners, errors):
        node["predicted_s"] = node["cycles"] * s_per_cycle
        node["model_error"] = err
        per_node.setdefault(node["name"], []).append(err)
    for rec in points:
        if rec.get("interval_cycles"):
            rec["predicted_interval_s"] = rec["interval_cycles"] * s_per_cycle
    return {
        "s_per_cycle": s_per_cycle,
        "clock_mhz_analog": 1e-6 / s_per_cycle if s_per_cycle else None,
        "samples": len(cycles),
        "summary": resource_model.error_summary(errors),
        "per_node": {name: resource_model.error_summary(errs)
                     for name, errs in sorted(per_node.items())},
    }


def _cache_phase(graph: Graph, build_kw: dict, baseline_folding, name: str,
                 verify: str, tune_kwargs: dict | None) -> dict:
    """Cold autotune vs warm cache rebuild: the synthesis-time-cache analog.

    The cold build measures candidate schedules into a fresh cache; the
    warm build replays the same recipe with ``tune="cache"`` (pure lookup,
    nothing measured).  Wall-clock ratio + hit accounting come back for the
    report; FINN's paper reports the same effect as ~10x faster synthesis
    when out-of-context checkpoints are reused.
    """
    cache = autotune.ScheduleCache()
    kw = dict(build_kw, target="engine", folding=baseline_folding,
              verify=verify, name=name, cache=cache,
              tune_kwargs=dict(tune_kwargs or {}))

    t0 = time.perf_counter()
    cold = build(list(graph), tune="auto", **kw)
    cold_wall = time.perf_counter() - t0
    t1 = time.perf_counter()
    warm = build(list(graph), tune="cache", **kw)
    warm_wall = time.perf_counter() - t1

    def tune_wall(rep):
        return next((s.wall_s for s in rep.steps if s.name == "tune"), 0.0)

    return {
        "cold_wall_s": cold_wall,
        "warm_wall_s": warm_wall,
        "cold_tune_wall_s": tune_wall(cold.report),
        "warm_tune_wall_s": tune_wall(warm.report),
        "cache_speedup": cold_wall / warm_wall if warm_wall else None,
        "warm_hits": warm.report.tune.get("cache_hits"),
        "warm_misses": warm.report.tune.get("cache_misses"),
        "cold_hits": cold.report.tune.get("cache_hits"),
        "cold_misses": cold.report.tune.get("cache_misses"),
        "entries": len(cache),
    }


def explore(cfg: ExploreConfig) -> dict:
    """Run the sweep; returns (and optionally saves) the explore record."""
    graph, build_kw, name, baseline_folding = _workload(cfg)
    shapes = layer_shapes(_lowered_shapes_graph(graph, build_kw, cfg))
    pe_targets = cfg.pe_targets
    simd_targets = cfg.simd_targets
    if cfg.quick and pe_targets is None and simd_targets is None:
        pe_targets = QUICK_GRID["pe_targets"]
        simd_targets = QUICK_GRID["simd_targets"]
    grid = sweep_grid(shapes, pe_targets, simd_targets,
                      packings=cfg.packings)

    x = _probe_input(graph, cfg.batch, cfg.seed)
    points: list[dict] = []
    for pt in grid:
        acc = build(list(graph), target="engine", tune="off",
                    folding=list(pt.foldings), verify=cfg.verify,
                    pack="always" if pt.packed else "never",
                    name=f"{name}_{pt.point_id}", **build_kw)
        acc.report.sweep = pt.as_dict()
        measured = _measure_point(acc, x, reps=cfg.reps)
        points.append(_point_record(pt, acc, measured))

    front = pareto_front(points, maximize=PARETO_MAXIMIZE,
                         minimize=PARETO_MINIMIZE)
    for i, rec in enumerate(points):
        rec["pareto"] = i in front

    calibration = _calibrate(points)
    if calibration:
        # attach the fitted record to the last build's report shape so the
        # schema is exercised end-to-end (tests assert the round-trip)
        acc.report.calibration = {
            "s_per_cycle": calibration["s_per_cycle"],
            "summary": calibration["summary"],
        }

    tune_kwargs = cfg.tune_kwargs
    if tune_kwargs is None and cfg.quick:
        tune_kwargs = QUICK_TUNE_KWARGS
    cache = (_cache_phase(graph, build_kw, baseline_folding, name,
                          cfg.verify, tune_kwargs)
             if cfg.cache_phase else {})

    record = {
        "name": f"{name}_quick" if cfg.quick else name,
        "config": cfg.config if cfg.graph is None else "custom",
        "quick": cfg.quick,
        "batch": cfg.batch,
        "reps": cfg.reps,
        "seed": cfg.seed,
        "grid": {
            "pe_targets": list(pe_targets) if pe_targets else None,
            "simd_targets": list(simd_targets) if simd_targets else None,
            "packings": [bool(p) for p in cfg.packings],
            "layers": [dataclasses.asdict(s) for s in shapes],
        },
        "n_points": len(points),
        "points": points,
        "pareto_front": [points[i]["point_id"] for i in front],
        "calibration": calibration,
        "cache": cache,
        # joint folding x packing space accounting: how many swept points
        # used packed storage, and how many of those made the frontier (a
        # packed point strictly dominates its unpacked twin on weight
        # bytes, so a sweep that crosses the packing axis must land >= 1)
        "packed_points": sum(1 for p in points if p["packed"]),
        "packed_pareto_points": sum(
            1 for i in front if points[i]["packed"]),
        # gate keys (scripts/check_bench_regression.py): bit-exactness is
        # binary, the cache speedup holds a floor, the model error a ceiling
        "bit_exact": all(p["bit_exact"] for p in points),
        **({"cache_speedup": cache["cache_speedup"],
            "min_cache_speedup": 1.2} if cache.get("cache_speedup") else {}),
        **({"min_packed_pareto_points": 1} if any(cfg.packings) else {}),
        **({"floor_only":
            (["cache_speedup"] if cache.get("cache_speedup") else [])
            + (["packed_pareto_points"] if any(cfg.packings) else [])}
           if cache.get("cache_speedup") or any(cfg.packings) else {}),
        **({"model_error_p90": calibration["summary"]["p90_abs"],
            "ceiling_only": ["model_error_p90"],
            "max_model_error_p90": _error_ceiling(
                calibration["summary"]["p90_abs"])} if calibration else {}),
    }
    if cfg.out_dir:
        record["path"] = save_record(record, cfg.out_dir)
    return record


def _error_ceiling(p90: float) -> float:
    """Regression ceiling for the committed baseline: generous headroom over
    the measured p90 so timer jitter never trips the gate, but a model that
    *stops predicting* (errors blowing past ~2x the committed level) does."""
    return round(max(2.0 * p90, p90 + 0.5), 3)


def _lowered_shapes_graph(graph: Graph, build_kw: dict, cfg: ExploreConfig):
    """Lower once (no tuning, no engine) just to read the MVU shapes."""
    acc = build(list(graph), target="interpret", tune="off", folding="none",
                verify="off", name="shapes", **build_kw)
    return acc.graph


def save_record(record: dict, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{record['name']}_explore.json")
    clean = {k: v for k, v in record.items() if k != "path"}
    with open(path, "w") as f:
        json.dump(clean, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_record(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
