"""AdamW with warmup-cosine schedule and global-norm clipping.

Functional (optax-style but self-contained): state is a pytree shaped like
params, so every sharding rule that applies to a parameter applies to its
moments (fully sharded optimizer state -- ZeRO-along-TP for free; moments
inherit the parameter's PartitionSpec).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        vhat = nu / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }
