"""Int8 gradient compression with error feedback for the DP all-reduce.

At 1000+-node scale the DP gradient all-reduce is ICI/DCN-bound; 4x
compression (f32/bf16 -> int8 with a shared per-tensor scale) cuts the
collective term proportionally.  Error feedback (residual accumulation)
preserves convergence:

    e   <- e + g                      (accumulate residual)
    s   <- pmax(|e|) / 127            (shared scale across replicas)
    q   <- round(e / s)  in int8
    e   <- e - q * s                  (new residual)
    g'  <- psum(q) * s / N            (int32-summed, dequantized mean)

This composes with the paper's theme: the same symmetric-int grid the MVU
uses for weights, applied to the gradient stream.  Use inside shard_map
over the DP axes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_compressed(grads, errors, axis_names):
    """Compressed mean-all-reduce; returns (mean_grads f32, new_errors)."""
    n = 1
    for a in axis_names:
        n = n * jax.lax.psum(1, a)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_names)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_e = x - q.astype(jnp.float32) * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis_names)
        return summed.astype(jnp.float32) * scale / n, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def psum_plain(grads, axis_names):
    """Uncompressed mean-all-reduce (baseline for the comparison)."""
    n = 1
    for a in axis_names:
        n = n * jax.lax.psum(1, a)
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.float32), axis_names) / n, grads
    )
