"""Checkpointing: atomic, async, mesh-reshape on restore.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json ; a checkpoint is only
visible once its final rename lands (write to ``.tmp`` then ``os.replace``),
so a crash mid-save never corrupts the latest checkpoint.  ``restore``
device_puts onto whatever mesh/shardings the *new* job provides, which is
exactly the elastic-rescale path (save on 512 chips, resume on 256, or on a
(2,2) host mesh in tests).

At real pod scale arrays would be saved per-host (process-sharded) instead
of gathered; the gather here is the single-host specialization of the same
manifest format (noted for honesty -- the restore path is identical).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        flat[key] = np.asarray(leaf)
    return flat, jax.tree.structure(tree)


def save(ckpt_dir: str, step: int, tree, *, extra: dict | None = None) -> str:
    """Atomic synchronous save; returns the checkpoint path."""
    flat, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(flat.keys()),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def save_async(ckpt_dir: str, step: int, tree, *, extra: dict | None = None) -> threading.Thread:
    """Snapshot to host memory now, write in a background thread."""
    flat, _ = _flatten(tree)  # device->host copy happens here, synchronously

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "time": time.time(),
                       "keys": sorted(flat.keys()), "extra": extra or {}}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a shape/array pytree).

    ``shardings``: optional matching pytree of NamedShardings -- this is
    where mesh-reshape happens: arrays are device_put onto the *new* mesh.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}

    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        out.append(arr.astype(leaf.dtype))
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def prune(ckpt_dir: str, keep: int) -> None:
    steps = available_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
