"""Benchmark regression gate: fresh quick-bench JSONs vs committed baselines.

Usage:
    python scripts/check_bench_regression.py BASELINE_DIR FRESH_DIR \
        [--max-regression 0.20] [--min-speedup 2.0]

For every ``*.json`` baseline record, the matching fresh record must

  * be bit-exact (``bit_exact`` true) when the baseline asserts it,
  * keep ``speedup`` (the machine-normalized throughput metric -- absolute
    samples/s varies across CI runners) within ``--max-regression`` of the
    baseline.

The absolute ``--min-speedup`` floor is enforced on the committed baseline
itself (the performance claim the repo ships), not the fresh run, so a
noisy runner can only trip the relative band, never an implicitly tighter
absolute one.  A baseline record may carry its own ``min_speedup`` field
overriding the CLI default: different benchmarks make different claims
(fused-vs-interpreter engines commit to 2x; the autotuner's tuned-vs-
heuristic gain commits to 1.15x).

Lower-is-better metrics (latency): a baseline record may list keys under
``lower_is_better`` (e.g. the serving benchmark's ``p99_vs_server`` tail-
latency ratio).  For each such key the fresh value must stay within
``--max-regression`` *above* the baseline, and the committed baseline
itself must sit at or under its own ``max_<key>`` ceiling when one is
present (the serving claim: p99 strictly better than the legacy server,
``max_p99_vs_server: 1.0``) -- the exact mirror of the speedup rules.

Absolute-only metrics: wall-clock-derived ratios (the explorer's
``cache_speedup``, its ``model_error_p90``) jitter too much run-to-run for
a relative band, so a record may list keys under ``floor_only`` /
``ceiling_only`` instead.  Each such key is held to its committed absolute
bound alone (``min_<key>`` / ``max_<key>``, required in the baseline) on
BOTH the baseline and the fresh record -- no baseline-relative band.

A fresh record carrying gated keys (``speedup``, ``bit_exact``, any
``lower_is_better`` metric, or any ``floor_only``/``ceiling_only`` metric)
that the committed baseline lacks fails with a clear "regenerate the
baseline" message -- a grown benchmark must never silently escape the
gate.

Absolute samples/s numbers from both runs are printed for the log but not
gated.  Exits non-zero on the first failure so CI fails the build.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def check_record(name: str, base: dict, fresh: dict, *,
                 max_regression: float, min_speedup: float) -> list[str]:
    errors = []
    # A fresh record gating on keys the committed baseline lacks means the
    # benchmark grew a metric (or a lower_is_better list) that was never
    # committed: fail with a pointer at the stale baseline instead of
    # letting the new metric silently escape the gate (or KeyError later).
    gated_fresh = {k for k in ("speedup", "bit_exact") if k in fresh}
    gated_fresh.update(fresh.get("lower_is_better", ()))
    gated_fresh.update(fresh.get("floor_only", ()))
    gated_fresh.update(fresh.get("ceiling_only", ()))
    stale = sorted(k for k in gated_fresh if k not in base)
    if stale:
        errors.append(
            f"{name}: committed baseline lacks gated key(s) {stale} present "
            f"in the fresh record -- regenerate and commit the baseline")
    if base.get("bit_exact") and not fresh.get("bit_exact"):
        errors.append(f"{name}: fused engine diverged from the interpreter")
    b_speed, f_speed = base.get("speedup"), fresh.get("speedup")
    if b_speed is not None and f_speed is not None:
        # min_speedup applies to the *committed* baseline (the claim the repo
        # makes); the fresh run is held to the relative band only, so the
        # absolute floor cannot silently shrink the advertised tolerance on
        # noisy runners.  A per-record ``min_speedup`` (e.g. the autotuner's
        # 1.15x tuned-vs-heuristic gain floor) overrides the CLI default.
        floor_abs = base.get("min_speedup", min_speedup)
        if b_speed < floor_abs:
            errors.append(
                f"{name}: committed baseline speedup {b_speed:.2f}x is below "
                f"the {floor_abs:.2f}x floor -- refresh the baseline")
        floor = b_speed * (1.0 - max_regression)
        if f_speed < floor:
            errors.append(
                f"{name}: speedup {f_speed:.2f}x regressed >"
                f"{max_regression:.0%} vs baseline {b_speed:.2f}x "
                f"(floor {floor:.2f}x)")
    for key in base.get("lower_is_better", ()):
        # latency-style metric: smaller is better, so the band and the
        # absolute claim flip sign relative to the speedup rules above
        b_val, f_val = base.get(key), fresh.get(key)
        if b_val is None or f_val is None:
            errors.append(
                f"{name}: lower-is-better metric {key!r} missing from the "
                f"{'baseline' if b_val is None else 'fresh'} record")
            continue
        ceil_abs = base.get(f"max_{key}")
        if ceil_abs is not None and b_val > ceil_abs:
            errors.append(
                f"{name}: committed baseline {key} {b_val:.3f} exceeds its "
                f"{ceil_abs:.3f} ceiling -- refresh the baseline")
        ceiling = b_val * (1.0 + max_regression)
        if f_val > ceiling:
            errors.append(
                f"{name}: {key} {f_val:.3f} regressed >"
                f"{max_regression:.0%} vs baseline {b_val:.3f} "
                f"(ceiling {ceiling:.3f})")
    for direction, list_key in (("floor", "floor_only"), ("ceiling", "ceiling_only")):
        # absolute-only metrics: wall-clock ratios too noisy for a relative
        # band are held to their committed bound alone, on both records
        for key in base.get(list_key, ()):
            bound = base.get(f"min_{key}" if direction == "floor" else f"max_{key}")
            if bound is None:
                errors.append(
                    f"{name}: {list_key} metric {key!r} has no "
                    f"{'min' if direction == 'floor' else 'max'}_{key} bound "
                    f"in the committed baseline")
                continue
            for side, rec in (("baseline", base), ("fresh", fresh)):
                val = rec.get(key)
                if val is None:
                    errors.append(
                        f"{name}: {list_key} metric {key!r} missing from the "
                        f"{side} record")
                elif direction == "floor" and val < bound:
                    errors.append(
                        f"{name}: {side} {key} {val:.3f} is below its "
                        f"{bound:.3f} floor"
                        + (" -- refresh the baseline" if side == "baseline" else ""))
                elif direction == "ceiling" and val > bound:
                    errors.append(
                        f"{name}: {side} {key} {val:.3f} exceeds its "
                        f"{bound:.3f} ceiling"
                        + (" -- refresh the baseline" if side == "baseline" else ""))
    for key in ("fused_samples_per_s", "unfused_samples_per_s"):
        if key in base or key in fresh:
            # values may be None (e.g. a percentile over zero samples --
            # ServingMetrics emits None, never NaN, to stay valid JSON)
            def fmt(rec):
                v = rec.get(key)
                return "n/a" if v is None else f"{v:.0f}"
            print(f"  {name}.{key}: baseline={fmt(base)} "
                  f"fresh={fmt(fresh)}  (informational)")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline_dir", type=pathlib.Path)
    ap.add_argument("fresh_dir", type=pathlib.Path)
    ap.add_argument("--max-regression", type=float, default=0.20,
                    help="allowed fractional speedup drop vs baseline")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="absolute fused-vs-interpreter floor")
    args = ap.parse_args()

    baselines = sorted(args.baseline_dir.glob("*.json"))
    if not baselines:
        print(f"no *.json baselines under {args.baseline_dir}", file=sys.stderr)
        return 2
    errors: list[str] = []
    for path in baselines:
        fresh_path = args.fresh_dir / path.name
        if not fresh_path.exists():
            errors.append(f"{path.name}: fresh run missing ({fresh_path})")
            continue
        base = json.loads(path.read_text())
        fresh = json.loads(fresh_path.read_text())
        errs = check_record(path.name, base, fresh,
                            max_regression=args.max_regression,
                            min_speedup=args.min_speedup)
        status = "FAIL" if errs else "ok"
        print(f"[{status}] {path.name}: speedup "
              f"{base.get('speedup', 0):.2f}x -> {fresh.get('speedup', 0):.2f}x")
        errors.extend(errs)
    # the reverse direction: a fresh record with no committed baseline means
    # a benchmark silently escaped the gate (e.g. a forgotten git add)
    known = {p.name for p in baselines}
    for fresh_path in sorted(args.fresh_dir.glob("*.json")):
        if fresh_path.name.endswith(".trace.json"):
            continue  # Chrome trace artifacts ride along, ungated
        if fresh_path.name not in known:
            errors.append(
                f"{fresh_path.name}: fresh record has no committed baseline "
                f"under {args.baseline_dir} -- commit one or drop the run")
    for e in errors:
        print(f"REGRESSION: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
