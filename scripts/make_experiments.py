"""Assemble EXPERIMENTS.md from dry-run JSONs + benchmark CSVs + the
hillclimb iteration records.  Run after dryrun/hillclimb/benchmarks:

    PYTHONPATH=src:. python scripts/make_experiments.py
"""

import csv
import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.roofline import dryrun_table, fmt_bytes, load, roofline_table


def csv_rows(path):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return list(csv.DictReader(f))


def hillclimb_rows(pattern):
    out = []
    for p in sorted(glob.glob(pattern)):
        with open(p) as f:
            d = json.load(f)
        if d.get("skipped"):
            continue
        tag = os.path.basename(p)[:-5].split("__")[-1]
        r = d["roofline"]
        out.append({
            "it": tag,
            "quant": d.get("quant") or "-",
            "fsdp": d.get("fsdp"),
            "seq_sp": d.get("seq_sp"),
            "naive": d.get("naive_attn"),
            "args_dev": d["memory"]["argument_bytes"],
            "temp_dev": d["memory"]["temp_bytes"],
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "coll_s": r["collective_s"],
            "dominant": r["dominant"],
            "bound_s": r["bound_s"],
        })
    return sorted(out, key=lambda r: r["it"])


def hc_table(rows):
    lines = ["| iter | quant | fsdp | seq-sp | args/dev | temp/dev | compute s | memory s | coll s | dominant |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['it']} | {r['quant']} | {r['fsdp']} | {r['seq_sp']} | "
            f"{fmt_bytes(r['args_dev'])} | {fmt_bytes(r['temp_dev'])} | "
            f"{r['compute_s']:.4g} | {r['memory_s']:.4g} | {r['coll_s']:.4g} | "
            f"{r['dominant']} |")
    return "\n".join(lines)


def main():
    final = load("experiments/dryrun_final") or load("experiments/dryrun")
    base = load("experiments/dryrun")

    nid = csv_rows("experiments/bench/nid_mlp.csv")
    sweep = csv_rows("experiments/bench/resource_sweep.csv")
    chain = csv_rows("experiments/bench/synthesis_time_chain.csv")
    large = csv_rows("experiments/bench/resource_large.csv")

    hc_a = hillclimb_rows("experiments/hillclimb/granite*__prefill_32k*.json")
    hc_b = hillclimb_rows("experiments/hillclimb/qwen2*__prefill_32k*.json")
    hc_c = hillclimb_rows("experiments/hillclimb/command*__decode_32k*.json")

    doc = []
    w = doc.append

    w("# EXPERIMENTS\n")
    w("All artifacts regenerable: `python -m repro.launch.dryrun --all --mesh "
      "both --seq-sp --save-dir experiments/dryrun_final`, "
      "`bash scripts/hillclimb.sh`, `python -m benchmarks.run`.\n")
    w("Hardware model: TPU v5e — 197 TFLOP/s bf16 (394 TOP/s int8), "
      "819 GB/s HBM, 50 GB/s/link ICI, 16 GB HBM/chip. Meshes: single pod "
      "(16,16)=('data','model') 256 chips; multi-pod (2,16,16)="
      "('pod','data','model') 512 chips.\n")

    # ----------------------------------------------------------- paper claims
    w("\n## Paper-claims validation (the faithful reproduction)\n")
    w("The paper's five headline findings (DESIGN.md §1), re-evaluated under "
      "the TPU metric mapping (RTL→Pallas closed-form model, HLS→XLA "
      "measured):\n")
    if nid:
        cyc = "; ".join(f"L{r['layer']}: {r['exec_cycles_model']} model vs "
                        f"{r['exec_cycles_paper_rtl']} paper" for r in nid)
        w(f"* **C5 (II=1 / exec cycles) — reproduced exactly.** The folding "
          f"cycle model NF·SF + 5 pipeline stages reproduces Table 7's "
          f"execution cycles on all four NID layers: {cyc}.")
    if sweep:
        small = [r for r in sweep if int(r["PE"]) * int(r["SIMD"]) <= 16
                 and r["simd_type"] == "standard"]
        if small:
            ratios = [float(r["hls_temp_bytes"]) / max(float(r["rtl_lut_bytes"]), 1)
                      for r in small]
            w(f"* **C1 (small designs: RTL ≪ HLS) — reproduced.** Across the "
              f"PE·SIMD ≤ 16 sweep points the XLA path's temp allocation is "
              f"{min(ratios):.1f}–{max(ratios):.1f}× the Pallas kernel's "
              f"modeled VMEM working set. Unlike the FPGA case the TPU RTL "
              f"analog stays below the HLS analog at *all* sizes (XLA "
              f"materializes full operand copies; the MXU fabric has no "
              f"LUT-count crossover), so the paper's large-design crossover "
              f"(HLS winning by ≤15% LUTs) does **not** transfer — noted as "
              f"an adaptation delta.")
        ifm = [r for r in sweep if r["sweep"] == "cfg1:ifm_ch" and r["simd_type"] == "standard"]
        if len(ifm) >= 2:
            w(f"* **C2 (IFM-channel sensitivity) — reproduced in structure.** "
              f"Sweeping IFM channels {ifm[0]['value']}→{ifm[-1]['value']}: "
              f"the RTL FF analog (pipeline state) stays flat "
              f"({ifm[0]['rtl_ff_bytes']}→{ifm[-1]['rtl_ff_bytes']} bytes — the "
              f"paper's flat RTL curves), while buffers grow with the input-"
              f"buffer depth K/SIMD exactly as Eq. 2 predicts "
              f"(inbuf {ifm[0]['rtl_inbuf_depth']}→{ifm[-1]['rtl_inbuf_depth']}); "
              f"the HLS-analog temp grows "
              f"{float(ifm[-1]['hls_temp_bytes'])/float(ifm[0]['hls_temp_bytes']):.0f}× "
              f"over the same range.")
    w("* **C3 (critical path) — structural claims reproduced** "
      "(benchmarks/critical_path.py): per-step datapath width (PE·SIMD, the "
      "FPGA critical-path driver) is invariant across IFM/OFM sweeps and "
      "grows with PE/SIMD; per-output latency from the cycle model follows "
      "the paper's latency curves. The absolute 45–80% clock-rate gap has no "
      "TPU analog (fixed clock) — documented, not claimed.")
    if chain:
        first, last = chain[0], chain[-1]
        w(f"* **C4 (synthesis time) — mechanism reproduced.** The monolithic "
          f"compile of a generated L-layer dataflow graph (HLS analog) grows "
          f"{float(last['hls_compile_s'])/max(float(first['hls_compile_s']),1e-9):.1f}× "
          f"from L={first['value']} to L={last['value']}, while the modular "
          f"Pallas path compiles each kernel parameterization once "
          f"(flat {last['rtl_compile_s']}s) — at L={last['value']} the ratio "
          f"is {last['hls/rtl']}×. (On this CPU container the HLS analog is "
          f"XLA; Mosaic compile on real TPUs is the true RTL-synthesis "
          f"analog.)")
    if nid:
        w("* **NID use case (Table 6/7) — end-to-end.** QAT training on the "
          "synthetic UNSW-NB15 stand-in, streamlining (BN+quant → integer "
          "thresholds), Table 6 PE/SIMD folding, integer inference through "
          "the Pallas MVU kernels: float teacher and integer pipeline both "
          "reach 100% test accuracy; dataflow interval 12 cycles, "
          "bottleneck layer 0 (matches the paper's layer-0-heavy design).\n")

    # ----------------------------------------------------------- dryrun
    for mesh in ("pod", "multipod"):
        n_ok = sum(1 for r in final if r.get("mesh") == mesh and not r.get("skipped"))
        n_skip = sum(1 for r in final if r.get("mesh") == mesh and r.get("skipped"))
        w(f"\n## Dry-run — {mesh} mesh ({'16x16, 256 chips' if mesh=='pod' else '2x16x16, 512 chips'}): "
          f"{n_ok} cells compiled, {n_skip} skipped\n")
        w("Every cell is `jit(fn, in_shardings=...).lower(ShapeDtypeStructs)"
          ".compile()` — no allocation. `args/dev` = persistent per-device "
          "bytes (params+opt+caches; the fit proof), `temp/dev` = XLA CPU-"
          "backend temporaries (upper bound — the CPU backend does not fuse "
          "like Mosaic). Collective GB/chip: while-body ops × scan trips.\n")
        w(dryrun_table(final, mesh))

    # ----------------------------------------------------------- roofline
    w("\n## Roofline (single pod, per assignment)\n")
    w("`compute_s` = HLO_FLOPs/(chips·197e12) with HLO FLOPs from two "
      "UNROLLED shallow variants linearly extrapolated (XLA cost_analysis "
      "counts while bodies once — measured, see dryrun.py). `memory_s` uses "
      "the fused-stream analytic model (the CPU backend's 'bytes accessed' "
      "overstates HBM traffic 10–300× from missing fusion; both are "
      "recorded, `roofline_hlo_bytes` keeps the spec-formula value). "
      "`collective_s` = parsed collective bytes/(chips·50e9). "
      "MODEL_FLOPS = 6·N·D (train) / 2·N·D (serve), N = active params.\n")
    w(roofline_table(final, "pod"))
    w("\nReading the table: train/prefill cells are **compute-dominant** at "
      "useful-FLOPs ratios of ~0.6–0.9 (remat recompute + attention "
      "quadratic terms explain the gap to 1.0); decode cells are "
      "**memory-dominant** (weight + KV streams at batch·1 token), which is "
      "precisely the regime the paper's quantized MVU attacks — see §Perf "
      "cell C.\n")

    # ----------------------------------------------------------- perf
    w("\n## Perf — hypothesis → change → measure log\n")
    w("Three cells per the assignment: worst roofline fraction "
      "(granite prefill), most collective-bound (qwen2-vl prefill), most "
      "paper-representative (command-r-plus decode). Baselines are the "
      "paper-faithful port (naive attention, TP-only sharding, bf16 "
      "weights); each iteration is one hypothesis.\n")

    def d(rows, a, b, key):
        ra = next((r for r in rows if r["it"].startswith(a)), None)
        rb = next((r for r in rows if r["it"].startswith(b)), None)
        if not (ra and rb) or not rb[key]:
            return "n/a"
        return f"{ra[key]/max(rb[key],1e-12):.1f}x"

    if hc_a:
        w("\n### Cell A: granite-moe-3b-a800m × prefill_32k "
          "(worst roofline fraction 0.55, collective/compute = 0.66)\n")
        w(hc_table(hc_a))
        w(f"\n* a0→a1 **CONFIRMED**: chunked attention. Hypothesis: the "
          f"naive 32k×32k fp32 score tensors dominate temp memory *and* "
          f"inflate the TP all-reduce payloads GSPMD re-shards per layer. "
          f"Measured: temp/dev {d(hc_a,'a0','a1','temp_dev')} smaller "
          f"(now fits HBM), compute term {d(hc_a,'a0','a1','compute_s')} "
          f"down, collective term {d(hc_a,'a0','a1','coll_s')} down.")
        w("* a1→a2 **REFUTED (by design)**: sequence-sharding the residual "
          "stream targets remat-boundary *saves*, but prefill has no "
          "backward pass — zero effect on inference cells. SP stays a "
          "train-only lever (it applies in the final train-cell pass).")
        w("* a2→a3 **CONFIRMED (negative result)**: FSDP on a 3B MoE "
          "regresses everything — per-layer weight all-gathers + "
          "f-dim-sharded experts force psums inside every expert GEMM "
          "(AR 151→1079 GB). FSDP is a capacity tool, not a speed tool; "
          "the auto-threshold (>8 GB/chip) correctly leaves it off here.")
    if hc_b:
        w("\n### Cell B: qwen2-vl-7b × prefill_32k (largest collective volume)\n")
        w(hc_table(hc_b))
        w(f"\n* b0→b1 **CONFIRMED**: same chunked-attention hypothesis at "
          f"28 layers/32k: collective term {d(hc_b,'b0','b1','coll_s')} "
          f"down (AR 1737→159 GB/chip), compute "
          f"{d(hc_b,'b0','b1','compute_s')} down, temp "
          f"{d(hc_b,'b0','b1','temp_dev')} down. The M-RoPE/VLM path adds "
          f"no collectives of its own — the whole excess was the naive "
          f"score tensors.")
        w("* b1→b2: no further change (prefill; same SP reasoning as a2).")
    if hc_c:
        w("\n### Cell C: command-r-plus-104b × decode_32k "
          "(memory-bound; the paper's technique)\n")
        w(hc_table(hc_c))
        w("\n* c0 baseline: bf16 weights TP-16 = 13 GB/chip + 4.3 GB KV = "
          "**17.7 GB/chip: does not fit 16 GB HBM**; memory term 0.0218 s "
          "= the full weight+KV stream per token.")
        w("* c0→c1 **CONFIRMED as capacity fix, REFUTED as perf fix**: "
          "FSDP fits (5.1 GB/chip) but adds per-step weight all-gathers "
          "over ICI — for latency-bound decode this trades the HBM wall "
          "for an ICI wall.")
        w(f"* c0→c2 **CONFIRMED**: W8A8 MVU (the paper's standard-SIMD "
          f"datapath on the MXU) fits TP-only (11.4 GB/chip) and cuts the "
          f"memory term {d(hc_c,'c0','c2','memory_s')}.")
        w(f"* c2→c3 **CONFIRMED**: W4A8 — int4-packed storage, int8-carried "
          f"MXU datapath — 8.2 GB/chip, memory term "
          f"{d(hc_c,'c0','c3','memory_s')} vs baseline. The weight stream "
          f"is now smaller than the KV stream: the bottleneck moved.")
        w(f"* c3→c4 **CONFIRMED**: int8 KV cache (KIVI-style per-token-head "
          f"scales, argmax-exact in tests) attacks the new bottleneck: "
          f"6.2 GB/chip, memory term {d(hc_c,'c0','c4','memory_s')} vs "
          f"baseline — a 2.8× end-to-end reduction of the dominant term, "
          f"entirely from the paper's 'precision is the resource' thesis.")
        w("* extension probe (qwen3-moe-235B decode, experiments/hillclimb/"
          "*d1*): quantizing only the attention projections leaves the bf16 "
          "expert bank (233B of 235B params) as the stream -- 30.6 GB/chip, "
          "still over HBM; auto-FSDP (5.0 GB/chip, memory term 0.0063 s) "
          "remains the capacity answer for fine-grained MoE serving. "
          "Grouped-MVU expert quantization is the identified follow-up.\n")

    # train cells before/after (baseline dir vs final dir)
    base_idx = {(r["arch"], r["shape"], r["mesh"]): r for r in base if not r.get("skipped")}
    fin_idx = {(r["arch"], r["shape"], r["mesh"]): r for r in final if not r.get("skipped")}
    rows = []
    for key, f in fin_idx.items():
        if key[1] != "train_4k" or key[2] != "pod" or key not in base_idx:
            continue
        b = base_idx[key]
        rows.append((key[0], b, f))
    if rows and base is not final:
        w("\n### Train cells: paper-faithful baseline vs optimized "
          "(chunked attention + seq-SP + auto-FSDP), single pod\n")
        w("| arch | compute s (b→o) | collective s (b→o) | temp/dev (b→o) | args/dev (b→o) |")
        w("|---|---|---|---|---|")
        for arch, b, f in sorted(rows):
            br, fr = b["roofline"], f["roofline"]
            w(f"| {arch} | {br['compute_s']:.3g} → {fr['compute_s']:.3g} "
              f"| {br['collective_s']:.3g} → {fr['collective_s']:.3g} "
              f"| {fmt_bytes(b['memory']['temp_bytes'])} → {fmt_bytes(f['memory']['temp_bytes'])} "
              f"| {fmt_bytes(b['memory']['argument_bytes'])} → {fmt_bytes(f['memory']['argument_bytes'])} |")
        w("\nDense/SSM/hybrid archs: activation temp drops 3-5x (remat "
          "saves sequence-sharded) and collectives drop ~4x (chunked "
          "attention removes the naive score-tensor reshards). "
          "Fine-grained-MoE (granite/qwen3): seq-SP *regresses* compute -- "
          "the MoE group reshape crosses the sharded sequence dim and GSPMD "
          "replicates dispatch work; a seq-shard-aware group assignment is "
          "the identified follow-up. FSDP archs (command-r/qwen3/jamba) "
          "now fit HBM for training (e.g. command-r args 66.9GB -> 4.2GB/chip).\n")

    # kernel-level
    w("\n### Kernel-level: faithful XNOR datapath vs beyond-paper MXU variant\n")
    w("The paper's XNOR-popcount lane is bit-serial LUT logic; the faithful "
      "TPU port packs 32 synapses/uint32 on the VPU (SWAR popcount ≈ 12 int "
      "ops / 32 MACs → ~10 T MAC/s peak at 0.94 GHz), while the beyond-paper "
      "variant unpacks to ±1 int8 and uses the MXU (394 TOP/s ÷ 2 ops = 197 "
      "T MAC/s). Napkin roofline: MXU wins ~19× on compute whenever the 8× "
      "VMEM expansion of unpacking fits (K ≤ ~64k per tile); the bit-packed "
      "path wins only when weight residency is the binding constraint — "
      "mirroring the paper's own LUT-vs-DSP tradeoff. Both validated "
      "bit-exact against ref.py (tests/test_kernels_mvu.py); CPU interpret "
      "timings in bench_output.txt are correctness-path numbers, not TPU "
      "projections.\n")

    # ----------------------------------------------------------- autotuning
    w("\n## Autotuning — heuristic folding vs empirical schedule search\n")
    w("`repro.core.autotune` replaces the one-shot `choose_folding` + "
      "`to_tpu_blocks` heuristic with a measured design-space search: "
      "candidates from the layer's folding divisors (+ the pallas-vs-xla "
      "backend and the engine microbatch tile), VMEM-pruned and "
      "cycle-ordered by the analytic resource model, timed with the paired "
      "interleaved timer, winners committed to the per-config "
      "`TUNED_SCHEDULES` caches. `FusedEngine(tune=\"cache\")` consumes "
      "them with zero measurement at load time; "
      "`python -m benchmarks.autotune_gain` re-proves the end-to-end gain "
      "(CI-gated at the committed record's 1.15x floor).\n")
    gain_path = "experiments/bench/autotune_gain.json"
    if os.path.exists(gain_path):
        with open(gain_path) as fh:
            gain = json.load(fh)
        w(f"End-to-end on `{gain['config']}` (batch {gain['batch']}): tuned "
          f"engine **{gain['speedup']:.2f}x** over the heuristic-default "
          f"engine, bit-exact={gain['bit_exact']}, "
          f"{gain['tuned_nodes']}/{gain['total_nodes']} nodes tuned, "
          f"microbatch tile {gain['microbatch_tile']}. "
          f"({gain.get('speedup_note', '')})\n")
    try:
        from repro.configs import cnv_bnn, nid_mlp

        for title, mod in (("NID-MLP", nid_mlp), ("CNV (quick, xnor)", cnv_bnn)):
            sched = getattr(mod, "TUNED_SCHEDULES", {})
            node_rows = [(k, v) for k, v in sched.items()
                         if not k.startswith("engine|")]
            if not node_rows:
                continue
            w(f"\n### {title}: per-layer heuristic vs tuned schedule\n")
            w("| cache key (device\\|op\\|mode\\|N\\|K\\|epilogue\\|px) | "
              "tuned blocks (m, n, k-step/rows) | backend | node speedup |")
            w("|---|---|---|---|")
            for key, v in node_rows:
                if "|conv" in key:
                    kk = f"rt={v.get('rows_per_tile', 'auto')}"
                elif "xnor" in key:
                    kk = v["block_kw"]
                else:
                    kk = v["block_k"]
                w(f"| `{key}` | ({v['block_m']}, {v['block_n']}, {kk}) "
                  f"| {v['backend']} | {v['speedup']:.2f}x |")
            eng = [(k, v) for k, v in sched.items() if k.startswith("engine|")]
            for key, v in eng:
                w(f"\nEngine-level: microbatch tile {v['microbatch']} "
                  f"(tuned at batch {v['batch']}, {v['speedup']:.2f}x over "
                  f"the heuristic plan).")
            w("")
    except ImportError:
        pass

    # ----------------------------------------------------------- build reports
    reports = sorted(glob.glob("experiments/build/*_build_report.json"))
    if reports:
        w("\n## Build pipeline (`repro.build`) — step reports\n")
        w("Every accelerator is now produced by one "
          "`repro.build.build(graph, target=...)` call running a FINN-style "
          "list of named steps (lower → finalize → fold → fuse_epilogues → "
          "fuse_swu → tune → dataflow → engine [→ calibrate]), each graph "
          "rewrite verified bit-exact against the reference interpreter on "
          "a probe batch. The BuildReport below is the software analog of "
          "the paper's per-design resource/synthesis tables: per-step "
          "wall-clock + verification, per-stage folding with LUT/FF/BRAM-"
          "analog estimates, predicted vs measured steady-state interval, "
          "and autotune cache accounting.\n")
        for path in reports:
            with open(path) as fh:
                rep = json.load(fh)
            w(f"\n### `{rep['name']}` (target `{rep['target']}`)\n")
            w("| step | wall s | verified | graph ops after |")
            w("|---|---|---|---|")
            for s in rep["steps"]:
                ops = ", ".join(f"{k}×{v}" for k, v in sorted(s["ops"].items()))
                ver = {True: "bit-exact", None: "—"}.get(s["verified"], "FAIL")
                w(f"| {s['name']} | {s['wall_s']:.3f} | {ver} | {ops} |")
            if rep.get("nodes"):
                w("\n| stage | op | N | K | PE | SIMD | cycles | LUT-analog B "
                  "| BRAM-analog B | tuned |")
                w("|---|---|---|---|---|---|---|---|---|---|")
                for n in rep["nodes"]:
                    w(f"| {n['name']} | {n['op']} | {n['n']} | {n['k']} "
                      f"| {n['pe']} | {n['simd']} | {n['cycles']} "
                      f"| {n['lut_bytes']} | {n['bram_bytes']} "
                      f"| {'yes' if n['tuned'] else 'no'} |")
            pred, meas = rep.get("predicted_interval_s"), rep.get("measured_interval_s")
            line = (f"\nSteady-state interval: predicted "
                    f"{pred * 1e6:.3f} µs (nominal 200 MHz)" if pred else "\n")
            if meas:
                line += (f", measured {meas * 1e6:.1f} µs "
                         f"({rep['cycle_time_source']} cycle time)")
            tune = rep.get("tune", {})
            if tune.get("mode", "off") != "off":
                line += (f"; autotune `{tune['mode']}`: "
                         f"{tune.get('cache_hits', 0)} cache hits, "
                         f"{tune.get('cache_misses', 0)} misses")
            w(line + f". Total build wall-clock {rep['total_wall_s']:.2f} s.")

    # ----------------------------------------------------------- serving load
    serve_path = "experiments/bench/serving_load.json"
    if os.path.exists(serve_path):
        w("\n## Serving load — continuous batching vs submit/flush\n")
        w("`repro.serving` fronts the fused engine with a bounded admission "
          "queue, a continuous batcher (flush on bucket-fill / pipeline-idle "
          "/ deadline-slack, the budget derived from "
          "`DataflowSchedule.steady_state_interval` via "
          "`dataflow.interval_seconds` with the measured cycle time), and a "
          "multi-replica pool (params `device_put` per device, least-loaded "
          "async dispatch).  `python -m benchmarks.serving_load` drives it "
          "and the legacy cadence-flushed `EngineServer` with the same "
          "open-loop Poisson arrivals; the committed record is CI-gated on "
          ">=1.0x throughput (`min_speedup`) AND strictly-better p99 "
          "(`lower_is_better: p99_vs_server`, ceiling 1.0).\n")
        with open(serve_path) as fh:
            sv = json.load(fh)
        w(f"Open-loop Poisson on `{sv['config']}` ({sv['requests']} requests "
          f"at {sv['rate_hz']:.0f}/s, SLO {sv['slo_ms']:.0f} ms, buckets "
          f"{sv['buckets']}):\n")
        w("| metric | continuous (`repro.serving`) | legacy `EngineServer` |")
        w("|---|---|---|")
        w(f"| p50 latency | {sv['serving_p50_ms']:.2f} ms "
          f"| {sv['server_p50_ms']:.2f} ms |")
        w(f"| p99 latency | {sv['serving_p99_ms']:.2f} ms "
          f"| {sv['server_p99_ms']:.2f} ms |")
        w(f"| deadline miss rate | {sv['serving_deadline_miss_rate']:.1%} "
          f"| {sv['server_deadline_miss_rate']:.1%} |")
        w(f"| open-loop completion | {sv['serving_samples_per_s']:.0f} "
          f"samples/s | {sv['server_samples_per_s']:.0f} samples/s |")
        w(f"| closed-loop saturation | "
          f"{sv['closed_loop_serving_samples_per_s']:.0f} samples/s | "
          f"{sv['closed_loop_server_samples_per_s']:.0f} samples/s |")
        note = sv.get("claim_note")
        w(f"\nCommitted claim: **{sv['speedup']:.2f}x** open-loop throughput, "
          f"p99 at **{sv['p99_vs_server']:.2f}x** the legacy server's, "
          f"bit_exact={sv['bit_exact']}."
          + (f" ({note})\n" if note else "\n"))

    # ----------------------------------------------------------- large table
    if large:
        w("\n## Appendix: Table 3/4 large-design convergence\n")
        w("| IFM ch | RTL LUT-analog bytes | HLS temp bytes | RTL FF bytes |")
        w("|---|---|---|---|")
        for r in large:
            w(f"| {r['value']} | {r['rtl_lut_bytes']} | {r['hls_temp_bytes']} "
              f"| {r['rtl_ff_bytes']} |")

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(doc) + "\n")
    print(f"EXPERIMENTS.md written ({len(doc)} blocks)")


if __name__ == "__main__":
    main()
