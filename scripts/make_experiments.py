"""Render EXPERIMENTS.md (+ docs/figures/*.svg) from committed artifacts.

Single source of truth for the experiments document: everything below is
read from JSON records checked into the repo, so the output is
deterministic and CI can regenerate it and fail on drift
(``git diff --exit-code EXPERIMENTS.md docs/figures``).

Inputs (all committed):
  experiments/bench/*.json             benchmark records (regression-gated)
  experiments/explore/*_explore.json   design-space explorer sweeps
  experiments/build/*_build_report.json  sample BuildReports
  repro.configs.*.TUNED_SCHEDULES      committed autotune winners

Regenerate the artifacts, then this document:

    python -m benchmarks.run --out-dir experiments/bench
    python -m repro.explore --config nid_mlp --quick
    python scripts/make_experiments.py

The SVG figures are hand-rolled (no plotting dependency, byte-stable
output) -- same data as the tables, drawn for the paper-figure analogs.
"""

from __future__ import annotations

import glob
import json
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

FIG_DIR = "docs/figures"
PALETTE = ["#4477aa", "#ee6677", "#228833", "#ccbb44", "#66ccee", "#aa3377"]


def _load(path):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


# --------------------------------------------------------------- svg helpers
def _fmt(x: float) -> str:
    """Deterministic coordinate formatting (fixed precision, no exponents)."""
    return f"{x:.2f}".rstrip("0").rstrip(".")


def _ticks(lo: float, hi: float, n: int = 5) -> list[float]:
    """'Nice' linear tick positions covering [lo, hi]."""
    if hi <= lo:
        hi = lo + 1.0
    raw = (hi - lo) / max(n - 1, 1)
    mag = 10 ** math.floor(math.log10(raw))
    step = min(s * mag for s in (1, 2, 5, 10) if s * mag >= raw)
    start = math.floor(lo / step) * step
    out = []
    t = start
    while t <= hi + step * 0.5:
        out.append(round(t, 10))
        t += step
    return out


def _log_ticks(lo: float, hi: float) -> list[float]:
    lo_e = math.floor(math.log10(max(lo, 1e-12)))
    hi_e = math.ceil(math.log10(max(hi, 1e-12)))
    return [10.0 ** e for e in range(lo_e, hi_e + 1)]


def _si(v: float) -> str:
    """Tick labels: 1.5k / 2M style, deterministic."""
    for div, suf in ((1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(v) >= div:
            s = f"{v / div:.3g}"
            return s + suf
    return f"{v:.4g}"


class _Canvas:
    """Minimal deterministic SVG plot surface with margins + axes."""

    def __init__(self, width=660, height=360, title="", xlabel="", ylabel=""):
        self.w, self.h = width, height
        self.ml, self.mr, self.mt, self.mb = 62, 16, 34, 46
        self.title, self.xlabel, self.ylabel = title, xlabel, ylabel
        self.body: list[str] = []

    @property
    def plot_w(self):
        return self.w - self.ml - self.mr

    @property
    def plot_h(self):
        return self.h - self.mt - self.mb

    def set_scales(self, x_lo, x_hi, y_lo, y_hi, log_x=False, log_y=False):
        self.log_x, self.log_y = log_x, log_y
        if log_x:
            x_lo, x_hi = math.log10(max(x_lo, 1e-12)), math.log10(max(x_hi, 1e-12))
        if log_y:
            y_lo, y_hi = math.log10(max(y_lo, 1e-12)), math.log10(max(y_hi, 1e-12))
        self.x_lo, self.x_hi = x_lo, (x_hi if x_hi > x_lo else x_lo + 1)
        self.y_lo, self.y_hi = y_lo, (y_hi if y_hi > y_lo else y_lo + 1)

    def px(self, x):
        if self.log_x:
            x = math.log10(max(x, 1e-12))
        return self.ml + (x - self.x_lo) / (self.x_hi - self.x_lo) * self.plot_w

    def py(self, y):
        if self.log_y:
            y = math.log10(max(y, 1e-12))
        return self.mt + self.plot_h - (y - self.y_lo) / (self.y_hi - self.y_lo) * self.plot_h

    def axes(self, x_ticks, y_ticks):
        b = self.body
        for t in y_ticks:
            y = self.py(t)
            b.append(f'<line x1="{self.ml}" y1="{_fmt(y)}" x2="{self.w - self.mr}" '
                     f'y2="{_fmt(y)}" stroke="#dddddd" stroke-width="1"/>')
            b.append(f'<text x="{self.ml - 6}" y="{_fmt(y + 3)}" text-anchor="end" '
                     f'font-size="10" fill="#555555">{_si(t)}</text>')
        for t in x_ticks:
            x = self.px(t)
            b.append(f'<line x1="{_fmt(x)}" y1="{self.mt}" x2="{_fmt(x)}" '
                     f'y2="{self.h - self.mb}" stroke="#eeeeee" stroke-width="1"/>')
            b.append(f'<text x="{_fmt(x)}" y="{self.h - self.mb + 14}" '
                     f'text-anchor="middle" font-size="10" fill="#555555">{_si(t)}</text>')
        b.append(f'<rect x="{self.ml}" y="{self.mt}" width="{self.plot_w}" '
                 f'height="{self.plot_h}" fill="none" stroke="#888888"/>')

    def legend(self, labels_colors):
        x = self.ml + 8
        for label, color in labels_colors:
            self.body.append(f'<rect x="{x}" y="{self.mt + 6}" width="10" '
                             f'height="10" fill="{color}"/>')
            self.body.append(f'<text x="{x + 14}" y="{self.mt + 15}" '
                             f'font-size="10" fill="#333333">{label}</text>')
            x += 14 + 7 * len(label) + 14

    def render(self) -> str:
        head = [
            f'<svg xmlns="http://www.w3.org/2000/svg" width="{self.w}" '
            f'height="{self.h}" viewBox="0 0 {self.w} {self.h}" '
            f'font-family="Helvetica, Arial, sans-serif">',
            f'<rect width="{self.w}" height="{self.h}" fill="#ffffff"/>',
            f'<text x="{self.w // 2}" y="18" text-anchor="middle" '
            f'font-size="13" fill="#111111">{self.title}</text>',
            f'<text x="{self.w // 2}" y="{self.h - 8}" text-anchor="middle" '
            f'font-size="11" fill="#333333">{self.xlabel}</text>',
            f'<text x="14" y="{self.h // 2}" text-anchor="middle" font-size="11" '
            f'fill="#333333" transform="rotate(-90 14 {self.h // 2})">'
            f'{self.ylabel}</text>',
        ]
        return "\n".join(head + self.body + ["</svg>"]) + "\n"


def line_chart(series, *, title, xlabel, ylabel, log_x=False, log_y=False):
    """series: [(label, [(x, y), ...]), ...]"""
    xs = [x for _, pts in series for x, _ in pts]
    ys = [y for _, pts in series for _, y in pts]
    c = _Canvas(title=title, xlabel=xlabel, ylabel=ylabel)
    c.set_scales(min(xs), max(xs), 0 if not log_y else min(ys), max(ys),
                 log_x=log_x, log_y=log_y)
    x_ticks = _log_ticks(min(xs), max(xs)) if log_x else _ticks(min(xs), max(xs))
    y_ticks = (_log_ticks(min(ys), max(ys)) if log_y
               else _ticks(0, max(ys)))
    c.axes(x_ticks, y_ticks)
    for i, (label, pts) in enumerate(series):
        color = PALETTE[i % len(PALETTE)]
        path = " ".join(f"{'M' if j == 0 else 'L'}{_fmt(c.px(x))},{_fmt(c.py(y))}"
                        for j, (x, y) in enumerate(sorted(pts)))
        c.body.append(f'<path d="{path}" fill="none" stroke="{color}" '
                      f'stroke-width="2"/>')
        for x, y in pts:
            c.body.append(f'<circle cx="{_fmt(c.px(x))}" cy="{_fmt(c.py(y))}" '
                          f'r="3" fill="{color}"/>')
    c.legend([(label, PALETTE[i % len(PALETTE)])
              for i, (label, _) in enumerate(series)])
    return c.render()


def bar_chart(groups, series_labels, *, title, xlabel, ylabel, log_y=False):
    """groups: [(group_label, [v_series0, v_series1, ...]), ...]"""
    vals = [v for _, vs in groups for v in vs]
    c = _Canvas(title=title, xlabel=xlabel, ylabel=ylabel)
    y_lo = min(vals) / 10 if log_y else 0
    c.set_scales(0, 1, y_lo, max(vals), log_y=log_y)
    y_ticks = _log_ticks(min(vals), max(vals)) if log_y else _ticks(0, max(vals))
    c.axes([], y_ticks)
    n_g, n_s = len(groups), len(series_labels)
    slot = c.plot_w / n_g
    bar_w = slot * 0.7 / n_s
    for gi, (label, vs) in enumerate(groups):
        x0 = c.ml + gi * slot + slot * 0.15
        for si, v in enumerate(vs):
            color = PALETTE[si % len(PALETTE)]
            y = c.py(v)
            h = c.mt + c.plot_h - y
            c.body.append(f'<rect x="{_fmt(x0 + si * bar_w)}" y="{_fmt(y)}" '
                          f'width="{_fmt(bar_w - 2)}" height="{_fmt(max(h, 0))}" '
                          f'fill="{color}"/>')
        c.body.append(f'<text x="{_fmt(c.ml + gi * slot + slot / 2)}" '
                      f'y="{c.h - c.mb + 14}" text-anchor="middle" '
                      f'font-size="10" fill="#555555">{label}</text>')
    c.legend([(label, PALETTE[i % len(PALETTE)])
              for i, label in enumerate(series_labels)])
    return c.render()


def heat_grid(xs, ys, cell_value, *, title, xlabel, ylabel, unit=""):
    """Grid heatmap; cell_value(x, y) -> float.  Blue = low, red = high."""
    vals = [cell_value(x, y) for x in xs for y in ys]
    lo, hi = min(vals), max(vals)
    c = _Canvas(title=title, xlabel=xlabel, ylabel=ylabel)
    cw, ch = c.plot_w / len(xs), c.plot_h / len(ys)

    def color(v):
        t = 0.5 if hi == lo else (v - lo) / (hi - lo)
        r = int(68 + t * (238 - 68))
        g = int(119 - t * (119 - 102))
        b = int(170 - t * (170 - 119))
        return f"#{r:02x}{g:02x}{b:02x}"

    for xi, x in enumerate(xs):
        for yi, y in enumerate(ys):
            v = cell_value(x, y)
            px = c.ml + xi * cw
            py = c.mt + (len(ys) - 1 - yi) * ch
            c.body.append(f'<rect x="{_fmt(px)}" y="{_fmt(py)}" '
                          f'width="{_fmt(cw - 1)}" height="{_fmt(ch - 1)}" '
                          f'fill="{color(v)}"/>')
            c.body.append(f'<text x="{_fmt(px + cw / 2)}" y="{_fmt(py + ch / 2 + 3)}" '
                          f'text-anchor="middle" font-size="10" '
                          f'fill="#ffffff">{_si(v)}{unit}</text>')
    for xi, x in enumerate(xs):
        c.body.append(f'<text x="{_fmt(c.ml + xi * cw + cw / 2)}" '
                      f'y="{c.h - c.mb + 14}" text-anchor="middle" '
                      f'font-size="10" fill="#555555">{x}</text>')
    for yi, y in enumerate(ys):
        c.body.append(f'<text x="{c.ml - 6}" '
                      f'y="{_fmt(c.mt + (len(ys) - 1 - yi) * ch + ch / 2 + 3)}" '
                      f'text-anchor="end" font-size="10" fill="#555555">{y}</text>')
    return c.render()


def write_fig(name: str, svg: str) -> str:
    os.makedirs(FIG_DIR, exist_ok=True)
    path = os.path.join(FIG_DIR, name)
    with open(path, "w") as f:
        f.write(svg)
    return path


# ------------------------------------------------------------------ figures
def fig_resource_curve(sweep: dict) -> str | None:
    curve = sweep.get("folding_curve") if sweep else None
    if not curve:
        return None
    series = [
        ("LUT analog (datapath VMEM B)",
         [(r["pe_simd"], r["rtl_lut_bytes"]) for r in curve]),
        ("FF analog (acc/control B)",
         [(r["pe_simd"], r["rtl_ff_bytes"]) for r in curve]),
        ("BRAM analog (weight store B)",
         [(r["pe_simd"], r["rtl_bram_bytes"]) for r in curve]),
    ]
    svg = line_chart(series, title="Resource analogs vs PE*SIMD (Figs 8-13 analog)",
                     xlabel="PE * SIMD (datapath MACs/cycle)",
                     ylabel="bytes", log_x=True, log_y=True)
    return write_fig("fig_resource_sweep.svg", svg)


def fig_heatmap(hm: dict) -> str | None:
    if not hm:
        return None
    cells = {(c["PE"], c["SIMD"]): c["delta_lut_bytes"] for c in hm["cells"]}
    svg = heat_grid(hm["pes"], hm["simds"], lambda pe, simd: cells[(pe, simd)],
                    title="HLS temp - RTL LUT-analog bytes (Fig 14 analog)",
                    xlabel="PE", ylabel="SIMD", unit="B")
    return write_fig("fig_heatmap.svg", svg)


def fig_interval(explore: dict) -> str | None:
    if not explore:
        return None
    pts = sorted(explore["points"], key=lambda p: p["pe_simd_product"])
    series = [
        ("steady-state interval (cycles)",
         [(p["pe_simd_product"], p["interval_cycles"]) for p in pts]),
        ("latency (cycles)",
         [(p["pe_simd_product"], p["latency_cycles"]) for p in pts]),
    ]
    svg = line_chart(series,
                     title="Interval/latency vs folding (Table 5 / Fig 15 analog)",
                     xlabel="sum of PE*SIMD across stages",
                     ylabel="cycles", log_x=True, log_y=True)
    return write_fig("fig_interval_sweep.svg", svg)


def fig_synthesis(synth: dict, explore: dict) -> str | None:
    if not synth:
        return None
    groups = [(f"L={r['value']}", [r["hls_compile_s"], r["rtl_compile_s"]])
              for r in synth["chain"]]
    if explore and explore.get("cache"):
        c = explore["cache"]
        groups.append(("cold/warm", [c["cold_wall_s"], c["warm_wall_s"]]))
    svg = bar_chart(groups, ["monolithic (HLS analog)", "modular+cached (RTL analog)"],
                    title="Synthesis-time analog: compile/tune wall-clock (Fig 16)",
                    xlabel="design size (chain depth) | explorer cold vs warm build",
                    ylabel="seconds")
    return write_fig("fig_synthesis_time.svg", svg)


# ----------------------------------------------------------------- sections
def section_claims(w, sweep, crit, synth, nid):
    w("\n## Paper-claims validation (the faithful reproduction)\n")
    w("The paper's five headline findings (DESIGN.md §1), re-evaluated under "
      "the TPU metric mapping (RTL→Pallas closed-form model, HLS→XLA "
      "measured). Each claim reads from a committed, regression-gated "
      "benchmark record under `experiments/bench/`.\n")
    if nid:
        cyc = "; ".join(f"L{r['layer']}: {r['exec_cycles_model']} model vs "
                        f"{r['exec_cycles_paper_rtl']} paper"
                        for r in nid["layers"])
        w(f"* **C5 (II=1 / exec cycles) — reproduced exactly.** The folding "
          f"cycle model NF·SF + 5 pipeline stages reproduces Table 7's "
          f"execution cycles on all four NID layers: {cyc}.")
    if sweep:
        small = [r for r in sweep["configs"]
                 if r["PE"] * r["SIMD"] <= 16 and r["simd_type"] == "standard"
                 and "hls_temp_bytes" in r]
        if small:
            ratios = [r["hls_temp_bytes"] / max(r["rtl_lut_bytes"], 1)
                      for r in small]
            w(f"* **C1 (small designs: RTL ≪ HLS) — reproduced.** Across the "
              f"PE·SIMD ≤ 16 sweep points the XLA path's temp allocation is "
              f"{min(ratios):.1f}–{max(ratios):.1f}× the Pallas kernel's "
              f"modeled VMEM working set. Unlike the FPGA case the TPU RTL "
              f"analog stays below the HLS analog at *all* sizes (XLA "
              f"materializes full operand copies; the MXU fabric has no "
              f"LUT-count crossover), so the paper's large-design crossover "
              f"(HLS winning by ≤15% LUTs) does **not** transfer — noted as "
              f"an adaptation delta.")
        ifm = [r for r in sweep["configs"]
               if r["sweep"] == "cfg1:ifm_ch" and r["simd_type"] == "standard"]
        if len(ifm) >= 2:
            hls_growth = (f"{ifm[-1]['hls_temp_bytes'] / ifm[0]['hls_temp_bytes']:.0f}× "
                          if "hls_temp_bytes" in ifm[0] else "")
            w(f"* **C2 (IFM-channel sensitivity) — reproduced in structure.** "
              f"Sweeping IFM channels {ifm[0]['value']}→{ifm[-1]['value']}: "
              f"the RTL FF analog (pipeline state) stays flat "
              f"({ifm[0]['rtl_ff_bytes']}→{ifm[-1]['rtl_ff_bytes']} bytes — the "
              f"paper's flat RTL curves), while buffers grow with the input-"
              f"buffer depth K/SIMD exactly as Eq. 2 predicts "
              f"(inbuf {ifm[0]['rtl_inbuf_depth']}→{ifm[-1]['rtl_inbuf_depth']})"
              + (f"; the HLS-analog temp grows {hls_growth}over the same range."
                 if hls_growth else "."))
    if crit:
        ok = all(crit["claims"].values())
        w(f"* **C3 (critical path) — structural claims "
          f"{'reproduced' if ok else 'FAILED'}** "
          f"(`benchmarks/critical_path.py`, claims {crit['claims']}): per-step "
          f"datapath width (PE·SIMD, the FPGA critical-path driver) is "
          f"invariant across IFM/OFM sweeps and grows with PE/SIMD; "
          f"per-output latency from the cycle model follows the paper's "
          f"latency curves. The absolute 45–80% clock-rate gap has no TPU "
          f"analog (fixed clock) — documented, not claimed.")
    if synth:
        first, last = synth["chain"][0], synth["chain"][-1]
        w(f"* **C4 (synthesis time) — mechanism reproduced.** The monolithic "
          f"compile of a generated L-layer dataflow graph (HLS analog) grows "
          f"{synth['hls_growth']:.1f}× from L={first['value']} to "
          f"L={last['value']}, while the modular Pallas path compiles each "
          f"kernel parameterization once (flat {last['rtl_compile_s']:.2f}s) "
          f"— at L={last['value']} the ratio is {last['hls_over_rtl']:.1f}×. The "
          f"end-to-end caching result (cold sweep vs warm replay) is in the "
          f"design-space exploration section below.")
    if nid:
        acc = nid["accuracy"]
        w(f"* **NID use case (Table 6/7) — end-to-end.** QAT training on the "
          f"synthetic UNSW-NB15 stand-in, streamlining (BN+quant → integer "
          f"thresholds), Table 6 PE/SIMD folding, integer inference through "
          f"the Pallas MVU kernels: float teacher {acc['float_acc']:.3f} vs "
          f"integer pipeline {acc['mvu_int_acc']:.3f} test accuracy; "
          f"dataflow interval {acc['pipeline_interval_cycles']} cycles, "
          f"bottleneck {acc['bottleneck']} (matches the paper's "
          f"layer-0-heavy design).\n")


def section_explore(w, explore, figs):
    if not explore:
        return
    w("\n## Design-space exploration (`repro.explore`)\n")
    w(f"The paper's experimental loop — synthesize every folding, read the "
      f"trade-off curves off the reports — run through the `repro.build` "
      f"pipeline on `{explore['config']}`: "
      f"{explore['n_points']} grid points (PE targets "
      f"{explore['grid']['pe_targets']}, SIMD targets "
      f"{explore['grid']['simd_targets']}), every point built with "
      f"verification on and measured end-to-end (batch {explore['batch']}). "
      f"All points bit-exact: **{explore['bit_exact']}**. Regenerate: "
      f"`python -m repro.explore --config nid_mlp --quick`.\n")
    if figs.get("interval"):
        w(f"![interval vs folding]({figs['interval']})\n")
    w("| point | PE tgt | SIMD tgt | packed | interval cyc | samples/s "
      "| LUT B | FF B | BRAM B | weight B | Pareto |")
    w("|---|---|---|---|---|---|---|---|---|---|---|")
    for p in sorted(explore["points"],
                    key=lambda r: (r["pe_simd_product"], r.get("packed", False))):
        w(f"| {p['point_id']} | {p['pe_target']} | {p['simd_target']} "
          f"| {'yes' if p.get('packed') else 'no'} "
          f"| {p['interval_cycles']} | {p['samples_per_s']:.0f} "
          f"| {p['lut_bytes']} | {p['ff_bytes']} | {p['bram_bytes']} "
          f"| {p.get('weight_bytes', '—')} "
          f"| {'**yes**' if p['pareto'] else 'no'} |")
    w(f"\nPareto frontier (maximize throughput, minimize LUT/FF/BRAM "
      f"analogs and HBM-resident weight bytes): "
      f"{', '.join(f'`{p}`' for p in explore['pareto_front'])}. "
      f"The frontier keeps both extremes — minimal-area fully-folded points "
      f"and the wide low-interval designs — exactly the paper's "
      f"area-vs-throughput trade-off curve.\n")
    if explore.get("packed_points"):
        w(f"The sweep crosses the weight-storage axis into the grid "
          f"(`packings` {explore['grid'].get('packings')}): "
          f"{explore['packed_points']}/{explore['n_points']} points built "
          f"with `pack=\"always\"` (bit-packed weights + packed datapath), "
          f"and {explore['packed_pareto_points']} of them land on the "
          f"frontier — a packed point strictly dominates its unpacked twin "
          f"on weight bytes at equal folding, so the packing axis is gated "
          f"to keep ≥{explore.get('min_packed_pareto_points', 1)} frontier "
          f"point (`floor_only`).\n")

    cal = explore.get("calibration") or {}
    if cal:
        s = cal["summary"]
        w("### Resource-model calibration across the whole sweep\n")
        w(f"One least-squares cycle time fit over all "
          f"{cal['samples']} (point, node) measurements: "
          f"s_per_cycle = {cal['s_per_cycle']:.3e} s "
          f"(a {cal['clock_mhz_analog']:.1f} MHz effective clock analog on "
          f"this host). Signed relative error of predicted = cycles × "
          f"s_per_cycle vs measured per-stage time:\n")
        w("| | n | mean abs | p50 abs | p90 abs | max abs | mean signed |")
        w("|---|---|---|---|---|---|---|")
        w(f"| all nodes | {s['n']} | {s['mean_abs']:.2f} | {s['p50_abs']:.2f} "
          f"| {s['p90_abs']:.2f} | {s['max_abs']:.2f} | {s['mean_signed']:.2f} |")
        for name, ns in cal.get("per_node", {}).items():
            w(f"| `{name}` | {ns['n']} | {ns['mean_abs']:.2f} "
              f"| {ns['p50_abs']:.2f} | {ns['p90_abs']:.2f} "
              f"| {ns['max_abs']:.2f} | {ns['mean_signed']:.2f} |")
        w(f"\nThe analytic II=1 cycle model is a *schedule* model, not a "
          f"host-time model: on the CPU interpret path, fixed per-dispatch "
          f"overhead dominates small stages, so errors are largest for "
          f"deeply-folded points (p90 {s['p90_abs']:.2f}, gated at ceiling "
          f"{explore.get('max_model_error_p90')}) — the same reason the "
          f"paper reports HLS estimates diverging from RTL synthesis "
          f"results. The fit direction is stable: the CI gate holds "
          f"`model_error_p90` to its committed absolute ceiling.\n")

    cache = explore.get("cache") or {}
    if cache:
        w("### Synthesis-time cache: cold sweep vs warm replay\n")
        w(f"Cold `tune=\"auto\"` build (measures every candidate schedule "
          f"into an empty `ScheduleCache`): **{cache['cold_wall_s']:.2f} s** "
          f"({cache['cold_misses']} misses tuned). Warm `tune=\"cache\"` "
          f"rebuild of the same design from the filled cache: "
          f"**{cache['warm_wall_s']:.2f} s** ({cache['warm_hits']} hits, "
          f"{cache['warm_misses']} misses, nothing measured) — "
          f"**{cache['cache_speedup']:.1f}× faster**, the software analog "
          f"of the paper's ~10× synthesis-time saving from out-of-context "
          f"checkpoint reuse. CI-gated at an absolute "
          f"{explore.get('min_cache_speedup')}× floor (`floor_only`).\n")


def section_figures(w, figs, sweep, hm):
    w("\n## Paper-figure analogs\n")
    w("Rendered from the committed records by `scripts/make_experiments.py` "
      "(hand-rolled deterministic SVG — byte-stable, so CI can diff them).\n")
    if figs.get("resource"):
        w(f"![resource vs PE*SIMD]({figs['resource']})\n")
        claims = sweep.get("claims", {}) if sweep else {}
        w(f"Figs 8–13 analog: BRAM analog flat under folding "
          f"(`{claims.get('bram_flat_under_folding')}`) — weights don't move "
          f"when time-multiplexed; LUT/FF analogs grow with the PE·SIMD "
          f"datapath; cycles shrink (Eq. 1).\n")
    if figs.get("heatmap"):
        w(f"![heatmap]({figs['heatmap']})\n")
        if hm:
            deltas = [c["delta_lut_bytes"] for c in hm["cells"]]
            w(f"Fig 14 analog at N={hm['shape']['N']}, K={hm['shape']['K']}: "
              f"the XLA (HLS-analog) footprint exceeds the folded Pallas "
              f"working set by {min(deltas)}–{max(deltas)} bytes across the "
              f"grid; the gap narrows as PE·SIMD grows (the RTL side's "
              f"working set approaches the unfolded monolith).\n")
    if figs.get("synthesis"):
        w(f"![synthesis time]({figs['synthesis']})\n")
        w("Fig 16 analog: monolithic compile grows with chain depth; the "
          "modular+cached path is flat. The right-most pair is the "
          "explorer's end-to-end cold-vs-warm build.\n")


def section_autotune(w):
    w("\n## Autotuning — heuristic folding vs empirical schedule search\n")
    w("`repro.core.autotune` replaces the one-shot `choose_folding` + "
      "`to_tpu_blocks` heuristic with a measured design-space search: "
      "candidates from the layer's folding divisors (+ the pallas-vs-xla "
      "backend and the engine microbatch tile), VMEM-pruned and "
      "cycle-ordered by the analytic resource model, timed with the paired "
      "interleaved timer, winners committed to the per-config "
      "`TUNED_SCHEDULES` caches. `FusedEngine(tune=\"cache\")` consumes "
      "them with zero measurement at load time; "
      "`python -m benchmarks.autotune_gain` re-proves the end-to-end gain "
      "(CI-gated at the committed record's 1.15x floor).\n")
    gain = _load("experiments/bench/autotune_gain.json")
    if gain:
        w(f"End-to-end on `{gain['config']}` (batch {gain['batch']}): tuned "
          f"engine **{gain['speedup']:.2f}x** over the heuristic-default "
          f"engine, bit-exact={gain['bit_exact']}, "
          f"{gain['tuned_nodes']}/{gain['total_nodes']} nodes tuned, "
          f"microbatch tile {gain['microbatch_tile']}. "
          f"({gain.get('speedup_note', '')})\n")
    try:
        from repro.configs import cnv_bnn, nid_mlp
    except ImportError:
        return
    for title, mod in (("NID-MLP", nid_mlp), ("CNV (quick, xnor)", cnv_bnn)):
        sched = getattr(mod, "TUNED_SCHEDULES", {})
        node_rows = [(k, v) for k, v in sched.items()
                     if not k.startswith("engine|")]
        if not node_rows:
            continue
        w(f"\n### {title}: per-layer heuristic vs tuned schedule\n")
        w("| cache key (device\\|op\\|mode\\|N\\|K\\|epilogue\\|px) | "
          "tuned blocks (m, n, k-step/rows) | backend | node speedup |")
        w("|---|---|---|---|")
        for key, v in node_rows:
            if "|conv" in key:
                kk = f"rt={v.get('rows_per_tile', 'auto')}"
            elif "xnor" in key:
                kk = v["block_kw"]
            else:
                kk = v["block_k"]
            be = v["backend"] + (" (packed)" if v.get("packed") else "")
            w(f"| `{key}` | ({v['block_m']}, {v['block_n']}, {kk}) "
              f"| {be} | {v['speedup']:.2f}x |")
        for key, v in [(k, v) for k, v in sched.items()
                       if k.startswith("engine|")]:
            w(f"\nEngine-level: microbatch tile {v['microbatch']} "
              f"(tuned at batch {v['batch']}, {v['speedup']:.2f}x over "
              f"the heuristic plan).")
        w("")


def section_packed(w):
    gain = _load("experiments/bench/packed_gain.json")
    if not gain:
        return
    w("\n## Bit-packed XNOR/popcount datapath — packed vs canonical\n")
    w("`repro.kernels.mvu_packed` stores binarized weights as uint32 "
      "bitplanes (32 weights/word, the paper's Fig. 4a SIMD lane packing) "
      "and 2-bit weights as four-per-byte int8 lanes; the `pack_weights` "
      "build step rewrites storage after tuning, and the autotuner "
      "carries a packed-vs-unpacked axis per node (`\"packed\"` in the "
      "ScheduleCache entry, `|packed` key suffix). "
      "`python -m benchmarks.packed_gain` re-proves the gain; "
      "`--retune` regenerates the committed schedules.\n")
    w("| claim | value |")
    w("|---|---|")
    w(f"| packed engine vs canonical unpack+matmul (`{gain['config']}`, "
      f"batch {gain['batch']}) | **{gain['speedup']:.2f}x** "
      f"(floor {gain['min_speedup']:.2f}x) |")
    w(f"| bit-exact (both datapaths vs interpreter) | {gain['bit_exact']} |")
    w(f"| nodes on the packed datapath | {gain['packed_nodes']}"
      f"/{gain['total_nodes']} "
      f"({', '.join(gain.get('packed_node_names', []))}) |")
    w(f"| kernel backends selected | "
      f"{', '.join(gain.get('packed_backends', []))} |")
    w(f"| HBM-resident weight bytes, binary-mode NID-MLP | "
      f"{gain['binary_weight_bytes_packed']} packed vs "
      f"{gain['binary_weight_bytes_canonical']} canonical = "
      f"**{gain['weight_bytes_reduction']:.2f}x** "
      f"(floor {gain['min_weight_bytes_reduction']:.1f}x) |")
    w("\nThe xnor pallas kernel *is* the packed datapath (both operands "
      "are uint32 words through the popcount identity "
      "`dot = 2·popcount(~(a⊕w)) − K`), so its canonical comparator is "
      "the unpack+matmul XLA path; binary-mode layers gain the storage "
      "reduction (int8 rows → bitplanes, ≈8x at K=600) with the "
      "`2·(x·w01) − Σx` identity on the packed words.\n")


def section_build_reports(w):
    reports = sorted(glob.glob("experiments/build/*_build_report.json"))
    if not reports:
        return
    w("\n## Build pipeline (`repro.build`) — step reports\n")
    w("Every accelerator is produced by one "
      "`repro.build.build(graph, target=...)` call running a FINN-style "
      "list of named steps (lower → finalize → fold → fuse_epilogues → "
      "fuse_swu → tune → pack_weights → dataflow → engine [→ calibrate]), "
      "each graph "
      "rewrite verified bit-exact against the reference interpreter on "
      "a probe batch. The BuildReport below is the software analog of "
      "the paper's per-design resource/synthesis tables (field-by-field "
      "schema: docs/formats.md).\n")
    for path in reports:
        rep = _load(path)
        w(f"\n### `{rep['name']}` (target `{rep['target']}`)\n")
        edges = rep.get("edges") or []
        srcs = [s for s, _ in edges]
        if any(srcs.count(s) > 1 for s in set(srcs)):
            # a branched (fan-out) graph: show the full edge list
            w("Topology (DAG): " +
              ", ".join(f"`{s}->{d}`" for s, d in edges) + "\n")
        w("| step | wall s | verified | graph ops after |")
        w("|---|---|---|---|")
        for s in rep["steps"]:
            ops = ", ".join(f"{k}×{v}" for k, v in sorted(s["ops"].items()))
            ver = {True: "bit-exact", None: "—"}.get(s["verified"], "FAIL")
            w(f"| {s['name']} | {s['wall_s']:.3f} | {ver} | {ops} |")
        if rep.get("nodes"):
            w("\n| stage | op | branch | N | K | PE | SIMD | cycles "
              "| LUT-analog B | BRAM-analog B | weights | tuned |")
            w("|---|---|---|---|---|---|---|---|---|---|---|---|")
            for n in rep["nodes"]:
                wb, cwb = n.get("weight_bytes", 0), n.get("canonical_weight_bytes", 0)
                if n.get("packed") and cwb:
                    wcol = f"{wb} B packed ({cwb / max(wb, 1):.1f}x)"
                elif wb:
                    wcol = f"{wb} B"
                else:
                    wcol = "—"
                w(f"| {n['name']} | {n['op']} | {n.get('branch', 'main')} "
                  f"| {n['n']} | {n['k']} "
                  f"| {n['pe']} | {n['simd']} | {n['cycles']} "
                  f"| {n['lut_bytes']} | {n['bram_bytes']} "
                  f"| {wcol} "
                  f"| {'yes' if n['tuned'] else 'no'} |")
        pred, meas = rep.get("predicted_interval_s"), rep.get("measured_interval_s")
        line = (f"\nSteady-state interval: predicted "
                f"{pred * 1e6:.3f} µs (nominal 200 MHz)" if pred else "\n")
        if meas:
            line += (f", measured {meas * 1e6:.1f} µs "
                     f"({rep['cycle_time_source']} cycle time)")
        tune = rep.get("tune", {})
        if tune.get("mode", "off") != "off":
            line += (f"; autotune `{tune['mode']}`: "
                     f"{tune.get('cache_hits', 0)} cache hits, "
                     f"{tune.get('cache_misses', 0)} misses")
        w(line + f". Total build wall-clock {rep['total_wall_s']:.2f} s.")


def section_residual(w):
    res = _load("experiments/bench/residual_mlp.json")
    if not res:
        return
    w("\n## Residual graphs — fan-out/fan-in through the DAG IR\n")
    w("The IR is a DAG, not a chain: nodes carry named input edges, "
      "elementwise-binary joins (`add`/`sub`/`mul` with per-input scales "
      "and FINN-style trailing-dim broadcast) merge forked streams, and "
      "the dataflow schedule balances branch latencies with a skew FIFO "
      "at each join (`fifo = max(2, ceil(skew / interval))` — the "
      "software analog of FINN's FIFO sizing at residual joins). "
      "`benchmarks/residual_mlp.py` proves a skip-connection NID-MLP "
      "variant end-to-end; `examples/residual_mlp.py` walks the same "
      "graph through every build target.\n")
    w("Lowered topology: " +
      ", ".join(f"`{s}->{d}`" for s, d in res["edges"]) + "\n")
    w("| claim | value |")
    w("|---|---|")
    w(f"| bit-exact (engine vs DAG interpreter, batch {res['batch']}) "
      f"| {res['bit_exact']} |")
    w(f"| committed speedup floor | {res['speedup']:.1f}x "
      f"(min {res['min_speedup']:.1f}x) |")
    w(f"| steady-state interval | {res['interval_cycles']} cycles "
      f"(bottleneck `{res['bottleneck']}`) |")
    w(f"| critical path | {res['critical_path_cycles']} cycles "
      f"(longest path, not the stage sum) |")
    for j in res["joins"]:
        skew = max(j["branch_latency"]) - min(j["branch_latency"])
        w(f"| join `{j['name']}` | branches {j['branches']}, latencies "
          f"{j['branch_latency']} (skew {skew}) -> FIFO depth "
          f"{j['fifo_depth']} |")
    note = res.get("claim_note")
    if note:
        w(f"\n{note[0].upper()}{note[1:]}.\n")


def section_serving(w):
    sv = _load("experiments/bench/serving_load.json")
    if not sv:
        return
    w("\n## Serving load — continuous batching vs submit/flush\n")
    w("`repro.serving` fronts the fused engine with a bounded admission "
      "queue, a continuous batcher (flush on bucket-fill / pipeline-idle "
      "/ deadline-slack, the budget derived from "
      "`DataflowSchedule.steady_state_interval` via "
      "`dataflow.interval_seconds` with the measured cycle time), and a "
      "multi-replica pool (params `device_put` per device, least-loaded "
      "async dispatch).  `python -m benchmarks.serving_load` drives it "
      "and the legacy cadence-flushed `EngineServer` with the same "
      "open-loop Poisson arrivals; the committed record is CI-gated on "
      ">=1.0x throughput (`min_speedup`) AND strictly-better p99 "
      "(`lower_is_better: p99_vs_server`, ceiling 1.0).\n")
    w(f"Open-loop Poisson on `{sv['config']}` ({sv['requests']} requests "
      f"at {sv['rate_hz']:.0f}/s, SLO {sv['slo_ms']:.0f} ms, buckets "
      f"{sv['buckets']}):\n")
    w("| metric | continuous (`repro.serving`) | legacy `EngineServer` |")
    w("|---|---|---|")
    w(f"| p50 latency | {sv['serving_p50_ms']:.2f} ms "
      f"| {sv['server_p50_ms']:.2f} ms |")
    w(f"| p99 latency | {sv['serving_p99_ms']:.2f} ms "
      f"| {sv['server_p99_ms']:.2f} ms |")
    w(f"| deadline miss rate | {sv['serving_deadline_miss_rate']:.1%} "
      f"| {sv['server_deadline_miss_rate']:.1%} |")
    w(f"| open-loop completion | {sv['serving_samples_per_s']:.0f} "
      f"samples/s | {sv['server_samples_per_s']:.0f} samples/s |")
    w(f"| closed-loop saturation | "
      f"{sv['closed_loop_serving_samples_per_s']:.0f} samples/s | "
      f"{sv['closed_loop_server_samples_per_s']:.0f} samples/s |")
    note = sv.get("claim_note")
    w(f"\nCommitted claim: **{sv['speedup']:.2f}x** open-loop throughput, "
      f"p99 at **{sv['p99_vs_server']:.2f}x** the legacy server's, "
      f"bit_exact={sv['bit_exact']}."
      + (f" ({note})\n" if note else "\n"))


def section_chaos(w):
    ch = _load("experiments/bench/chaos_serving.json")
    if not ch:
        return
    w("\n## Chaos serving — self-healing under a committed fault plan\n")
    plan = ch.get("fault_plan", {})
    rates = ", ".join(f"{k} {v:.0%}" for k, v in plan.get("rates", {}).items())
    events = ", ".join(f"{e['kind']}@(r{e['replica']},d{e['at_dispatch']})"
                       for e in plan.get("events", []))
    w(f"`python -m benchmarks.chaos_serving` drives the hardened serving "
      f"path (default `FaultPolicy` + hedging) and the pre-hardening "
      f"baseline (`FaultPolicy.disabled()`) through the same "
      f"{ch['requests']}-request Poisson load on {ch['replicas']} logical "
      f"replicas, both injected with the identical committed `FaultPlan` "
      f"(seed {plan.get('seed')}; per-dispatch rates: {rates}; scripted "
      f"events: {events}). Draws are pure functions of "
      f"`(seed, replica, dispatch_index)`, so the schedule replays exactly "
      f"(schema: docs/formats.md).\n")
    w("| claim (CI-gated, absolute) | hardened | baseline (same plan) |")
    w("|---|---|---|")
    w(f"| corrupted results delivered (ceiling {ch['max_corrupted_delivered']}) "
      f"| **{ch['corrupted_delivered']}** "
      f"| {ch['baseline_corrupted_delivered']} |")
    w(f"| gold-tier completion within deadline "
      f"(floor {ch['min_gold_completion_rate']:.0%}) "
      f"| **{ch['gold_completion_rate']:.1%}** "
      f"| {ch['baseline_gold_completion_rate']:.1%} |")
    w(f"| requests stuck forever (hung replica) | {ch['stuck_requests']} "
      f"| {ch['baseline_stuck_requests']} |")
    w(f"| availability (completed/submitted) | {ch['availability']:.1%} "
      f"| {ch['baseline_availability']:.1%} |")
    w(f"\nThe same plan breaks the baseline in "
      f"**{ch['baseline_failure_modes']}** distinct mode(s) (floor "
      f"{ch['min_baseline_failure_modes']}) — the A/B proof the hardening "
      f"is load-bearing. Hardened-arm mechanics over the run: "
      f"{ch['retries']} retries, {ch['hedges']} hedges "
      f"({ch['hedge_wins']} won), {ch['corrupt_batches_caught']} corrupt "
      f"batches caught by the integrity guard, {ch['quarantines']} "
      f"quarantines, {ch['probes']} canary probes, {ch['recoveries']} "
      f"recoveries; p99 {ch['p99_ms']:.1f} ms against a "
      f"{ch['slo_ms']:.0f} ms SLO. Nightly CI re-runs this as a long soak "
      f"at doubled fault rates.\n")
    if "trace_events" in ch:
        ann = ch.get("trace_annotations", {})
        ann_s = ", ".join(f"{k} {v}" for k, v in sorted(ann.items()) if v)
        w(f"The hardened arm runs fully traced (docs/observability.md): "
          f"{ch['trace_events']} events on the Chrome trace "
          f"({ch['trace_dropped']} dropped by the bounded buffer), fault "
          f"machinery visible as instants ({ann_s} — "
          f"`trace_fault_annotations` gated at floor "
          f"{ch['min_trace_fault_annotations']}), and the live "
          f"`DriftMonitor` latched {ch.get('drift_flagged_ever', [])} into "
          f"`flagged_ever` — the scripted straggle replica among them "
          f"(`straggler_flagged` gated at floor "
          f"{ch['min_straggler_flagged']}). CI uploads the trace JSON as an "
          f"artifact.\n")


def section_telemetry(w):
    ov = _load("experiments/bench/telemetry_overhead.json")
    if not ov:
        return
    w("\n## Telemetry overhead — the zero-overhead-when-disabled contract\n")
    w(f"`python -m benchmarks.telemetry_overhead` pairs tracer-off and "
      f"tracer-on rounds of the same arrival-paced Poisson serving load "
      f"({ov['requests']} requests at {ov['rate_hz']:.0f}/s, "
      f"{ov['load']:.0%} of one-replica capacity, {ov['rounds']} paired "
      f"rounds, median ratio). The off arm runs the identical instrumented "
      f"code with every `tracer=None` guard disabled — the "
      f"zero-overhead-when-disabled measurement — and the on arm records "
      f"the full request lifecycle ({ov['trace_events_per_run']} events "
      f"per run).\n")
    w("| metric | value |")
    w("|---|---|")
    w(f"| tracing overhead (gated ceiling "
      f"{ov['max_tracing_overhead']:.0%}) | "
      f"**{ov['tracing_overhead'] * 100:.2f}%** |")
    w(f"| per-event emit cost | {ov['emit_cost_us']:.2f} µs |")
    w(f"| completion throughput off / on | "
      f"{ov['off_samples_per_s']:.0f} / {ov['on_samples_per_s']:.0f} "
      f"samples/s |")
    w(f"| p99 on vs off | {ov['p99_on_vs_off']:.2f}x |")
    w(f"\nThe quick serving-load gate also runs `--traced` (the continuous "
      f"arm with a live tracer), so the committed throughput/p99 claims "
      f"hold with telemetry enabled, not just in a dedicated benchmark.\n")


def section_appendix(w, sweep):
    large = sweep.get("large") if sweep else None
    if not large:
        return
    w("\n## Appendix: Table 3/4 large-design convergence\n")
    w("| IFM ch | RTL LUT-analog bytes | HLS temp bytes | RTL FF bytes |")
    w("|---|---|---|---|")
    for r in large:
        w(f"| {r['value']} | {r['rtl_lut_bytes']} "
          f"| {r.get('hls_temp_bytes', '—')} | {r['rtl_ff_bytes']} |")


def main():
    sweep = _load("experiments/bench/resource_sweep.json")
    crit = _load("experiments/bench/critical_path.json")
    synth = _load("experiments/bench/synthesis_time.json")
    hm = _load("experiments/bench/heatmap.json")
    nid = _load("experiments/bench/nid_mlp.json")
    explores = sorted(glob.glob("experiments/explore/*_explore.json"))
    explore = _load(explores[0]) if explores else None

    figs = {
        "resource": fig_resource_curve(sweep),
        "heatmap": fig_heatmap(hm),
        "interval": fig_interval(explore),
        "synthesis": fig_synthesis(synth, explore),
    }

    doc = []
    w = doc.append
    w("# EXPERIMENTS\n")
    w("Rendered from committed artifacts by `scripts/make_experiments.py` — "
      "CI regenerates this file and the figures and fails on drift. To "
      "refresh the underlying records:\n")
    w("```\npython -m benchmarks.run --out-dir experiments/bench\n"
      "python -m repro.explore --config nid_mlp --quick\n"
      "python scripts/make_experiments.py\n```\n")
    w("Hardware model: TPU v5e — 197 TFLOP/s bf16 (394 TOP/s int8), "
      "819 GB/s HBM, 16 GB HBM/chip; numbers in this file are measured on "
      "the CPU interpret path (correctness + structure, not TPU "
      "projections). Metric mapping: DESIGN.md (LUT→VMEM working set, "
      "FF→accumulator state, BRAM→weight store, synthesis time→compile/"
      "tune wall-clock).\n")

    section_claims(w, sweep, crit, synth, nid)
    section_explore(w, explore, figs)
    section_figures(w, figs, sweep, hm)
    section_autotune(w)
    section_packed(w)
    section_build_reports(w)
    section_residual(w)
    section_serving(w)
    section_chaos(w)
    section_telemetry(w)
    section_appendix(w, sweep)

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(doc) + "\n")
    n_figs = sum(1 for p in figs.values() if p)
    print(f"EXPERIMENTS.md written ({len(doc)} blocks, {n_figs} figures)")


if __name__ == "__main__":
    main()
