"""Integration tests for the self-healing serving path: injected dispatch
failures / hangs / stragglers / corruption / replica death against the
hardened ContinuousBatcher + ReplicaPool, plus the A/B contract that
``FaultPolicy.disabled()`` reproduces the pre-hardening behavior (minus
silently dropped rids, which are unconditionally fixed)."""

import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import lowering
from repro.core.engine import FusedEngine
from repro.core.ir import Node
from repro.serving import (
    BEST_EFFORT,
    ContinuousBatcher,
    FaultEvent,
    FaultPlan,
    FaultPolicy,
    ReplicaPool,
)
from repro.serving.health import QUARANTINED


def _mlp_graph(dims=(24, 16, 8), bits=2, seed=3):
    rng = np.random.default_rng(seed)
    g = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(dims) - 2:
            g.append(Node("quant_act", f"act{i}", {"bits": bits, "act_scale": 1.0}))
    return lowering.finalize(
        lowering.lower_to_mvu(g, mode="standard", weight_bits=4, act_bits=bits))


def _samples(n, k=24, bits=2, seed=0):
    return np.random.default_rng(seed).integers(0, 2**bits, (n, k)).astype(np.int32)


def _setup(policy, faults=None, *, n_replicas=2, buckets=(1, 4, 8), **kw):
    """Engine + batcher over ``n_replicas`` LOGICAL replicas on one device
    (the chaos substrate -- fault schedules are per logical replica)."""
    engine = FusedEngine(_mlp_graph())
    d = jax.local_devices()[0]
    pool = ReplicaPool(engine, devices=[d] * n_replicas, faults=faults,
                       policy=policy)
    batcher = ContinuousBatcher(engine, batch_buckets=buckets, pool=pool,
                                fault_policy=policy, **kw)
    return engine, batcher


# ------------------------------------------- satellite: no rid ever dropped
def test_injected_dispatch_failure_retries_to_completion():
    """A failed dispatch re-enqueues its whole batch; the retry lands on a
    healthy replica and every result is bit-exact -- no rid dropped."""
    plan = FaultPlan(seed=0, events=[FaultEvent("error", replica=0, at_dispatch=0)])
    engine, batcher = _setup(FaultPolicy(max_retries=2), plan)
    xs = _samples(8)
    rids = batcher.submit_batch(xs)
    batcher.drain(timeout=60)
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(batcher.results[rid].out, want[i])
    c = batcher.metrics.counters
    assert c["dispatch_failures"] == 1 and c["retries"] == 8
    assert c["completed"] == 8 and c["shed"] == 0


def test_real_dispatch_exception_does_not_lose_the_batch():
    """Regression for the original bug: an exception out of engine.dispatch
    used to propagate with the popped entries lost forever."""
    engine, batcher = _setup(FaultPolicy(max_retries=2), n_replicas=1)
    real, tripped = engine.dispatch, {"n": 0}

    def flaky(x, params=None):
        if tripped["n"] == 0:
            tripped["n"] += 1
            raise RuntimeError("transient device error")
        return real(x, params=params)

    engine.dispatch = flaky
    xs = _samples(4)
    rids = batcher.submit_batch(xs)
    batcher.drain(timeout=60)
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(batcher.results[rid].out, want[i])
    assert batcher.metrics.counters["dispatch_failures"] == 1


def test_exhausted_retries_resolve_as_shed_never_dropped():
    plan = FaultPlan(seed=1, rates={"error": 1.0})  # every dispatch fails
    _, batcher = _setup(FaultPolicy(max_retries=1), plan)
    rids = batcher.submit_batch(_samples(8))
    batcher.drain(timeout=60)
    assert sorted(batcher.results) == rids  # every rid resolved...
    assert all(batcher.results[r].shed for r in rids)  # ...as shed
    assert batcher.metrics.counters["completed"] == 0
    assert batcher.metrics.availability() == 0.0


def test_disabled_policy_still_resolves_failed_dispatch_as_shed():
    """The satellite fix is unconditional: even the pre-hardening baseline
    policy must not silently drop a batch whose dispatch raised."""
    plan = FaultPlan(seed=2, rates={"error": 1.0})
    _, batcher = _setup(FaultPolicy.disabled(), plan)
    rids = batcher.submit_batch(_samples(4))
    batcher.drain(timeout=60)
    assert sorted(batcher.results) == rids
    assert all(batcher.results[r].shed for r in rids)
    assert batcher.metrics.counters["retries"] == 0  # but no retries either


# --------------------------------------- satellite: harvest/drain timeouts
def test_harvest_timeout_names_the_hung_replica():
    plan = FaultPlan(seed=0, events=[FaultEvent("hang", replica=0, at_dispatch=0)])
    # no dispatch timeout: nothing recovers the hang automatically, the
    # explicit harvest timeout is the only way out
    _, batcher = _setup(FaultPolicy(dispatch_timeout_s=None), plan,
                        n_replicas=1)
    batcher.submit_batch(_samples(4))
    batcher.flush_all()
    with pytest.raises(TimeoutError, match=r"replica\(s\) \[0\]"):
        batcher.harvest(block=True, timeout=0.05)


def test_drain_timeout_bounds_a_hung_replica():
    plan = FaultPlan(seed=0, events=[FaultEvent("hang", replica=0, at_dispatch=0)])
    _, batcher = _setup(FaultPolicy(dispatch_timeout_s=None), plan,
                        n_replicas=1)
    batcher.submit_batch(_samples(4))
    with pytest.raises(TimeoutError):
        batcher.drain(timeout=0.05)


def test_dispatch_timeout_quarantines_and_redispatches():
    """With the policy timeout armed the hang self-heals: the replica is
    quarantined, the batch re-executes elsewhere, results stay bit-exact."""
    plan = FaultPlan(seed=0, events=[FaultEvent("hang", replica=0, at_dispatch=0)])
    engine, batcher = _setup(
        FaultPolicy(dispatch_timeout_s=0.05, probe_backoff_s=100.0), plan)
    xs = _samples(8)
    rids = batcher.submit_batch(xs)
    batcher.drain(timeout=60)
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(batcher.results[rid].out, want[i])
    c = batcher.metrics.counters
    assert c["timeouts"] == 1 and c["quarantines"] >= 1
    assert batcher.pool.replicas[0].health.state == QUARANTINED


# ----------------------------------------------------------------- hedging
def test_hedged_dispatch_first_bit_exact_result_wins():
    plan = FaultPlan(seed=0, events=[
        FaultEvent("straggle", replica=0, at_dispatch=0, delay_s=0.5)])
    engine, batcher = _setup(
        FaultPolicy(hedging=True, hedge_after_s=0.02, dispatch_timeout_s=None),
        plan)
    xs = _samples(8)
    rids = batcher.submit_batch(xs)
    t0 = time.perf_counter()
    batcher.drain(timeout=60)
    elapsed = time.perf_counter() - t0
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(batcher.results[rid].out, want[i])
    c = batcher.metrics.counters
    assert c["hedges"] == 1 and c["hedge_wins"] == 1
    assert elapsed < 0.4  # the hedge beat the 0.5s straggler


# --------------------------------------------------------- integrity guard
def test_corrupted_batch_quarantines_and_reexecutes_bit_exact():
    plan = FaultPlan(seed=0, events=[FaultEvent("corrupt", replica=0, at_dispatch=0)])
    engine, batcher = _setup(FaultPolicy(probe_backoff_s=100.0), plan)
    xs = _samples(8)
    rids = batcher.submit_batch(xs)
    batcher.drain(timeout=60)
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(batcher.results[rid].out, want[i])
    c = batcher.metrics.counters
    assert c["corrupt_batches"] == 1 and c["quarantines"] == 1
    assert batcher.pool.replicas[0].health.quarantine_reason.startswith("integrity")


def test_disabled_policy_delivers_the_corruption_baseline():
    """The A/B contract the chaos benchmark rests on: without the guard the
    corrupted batch is delivered as-is."""
    plan = FaultPlan(seed=0, events=[FaultEvent("corrupt", replica=0, at_dispatch=0)])
    engine, batcher = _setup(FaultPolicy.disabled(), plan, n_replicas=1)
    xs = _samples(4)
    rids = batcher.submit_batch(xs)
    batcher.drain(timeout=60)
    want = np.asarray(engine(jnp.asarray(xs)))
    got = np.stack([batcher.results[r].out for r in rids])
    assert not np.array_equal(got, want)  # corrupted result reached a client


# ------------------------------------------------------------ replica death
def test_replica_death_fails_over_and_completes():
    plan = FaultPlan(seed=0, events=[FaultEvent("die", replica=0, at_dispatch=0)])
    engine, batcher = _setup(FaultPolicy(max_retries=3, probe_backoff_s=100.0),
                             plan)
    xs = _samples(12)
    rids = batcher.submit_batch(xs)
    batcher.drain(timeout=60)
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(batcher.results[rid].out, want[i])
    assert batcher.pool.replicas[0].health.dead


# ------------------------------------------------------------ canary probes
def test_canary_probe_recovers_a_transiently_failing_replica():
    plan = FaultPlan(seed=0, events=[
        FaultEvent("error", replica=0, at_dispatch=k) for k in range(3)])
    engine, batcher = _setup(FaultPolicy(max_retries=2, probe_backoff_s=0.01),
                             plan, n_replicas=1)
    rid = batcher.submit(_samples(1)[0])
    batcher.drain(timeout=60)
    assert batcher.results[rid].shed  # all three attempts hit the fault
    pool = batcher.pool
    for _ in range(100):
        if pool.healthy_count:
            break
        time.sleep(0.01)
        pool.maintain()
    assert pool.healthy_count == 1 and pool.recoveries == 1
    assert pool.replicas[0].health.recoveries == 1
    # the recovered replica serves bit-exact again
    x = _samples(2, seed=9)
    rid2 = batcher.submit(x[0])
    batcher.drain(timeout=60)
    np.testing.assert_array_equal(
        batcher.results[rid2].out, np.asarray(engine(jnp.asarray(x[:1])))[0])


def test_deadline_aware_retry_sheds_instead_of_retrying_past_slo():
    plan = FaultPlan(seed=0, events=[FaultEvent("error", replica=0, at_dispatch=0)])
    _, batcher = _setup(FaultPolicy(max_retries=5), plan, n_replicas=1)
    rid = batcher.submit(_samples(1)[0], deadline=1.0, now=0.0)
    batcher.poll(now=2.0)  # past the deadline: launch fails, no retry
    r = batcher.results[rid]
    assert r.shed and batcher.metrics.counters["retries"] == 0
    assert batcher.metrics.counters["shed"] == 1


# ----------------------------------------------------------------- brownout
def test_brownout_sheds_best_effort_and_shrinks_buckets():
    policy = FaultPolicy(probe_backoff_s=100.0, brownout_cooldown_s=100.0)
    engine, batcher = _setup(policy, buckets=(1, 4, 8))
    be = batcher.submit_batch(_samples(2), tier=BEST_EFFORT)
    for r in batcher.pool.replicas:
        batcher.pool.quarantine(r, "test")
    batcher.poll()  # healthy_frac 0 -> severe brownout
    assert batcher.metrics.brownout_level == 2
    assert batcher.active_buckets == (1, 4)  # largest bucket retired
    # the queued best-effort work was dropped on entry
    assert all(batcher.results[r].shed for r in be)
    assert batcher.metrics.counters["brownout_shed"] == 2
    # fresh best-effort arrivals shed at the front door, gold still lands
    door = batcher.submit(_samples(1)[0], tier=BEST_EFFORT)
    assert batcher.results[door].shed
    x = _samples(1, seed=7)
    gold = batcher.submit(x[0])
    assert batcher.queue.depth == 1
    batcher.drain(timeout=60)  # full quarantine: fallback dispatch serves gold
    np.testing.assert_array_equal(
        batcher.results[gold].out, np.asarray(engine(jnp.asarray(x)))[0])


# --------------------------------------------------- zero-overhead-healthy
def test_no_faults_means_no_fault_side_effects():
    """Fault handling enabled + healthy replicas: bit-exact results, every
    fault counter zero, availability 1.0 (the zero-overhead claim)."""
    engine, batcher = _setup(FaultPolicy(hedging=True))
    xs = _samples(13)
    rids = batcher.submit_batch(xs)
    batcher.drain(timeout=60)
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(batcher.results[rid].out, want[i])
    c = batcher.metrics.counters
    for key in ("dispatch_failures", "retries", "hedges", "hedge_wins",
                "timeouts", "corrupt_batches", "quarantines", "probes",
                "brownout_shed", "shed", "rejected"):
        assert c[key] == 0, key
    assert batcher.metrics.availability() == 1.0
    snap = batcher.pool.health_snapshot()
    assert snap["healthy"] == snap["total"] == 2


def test_pick_skips_quarantined_replicas():
    _, batcher = _setup(FaultPolicy(probe_backoff_s=100.0))
    pool = batcher.pool
    pool.quarantine(pool.replicas[0], "test")
    rids = batcher.submit_batch(_samples(8))
    batcher.drain(timeout=60)
    assert pool.load()[0] == 0 and pool.load()[1] > 0
    assert all(not batcher.results[r].shed for r in rids)


def test_accelerator_serve_plumbs_fault_policy():
    from repro.build import build

    rng = np.random.default_rng(0)
    raw = [Node("input", "in", {"shape": (24,), "bits": 2}),
           Node("linear", "fc0", {},
                {"w": jnp.asarray(rng.normal(0, 0.5, (8, 24)).astype(np.float32))})]
    acc = build(raw, target="engine", verify="off", tune="off")
    b = acc.serve(warmup=False, fault_policy=FaultPolicy.disabled())
    assert not b.fault_policy.enabled and not b.pool.policy.enabled
    b2 = acc.serve(warmup=False)
    assert b2.fault_policy.enabled  # hardened by default
