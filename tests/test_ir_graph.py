"""The DAG graph algebra: topological iteration, branch labeling,
elementwise-binary broadcast semantics, the DAG interpreter, and the
illegal-graph diagnostics (cycle, dangling edge, multi-sink, arity)."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dataflow, ir
from repro.core.ir import Graph, Node


def _input(shape=(16,), bits=2, name="in"):
    return Node("input", name, {"shape": shape, "bits": bits})


def _linear(name, n, k, src=None):
    w = jnp.asarray(np.arange(n * k).reshape(n, k) % 5 - 2, jnp.float32)
    return Node("linear", name, {}, {"w": w},
                inputs=(src,) if src else None)


# ------------------------------------------------------------ graph algebra
def test_as_graph_materializes_chain_edges():
    g = [_input(), _linear("fc0", 4, 16), Node("quant_act", "a", {"bits": 2})]
    eg = ir.as_graph(g)
    assert [n.inputs for n in eg] == [(), ("in",), ("fc0",)]
    # explicit edges pass through untouched; attrs/params dicts are shared
    assert eg[1].params is g[1].params
    g2 = ir.as_graph(eg)
    assert [n.inputs for n in g2] == [(), ("in",), ("fc0",)]


def test_toposort_is_stable_for_chains_and_orders_dags():
    chain = [_input(), _linear("fc0", 4, 16), _linear("fc1", 4, 4)]
    assert [n.name for n in ir.toposort(chain)] == ["in", "fc0", "fc1"]
    # authoring order scrambled; topo order must respect edges
    dag = Graph([
        Node("add", "res", {}, inputs=("a1", "a0")),
        Node("quant_act", "a1", {"bits": 2}, inputs=("fc1",)),
        _linear("head", 2, 4, "res"),
        _input(),
        Node("quant_act", "a0", {"bits": 2}, inputs=("fc0",)),
        _linear("fc0", 4, 16, "in"),
        _linear("fc1", 4, 4, "a0"),
    ])
    names = [n.name for n in ir.toposort(dag)]
    for src, dst in ir.edge_list(dag):
        assert names.index(src) < names.index(dst)


def test_cycle_diagnostic_names_the_nodes():
    g = Graph([
        _input(),
        _linear("fc0", 4, 16, "fc1"),
        _linear("fc1", 4, 4, "fc0"),
    ])
    with pytest.raises(ValueError, match=r"cycle through.*'fc0'.*'fc1'"):
        ir.validate_graph(g)


def test_dangling_edge_diagnostic():
    g = Graph([_input(), _linear("fc0", 4, 16, "ghost")])
    with pytest.raises(ValueError,
                       match=r"node 'fc0' \(linear\): dangling input edge "
                             r"from 'ghost'"):
        ir.validate_graph(g)


def test_dangling_branch_diagnostic():
    # fc1 forks off but nothing consumes it: two sinks
    g = Graph([_input(), _linear("fc0", 4, 16, "in"),
               _linear("fc1", 4, 4, "fc0"), _linear("fc2", 4, 4, "fc0")])
    with pytest.raises(ValueError, match=r"exactly one output \(sink\).*"
                                         r"dangling branch"):
        ir.validate_graph(g)


def test_eltwise_arity_diagnostic():
    g = Graph([_input(), _linear("fc0", 4, 16, "in"),
               Node("add", "res", {}, inputs=("fc0",))])
    with pytest.raises(ValueError,
                       match=r"node 'res' \(add\): 'add' takes exactly 2 "
                             r"inputs, got 1"):
        ir.validate_graph(g)


def test_branch_labels_name_fork_arms():
    g = Graph([
        _input(),
        _linear("fc0", 16, 16, "in"),
        _linear("fc1", 16, 16, "fc0"),   # arm A (through one more layer)
        _linear("fc2", 16, 16, "fc1"),
        Node("add", "res", {}, inputs=("fc2", "fc0")),  # arm B is direct
        _linear("head", 2, 16, "res"),
    ])
    labels = ir.branch_labels(g)
    assert labels["fc0"] == "main"
    assert labels["fc1"] == "fc0/fc1"
    assert labels["fc2"] == "fc0/fc1"      # inherited along the arm
    assert labels["res"] == "main"         # joins return to the trunk
    assert labels["head"] == "main"


def test_graph_output_and_edges():
    g = Graph([_input(), _linear("fc0", 4, 16, "in")])
    assert ir.graph_output(g).name == "fc0"
    assert ir.edge_list(g) == [["in", "fc0"]]


# ------------------------------------------------------- shape propagation
def test_broadcast_shapes():
    assert ir.broadcast_shapes((64,), (64,)) == (64,)
    assert ir.broadcast_shapes((8, 8, 4), (4,)) == (8, 8, 4)
    assert ir.broadcast_shapes((8, 8, 4), (1,)) == (8, 8, 4)
    assert ir.broadcast_shapes((1,), (8, 8, 4)) == (8, 8, 4)
    with pytest.raises(ValueError, match=r"cannot broadcast.*\(64,\).*\(32,\)"):
        ir.broadcast_shapes((64,), (32,))


def test_propagate_multi_input_and_infer_shapes():
    res = Node("add", "res", {}, inputs=("a", "b"))
    assert ir.propagate(res, (64,), (64,)) == (64,)
    assert ir.propagate(res, (8, 8, 4), (4,)) == (8, 8, 4)
    with pytest.raises(ValueError, match="exactly 2 input shapes"):
        ir.propagate(res, (64,))
    g = Graph([
        _input(),
        _linear("fc0", 8, 16, "in"),
        _linear("fc1", 8, 8, "fc0"),
        Node("add", "res", {}, inputs=("fc1", "fc0")),
    ])
    assert ir.infer_shapes(g) == {
        "in": (16,), "fc0": (8,), "fc1": (8,), "res": (8,)}
    rows = ir.io_shapes(g)
    assert [(n.name, ins, out) for n, ins, out in rows] == [
        ("in", (), (16,)), ("fc0", ((16,),), (8,)),
        ("fc1", ((8,),), (8,)), ("res", ((8,), (8,)), (8,))]


def test_eltwise_broadcast_fails_validation_when_illegal():
    g = Graph([
        _input((16,)),
        _linear("fc0", 8, 16, "in"),
        _linear("fc1", 4, 8, "fc0"),
        Node("add", "res", {}, inputs=("fc1", "fc0")),  # (4,) + (8,)
    ])
    with pytest.raises(ValueError,
                       match=r"node 'res' \(add\): cannot broadcast"):
        ir.validate_graph(g)


# --------------------------------------------------------- DAG interpreter
def test_eltwise_semantics_add_sub_mul_with_scales():
    a = jnp.asarray([[1, 2, 3]], jnp.int32)
    b = jnp.asarray([[10, 20, 30]], jnp.int32)
    for op, want in [("add", [[21, 42, 63]]),
                     ("sub", [[-19, -38, -57]]),
                     ("mul", [[20, 80, 180]])]:
        node = Node(op, "e", {"scales": (1, 2)}, inputs=("x", "y"))
        _, fn = dataflow.node_runner(node)
        np.testing.assert_array_equal(np.asarray(fn(None, a, b)), want)


def test_eltwise_broadcasts_trailing_dims_not_batch():
    # (B, H, W, C) + (B, C): the (C,) sample shape aligns to the trailing
    # channel dim, never to the batch axis
    x = jnp.asarray(np.arange(2 * 2 * 2 * 3).reshape(2, 2, 2, 3), jnp.int32)
    y = jnp.asarray([[1, 2, 3], [10, 20, 30]], jnp.int32)
    node = Node("add", "e", {}, inputs=("x", "y"))
    _, fn = dataflow.node_runner(node)
    got = np.asarray(fn(None, x, y))
    want = np.asarray(x) + np.asarray(y)[:, None, None, :]
    np.testing.assert_array_equal(got, want)


def test_trace_and_execute_run_branched_graphs():
    from repro.core import lowering

    g = Graph([
        _input((4,)),
        _linear("fc0", 4, 4, "in"),
        Node("add", "res", {}, inputs=("fc0", "in")),
    ])
    low = lowering.finalize(lowering.lower_to_mvu(g, mode="standard",
                                                  weight_bits=2, act_bits=2))
    x = jnp.asarray([[1, 0, 2, 1]], jnp.float32)
    env = dataflow.trace(low, x)
    assert set(env) == {"in", "fc0.mvu", "res"}
    np.testing.assert_array_equal(
        np.asarray(env["res"]), np.asarray(env["fc0.mvu"] + env["in"]))
    np.testing.assert_array_equal(np.asarray(dataflow.execute(low, x)),
                                  np.asarray(env["res"]))


def test_trace_multi_input_graph_takes_a_feed_dict():
    g = Graph([
        _input((4,), name="xa"),
        _input((4,), name="xb"),
        Node("add", "res", {}, inputs=("xa", "xb")),
    ])
    xa = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    xb = jnp.asarray([[10, 20, 30, 40]], jnp.int32)
    with pytest.raises(ValueError, match="2 input nodes"):
        dataflow.trace(g, xa)
    got = dataflow.execute(g, {"xa": xa, "xb": xb})
    np.testing.assert_array_equal(np.asarray(got), [[11, 22, 33, 44]])


def test_schedule_reports_branch_joins():
    from repro.core import lowering

    # both arms of the fork carry MVU stages: the long arm two, the short
    # arm one, so the critical path differs from the sum over all stages
    g = Graph([
        _input((16,)),
        _linear("fc0", 16, 16, "in"),
        _linear("fc1", 16, 16, "fc0"),
        _linear("fc2", 16, 16, "fc1"),
        _linear("fc3", 16, 16, "fc0"),
        Node("add", "res", {}, inputs=("fc2", "fc3")),
        _linear("head", 2, 16, "res"),
    ])
    low = lowering.finalize(lowering.lower_to_mvu(g, mode="standard",
                                                  weight_bits=2, act_bits=2))
    sched = dataflow.schedule(low)
    assert len(sched.joins) == 1
    j = sched.joins[0]
    assert j.name == "res"
    # the two-layer arm accumulates more latency than the direct edge, and
    # the skew FIFO must cover the difference (>= the floor of 2)
    assert j.branch_latency[0] != j.branch_latency[1]
    assert j.fifo_depth >= 2
    assert sched.summary()["joins"][0]["name"] == "res"
    # critical path: latency is the longest path, not the sum of all stages
    assert sched.latency_cycles < sum(s.cycles for s in sched.stages)
