import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import packing


@settings(max_examples=30, deadline=None)
@given(
    st.integers(1, 4),  # rows
    st.integers(1, 130),  # K bits (crosses word boundaries)
    st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip(rows, k, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (rows, k)).astype(np.int32)
    packed = packing.pack_bits(jnp.asarray(bits))
    assert packed.shape == (rows, packing.num_words(k))
    assert packed.dtype == jnp.uint32
    back = np.asarray(packing.unpack_bits(packed, k))
    np.testing.assert_array_equal(back, bits)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_xnor_popcount_identity(k, seed):
    """2*popcount(~(a^w)) - K equals the bipolar dot product (padded-K form)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, (k,)).astype(np.int32)
    w = rng.integers(0, 2, (k,)).astype(np.int32)
    ap = packing.pack_bits(jnp.asarray(a))
    wp = packing.pack_bits(jnp.asarray(w))
    pc = int(np.sum(np.asarray(packing.popcount(~(ap ^ wp)))))
    kp = packing.padded_bits(k)
    dot = 2 * pc - kp - (kp - k)
    want = int(((2 * a - 1) * (2 * w - 1)).sum())
    assert dot == want


@settings(max_examples=30, deadline=None)
@given(
    st.integers(1, 4),  # rows
    st.integers(1, 19),  # K lanes (crosses byte boundaries)
    st.integers(0, 2**31 - 1),
)
def test_int2_pack_unpack_roundtrip(rows, k, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(-2, 2, (rows, k)).astype(np.int32)  # [-2, 1]
    packed = packing.pack_int2(jnp.asarray(vals))
    assert packed.shape == (rows, packing.num_int2_bytes(k))
    assert packed.dtype == jnp.uint8
    back = np.asarray(packing.unpack_int2(packed, k))
    np.testing.assert_array_equal(back, vals)


def test_pack_bits_masks_to_lsb():
    """Multi-bit inputs (e.g. 2-bit activations on a 1-bit layer) must not
    leak into neighboring/pad bit positions -- that silently breaks the
    XNOR/popcount pad-correction identity."""
    vals = jnp.asarray([[2, 3, 0, 1, 2]])  # value 2 has LSB 0
    packed = packing.pack_bits(vals)
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_bits(packed, 5)), [[0, 1, 0, 1, 0]]
    )
    # pad bits of the last word stay zero even with multi-bit inputs
    assert int(packed[0, 0]) == 0b01010


def test_pad_correction():
    # whole-word K degrades to the textbook 2*pc - K
    assert packing.pad_correction(64) == 64
    # K=600 pads to 608: Kp + (Kp - K)
    assert packing.pad_correction(600) == 608 + 8
    # kernels pass their block-padded width explicitly
    assert packing.pad_correction(600, 640) == 640 + 40
    with pytest.raises(ValueError):
        packing.pad_correction(64, 32)


def test_pack_zero_k_and_unpack_overflow_raise():
    empty = packing.pack_bits(jnp.zeros((3, 0), jnp.int32))
    assert empty.shape == (3, 0)
    with pytest.raises(ValueError):
        packing.unpack_bits(jnp.zeros((2, 2), jnp.uint32), 65)
    with pytest.raises(ValueError):
        packing.unpack_int2(jnp.zeros((2, 2), jnp.uint8), 9)
    with pytest.raises(ValueError):
        packing.unpack_bits(jnp.zeros((2, 2), jnp.uint32), -1)
    with pytest.raises(ValueError):
        packing.padded_bits(-1)


def test_bipolar_maps():
    x = jnp.asarray([-3, -1, 0, 1, 5])
    b = packing.bipolar_to_bits(x)
    np.testing.assert_array_equal(np.asarray(b), [0, 0, 0, 1, 1])
    np.testing.assert_array_equal(
        np.asarray(packing.bits_to_bipolar(jnp.asarray([0, 1]))), [-1, 1]
    )
