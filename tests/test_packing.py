import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import packing


@settings(max_examples=30, deadline=None)
@given(
    st.integers(1, 4),  # rows
    st.integers(1, 130),  # K bits (crosses word boundaries)
    st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip(rows, k, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (rows, k)).astype(np.int32)
    packed = packing.pack_bits(jnp.asarray(bits))
    assert packed.shape == (rows, packing.num_words(k))
    assert packed.dtype == jnp.uint32
    back = np.asarray(packing.unpack_bits(packed, k))
    np.testing.assert_array_equal(back, bits)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_xnor_popcount_identity(k, seed):
    """2*popcount(~(a^w)) - K equals the bipolar dot product (padded-K form)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, (k,)).astype(np.int32)
    w = rng.integers(0, 2, (k,)).astype(np.int32)
    ap = packing.pack_bits(jnp.asarray(a))
    wp = packing.pack_bits(jnp.asarray(w))
    pc = int(np.sum(np.asarray(packing.popcount(~(ap ^ wp)))))
    kp = packing.padded_bits(k)
    dot = 2 * pc - kp - (kp - k)
    want = int(((2 * a - 1) * (2 * w - 1)).sum())
    assert dot == want


def test_bipolar_maps():
    x = jnp.asarray([-3, -1, 0, 1, 5])
    b = packing.bipolar_to_bits(x)
    np.testing.assert_array_equal(np.asarray(b), [0, 0, 0, 1, 1])
    np.testing.assert_array_equal(
        np.asarray(packing.bits_to_bipolar(jnp.asarray([0, 1]))), [-1, 1]
    )
