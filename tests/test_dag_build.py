"""Branched (fan-out/fan-in) graphs through the full stack: the residual
MLP config builds bit-exactly for every target, the BuildReport records
the topology, verification errors name the failing node + branch, and
random legal DAGs stay interpreter==engine bit-exact across weight
codings (deterministic sweep always; hypothesis widens it when present)."""

import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.build import VerificationError, build, default_steps
from repro.configs import residual_mlp
from repro.core import dataflow, ir, lowering
from repro.core.engine import FusedEngine
from repro.core.ir import Graph, Node


def _x(batch=16, k=600, bits=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**bits, (batch, k)), jnp.int32)


# ------------------------------------------------------------ build targets
@pytest.mark.parametrize("target", ["interpret", "engine", "pipeline"])
def test_residual_mlp_builds_bit_exact(target):
    acc = build(residual_mlp.build_graph(), target=target, mode="standard",
                weight_bits=2, act_bits=2, folding=residual_mlp.foldings(),
                name="residual_mlp")
    # every verification hook that had something to check passed
    assert all(s.verified in (True, None) for s in acc.report.steps)
    assert any(s.verified for s in acc.report.steps)
    x = _x()
    y = np.asarray(acc(x))
    np.testing.assert_array_equal(y, np.asarray(acc.interpret(x)))
    assert y.shape == (16, 1)


def test_report_records_topology_and_branches():
    acc = build(residual_mlp.build_graph(), target="engine", mode="standard",
                weight_bits=2, act_bits=2, folding=residual_mlp.foldings(),
                name="residual_mlp")
    rep = acc.report
    # the serialized edge list contains the fan-out (two consumers of
    # fc0.mvu) and the fan-in (two producers into the join)
    assert ["fc0.mvu", "fc1.mvu"] in rep.edges
    assert ["fc0.mvu", "res"] in rep.edges
    assert ["fc1.mvu", "res"] in rep.edges
    nodes = {n.name: n for n in rep.nodes}
    assert nodes["fc1.mvu"].branch == "fc0.mvu/fc1.mvu"
    assert nodes["fc0.mvu"].branch == "main"
    assert nodes["fc2.mvu"].branch == "main"
    assert nodes["fc1.mvu"].inputs == ["fc0.mvu"]
    assert nodes["fc2.mvu"].inputs == ["res"]
    # the schedule summary carries the join's skew-FIFO record
    joins = rep.schedule["joins"]
    assert joins[0]["name"] == "res" and joins[0]["fifo_depth"] >= 2
    # round-trips through JSON with the new fields intact
    rep2 = type(rep).from_json(rep.to_json())
    assert rep2.edges == rep.edges
    assert {n.name: n.branch for n in rep2.nodes} == \
        {n.name: n.branch for n in rep.nodes}


def test_verification_error_names_node_and_branch():
    """Corrupting ONE arm of the fork must fail the build with the node id
    and its branch path in the message (satellite bugfix regression)."""

    def corrupt_branch(state):
        g = []
        for n in state.graph:
            if n.name == "fc1.mvu" and "mvu" in n.params:
                p = n.params["mvu"]
                bad = dataclasses.replace(p, weights=p.weights + 1)
                g.append(Node(n.op, n.name, dict(n.attrs), {"mvu": bad},
                              inputs=n.inputs))
            else:
                g.append(n)
        return g

    steps = default_steps("engine")
    steps.insert(steps.index("dataflow"), corrupt_branch)
    with pytest.raises(VerificationError,
                       match=r"first divergent node: 'fc1\.mvu' on branch "
                             r"'fc0\.mvu/fc1\.mvu'") as ei:
        build(residual_mlp.build_graph(), mode="standard", weight_bits=2,
              act_bits=2, folding=residual_mlp.foldings(), steps=steps)
    assert ei.value.step == "corrupt_branch"
    assert ei.value.node == "fc1.mvu"
    assert ei.value.branch == "fc0.mvu/fc1.mvu"


# ------------------------------------------------- random legal DAG sweep
def _random_dag(seed: int, depth: int, *, width=12, bits=2) -> Graph:
    """A random legal DAG: a quantized MLP trunk with random skip joins
    (fan-out <= 3, elementwise add/sub/mul re-quantized after each join)."""
    rng = np.random.default_rng(seed)

    def lin(name, n, k, src):
        w = (rng.normal(0, 1, (n, k)) / np.sqrt(k)).astype(np.float32)
        return Node("linear", name, {}, {"w": jnp.asarray(w)}, inputs=(src,))

    def bnorm(name, n, src):
        return Node("batchnorm", name, {}, {
            "gamma": jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32)),
            "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
            "mean": jnp.asarray(rng.normal(0, 1, n).astype(np.float32)),
            "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
        }, inputs=(src,))

    def qact(name, src):
        return Node("quant_act", name, {"bits": bits, "act_scale": 1.0},
                    inputs=(src,))

    g = [Node("input", "in", {"shape": (width,), "bits": bits})]
    fanout = {"in": 0}
    streams = ["in"]
    prev = "in"
    for i in range(depth):
        g += [lin(f"fc{i}", width, width, prev),
              bnorm(f"bn{i}", width, f"fc{i}"), qact(f"act{i}", f"bn{i}")]
        fanout[prev] += 1
        cur = f"act{i}"
        fanout[cur] = 0
        joinable = [s for s in streams if fanout[s] < 3 and s != cur]
        if joinable and rng.random() < 0.6:
            src = joinable[int(rng.integers(len(joinable)))]
            op = ("add", "sub", "mul")[int(rng.integers(3))]
            g.append(Node(op, f"join{i}", {"scales": (1, 1)},
                          inputs=(cur, src)))
            # re-quantize the joined stream so every MVU still consumes a
            # bits-wide activation (xnor packs 1-bit inputs)
            g.append(qact(f"jq{i}", f"join{i}"))
            fanout[cur] += 1
            fanout[src] += 1
            cur = f"jq{i}"
            fanout[cur] = 0
        streams.append(cur)
        prev = cur
    g.append(lin("head", 2, width, prev))
    fanout[prev] += 1
    return Graph(g)


def _assert_dag_bit_exact(seed: int, depth: int, mode: str, bits: int):
    g = _random_dag(seed, depth, bits=bits)
    ir.validate_graph(g)
    low = lowering.finalize(lowering.streamline(lowering.lower_to_mvu(
        g, mode=mode, weight_bits=bits, act_bits=bits)))
    x = jnp.asarray(np.random.default_rng(seed + 99).integers(
        0, 2**bits, (8, 12)), jnp.int32)
    want = np.asarray(dataflow.execute(low, x))
    got = np.asarray(FusedEngine(low)(x))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("mode,bits", [("standard", 2), ("binary", 2),
                                       ("xnor", 1)])
def test_random_dags_interpreter_equals_engine(mode, bits):
    for seed, depth in [(0, 3), (1, 4), (2, 6)]:
        _assert_dag_bit_exact(seed, depth, mode, bits)


def test_random_dags_property():
    """Hypothesis-widened version of the deterministic sweep (nightly CI
    installs hypothesis; tier-1 skips when it is absent)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(seed=st.integers(0, 10_000), depth=st.integers(1, 6),
                      mode=st.sampled_from(["standard", "binary", "xnor"]))
    def run(seed, depth, mode):
        _assert_dag_bit_exact(seed, depth, mode, 1 if mode == "xnor" else 2)

    run()
