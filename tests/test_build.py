"""repro.build tests: golden default step order per target, custom-step
injection/replacement, verification hooks naming the failing step,
BuildReport JSON round-trip, the Accelerator facade, and the EngineServer
shim's bit-exactness with ContinuousBatcher on one submit/flush trace."""

import dataclasses
import json
import os
import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.build import (
    Accelerator,
    BuildConfig,
    BuildError,
    BuildReport,
    VerificationError,
    build,
    default_steps,
)
from repro.core.folding import Folding
from repro.core.ir import Node


def _mlp_graph(dims=(24, 16, 8), bits=2, seed=3):
    rng = np.random.default_rng(seed)
    g = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(dims) - 2:
            g.append(Node("batchnorm", f"bn{i}", {}, {
                "gamma": jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32)),
                "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
                "mean": jnp.asarray(rng.normal(0, 1, n).astype(np.float32)),
                "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
            }))
            g.append(Node("quant_act", f"act{i}", {"bits": bits, "act_scale": 1.0}))
    return g


def _conv_graph(bits=2, seed=11):
    rng = np.random.default_rng(seed)
    g = [Node("input", "in", {"shape": (8, 8, 3), "bits": bits})]
    w = rng.normal(0, 0.5, (3, 3, 3, 6)).astype(np.float32)
    g.append(Node("conv", "c0", {"kernel": 3, "stride": 1, "pad": 0},
                  {"w": jnp.asarray(w)}))
    g.append(Node("quant_act", "act0", {"bits": bits, "act_scale": 1.0}))
    return g


def _x(dims=(24,), bits=2, batch=13, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**bits, (batch, *dims)), jnp.int32)


# ----------------------------------------------------------- default steps
def test_golden_default_step_order_per_target():
    """The FINN ``build_dataflow_steps`` analog is part of the API contract:
    pin the default lists so a reorder is a deliberate, visible change."""
    assert default_steps("interpret") == [
        "validate", "lower", "finalize", "fold", "pack_weights", "dataflow"]
    assert default_steps("engine") == [
        "validate", "lower", "finalize", "fold", "fuse_epilogues",
        "fuse_swu", "tune", "pack_weights", "dataflow", "engine"]
    assert default_steps("pipeline") == default_steps("engine")
    assert default_steps("serving") == default_steps("engine") + ["calibrate"]
    with pytest.raises(BuildError, match="unknown|target"):
        default_steps("bitfile")
    # executed step order matches the declared default
    acc = build(_mlp_graph(), mode="standard", weight_bits=4, act_bits=2)
    assert acc.report.step_names == default_steps("engine")


def test_build_engine_bit_exact_and_verified_steps():
    acc = build(_mlp_graph(), target="engine", mode="standard",
                weight_bits=4, act_bits=2)
    x = _x()
    np.testing.assert_array_equal(np.asarray(acc(x)),
                                  np.asarray(acc.interpret(x)))
    by_name = {s.name: s for s in acc.report.steps}
    # every graph rewrite from the first executable graph on is verified
    for name in ("finalize", "fold", "fuse_epilogues", "fuse_swu", "engine"):
        assert by_name[name].verified is True
    # the reference graph keeps the unfused bn/quant chain
    assert any(n.op == "batchnorm" for n in acc.ref_graph)
    assert all(n.op not in ("batchnorm", "quant_act") for n in acc.graph)


def test_interpret_target_has_no_engine():
    acc = build(_mlp_graph(), target="interpret", mode="standard",
                weight_bits=4, act_bits=2)
    assert acc.report.step_names == default_steps("interpret")
    x = _x()
    np.testing.assert_array_equal(np.asarray(acc(x)),
                                  np.asarray(acc.interpret(x)))
    with pytest.raises(BuildError, match="engine"):
        acc.engine


def test_explicit_folding_overrides_are_applied_per_node():
    folds = [Folding(8, 12), Folding(4, 16)]
    acc = build(_mlp_graph(), target="interpret", mode="standard",
                weight_bits=4, act_bits=2, folding=folds)
    mvus = [n for n in acc.graph if n.op == "mvu"]
    assert [n.attrs["config"].folding for n in mvus] == folds
    with pytest.raises(BuildError, match="folding override"):
        build(_mlp_graph(), target="interpret", mode="standard",
              weight_bits=4, act_bits=2, folding=[Folding(8, 12)])


def test_custom_step_injection_and_replacement():
    """Steps splice by name or callable, exactly like FINN's custom step
    lists; a custom step may mutate the state or return a graph."""
    seen = {}

    def audit_step(state):
        seen["ops"] = [n.op for n in state.graph]

    def rename_step(state):  # returns a graph -> replaces state.graph
        g = []
        for n in state.graph:
            if n.op == "input":
                g.append(Node("input", "renamed_in", dict(n.attrs),
                              dict(n.params)))
            elif n.inputs and "in" in n.inputs:  # repoint consumers' edges
                g.append(dataclasses.replace(n, inputs=tuple(
                    "renamed_in" if s == "in" else s for s in n.inputs)))
            else:
                g.append(n)
        return g

    steps = default_steps("engine")
    steps.insert(steps.index("fold"), audit_step)
    steps.insert(steps.index("engine"), rename_step)
    acc = build(_mlp_graph(), mode="standard", weight_bits=4, act_bits=2,
                steps=steps)
    assert seen["ops"][0] == "input" and "mvu" in seen["ops"]
    assert acc.graph[0].name == "renamed_in"
    assert acc.report.step_names == [
        "validate", "lower", "finalize", "audit_step", "fold",
        "fuse_epilogues", "fuse_swu", "tune", "pack_weights", "dataflow",
        "rename_step", "engine"]
    x = _x()
    np.testing.assert_array_equal(np.asarray(acc(x)),
                                  np.asarray(acc.interpret(x)))
    with pytest.raises(BuildError, match="unknown build step"):
        build(_mlp_graph(), steps=["validate", "no_such_step"])


def test_verification_hook_names_the_failing_step():
    """A transform that changes the numbers must fail the build with the
    step's name in the error (FINN's verification steps)."""

    def corrupt_weights(state):
        g = []
        for n in state.graph:
            if n.op == "mvu" and "mvu" in n.params:
                p = n.params["mvu"]
                bad = dataclasses.replace(p, weights=p.weights + 1) \
                    if dataclasses.is_dataclass(p) else p
                g.append(Node(n.op, n.name, dict(n.attrs), {"mvu": bad}))
            else:
                g.append(n)
        return g

    steps = default_steps("engine")
    steps.insert(steps.index("fuse_epilogues"), corrupt_weights)
    with pytest.raises(VerificationError, match="corrupt_weights") as ei:
        build(_mlp_graph(), mode="standard", weight_bits=4, act_bits=2,
              steps=steps)
    assert ei.value.step == "corrupt_weights"
    # verify="off" skips the hooks: the same corrupted build succeeds
    acc = build(_mlp_graph(), mode="standard", weight_bits=4, act_bits=2,
                steps=steps, verify="off")
    assert all(s.verified is None for s in acc.report.steps)


def test_conv_chain_builds_and_fuses_swu():
    acc = build(_conv_graph(), target="engine", mode="standard",
                weight_bits=4, act_bits=2, folding="none")
    assert [n.op for n in acc.graph] == ["input", "conv_mvu"]
    assert any(n.op == "swu" for n in acc.ref_graph)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(0, 4, (3, 8, 8, 3)), jnp.int32)
    np.testing.assert_array_equal(np.asarray(acc(x)),
                                  np.asarray(acc.interpret(x)))


# ----------------------------------------------------------------- report
def test_build_report_roundtrips_through_json(tmp_path):
    acc = build(_mlp_graph(), mode="standard", weight_bits=4, act_bits=2,
                folding=[Folding(8, 12), Folding(4, 16)])
    rep = acc.report
    assert rep.target == "engine"
    assert [n.name for n in rep.nodes] == ["fc0.mvu", "fc1.mvu"]
    assert [(n.pe, n.simd) for n in rep.nodes] == [(8, 12), (4, 16)]
    assert rep.schedule["bottleneck"] in ("fc0.mvu", "fc1.mvu")
    assert rep.predicted_interval_s == pytest.approx(
        rep.schedule["interval_cycles"] / 200e6)
    assert rep.total_wall_s > 0

    path = acc.save_report(str(tmp_path / "r.json"))
    loaded = BuildReport.load(path)
    assert loaded.to_json() == rep.to_json()
    assert loaded.step_names == rep.step_names
    assert loaded.nodes == rep.nodes
    # the file is plain JSON (committable next to the autotune cache)
    with open(path) as f:
        assert json.load(f)["name"] == "build"


def test_output_dir_emits_report_json(tmp_path):
    out = str(tmp_path / "build")
    acc = build(_mlp_graph(), mode="standard", weight_bits=4, act_bits=2,
                name="unit_mlp", output_dir=out)
    path = os.path.join(out, "unit_mlp_build_report.json")
    assert acc.report.path == path and os.path.exists(path)
    assert BuildReport.load(path).name == "unit_mlp"


def test_tune_cache_accounting_in_report():
    from repro.core import autotune

    graph = _mlp_graph()
    cache = autotune.ScheduleCache()
    acc = build(graph, mode="standard", weight_bits=4, act_bits=2,
                tune="cache", cache=cache)
    t = acc.report.tune
    assert t["mode"] == "cache" and t["cache_hits"] == 0
    assert t["cache_misses"] == 2  # both MVU stages missed the empty cache
    # misses keep the heuristic schedule (pure lookup, nothing measured)
    assert all(n.attrs["config"].blocks is None
               for n in acc.graph if n.op == "mvu")


def test_build_config_validation_and_snapshot():
    with pytest.raises(BuildError, match="target"):
        BuildConfig(target="asic")
    with pytest.raises(BuildError, match="tune"):
        BuildConfig(tune="sometimes")
    with pytest.raises(BuildError, match="folding"):
        BuildConfig(folding="maybe")
    snap = BuildConfig(folding=[Folding(2, 4)], steps=["validate"],
                       graph=[Node("input", "in", {"shape": (4,)})]).snapshot()
    json.dumps(snap)  # must be JSON-safe
    assert snap["folding"] == [[2, 4]] and snap["graph"] == "list"
    # build(config) uses the embedded graph; build() without one fails
    cfg = BuildConfig(graph=_mlp_graph(), target="interpret",
                      weight_bits=4, act_bits=2, mode="standard")
    acc = build(cfg)
    assert isinstance(acc, Accelerator)
    with pytest.raises(BuildError, match="graph"):
        build(BuildConfig())


# -------------------------------------------------- EngineServer shim parity
def _trace(n=13, k=24, bits=2, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2**bits, (n, k)).astype(np.int32)


def test_engine_server_shim_matches_continuous_batcher_trace():
    """Regression (deprecation contract): the shim and a manually-flushed
    ContinuousBatcher must stay bit-exact on the SAME submit/flush trace --
    same per-rid outputs, same flush/padding accounting."""
    from repro.launch.serve import EngineServer

    acc = build(_mlp_graph(), mode="standard", weight_bits=4, act_bits=2)
    xs = _trace()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        server = EngineServer(acc.engine, batch_buckets=(1, 4, 8))
    batcher = acc.serve(batch_buckets=(1, 4, 8), greedy_when_idle=False,
                        warmup=False)

    # identical trace on both: 5 singles, one 8-block, flush, 3 singles, flush
    def drive(submit, submit_batch, flush):
        rids = [submit(xs[i]) for i in range(5)]
        rids += submit_batch(xs[5:13])
        out = {r: o for r, o in flush()}
        rids += [submit(xs[i]) for i in range(3)]
        out.update({r: o for r, o in flush()})
        return rids, out

    s_rids, s_out = drive(
        server.submit, server.submit_batch,
        lambda: [(r.rid, r.out) for r in server.flush()])

    def batcher_flush():
        batcher.flush_all()
        done = batcher.harvest(block=True)
        return [(rid, batcher.pop_result(rid).out) for rid in done]

    b_rids, b_out = drive(batcher.submit, batcher.submit_batch, batcher_flush)

    assert s_rids == b_rids
    want = np.asarray(acc.engine(jnp.asarray(np.concatenate([xs, xs[:3]]))))
    for i, rid in enumerate(s_rids):
        np.testing.assert_array_equal(s_out[rid], want[i])
        np.testing.assert_array_equal(b_out[rid], want[i])
    # same coalescing arithmetic on both sides of the shim
    assert server.stats["flushes"] == batcher.metrics.counters["flushes"]
    assert (server.stats["padded_samples"]
            == batcher.metrics.counters["padded_samples"])


def test_engine_server_warns_once_pointing_at_build():
    import repro.launch.serve as serve_mod

    acc = build(_mlp_graph(), mode="standard", weight_bits=4, act_bits=2)
    serve_mod._ENGINE_SERVER_WARNED = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        serve_mod.EngineServer(acc.engine, batch_buckets=(1, 4))
        serve_mod.EngineServer(acc.engine, batch_buckets=(1, 4))
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)
           and "EngineServer" in str(x.message)]
    assert len(dep) == 1  # a single warning per process, not per instance
    assert "repro.build" in str(dep[0].message)
    assert "serving" in str(dep[0].message)
