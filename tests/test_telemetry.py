"""repro.telemetry tests: tracer span discipline (nesting, bounded buffer,
Chrome export), log-bucketed histograms + windowed rates + Prometheus
exposition, the drift monitor (censored observations, latched flags),
thread-safe ServingMetrics, engine/pipeline instrumentation invariants
(traced == untraced bit-exactness, per-node spans sum within the enclosing
span), and the regression gate's None tolerance."""

import importlib.util
import json
import math
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.engine import FusedEngine
from repro.distributed.pipeline import emit_schedule_spans, pipeline_occupancy
from repro.serving import ContinuousBatcher, ServingMetrics
from repro.telemetry import (
    DEFAULT_BAND,
    DriftMonitor,
    LogHistogram,
    Tracer,
    WindowedRate,
    render_prometheus,
)
from tests.test_serving import _mlp_graph, _samples

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeClock:
    """Deterministic monotone clock: each call advances by ``step``."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


def assert_no_overlap_within_thread(spans):
    """Within one thread, duration spans must nest or be disjoint -- a pair
    that partially overlaps would mean the stack discipline broke."""
    by_tid = {}
    for sp in spans:
        by_tid.setdefault(sp["tid"], []).append(sp)
    for tid, sps in by_tid.items():
        sps = sorted(sps, key=lambda s: (s["t0"], -s["t1"]))
        for a, b in zip(sps, sps[1:]):
            nested = b["t0"] >= a["t0"] and b["t1"] <= a["t1"]
            disjoint = b["t0"] >= a["t1"]
            assert nested or disjoint, (
                f"spans overlap without nesting on tid {tid}: {a} vs {b}")


# ------------------------------------------------------------------- tracer
def test_spans_nest_and_never_overlap_within_a_thread():
    tr = Tracer(clock=FakeClock(step=1.0))
    with tr.span("outer", cat="t"):
        with tr.span("inner1", cat="t"):
            pass
        with tr.span("inner2", cat="t"):
            with tr.span("leaf", cat="t"):
                pass
    spans = tr.spans()
    assert [s["name"] for s in spans] == ["inner1", "leaf", "inner2", "outer"]
    depths = {s["name"]: s["depth"] for s in spans}
    assert depths == {"outer": 0, "inner1": 1, "inner2": 1, "leaf": 2}
    assert_no_overlap_within_thread(spans)
    outer = next(s for s in spans if s["name"] == "outer")
    for s in spans:
        assert outer["t0"] <= s["t0"] and s["t1"] <= outer["t1"]


def test_tracer_buffer_bounded_and_drop_accounted():
    tr = Tracer(capacity=8)
    for i in range(20):
        tr.instant("tick", n=i)
    assert len(tr) == 8
    assert tr.dropped == 12
    # oldest dropped: the survivors are the 8 newest
    assert [ev["args"]["n"] for ev in tr.events()] == list(range(12, 20))
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_span_args_mutable_while_open_and_land_in_event():
    tr = Tracer()
    with tr.span("dispatch", cat="serving", bucket=8) as sp:
        sp.args["replica"] = 3
    ev = tr.spans(name="dispatch")[0]
    assert ev["args"] == {"bucket": 8, "replica": 3}


def test_chrome_export_is_valid_json_with_named_lanes():
    tr = Tracer(meta={"run": "test"})
    with tr.span("work", cat="engine"):
        tr.instant("mark", cat="engine", k=1)
    tr.begin_async("request", 7, cat="request")
    tr.end_async("request", 7, cat="request")
    tr.counter("queue_depth", 3, cat="serving")
    tr.emit_span("micro0", 0.0, 1.0, cat="pipeline", tid="stage0", stage=0)
    doc = json.loads(json.dumps(tr.to_chrome()))  # strict-JSON round trip
    evs = doc["traceEvents"]
    phases = sorted(e["ph"] for e in evs)
    assert phases == sorted(["X", "i", "b", "e", "C", "X", "M"])
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta[0]["args"]["name"] == "stage0"
    lane_tid = meta[0]["tid"]
    assert any(e["ph"] == "X" and e["tid"] == lane_tid for e in evs)
    assert doc["metadata"]["run"] == "test"
    async_evs = [e for e in evs if e["ph"] in ("b", "e")]
    assert {e["id"] for e in async_evs} == {7}


def test_tracer_summary_aggregates_per_name():
    clock = FakeClock(step=1.0)
    tr = Tracer(clock=clock)
    for _ in range(3):
        with tr.span("step"):
            pass
    s = tr.summary()
    assert s["spans"]["step"]["count"] == 3
    assert s["events"]["X"] == 3
    assert s["dropped"] == 0


# ---------------------------------------------------------------- histogram
def test_log_histogram_percentiles_within_bucket_width():
    h = LogHistogram()
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-5.0, sigma=1.0, size=5000)
    for v in vals:
        h.observe(float(v))
    for p in (50, 95, 99):
        want = float(np.percentile(vals, p, method="inverted_cdf"))
        assert h.percentile(p) == pytest.approx(want, rel=0.05)
    assert h.count == 5000
    assert h.mean() == pytest.approx(float(vals.mean()))


def test_log_histogram_single_sample_exact_and_empty_none():
    h = LogHistogram()
    assert h.percentile(50) is None and h.mean() is None
    h.observe(0.123)
    # the midpoint estimate is clamped into [min, max]
    assert h.percentile(50) == pytest.approx(0.123)
    assert h.percentile(99) == pytest.approx(0.123)


def test_log_histogram_merge_and_json_round_trip():
    a, b = LogHistogram(), LogHistogram()
    for v in (0.001, 0.002, 0.004):
        a.observe(v)
    for v in (0.008, 0.016):
        b.observe(v)
    a.merge(b)
    assert a.count == 5 and a.max == 0.016
    rt = LogHistogram.from_json(json.loads(json.dumps(a.to_json())))
    assert rt.buckets == a.buckets and rt.count == a.count
    assert rt.percentile(50) == a.percentile(50)
    with pytest.raises(ValueError, match="merge"):
        a.merge(LogHistogram(lo=1e-3))


def test_log_histogram_underflow_bucket():
    h = LogHistogram(lo=1e-3)
    h.observe(1e-9)  # below lo: underflow bucket, counted, percentile = lo..
    assert h.buckets == {-1: 1}
    assert h.count == 1
    # ..clamped to the observed range
    assert h.percentile(50) == pytest.approx(1e-9)


# ------------------------------------------------------------ windowed rate
def test_windowed_rate_slides():
    t = {"now": 0.0}
    rate = WindowedRate(10.0, slots=20, clock=lambda: t["now"])
    for i in range(50):
        t["now"] = i * 0.1
        rate.add()
    assert rate.rate() == pytest.approx(5.0, rel=0.15)  # 50 events in 5 s
    t["now"] = 30.0  # window slid past everything
    assert rate.rate() == 0.0


# --------------------------------------------------------------- prometheus
def test_render_prometheus_exposition():
    h = LogHistogram()
    h.observe(0.002)
    h.observe(0.004)
    text = render_prometheus(
        counters={"completed": 2}, gauges={"depth": 3, "p99": None},
        histograms={"latency_seconds": h}, prefix="t")
    assert "# TYPE t_completed_total counter" in text
    assert "t_completed_total 2" in text
    assert "t_depth 3.0" in text
    assert "t_p99 NaN" in text  # Prometheus spells missing values NaN
    assert 't_latency_seconds_bucket{le="+Inf"} 2' in text
    assert "t_latency_seconds_count 2" in text
    # cumulative le buckets are monotone
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if "_bucket{" in line]
    assert cums == sorted(cums)


# ------------------------------------------------------------ drift monitor
def test_drift_monitor_flags_sustained_high_ratio_only():
    dm = DriftMonitor({"stage0": 1.0}, min_samples=2)
    dm.observe("stage0", 1.1)
    assert dm.flagged() == []  # in band
    dm.observe("stage0", 1.2)
    assert dm.flagged() == []
    for _ in range(6):
        dm.observe("stage0", 10.0)  # EWMA climbs out of the band
    assert dm.flagged() == ["stage0"]
    assert dm.flagged_ever() == ["stage0"]
    # recovery clears the live flag but not the latch
    for _ in range(30):
        dm.observe("stage0", 1.0)
    assert dm.flagged() == []
    assert dm.flagged_ever() == ["stage0"]


def test_drift_monitor_censored_semantics():
    dm = DriftMonitor({"r": 1.0})
    # a lower bound inside the band proves nothing: dropped, no state, no flag
    assert dm.observe("r", 2.0, censored=True) is None
    assert dm.flagged_ever() == []
    # a lower bound above band-high is conclusive: recorded AND latched,
    # even though later clean samples pull the EWMA back into the band
    assert dm.observe("r", 10.0, censored=True) == pytest.approx(10.0)
    assert dm.flagged_ever() == ["r"]
    assert dm.observe("r", 2.0, censored=True) is None  # counted this time
    for _ in range(30):
        dm.observe("r", 1.0)
    assert dm.flagged() == []
    assert dm.flagged_ever() == ["r"]
    st = dm.status()
    assert st["keys"]["r"]["censored_hits"] == 1
    assert st["keys"]["r"]["censored_dropped"] >= 1
    json.dumps(st)  # JSON-safe


def test_drift_monitor_unknown_key_discarded():
    dm = DriftMonitor()
    assert dm.observe("nobody", 1.0) is None  # no prediction, no explicit
    assert dm.observe("x", 5.0, predicted_s=1.0) == pytest.approx(5.0)
    assert dm.flagged_ever() == ["x"]
    assert DEFAULT_BAND[0] < 1.0 < DEFAULT_BAND[1]


def test_drift_monitor_from_schedule():
    from repro.core import dataflow

    g = _mlp_graph()
    sched = dataflow.schedule(g)
    dm = DriftMonitor.from_schedule(sched, 1e-8)
    assert dm.predictions
    for s in sched.stages:
        assert dm.predictions[s.name] == pytest.approx(s.cycles * 1e-8)


# ----------------------------------------------------------- serving metrics
def test_serving_metrics_concurrent_increments_lose_nothing():
    """Regression: ServingMetrics is shared across harvest / monitor
    threads; concurrent count() and observe_latency() must never lose an
    increment (the pre-lock implementation did)."""
    m = ServingMetrics()
    N, T = 2000, 8

    def work():
        for i in range(N):
            m.count("retries")
            m.observe_latency(0.001 * (1 + i % 7))
            if i % 64 == 0:
                m.snapshot()  # concurrent reads must not throw either

    threads = [threading.Thread(target=work) for _ in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert m.counters["retries"] == N * T
    assert m.counters["completed"] == N * T
    assert m.latency.count == N * T


def test_serving_metrics_empty_percentiles_are_json_null_not_nan():
    m = ServingMetrics()
    snap = m.snapshot()
    assert snap["p50_ms"] is None and snap["p99_ms"] is None
    text = json.dumps(snap)  # NaN would raise with allow_nan=False
    json.loads(text)
    json.dumps(snap, allow_nan=False)
    assert not math.isnan(snap["availability"])


def test_serving_metrics_percentiles_and_prometheus():
    m = ServingMetrics()
    for ms in range(1, 101):
        m.observe_latency(ms / 1e3)
    pct = m.latency_percentiles()
    assert pct["p50_ms"] == pytest.approx(50.0, rel=0.05)
    assert pct["p99_ms"] == pytest.approx(99.0, rel=0.05)
    text = m.prometheus()
    assert "repro_serving_completed_total 100" in text
    assert 'repro_serving_latency_seconds_bucket{le="+Inf"} 100' in text


# ------------------------------------------------- engine instrumentation
def test_engine_profile_bit_exact_and_node_spans_nest():
    engine = FusedEngine(_mlp_graph(), microbatches=2)
    x = jnp.asarray(_samples(6))
    want = np.asarray(engine(x))
    tr = Tracer()
    drift = DriftMonitor.from_schedule(engine.schedule, 1e-8)
    got, plan = engine.profile(x, tr, drift=drift)
    np.testing.assert_array_equal(np.asarray(got), want)

    spans = tr.spans()
    assert_no_overlap_within_thread(spans)
    outer = tr.spans(name="engine.profile")[0]
    node_spans = tr.spans(cat="node")
    assert len(node_spans) == plan.n_micro * len(engine.graph)
    # per-node spans sum to no more than the enclosing profile span
    assert sum(s["dur"] for s in node_spans) <= outer["dur"] + 1e-9
    for s in node_spans:
        assert outer["t0"] <= s["t0"] and s["t1"] <= outer["t1"]
    # every scheduled stage's observation reached the drift monitor (the
    # input node has no schedule stage, so no prediction: dropped)
    assert set(drift.status()["keys"]) == {s.name for s in engine.schedule.stages}


def test_engine_dispatch_traced_matches_untraced():
    engine = FusedEngine(_mlp_graph())
    x = jnp.asarray(_samples(5))
    plain, _ = engine.dispatch(x)
    tr = Tracer()
    traced, plan = engine.dispatch(x, tracer=tr)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(traced))
    sp = tr.spans(name="engine.dispatch")
    assert len(sp) == 1
    assert sp[0]["args"]["batch"] == 5
    assert sp[0]["args"]["n_micro"] == plan.n_micro


# ------------------------------------------------- serving instrumentation
def test_traced_serving_bit_exact_with_untraced():
    engine = FusedEngine(_mlp_graph())
    xs = _samples(12)
    want = np.asarray(engine(jnp.asarray(xs)))

    plain = ContinuousBatcher(engine, batch_buckets=(1, 4))
    rids_p = plain.submit_batch(xs)
    plain.drain()

    tr = Tracer()
    drift = DriftMonitor()
    traced = ContinuousBatcher(engine, batch_buckets=(1, 4),
                               tracer=tr, drift=drift)
    rids_t = traced.submit_batch(xs)
    traced.drain()

    for rid_p, rid_t, y in zip(rids_p, rids_t, want):
        np.testing.assert_array_equal(plain.results[rid_p].out, y)
        np.testing.assert_array_equal(traced.results[rid_t].out, y)

    # full request lifecycle on the trace: every admitted rid opens and
    # closes exactly one async interval
    begins = [e for e in tr.events() if e["ph"] == "b"]
    ends = [e for e in tr.events() if e["ph"] == "e"]
    assert {e["id"] for e in begins} == set(rids_t)
    assert {e["id"] for e in ends} == set(rids_t)
    assert tr.spans(name="dispatch") and tr.spans(name="resolve")
    assert_no_overlap_within_thread(tr.spans())
    # resolved latencies fed the drift monitor (per-replica keys)
    assert any(k.startswith("replica:") for k in drift.status()["keys"])


# ------------------------------------------------------------------ pipeline
def test_pipeline_occupancy_accounting():
    occ = pipeline_occupancy(4, 8)
    assert occ["ticks"] == 11
    assert occ["bubble_ticks_per_stage"] == 3
    assert occ["occupancy"] == pytest.approx(8 / 11)
    assert pipeline_occupancy(1, 8)["occupancy"] == 1.0


def test_emit_schedule_spans_reconstructs_lanes():
    tr = Tracer()
    occ = emit_schedule_spans(tr, n_stages=3, n_micro=4, t0=0.0, t1=6.0)
    assert occ["ticks"] == 6
    spans = tr.spans(cat="pipeline")
    assert len(spans) == 3 * 6  # every stage emits every tick
    for s in range(3):
        lane = [sp for sp in spans if sp["tid"] == f"stage{s}"]
        busy = [sp for sp in lane if sp["name"] != "bubble"]
        assert len(busy) == 4 and len(lane) - len(busy) == 2
        # stage s runs microbatch m at tick s + m
        for sp in busy:
            assert sp["args"]["tick"] == s + sp["args"]["micro"]
        # lane ticks tile [t0, t1] exactly
        lane.sort(key=lambda sp: sp["t0"])
        assert lane[0]["t0"] == 0.0 and lane[-1]["t1"] == pytest.approx(6.0)
        for a, b in zip(lane, lane[1:]):
            assert a["t1"] == pytest.approx(b["t0"])


def test_pipeline_traced_multidevice_occupancy():
    """Traced as_pipeline on a 4-stage host mesh: bit-exact with the fused
    engine AND the trace carries one lane per stage with the static GPipe
    occupancy (subprocess so XLA_FLAGS never leaks into this process)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import lowering
        from repro.core.engine import FusedEngine
        from repro.core.ir import Node
        from repro.telemetry import Tracer

        rng = np.random.default_rng(0)
        d, L, bits = 32, 4, 2
        g = [Node("input", "in", {"shape": (d,), "bits": bits})]
        for i in range(L):
            w = rng.normal(0, 0.5, (d, d)).astype(np.float32)
            g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
            g.append(Node("quant_act", f"act{i}",
                          {"bits": bits, "act_scale": 1.0}))
        fin = lowering.finalize(
            lowering.lower_to_mvu(g, mode="standard", weight_bits=4,
                                  act_bits=bits))
        eng = FusedEngine(fin)
        x = jnp.asarray(rng.integers(0, 2**bits, (8, 4, d)), jnp.int32)
        tr = Tracer()
        run = eng.as_pipeline(jax.make_mesh((4,), ("stage",)), tracer=tr)
        got = np.asarray(run(x))
        want = np.asarray(eng(x.reshape(32, d))).reshape(8, 4, d)
        assert np.array_equal(got, want)

        runs = tr.spans(name="pipeline.run")
        assert len(runs) == 1
        occ = runs[0]["args"]["occupancy"]
        assert abs(occ - 8 / 11) < 1e-9, occ
        lanes = {sp["tid"] for sp in tr.spans(cat="pipeline")
                 if isinstance(sp["tid"], str)}
        assert lanes == {f"stage{s}" for s in range(4)}, lanes
        chrome = tr.to_chrome()
        names = [e["args"]["name"] for e in chrome["traceEvents"]
                 if e["ph"] == "M"]
        assert sorted(names) == [f"stage{s}" for s in range(4)]
        print("OK")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "OK" in proc.stdout


# --------------------------------------------------------------------- build
def test_build_telemetry_embeds_step_spans_in_report():
    import repro.build as build
    from repro.build import BuildConfig

    g = _mlp_graph()
    acc = build.build(g, config=BuildConfig(target="engine", telemetry=True))
    tele = acc.report.telemetry
    assert tele["spans"]  # one span per executed step
    assert set(tele["spans"]) == {f"step.{s}" for s in acc.report.step_names}
    json.dumps(acc.report.to_json())
    # telemetry off: no tracer, empty report section (the default)
    acc2 = build.build(g, config=BuildConfig(target="engine"))
    assert acc2.tracer is None and acc2.report.telemetry == {}


def test_accelerator_drift_monitor_requires_calibration():
    import repro.build as build
    from repro.build import BuildConfig
    from repro.build.config import BuildError

    acc = build.build(_mlp_graph(), config=BuildConfig(target="engine"))
    with pytest.raises(BuildError, match="calibrated"):
        acc.drift_monitor()


# ------------------------------------------------------- CI regression gate
def _gate():
    spec = importlib.util.spec_from_file_location(
        "check_bench_regression",
        os.path.join(REPO, "scripts", "check_bench_regression.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_regression_gate_tolerates_null_metrics():
    """Percentiles over zero samples serialize as JSON null; the gate's
    informational prints must render them as n/a, not crash formatting."""
    gate = _gate()
    base = {"bit_exact": True, "speedup": 2.5,
            "fused_samples_per_s": None, "unfused_samples_per_s": 100.0}
    fresh = {"bit_exact": True, "speedup": 2.5,
             "fused_samples_per_s": 123.0, "unfused_samples_per_s": None}
    assert gate.check_record("r", base, fresh,
                             max_regression=0.2, min_speedup=2.0) == []
