"""Shared trailing-median straggler detector (repro.distributed.stragglers)
plus the StepWatchdog refactor onto it: one definition, two consumers
(training watchdog, serving replica health), zero behavior change."""

import numpy as np
import pytest

from repro.distributed.fault_tolerance import StepWatchdog
from repro.distributed.stragglers import TrailingStats


def test_trailing_stats_validates_args():
    with pytest.raises(ValueError, match="window"):
        TrailingStats(window=0)
    with pytest.raises(ValueError, match="factor"):
        TrailingStats(factor=1.0)


def test_no_verdict_before_min_samples():
    """Early observations are warmup noise: a 100x outlier inside the
    min_samples window must not be flagged."""
    s = TrailingStats(min_samples=8, factor=3.0)
    flags = [s.observe(dt) for dt in [0.01] * 7 + [1.0]]
    assert flags == [False] * 8  # the 8th tested against only 7 samples
    assert s.threshold() is None or len(s) >= 8
    assert s.stragglers == 0


def test_outlier_tested_before_appended():
    """The straggler is judged against the trailing window BEFORE joining
    it -- one outlier never vouches for itself."""
    s = TrailingStats(min_samples=4, factor=3.0)
    for _ in range(8):
        assert not s.observe(0.010)
    assert s.threshold() == pytest.approx(0.030)
    assert s.observe(0.050)  # 5x the trailing median: flagged
    assert s.stragglers == 1
    # the outlier is now IN the window but the median barely moves
    assert s.median == pytest.approx(0.010)
    assert not s.observe(0.012)


def test_window_is_bounded_and_median_tracks_recent():
    s = TrailingStats(window=4, min_samples=2, factor=3.0)
    for dt in (1.0, 1.0, 1.0, 1.0, 5.0, 5.0, 5.0, 5.0):
        s.observe(dt)
    assert len(s) == 4
    assert s.median == pytest.approx(5.0)  # old regime aged out


def test_would_flag_is_pure():
    s = TrailingStats(min_samples=2, factor=2.0)
    s.observe(0.01), s.observe(0.01)
    before = len(s)
    assert s.would_flag(0.05) and not s.would_flag(0.015)
    assert len(s) == before  # probe recorded nothing


def test_ewma_smooths_toward_recent():
    s = TrailingStats(ewma_alpha=0.5)
    assert s.ewma == 0.0  # unarmed
    s.observe(0.010)
    assert s.ewma == pytest.approx(0.010)  # first sample seeds it
    s.observe(0.030)
    assert s.ewma == pytest.approx(0.020)


def test_median_is_robust_where_mean_is_not():
    """The design reason for the trailing median: one straggler in the
    window must not drag the threshold up and mask the next one."""
    s = TrailingStats(min_samples=4, factor=3.0, window=32)
    for _ in range(8):
        s.observe(0.010)
    s.observe(1.0)  # a huge straggler lands in the window
    assert s.stragglers == 1
    assert s.observe(0.050)  # the NEXT straggler is still caught
    assert s.stragglers == 2
    mean = np.mean(list(s.times)[:-1])
    assert 0.050 < 3.0 * mean  # a mean-based cutoff would have missed it


def test_step_watchdog_unchanged_after_refactor():
    """StepWatchdog semantics on the shared util are identical to the old
    inline implementation: flag when dt > factor * trailing median with at
    least 8 prior samples, then append."""
    wd = StepWatchdog(window=16, straggler_factor=3.0)
    for _ in range(8):
        wd._stats.observe(0.010)
    assert wd.stragglers == 0
    assert wd._stats.observe(0.050)
    assert wd.stragglers == 1
    assert wd.median == pytest.approx(0.010)
    assert wd.factor == 3.0 and len(wd.times) == 9


def test_step_watchdog_context_manager_records():
    wd = StepWatchdog(window=4, straggler_factor=50.0)
    for _ in range(3):
        with wd:
            pass
    assert len(wd.times) == 3 and wd.stragglers == 0
