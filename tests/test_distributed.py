"""Distribution tests: run in subprocesses with forced host device counts
so the main pytest process keeps a single CPU device (per the dry-run
contract: XLA_FLAGS is never set globally)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(n: int, body: str, timeout: int = 600) -> str:
    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        """
    ) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_devices(8, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models.model import build
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import shard_train_step, init_sharded
        from repro.optim import adamw

        cfg = get_reduced("yi-9b").replace(dtype="float32", remat=False)
        model = build(cfg)
        opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(9), (8, 33), 0, cfg.vocab_size)}

        mesh = make_host_mesh((4, 2))
        step, p_sh, o_sh, b_sh = shard_train_step(model, mesh, opt_cfg, batch)
        params, opt_state, _, _ = init_sharded(model, mesh)
        p0 = jax.tree.map(lambda x: np.asarray(x).copy(), params)
        b = jax.device_put(batch, b_sh)
        params, opt_state, metrics = step(params, opt_state, b)
        l_mesh = float(metrics["loss"])

        # single-device reference
        params1 = model.init(jax.random.PRNGKey(0))
        opt1 = adamw.init(params1)
        from repro.launch.train import make_train_step
        params1, opt1, m1 = make_train_step(model, opt_cfg)(params1, opt1, batch)
        l_single = float(m1["loss"])
        assert abs(l_mesh - l_single) < 1e-3, (l_mesh, l_single)

        # params actually moved and match the single-device update
        moved = sum(float(np.abs(np.asarray(a) - b0).max()) for a, b0 in
                    zip(jax.tree.leaves(params), jax.tree.leaves(p0)))
        assert moved > 0
        err = max(float(np.abs(np.asarray(a) - np.asarray(b1)).max())
                  for a, b1 in zip(jax.tree.leaves(params), jax.tree.leaves(params1)))
        assert err < 5e-3, err
        print("OK", l_mesh, l_single, err)
    """)
    assert "OK" in out


def test_moe_and_hybrid_shard_on_mesh():
    out = run_devices(8, """
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models.model import build
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import shard_train_step, init_sharded
        from repro.optim import adamw

        for arch in ["granite-moe-3b-a800m", "jamba-1.5-large-398b"]:
            cfg = get_reduced(arch).replace(dtype="float32")
            model = build(cfg)
            mesh = make_host_mesh((2, 4))
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (4, 17), 0, cfg.vocab_size)}
            step, p_sh, o_sh, b_sh = shard_train_step(
                model, mesh, adamw.AdamWConfig(total_steps=5), batch)
            params, opt_state, _, _ = init_sharded(model, mesh)
            params, opt_state, metrics = step(params, opt_state, jax.device_put(batch, b_sh))
            assert jnp.isfinite(metrics["loss"]), arch
            print("OK", arch, float(metrics["loss"]))
    """)
    assert out.count("OK") == 2


def test_serve_fns_shard_and_decode():
    out = run_devices(8, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models.model import build
        from repro.launch.mesh import make_host_mesh
        from repro.launch.serve import shard_serve_fns

        cfg = get_reduced("yi-9b").replace(dtype="float32")
        model = build(cfg)
        mesh = make_host_mesh((4, 2))
        B, L = 8, 64
        prefill, decode, p_sh, s_sh = shard_serve_fns(model, mesh, B, L)
        params = jax.jit(model.init, out_shardings=p_sh)(jax.random.PRNGKey(0))
        state = jax.jit(lambda: model.init_decode_state(B, L), out_shardings=s_sh)()
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0, cfg.vocab_size)
        logits, state = prefill(params, {"tokens": toks}, state)
        for _ in range(4):
            logits, state = decode(params, state, jnp.argmax(logits, -1))
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        print("OK")
    """)
    assert "OK" in out


def test_checkpoint_elastic_rescale():
    out = run_devices(8, """
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models.model import build
        from repro.launch.mesh import make_host_mesh
        from repro.distributed.sharding import param_shardings
        from repro.checkpoint import ckpt

        cfg = get_reduced("yi-9b").replace(dtype="float32")
        model = build(cfg)
        mesh_a = make_host_mesh((2, 4))
        shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params = jax.jit(model.init, out_shardings=param_shardings(mesh_a, shape))(
            jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 7, params)
            assert ckpt.latest_step(d) == 7
            # restore onto a *different* mesh shape (elastic rescale)
            mesh_b = make_host_mesh((8, 1))
            restored = ckpt.restore(d, 7, shape, param_shardings(mesh_b, shape))
            err = max(float(jnp.abs(a - b).max()) for a, b in
                      zip(jax.tree.leaves(params), jax.tree.leaves(restored)))
            assert err == 0.0, err
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import (
            pipeline_apply, sequential_reference, stage_params_split)

        L, S, n_micro, mb, d = 8, 4, 8, 4, 16
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (L, d, d)) * (1.0 / np.sqrt(d))
        b = jnp.zeros((L, d))
        params = {"w": w, "b": b}
        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

        def layer_fn(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        mesh = jax.make_mesh((S,), ("stage",))
        got = pipeline_apply(layer_fn, stage_params_split(params, S), x, mesh)
        want = sequential_reference(layer_fn, params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

        # gradients flow through the pipeline schedule
        def loss(params):
            y = pipeline_apply(layer_fn, stage_params_split(params, S), x, mesh)
            return jnp.sum(y ** 2)
        g = jax.grad(loss)(params)
        def loss_ref(params):
            return jnp.sum(sequential_reference(layer_fn, params, x) ** 2)
        g_ref = jax.grad(loss_ref)(params)
        np.testing.assert_allclose(np.asarray(g["w"]), np.asarray(g_ref["w"]),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_grad_compression_converges():
    out = run_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim import grad_compress as gc

        mesh = jax.make_mesh((4,), ("data",))
        # toy regression, data-parallel over 4 devices
        k = jax.random.PRNGKey(0)
        w_true = jax.random.normal(k, (16,))
        X = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        y = X @ w_true

        def local_grad(w, xb, yb):
            return jax.grad(lambda w: jnp.mean((xb @ w - yb) ** 2))(w)

        # each data shard keeps its own error-feedback residual
        w = jnp.zeros((16,))
        errs = jnp.zeros((4, 16))

        @jax.jit
        def step(w, errs, X, y):
            def inner(w, e, xb, yb):
                g = local_grad(w, xb, yb)
                gg, e2 = gc.psum_compressed({"w": g}, {"w": e[0]}, ("data",))
                return gg["w"], e2["w"][None]
            g, errs = shard_map(inner, mesh=mesh,
                in_specs=(P(), P("data"), P("data"), P("data")),
                out_specs=(P(), P("data")), check_rep=False)(w, errs, X, y)
            return w - 0.2 * g, errs

        for i in range(200):
            w, errs = step(w, errs, X, y)
        final = float(jnp.mean((X @ w - y) ** 2))
        assert final < 1e-3, final
        print("OK", final)
    """)
    assert "OK" in out


def test_as_pipeline_rejects_unstackable_graphs():
    """FusedEngine.as_pipeline error paths (the happy path runs in
    tests/test_engine.py): heterogeneous ops, heterogeneous MVU shapes,
    mixed epilogue forms, and the xnor packed-width rejection must all fail
    with clear errors before any device work happens."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import lowering
    from repro.core.engine import FusedEngine
    from repro.core.ir import Node

    rng = np.random.default_rng(31)
    mesh = jax.make_mesh((1,), ("stage",))

    def mlp(dims, bits, with_bn=True):
        g = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
        for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
            w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
            g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
            if with_bn and i < len(dims) - 2:
                g.append(Node("batchnorm", f"bn{i}", {}, {
                    "gamma": jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32)),
                    "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
                    "mean": jnp.asarray(rng.normal(0, 1, n).astype(np.float32)),
                    "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
                }))
                g.append(Node("quant_act", f"act{i}",
                              {"bits": bits, "act_scale": 1.0}))
        return g

    def engine(dims, mode, bits, **kw):
        fin = lowering.finalize(lowering.lower_to_mvu(
            mlp(dims, bits, **kw), mode=mode, weight_bits=4, act_bits=bits))
        return FusedEngine(fin)

    # heterogeneous ops: a conv graph keeps a conv_mvu node in the chain
    g = [Node("input", "in", {"shape": (6, 6, 3), "bits": 2}),
         Node("conv", "c0", {"kernel": 3, "stride": 1, "pad": 0},
              {"w": jnp.asarray(rng.normal(0, 0.5, (3, 3, 3, 4)).astype(np.float32))})]
    conv_engine = FusedEngine(lowering.finalize(
        lowering.lower_to_mvu(g, mode="standard", weight_bits=4, act_bits=2)))
    with pytest.raises(ValueError, match="pure MVU chain"):
        conv_engine.as_pipeline(mesh)

    # heterogeneous (N, K) stage shapes cannot stack into one layer_fn
    with pytest.raises(ValueError, match="homogeneous"):
        engine([24, 16, 8], "standard", 2).as_pipeline(mesh)

    # xnor stages: the static packed width breaks parameter stacking
    with pytest.raises(ValueError, match="xnor"):
        engine([32, 32, 32], "xnor", 1).as_pipeline(mesh)

    # mixed epilogue forms: hidden stage carries fused thresholds, the head
    # runs raw accumulators -- stacking would silently change semantics
    mixed = engine([16, 16, 16], "standard", 2)
    mvus = [n for n in mixed.graph if n.op == "mvu"]
    assert mvus[0].params["mvu"].thresholds is not None
    assert mvus[-1].params["mvu"].thresholds is None
    with pytest.raises(ValueError, match="epilogue"):
        mixed.as_pipeline(mesh)


def test_dryrun_cell_lowers_on_host_mesh():
    """The dry-run cell builder (shardings + lower + compile + cost) works
    on a small host mesh with a reduced config — CI-scale proof of the
    sharding rules used by the 256/512-chip meshes."""
    out = run_devices(8, """
        import jax
        jax.devices()  # lock 8 host devices before importing dryrun
        from repro.launch import dryrun
        from repro.configs import get_reduced
        from repro.launch.mesh import make_host_mesh, use_mesh

        mesh = make_host_mesh((4, 2))
        for arch in ["yi-9b", "granite-moe-3b-a800m", "mamba2-780m"]:
            cfg = get_reduced(arch)
            fn, args, donate, shardings, cfg, acct = dryrun.build_cell(
                cfg, "train_4k", mesh)
            with use_mesh(mesh):
                compiled = jax.jit(fn, in_shardings=shardings,
                                   donate_argnums=donate).lower(*args).compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            assert cost.get("flops", 0) > 0
            coll = dryrun.parse_collective_bytes(
                compiled.as_text(), dryrun.scan_trip_count(cfg))
            print("OK", arch, int(coll["total_bytes"]))
    """, timeout=900)
    assert out.count("OK") == 3
