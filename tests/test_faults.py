"""Unit tests for the serving failure model's pure pieces: FaultPlan
determinism + JSON round-trip, the corruption/integrity pair, the interval
-arithmetic output bound, the replica health state machine, and the
brownout controller."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import lowering
from repro.core.ir import Node
from repro.serving import (
    BrownoutController,
    FaultEvent,
    FaultPlan,
    FaultPolicy,
    ReplicaHealth,
    check_integrity,
    infer_output_range,
)
from repro.serving.faults import corrupt_array
from repro.serving.health import HEALTHY, QUARANTINED, SUSPECT


def _mlp_graph(dims=(24, 16, 8), bits=2, seed=3):
    rng = np.random.default_rng(seed)
    g = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(dims) - 2:
            g.append(Node("quant_act", f"act{i}", {"bits": bits, "act_scale": 1.0}))
    return lowering.finalize(
        lowering.lower_to_mvu(g, mode="standard", weight_bits=4, act_bits=bits))


# ---------------------------------------------------------------- fault plan
def test_fault_plan_draw_is_deterministic_and_timing_independent():
    plan = FaultPlan(seed=11, rates={"error": 0.1, "corrupt": 0.1})
    draws = [plan.draw(r, k) for r in range(3) for k in range(50)]
    # replaying the plan (any order) reproduces the identical schedule
    replay = [plan.draw(r, k) for r in range(3) for k in range(50)]
    assert draws == replay
    shuffled = [plan.draw(r, k) for r in reversed(range(3))
                for k in reversed(range(50))]
    assert draws == list(reversed(shuffled))
    kinds = {d.kind for d in draws if d is not None}
    assert kinds <= {"error", "corrupt"} and kinds  # both rates fire at n=150


def test_fault_plan_rates_approximate_probabilities():
    plan = FaultPlan(seed=0, rates={"error": 0.2})
    n = 2000
    hits = sum(plan.draw(0, k) is not None for k in range(n))
    assert 0.15 < hits / n < 0.25


def test_fault_plan_explicit_events_override_rates():
    plan = FaultPlan(seed=0, rates={"error": 1.0},
                     events=[FaultEvent("hang", replica=1, at_dispatch=3)])
    ev = plan.draw(1, 3)
    assert ev.kind == "hang"  # the event suppresses the certain rate draw
    assert plan.draw(1, 4).kind == "error"


def test_fault_plan_replica_scoping_and_validation():
    plan = FaultPlan(seed=0, rates={"error": 1.0}, replicas=(2,))
    assert plan.draw(0, 0) is None and plan.draw(2, 0).kind == "error"
    with pytest.raises(ValueError, match="kind"):
        FaultPlan(rates={"explode": 0.5})
    with pytest.raises(ValueError, match="rate"):
        FaultPlan(rates={"error": 1.5})
    with pytest.raises(ValueError, match="kind"):
        FaultEvent("explode", 0, 0)


def test_fault_plan_json_round_trip(tmp_path):
    plan = FaultPlan(seed=7, rates={"straggle": 0.05}, straggle_delay_s=0.02,
                     events=[FaultEvent("die", 2, 9)], replicas=(0, 1))
    path = plan.save(str(tmp_path / "plan.json"))
    back = FaultPlan.load(path)
    assert back == plan
    assert [back.draw(r, k) for r in range(3) for k in range(30)] == \
           [plan.draw(r, k) for r in range(3) for k in range(30)]


def test_corrupt_array_is_deterministic_and_out_of_place():
    y = np.arange(12, dtype=np.int32).reshape(3, 4)
    a = corrupt_array(y, FaultPlan(seed=5).corruption_rng(0, 0))
    b = corrupt_array(y, FaultPlan(seed=5).corruption_rng(0, 0))
    np.testing.assert_array_equal(a, b)  # same rng key, same corruption
    np.testing.assert_array_equal(y, np.arange(12).reshape(3, 4))  # no mutation
    assert (a != y).any()
    assert (np.abs(a.astype(np.int64)) >= (1 << 30)).any()  # high-bit flip
    f = corrupt_array(y.astype(np.float32),
                      FaultPlan(seed=5).corruption_rng(0, 1))
    assert np.isnan(f).any()


# ------------------------------------------------------------ integrity guard
def test_infer_output_range_bounds_the_real_engine_output():
    from repro.core.engine import FusedEngine

    graph = _mlp_graph()
    lo, hi = infer_output_range(graph)
    engine = FusedEngine(graph)
    xs = np.random.default_rng(0).integers(0, 4, (64, 24)).astype(np.int32)
    ys = np.asarray(engine(jnp.asarray(xs)))
    assert lo <= float(ys.min()) and float(ys.max()) <= hi
    # the bound is tight enough that a high-bit flip escapes it
    assert hi < 2**30 and lo > -(2**30)


def test_infer_output_range_returns_none_on_unknown_ops():
    g = [Node("input", "in", {"shape": (4,), "bits": 2}),
         Node("mystery", "m", {}, {})]
    assert infer_output_range(g) is None


def test_check_integrity_catches_corruption_but_passes_clean():
    from repro.core.engine import FusedEngine

    graph = _mlp_graph()
    rng_bound = infer_output_range(graph)
    engine = FusedEngine(graph)
    xs = np.random.default_rng(1).integers(0, 4, (8, 24)).astype(np.int32)
    ys = np.asarray(engine(jnp.asarray(xs)))
    assert check_integrity(ys, dtype=ys.dtype, value_range=rng_bound) is None
    bad = corrupt_array(ys, FaultPlan(seed=1).corruption_rng(0, 0))
    reason = check_integrity(bad, dtype=ys.dtype, value_range=rng_bound)
    assert reason is not None  # NaN (float out) or range escape (int out)
    assert "dtype" in check_integrity(ys.astype(np.int64), dtype=ys.dtype)
    nan = np.full((2, 3), np.nan, np.float32)
    assert "finite" in check_integrity(nan, dtype=np.float32)
    # integer path: a high-bit flip escapes the interval bound exactly
    iy = np.arange(12, dtype=np.int32).reshape(3, 4)
    ibad = corrupt_array(iy, FaultPlan(seed=2).corruption_rng(0, 0))
    assert "range" in check_integrity(ibad, value_range=(0.0, 11.0))
    assert check_integrity(iy, value_range=(0.0, 11.0)) is None


# ---------------------------------------------------------------- health fsm
def test_health_failure_ladder_and_recovery_by_success():
    p = FaultPolicy(suspect_after=1, quarantine_after=3)
    h = ReplicaHealth(p)
    assert h.state == HEALTHY and h.usable
    h.record_failure(0.0, "boom")
    assert h.state == SUSPECT and h.usable
    h.record_success(0.01)  # a clean resolve clears suspicion
    assert h.state == HEALTHY and h.consecutive_failures == 0
    for t in (1.0, 2.0, 3.0):
        h.record_failure(t, "boom")
    assert h.state == QUARANTINED and not h.usable
    assert h.quarantine_reason == "boom"
    assert h.next_probe_at == pytest.approx(3.0 + p.probe_backoff_s)


def test_health_straggles_escalate_to_quarantine_verdict():
    p = FaultPolicy(straggler_min_samples=4, straggler_factor=3.0,
                    straggles_to_quarantine=2)
    h = ReplicaHealth(p)
    for _ in range(6):
        assert h.record_success(0.010) is None
    assert h.record_success(0.100) == "straggle"
    assert h.state == SUSPECT
    assert h.record_success(0.100) == "quarantine"  # caller quarantines


def test_health_probe_backoff_caps_and_recovery_resets():
    p = FaultPolicy(probe_backoff_s=0.1, probe_backoff_cap_s=0.3)
    h = ReplicaHealth(p)
    h.quarantine(0.0, "corrupt output")
    assert h.due_probe(0.1) and not h.due_probe(0.05)
    assert not h.note_probe(False, 0.1)
    assert h.next_probe_at == pytest.approx(0.3)  # 0.1 * 2^1
    assert not h.note_probe(False, 0.3)
    assert h.next_probe_at == pytest.approx(0.6)  # capped at 0.3 backoff
    assert h.note_probe(True, 0.6)
    assert h.state == HEALTHY and h.recoveries == 1
    assert h.quarantine_reason is None and h.next_probe_at is None


def test_health_policy_disabled_hedge_delay():
    assert FaultPolicy.disabled().hedge_delay(1.0) is None
    assert FaultPolicy(hedging=True, hedge_after_s=0.2).hedge_delay(1.0) == 0.2
    p = FaultPolicy(hedging=True, hedge_factor=4.0)
    assert p.hedge_delay(0.0) is None  # EWMA unarmed: never hedge blind
    assert p.hedge_delay(0.05) == pytest.approx(0.2)


# ------------------------------------------------------------------ brownout
def test_brownout_levels_and_hysteresis():
    p = FaultPolicy(brownout_healthy_frac=0.5, severe_healthy_frac=0.25,
                    brownout_depth_frac=0.75, brownout_cooldown_s=1.0)
    b = BrownoutController(p)
    assert b.update(healthy_frac=1.0, depth_frac=0.1, now=0.0) == 0
    assert b.update(healthy_frac=0.5, depth_frac=0.1, now=1.0) == 1
    assert b.shedding_best_effort and not b.shrink_buckets
    assert b.update(healthy_frac=0.25, depth_frac=0.1, now=2.0) == 2
    assert b.shrink_buckets
    # pressure gone, but de-escalation waits out the cooldown
    assert b.update(healthy_frac=1.0, depth_frac=0.0, now=2.5) == 2
    assert b.update(healthy_frac=1.0, depth_frac=0.0, now=3.6) == 0
    # queue pressure alone also browns out
    assert b.update(healthy_frac=1.0, depth_frac=0.8, now=4.0) == 1
    assert b.update(healthy_frac=1.0, depth_frac=1.0, now=4.1) == 2


def test_brownout_disabled_policy_stays_level_zero():
    b = BrownoutController(FaultPolicy.disabled())
    assert b.update(healthy_frac=0.0, depth_frac=1.0, now=0.0) == 0
