import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import quantize as q


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 3, 4, 8]))
def test_weight_quant_grid_and_range(seed, bits):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, (6, 40)).astype(np.float32)
    qt = q.quantize_weights(jnp.asarray(w), bits)
    lo, hi = q.int_bounds(bits, signed=True)
    vals = np.asarray(qt.values)
    assert vals.min() >= lo and vals.max() <= hi
    # dequantized error bounded by half a step per element
    deq = vals * np.asarray(qt.scale)
    step = np.asarray(qt.scale)
    assert (np.abs(deq - w) <= step / 2 + 1e-6).all()


def test_weight_quant_binary_sign():
    w = jnp.asarray([[0.5, -0.2, 0.0, -3.0]])
    qt = q.quantize_weights(w, 1)
    np.testing.assert_array_equal(np.asarray(qt.values), [[1, -1, 1, -1]])


def test_fake_quant_ste_gradient_passthrough():
    w = jnp.linspace(-2, 2, 64).reshape(4, 16)
    g = jax.grad(lambda x: jnp.sum(q.fake_quant_weights(x, 4)))(w)
    # STE: gradient of sum is ~1 everywhere (scale held via stop_gradient)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(np.asarray(g)), atol=1e-5)


def test_fake_quant_activations_levels():
    x = jnp.linspace(-1, 2, 101)
    y = np.asarray(q.fake_quant_activations(x, 2, max_val=1.0))
    levels = np.unique(np.round(y * 3).astype(int))
    assert set(levels).issubset({0, 1, 2, 3})
    assert y.min() >= 0 and y.max() <= 1.0


def test_binarize_bipolar_values_and_grad():
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.7, 3.0])
    y = np.asarray(q.binarize_bipolar(x))
    np.testing.assert_array_equal(y, [-1, -1, 1, 1, 1])
    g = jax.grad(lambda v: jnp.sum(q.binarize_bipolar(v)))(x)
    # clipped-identity STE: grad 1 inside [-1,1], 0 outside
    np.testing.assert_array_equal(np.asarray(g), [0, 1, 1, 1, 0])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quantize_dequantize_idempotent(seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, (4, 32)).astype(np.float32)
    qt = q.quantize_weights(jnp.asarray(w), 4)
    deq = np.asarray(qt.values) * np.asarray(qt.scale)
    qt2 = q.quantize_weights(jnp.asarray(deq), 4)
    deq2 = np.asarray(qt2.values) * np.asarray(qt2.scale)
    np.testing.assert_allclose(deq, deq2, atol=1e-5)
