import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import thresholds as th


def test_apply_thresholds_basic():
    acc = jnp.asarray([[-5, 0, 5]]).T  # (3,1)
    t = jnp.asarray([[-2, 1, 4]])  # one channel, 3 thresholds
    out = np.asarray(th.apply_thresholds(acc, jnp.tile(t, (1, 1))))
    # channel 0 thresholds [-2,1,4]: acc -5 ->0; 0 ->1; 5 ->3
    np.testing.assert_array_equal(out[:, 0], [0, 1, 3])


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_thresholds_equal_bn_then_quant(seed, bits):
    """The folded integer thresholds reproduce quant(BN(acc)) exactly."""
    rng = np.random.default_rng(seed)
    c = 8
    gamma = rng.uniform(-2, 2, c).astype(np.float32)
    gamma[np.abs(gamma) < 1e-2] = 0.5  # keep away from zero
    beta = rng.uniform(-1, 1, c).astype(np.float32)
    mean = rng.uniform(-5, 5, c).astype(np.float32)
    var = rng.uniform(0.1, 4, c).astype(np.float32)
    act_scale = 1.0
    n_levels = 2**bits

    t, flip = th.bn_quant_thresholds(
        jnp.asarray(gamma), jnp.asarray(beta), jnp.asarray(mean), jnp.asarray(var),
        bits=bits, act_scale=act_scale,
    )
    t_int = th.integerize_thresholds(t)

    acc = rng.integers(-50, 50, (64, c)).astype(np.int32)
    # reference: BN then round-to-nearest unsigned quantizer
    std = np.sqrt(var + 1e-5)
    y = (acc - mean) * gamma / std + beta
    want = np.clip(np.round(y / act_scale), 0, n_levels - 1).astype(np.int32)

    acc_eff = np.where(np.asarray(flip)[None, :], -acc, acc)
    got = np.asarray(th.apply_thresholds(jnp.asarray(acc_eff), t_int))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_threshold_activation_monotone(seed):
    rng = np.random.default_rng(seed)
    t = np.sort(rng.integers(-100, 100, (4, 7)), axis=1)
    acc = np.sort(rng.integers(-200, 200, (32, 4)), axis=0)
    out = np.asarray(th.apply_thresholds(jnp.asarray(acc), jnp.asarray(t)))
    assert (np.diff(out, axis=0) >= 0).all()  # nondecreasing in acc


def test_streamline_signs():
    w = jnp.asarray([[1, -2], [3, 4]], jnp.float32)
    flip = jnp.asarray([True, False])
    out = np.asarray(th.streamline_signs(w, flip))
    np.testing.assert_array_equal(out, [[-1, 2], [3, 4]])
