"""Streaming conv path tests: the SWU lowering and the fused SWU+MVU kernel
against ``jax.lax.conv_general_dilated`` over the full (kernel, stride, pad)
grid, plus graph-level fusion (``fuse_swu``) and the CNV topology end-to-end."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import cnv_bnn
from repro.core import dataflow, lowering, swu
from repro.core.engine import FusedEngine
from repro.core.folding import Folding
from repro.core.ir import Graph, Node
from repro.kernels import ops, packing

GRID = [(kd, st, pd) for kd in (1, 3, 5) for st in (1, 2) for pd in (0, 1, 2)]
MODES = ("standard", "binary", "xnor")


def _lax_conv(x, w, stride, pad):
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


@pytest.mark.parametrize("kd,stride,pad", GRID)
def test_sliding_window_matches_lax_conv(kd, stride, pad):
    """swu.sliding_window x packed weights == lax conv, non-square input."""
    rng = np.random.default_rng(kd * 100 + stride * 10 + pad)
    x = rng.normal(size=(2, 9, 13, 3)).astype(np.float32)
    w = rng.normal(size=(kd, kd, 3, 5)).astype(np.float32)
    got = swu.conv_via_swu_mvu(jnp.asarray(x), jnp.asarray(w), stride, pad)
    want = _lax_conv(jnp.asarray(x), jnp.asarray(w), stride, pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("i,kd,stride,pad", [(i, *g) for i, g in enumerate(GRID)])
def test_conv_mvu_kernel_matches_lax_conv(i, kd, stride, pad):
    """Fused line-buffer kernel == lax conv, exact integer equality.

    The weight coding rotates through the grid so every (mode, kernel,
    stride, pad) region is covered without tripling the compile count."""
    mode = MODES[i % len(MODES)]
    rng = np.random.default_rng(i)
    h, wdim, c, n = 8, 11, 3, 6  # non-square on purpose
    k = kd * kd * c
    if mode == "standard":
        x = rng.integers(0, 8, (2, h, wdim, c)).astype(np.int32)
        w_rows = rng.integers(-7, 8, (n, k)).astype(np.int8)
        w_arg, x_arg = jnp.asarray(w_rows), jnp.asarray(x)
        x_f, w_f = x, w_rows
    elif mode == "binary":
        x = rng.integers(0, 8, (2, h, wdim, c)).astype(np.int32)
        bits = rng.integers(0, 2, (n, k)).astype(np.int8)
        w_arg, x_arg = jnp.asarray(bits), jnp.asarray(x)
        x_f, w_f = x, 2 * bits - 1  # {0,1}-coded +/-1
    else:  # xnor: both operands bipolar
        x = rng.integers(0, 2, (2, h, wdim, c)).astype(np.int32)
        bits = rng.integers(0, 2, (n, k)).astype(np.int32)
        w_arg, x_arg = packing.pack_bits(jnp.asarray(bits)), jnp.asarray(x)
        x_f, w_f = 2 * x - 1, 2 * bits - 1
    got = np.asarray(ops.conv_mvu(
        x_arg, w_arg, kernel=kd, stride=stride, pad=pad, mode=mode,
        k_bits=k if mode == "xnor" else None))
    # reference: lax conv on the equivalent float weights, (ky, kx, c) order
    w_hwio = np.asarray(w_f).reshape(n, kd, kd, c).transpose(1, 2, 3, 0)
    want = np.asarray(_lax_conv(jnp.asarray(x_f), jnp.asarray(w_hwio),
                                stride, pad)).astype(np.int64)
    if mode == "xnor" and pad:
        # zero pad pixels contribute -1 per synapse in the bipolar view;
        # the line-buffer kernel treats pads as stored-bit 0 == -1, and so
        # does the reference once x is mapped to 2x-1 *before* padding, so
        # re-derive the reference with explicitly padded bipolar input.
        xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        want = np.asarray(_lax_conv(jnp.asarray(2 * xp - 1),
                                    jnp.asarray(w_hwio), stride, 0))
    b = x.shape[0]
    assert got.shape[0] == b
    np.testing.assert_array_equal(got.reshape(want.shape), want)


def test_conv_mvu_kernel_threshold_epilogue():
    """Fused kernel thresholds == materialized SWU + threshold reference."""
    rng = np.random.default_rng(3)
    kd, st, pd, c, n = 3, 1, 1, 4, 5
    k = kd * kd * c
    x = jnp.asarray(rng.integers(0, 4, (2, 7, 9, c)), jnp.int32)
    w = jnp.asarray(rng.integers(-7, 8, (n, k)), jnp.int8)
    t = jnp.asarray(np.sort(rng.integers(-30, 30, (n, 3)), axis=1), jnp.int32)
    got = ops.conv_mvu(x, w, kernel=kd, stride=st, pad=pd, thresholds=t)
    want = ops.conv_mvu(x, w, kernel=kd, stride=st, pad=pd, thresholds=t,
                        backend="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(jnp.max(got)) <= 3 and int(jnp.min(got)) >= 0


def test_fuse_swu_collapses_pairs():
    g: Graph = [Node("input", "in", {"shape": (8, 8, 3), "bits": 2})]
    rng = np.random.default_rng(0)
    g.append(Node("conv", "c0", {"kernel": 3, "stride": 1, "pad": 1},
                  {"w": jnp.asarray(rng.normal(0, .5, (3, 3, 3, 4)).astype(np.float32))}))
    fin = lowering.finalize(lowering.lower_to_mvu(g, mode="standard"))
    assert [n.op for n in fin] == ["input", "swu", "mvu"]
    fused = lowering.fuse_swu(fin)
    assert [n.op for n in fused] == ["input", "conv_mvu"]
    node = fused[1]
    assert node.attrs["kernel"] == 3 and node.attrs["pad"] == 1
    assert node.name == "c0.conv_mvu" and "mvu" in node.params
    # un-finalized mvu nodes (still float) must NOT fuse
    raw = lowering.lower_to_mvu(g, mode="standard")
    assert [n.op for n in lowering.fuse_swu(raw)] == ["input", "swu", "mvu"]


@pytest.mark.parametrize("mode", MODES)
def test_cnv_engine_bit_exact_vs_interpreter(mode):
    """CNV-style graph (>=2 conv + pool + dense): FusedEngine == interpreter,
    all swu+mvu pairs collapsed into conv_mvu stages."""
    bits = 1 if mode == "xnor" else 2
    spec = cnv_bnn.CNVSpec(image=10, channels=(4, 4), pool_after=(1,),
                           fc=(8, 4), weight_bits=1 if mode != "standard" else 4,
                           act_bits=bits)
    g = cnv_bnn.build_graph(spec, seed=2)
    fin = lowering.finalize(lowering.lower_to_mvu(
        g, mode=mode, weight_bits=spec.weight_bits, act_bits=bits))
    fin = lowering.apply_folding(fin)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(0, 2**bits, (3, 10, 10, 3)), jnp.int32)

    want = np.asarray(dataflow.execute(fin, x))
    engine = FusedEngine(fin)
    got = np.asarray(engine(x))
    np.testing.assert_array_equal(got, want)
    ops_left = [n.op for n in engine.graph]
    assert "swu" not in ops_left and "batchnorm" not in ops_left
    assert ops_left.count("conv_mvu") == 2 and ops_left.count("mvu") == 2
    assert got.shape == (3, 4)


def test_conv_folding_pixel_cycles():
    """Conv folding counts the pixel dimension: cycles = OH*OW * NF * SF."""
    f = Folding(pe=4, simd=9)
    assert f.conv_cycles(8, 36, oh=6, ow=5) == 30 * (8 // 4) * (36 // 9)
    # apply_folding threads conv pixel counts into the schedule
    rng = np.random.default_rng(1)
    g: Graph = [Node("input", "in", {"shape": (8, 8, 3), "bits": 2}),
                Node("conv", "c0", {"kernel": 3, "stride": 1, "pad": 0},
                     {"w": jnp.asarray(rng.normal(0, .5, (3, 3, 3, 4)).astype(np.float32))})]
    fin = lowering.fuse_swu(lowering.finalize(lowering.lower_to_mvu(g)))
    fin = lowering.apply_folding(fin, max_pe=4, max_simd=9)
    sched = dataflow.schedule(fin)
    st = sched.stages[0]
    fold = fin[1].attrs["config"].resolved_folding()
    assert st.n_pixels == 36
    assert st.cycles == fold.conv_cycles(4, 27, oh=6, ow=6)


def test_sliding_window_property_random_shapes():
    """Hypothesis sweep: sliding_window + fused kernel == lax conv for
    arbitrary shapes/strides/pads (nightly CI installs hypothesis)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(
        h=st.integers(5, 12), w=st.integers(5, 12),
        c=st.integers(1, 4), kd=st.sampled_from([1, 3, 5]),
        stride=st.integers(1, 2), pad=st.integers(0, 2),
        seed=st.integers(0, 2**16),
    )
    def check(h, w, c, kd, stride, pad, seed):
        hypothesis.assume(h + 2 * pad >= kd and w + 2 * pad >= kd)
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(1, h, w, c)).astype(np.float32)
        wt = rng.normal(size=(kd, kd, c, 3)).astype(np.float32)
        got = swu.conv_via_swu_mvu(jnp.asarray(x), jnp.asarray(wt), stride, pad)
        want = _lax_conv(jnp.asarray(x), jnp.asarray(wt), stride, pad)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=1e-3)
        # and the fused line-buffer kernel, exact on the integer grid
        xi = jnp.asarray(rng.integers(0, 8, (1, h, w, c)), jnp.int32)
        wi = jnp.asarray(rng.integers(-7, 8, (3, kd * kd * c)), jnp.int8)
        kw = dict(kernel=kd, stride=stride, pad=pad, mode="standard")
        fused = ops.conv_mvu(xi, wi, **kw)
        ref = ops.conv_mvu(xi, wi, backend="xla", **kw)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))

    check()
