"""Autotuner tests: candidate enumeration + model pruning, cache
persistence, tune_graph purity, engine integration, and the zero-
measurement guarantee of ``FusedEngine(tune="cache")``."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import autotune, dataflow, lowering
from repro.core.engine import FusedEngine
from repro.core.ir import Graph, Node
from repro.core.mvu import KernelBlocks, MVUConfig


def _mlp_graph(rng, dims, bits=2) -> Graph:
    g: Graph = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(dims) - 2:
            g.append(Node("quant_act", f"act{i}", {"bits": bits, "act_scale": 1.0}))
    return g


def _finalized(rng, dims, mode="standard", bits=2) -> Graph:
    g = _mlp_graph(rng, dims, bits)
    return lowering.finalize(
        lowering.lower_to_mvu(g, mode=mode, weight_bits=4, act_bits=bits))


def _no_timer(*a, **kw):
    raise AssertionError("timer must not run in cache mode")


# ------------------------------------------------------------ candidates
def test_candidates_pruned_and_ordered():
    cfg = MVUConfig(in_features=96, out_features=24)
    cands = autotune.enumerate_candidates(cfg, vmem_bytes=1 << 30)
    pallas = [c for c in cands if c.backend == "pallas"]
    # ordered by the analytic cycle model: measurement starts from the
    # model's best guess
    measured_order = [c.predicted_cycles for c in pallas[:-1] or pallas]
    assert measured_order == sorted(measured_order)
    # the xla backend is always in the design space
    assert any(c.backend == "xla" for c in cands)
    # block shapes are legal: clamped to the TPU minima
    assert all(c.blocks.block_n >= 8 and c.blocks.block_k >= 8 for c in pallas)


def test_candidates_vmem_pruning_rejects_over_budget():
    cfg = MVUConfig(in_features=2048, out_features=512)
    tight = autotune.enumerate_candidates(cfg, vmem_bytes=64 * 1024)
    loose = autotune.enumerate_candidates(cfg, vmem_bytes=1 << 30)
    # the shortlists exclude the heuristic/xla fallbacks appended at the end
    tight_measured = [c for c in tight if c.vmem_bytes > 0]
    loose_measured = [c for c in loose if c.vmem_bytes > 0]
    assert all(c.vmem_bytes <= 64 * 1024 for c in tight_measured)
    assert len(tight_measured) < len(loose_measured)


def test_conv_candidates_use_conv_working_set():
    cfg = MVUConfig(in_features=27, out_features=8, mode="xnor")
    cands = autotune.enumerate_candidates(
        cfg, n_pixels=36, in_shape=(8, 8, 3),
        conv={"kernel": 3, "stride": 1, "pad": 0}, vmem_bytes=1 << 30)
    pallas = [c for c in cands if c.backend == "pallas" and c.vmem_bytes > 0]
    assert pallas, "conv enumeration produced no measurable candidates"
    # conv schedules only vary block_m x block_n
    assert {c.blocks.block_n for c in pallas} >= {8}


# ----------------------------------------------------------------- cache
def test_cache_roundtrip(tmp_path):
    cache = autotune.ScheduleCache()
    key = "cpu|standard|n8|k16|thresh|px1"
    cache.put(key, {"backend": "xla", "block_m": 32, "block_n": 8,
                    "block_k": 16, "block_kw": 8})
    path = str(tmp_path / "cache.json")
    cache.save(path)
    back = autotune.ScheduleCache.load(path)
    assert back.get(key) == cache.get(key)
    assert key in back and len(back) == 1


def test_cache_version_mismatch_raises(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text('{"version": 99, "entries": {}}')
    with pytest.raises(ValueError):
        autotune.ScheduleCache.load(str(path))


def test_default_cache_contains_committed_schedules():
    cache = autotune.default_cache()
    from repro.configs import cnv_bnn, nid_mlp

    for key in nid_mlp.TUNED_SCHEDULES:
        assert key in cache
    for key in cnv_bnn.TUNED_SCHEDULES:
        assert key in cache


# ------------------------------------------------------------ tune_graph
def test_tune_graph_cache_mode_is_pure_lookup():
    rng = np.random.default_rng(0)
    fin = _finalized(rng, [16, 8])
    key = autotune.node_key(fin[1].attrs["config"], epilogue="scale")
    cache = autotune.ScheduleCache({key: {
        "backend": "xla", "block_m": 64, "block_n": 8, "block_k": 16,
        "block_kw": 8}})
    tuned = autotune.tune_graph(fin, cache=cache, mode="cache",
                                timer=_no_timer)
    cfg = tuned[1].attrs["config"]
    assert cfg.backend == "xla"
    assert cfg.blocks == KernelBlocks(block_m=64, block_n=8, block_k=16,
                                      block_kw=8)
    assert cfg.block_m == 64
    # purity: the input graph keeps its heuristic config
    assert fin[1].attrs["config"].blocks is None
    assert fin[1].attrs["config"].backend == "pallas"


def test_tune_graph_cache_miss_keeps_heuristic():
    rng = np.random.default_rng(1)
    fin = _finalized(rng, [16, 8])
    tuned = autotune.tune_graph(fin, cache=autotune.ScheduleCache(),
                                mode="cache", timer=_no_timer)
    assert tuned[1].attrs["config"].blocks is None


def test_tune_graph_auto_fills_cache_and_stays_bit_exact():
    rng = np.random.default_rng(2)
    fin = _finalized(rng, [24, 12, 8])
    cache = autotune.ScheduleCache()
    tuned = autotune.tune_graph(fin, cache=cache, mode="auto",
                                sample_m=32, reps=1, max_measure=2)
    assert len(cache) == 2  # one entry per mvu node
    x = jnp.asarray(rng.integers(0, 4, (9, 24)), jnp.int32)
    want = np.asarray(dataflow.execute(fin, x))
    got = np.asarray(dataflow.execute(tuned, x))
    np.testing.assert_array_equal(got, want)


def test_tune_graph_rejects_unknown_mode():
    rng = np.random.default_rng(3)
    fin = _finalized(rng, [16, 8])
    with pytest.raises(ValueError):
        autotune.tune_graph(fin, cache=autotune.ScheduleCache(), mode="always")


# ---------------------------------------------------------------- engine
def test_engine_cache_mode_zero_measurement(monkeypatch):
    """Acceptance: tune="cache" is a pure cache lookup -- constructing the
    engine must never invoke the timer, even on a fully-populated cache."""
    monkeypatch.setattr(autotune, "paired_timer", _no_timer)
    rng = np.random.default_rng(4)
    fin = _finalized(rng, [24, 12, 8])
    cache = autotune.ScheduleCache()
    for node in lowering.fuse_epilogues(fin):
        if node.op != "mvu":
            continue
        key = autotune.node_key(
            node.attrs["config"],
            epilogue=autotune.epilogue_form(node.params["mvu"]))
        cache.put(key, {"backend": "xla", "block_m": 32, "block_n": 16,
                        "block_k": 32, "block_kw": 8})
    engine = FusedEngine(fin, tune="cache", cache=cache)
    cfgs = [n.attrs["config"] for n in engine.graph if n.op == "mvu"]
    assert all(c.backend == "xla" and c.blocks is not None for c in cfgs)
    # ... and tune="auto" on a cache miss WOULD measure (the stub trips),
    # proving the stub observes the measurement path
    with pytest.raises(AssertionError, match="timer must not run"):
        FusedEngine(fin, tune="auto", cache=autotune.ScheduleCache())


def test_engine_tuned_bit_exact_with_heuristic():
    rng = np.random.default_rng(5)
    fin = _finalized(rng, [32, 16, 8])
    cache = autotune.ScheduleCache()
    FusedEngine(fin, tune="auto", cache=cache,  # fill by measuring once
                tune_kwargs={"sample_m": 32, "reps": 1, "max_measure": 3})
    x = jnp.asarray(rng.integers(0, 4, (21, 32)), jnp.int32)
    want = np.asarray(FusedEngine(fin)(x))
    got = np.asarray(FusedEngine(fin, tune="cache", cache=cache)(x))
    np.testing.assert_array_equal(got, want)


def test_engine_rejects_unknown_tune_mode():
    rng = np.random.default_rng(6)
    fin = _finalized(rng, [16, 8])
    with pytest.raises(ValueError):
        FusedEngine(fin, tune="yes")


def test_engine_microbatch_entry_overrides_plan():
    rng = np.random.default_rng(7)
    fin = _finalized(rng, [16, 8])
    engine = FusedEngine(fin)
    key = autotune.engine_key(engine.graph)
    cache = autotune.ScheduleCache({key: {"microbatch": 4, "batch": 64}})
    tuned = FusedEngine(fin, tune="cache", cache=cache)
    assert tuned._tile == 4
    assert tuned.plan(64).n_micro == 16
    x = jnp.asarray(rng.integers(0, 4, (13, 16)), jnp.int32)
    np.testing.assert_array_equal(np.asarray(tuned(x)),
                                  np.asarray(engine(x)))


def test_tune_engine_records_entry():
    rng = np.random.default_rng(8)
    fin = _finalized(rng, [16, 8])
    cache = autotune.ScheduleCache()
    calls = []

    def fake_timer(fa, fb, *args, **kw):
        calls.append(1)
        return (1.0, 0.5, 2.0)  # candidate "wins" by 2x

    entry = autotune.tune_engine(fin, 32, cache=cache, timer=fake_timer)
    assert calls, "tune_engine measured no candidates"
    key = autotune.engine_key(FusedEngine(fin).graph)
    assert cache.get(key) == entry
    assert entry["microbatch"] >= 1 and entry["speedup"] == 2.0


def test_tune_engine_baseline_ignores_prior_engine_entry():
    """Re-tuning must baseline against the heuristic plan, not the previous
    engine entry -- otherwise the recorded speedup silently becomes
    relative-to-last-tuning and candidate tiles drift."""
    rng = np.random.default_rng(10)
    fin = _finalized(rng, [16, 8])
    heur_tile = FusedEngine(fin).plan(32).microbatch
    key = autotune.engine_key(FusedEngine(fin).graph)
    cache = autotune.ScheduleCache({key: {"microbatch": 999, "batch": 32,
                                          "speedup": 9.9}})

    def never_wins(fa, fb, *args, **kw):
        return (1.0, 1.0, 1.0)

    entry = autotune.tune_engine(fin, 32, cache=cache, timer=never_wins)
    assert entry["microbatch"] == heur_tile  # not 999 or a 999-multiple
    assert entry["speedup"] == 1.0


# ------------------------------------------- config-time schedule legality
def test_illegal_explicit_folding_fails_at_config_time():
    """Regression: an MVUConfig with a non-divisor PE/SIMD folding must fail
    when the folding is resolved (config time), not silently mis-tile."""
    from repro.core.folding import Folding

    bad_pe = MVUConfig(in_features=64, out_features=64, folding=Folding(3, 2))
    with pytest.raises(ValueError, match="PE=3"):
        bad_pe.resolved_folding()
    with pytest.raises(ValueError):
        bad_pe.kernel_blocks()
    bad_simd = MVUConfig(in_features=600, out_features=64,
                         folding=Folding(64, 7))
    with pytest.raises(ValueError, match="SIMD=7"):
        bad_simd.kernel_blocks()
    # legal foldings (the paper's Table 6 choices) still resolve
    ok = MVUConfig(in_features=600, out_features=64, folding=Folding(64, 50))
    assert ok.resolved_folding() == Folding(64, 50)
    assert ok.kernel_blocks()["block_n"] == 64


def test_resource_model_uses_actual_kernel_blocks():
    """Regression: the VMEM estimate must reflect the clamped blocks the
    kernel really allocates, not the raw PE/SIMD folding."""
    from repro.core.folding import Folding
    from repro.core.resource_model import mvu_resources

    n, k = 4, 6
    fold = Folding(1, 1)  # raw model would claim a ~1-byte weight tile
    res = mvu_resources(n, k, fold, mode="standard", weight_bits=4,
                        block_m=32)
    # to_tpu_blocks clamps to block_n=8, block_k=8; K pads to one 8-step
    a_tile = 32 * 8          # block_m x padded-K int8
    w_tile = 8 * 8           # block_n x block_k int8
    acc = 32 * 8 * 4         # int32 accumulators
    out = 32 * 8 * 4
    assert res.lut_bytes == a_tile + w_tile + acc + out
    # an explicit (tuned) schedule overrides the derived one
    res2 = mvu_resources(n, k, fold, mode="standard", weight_bits=4,
                         blocks={"block_m": 8, "block_n": 8, "block_k": 8})
    assert res2.lut_bytes == 8 * 8 + 8 * 8 + 8 * 8 * 4 + 8 * 8 * 4
    # BRAM/cycle terms stay on the folding abstraction
    assert res.cycles == fold.cycles(n, k)
    assert res.bram_bytes == res2.bram_bytes


def test_explicit_blocks_override_folding_derivation():
    cfg = MVUConfig(in_features=64, out_features=32,
                    blocks=KernelBlocks(block_m=64, block_n=16, block_k=32))
    assert cfg.kernel_blocks() == {"block_m": 64, "block_n": 16, "block_k": 32}
    xcfg = MVUConfig(in_features=64, out_features=32, mode="xnor",
                     blocks=KernelBlocks(block_m=64, block_n=16, block_kw=2))
    assert xcfg.kernel_blocks() == {"block_m": 64, "block_n": 16, "block_kw": 2}


# ------------------------------------------------------------------ keys
def test_node_key_fields():
    cfg = MVUConfig(in_features=600, out_features=64, mode="standard")
    key = autotune.node_key(cfg, epilogue="thresh", n_pixels=3, device="cpu")
    assert key == "cpu|mvu|standard|n64|k600|thresh|px3"


def test_node_key_separates_conv_geometry():
    """Two conv layers with equal (mode, N, K, px) but different geometry
    must not collide on one schedule entry."""
    cfg = MVUConfig(in_features=36, out_features=8)
    a = Node("conv_mvu", "a", {"kernel": 3, "stride": 1, "pad": 0,
                               "config": cfg})
    b = Node("conv_mvu", "b", {"kernel": 3, "stride": 2, "pad": 1,
                               "config": cfg})
    ka = autotune.node_key(cfg, device="cpu", op=autotune.op_tag(a, (14, 14, 4)))
    kb = autotune.node_key(cfg, device="cpu", op=autotune.op_tag(b, (28, 28, 4)))
    assert ka != kb
    assert "conv3s1p0@14x14x4" in ka and "conv3s2p1@28x28x4" in kb
    # dense nodes tag as plain mvu
    assert autotune.op_tag(Node("mvu", "d", {"config": cfg})) == "mvu"


def test_engine_key_stable_and_device_scoped():
    rng = np.random.default_rng(9)
    fin = _finalized(rng, [16, 8])
    k1 = autotune.engine_key(fin, device="cpu")
    k2 = autotune.engine_key(fin, device="cpu")
    k3 = autotune.engine_key(fin, device="tpu-v5e")
    assert k1 == k2 and k1 != k3
    assert k1.startswith("engine|cpu|")
