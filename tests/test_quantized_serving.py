"""Integer-deployed MVU serving: post-training quantization of a trained
model keeps its behaviour, and the deployment path runs end-to-end."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models.layers import quantize_model_params
from repro.models.model import build


def test_w8a8_serving_matches_dense_argmax():
    cfg_dense = get_reduced("yi-9b").replace(dtype="float32", remat=False)
    model_d = build(cfg_dense)
    params = model_d.init(jax.random.PRNGKey(0))

    cfg_q = cfg_dense.replace(linear_backend="mvu_w8a8")
    model_q = build(cfg_q)
    qparams = quantize_model_params(params, "mvu_w8a8")

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg_dense.vocab_size)
    sd = model_d.init_decode_state(2, 32)
    sq = model_q.init_decode_state(2, 32)
    ld, sd = model_d.prefill(params, {"tokens": toks}, sd)
    lq, sq = model_q.prefill(qparams, {"tokens": toks}, sq)
    assert bool(jnp.all(jnp.isfinite(lq)))
    # W8A8 on a random init: logits stay close, decode runs
    corr = np.corrcoef(np.asarray(ld).ravel(), np.asarray(lq).ravel())[0, 1]
    assert corr > 0.98, corr
    for _ in range(3):
        lq, sq = model_q.decode_step(qparams, sq, jnp.argmax(lq, -1))
    assert lq.shape == (2, cfg_dense.vocab_size)


def test_quantized_weight_bytes_shrink():
    cfg = get_reduced("yi-9b").replace(dtype="bfloat16")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    q = quantize_model_params(params, "mvu_w8a8")

    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    dense_proj = nbytes(params["layers"]["attn"]) + nbytes(params["layers"]["ffn"])
    q_proj = nbytes(q["layers"]["attn"]) + nbytes(q["layers"]["ffn"])
    assert q_proj < 0.6 * dense_proj  # int8 + scales vs bf16


def test_int8_kv_cache_decode_consistency():
    """int8 KV cache (per-token-head scales): greedy decode matches float."""
    cfg = get_reduced("yi-9b").replace(dtype="float32", remat=False)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)

    mq = build(cfg.replace(kv_quant=True))
    s1, s2 = m.init_decode_state(2, 32), mq.init_decode_state(2, 32)
    assert s2["caches"]["k"].dtype == jnp.int8
    l1, s1 = m.prefill(params, {"tokens": toks}, s1)
    l2, s2 = mq.prefill(params, {"tokens": toks}, s2)
    for _ in range(4):
        l1, s1 = m.decode_step(params, s1, jnp.argmax(l1, -1))
        l2, s2 = mq.decode_step(params, s2, jnp.argmax(l2, -1))
    corr = np.corrcoef(np.asarray(l1).ravel(), np.asarray(l2).ravel())[0, 1]
    assert corr > 0.99, corr
    assert (np.argmax(np.asarray(l1), -1) == np.argmax(np.asarray(l2), -1)).all()
