"""Packed-datapath tests: the bit-packed kernel family vs the ref.py
oracles (all three weight codings x all epilogues x non-divisor K, both
backends), the pack_weights build step + report accounting, the packed
ScheduleCache key space, and the packed-vs-unpacked autotuner axis."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.build import build
from repro.core import autotune, lowering
from repro.core.ir import Graph, Node
from repro.core.mvu import KernelBlocks, MVUConfig
from repro.kernels import mvu_packed, packing, ref

SHAPES = [
    (4, 8, 32),     # one whole word
    (17, 9, 33),    # K one past a word boundary
    (33, 65, 127),  # nothing divides anything
    (65, 30, 600),  # NID layer-0-like K
]


def _rand(shape, lo, hi, seed, dtype=np.int8):
    return np.random.default_rng(seed).integers(lo, hi, shape).astype(dtype)


def _epilogue_args(n, epilogue, seed):
    if epilogue == "thresh":
        t = np.sort(_rand((n, 7), -200, 200, seed, np.int32), axis=1)
        return jnp.asarray(t), None
    if epilogue == "scale":
        s = np.random.default_rng(seed).uniform(0.1, 2.0, n).astype(np.float32)
        return None, jnp.asarray(s)
    return None, None


# ------------------------------------------------- bit-exactness matrix
@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("epilogue", ["raw", "thresh", "scale"])
@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_packed_binary_matches_oracle(m, n, k, epilogue, backend):
    a = _rand((m, k), -8, 8, 1)
    wb = _rand((n, k), 0, 2, 2)
    t, s = _epilogue_args(n, epilogue, 3)
    want = np.asarray(ref.mvu_binary_ref(jnp.asarray(a), jnp.asarray(wb), t, s))
    wp = mvu_packed.pack_mvu_weights(jnp.asarray(wb), "binary")
    assert wp.dtype == jnp.uint32 and wp.shape == (n, packing.num_words(k))
    got = mvu_packed.mvu_packed(jnp.asarray(a), wp, "binary", k, t, s,
                                backend=backend, block_m=32, block_n=16,
                                block_kw=2)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("epilogue", ["raw", "thresh", "scale"])
@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_packed_int2_matches_oracle(m, n, k, epilogue, backend):
    a = _rand((m, k), -8, 8, 4)
    w = _rand((n, k), -2, 2, 5)  # signed 2-bit grid [-2, 1]
    t, s = _epilogue_args(n, epilogue, 6)
    want = np.asarray(ref.mvu_int_ref(jnp.asarray(a), jnp.asarray(w), t, s))
    wp = mvu_packed.pack_mvu_weights(jnp.asarray(w), "standard")
    assert wp.dtype == jnp.uint8 and wp.shape == (n, packing.num_int2_bytes(k))
    got = mvu_packed.mvu_packed(jnp.asarray(a), wp, "standard", k, t, s,
                                backend=backend, block_m=32, block_n=16,
                                block_k=32)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("epilogue", ["raw", "thresh", "scale"])
def test_packed_xnor_xla_matches_oracle(m, n, k, epilogue):
    """The xnor *pallas* kernel is covered by test_kernels_mvu; here the
    packed-family XLA popcount path must agree on the same packed words."""
    ab = _rand((m, k), 0, 2, 7, np.int32)
    wb = _rand((n, k), 0, 2, 8, np.int32)
    ap, wp = packing.pack_bits(jnp.asarray(ab)), packing.pack_bits(jnp.asarray(wb))
    t, s = _epilogue_args(n, epilogue, 9)
    want = np.asarray(ref.mvu_xnor_ref(ap, wp, k, t, s))
    got = mvu_packed.mvu_packed(ap, wp, "xnor", k, t, s, backend="xla")
    np.testing.assert_array_equal(np.asarray(got), want)


def test_pack_mvu_weights_rejects_wide_standard():
    w = jnp.asarray(_rand((4, 8), -8, 8, 10))
    with pytest.raises(ValueError, match="2-bit"):
        mvu_packed.pack_mvu_weights(w, "standard")


def test_packed_weight_bytes_model():
    # binary/xnor: N * ceil(K/32) words * 4 B; standard: N * ceil(K/4) B
    assert mvu_packed.packed_weight_bytes(64, 600, "binary", 1) == 64 * 19 * 4
    assert mvu_packed.packed_weight_bytes(64, 600, "xnor", 1) == 64 * 19 * 4
    assert mvu_packed.packed_weight_bytes(64, 600, "standard", 2) == 64 * 150


# --------------------------------------------------- cache key space
def test_node_key_packed_never_aliases_canonical():
    cfg = MVUConfig(in_features=64, out_features=32, mode="binary")
    plain = autotune.node_key(cfg, epilogue="thresh", device="cpu")
    packed = autotune.node_key(
        MVUConfig(**{**cfg.__dict__, "packed": True}),
        epilogue="thresh", device="cpu")
    assert plain != packed
    assert packed == plain + "|packed"
    # a cache holding both resolves each config to its own entry
    cache = autotune.ScheduleCache({
        plain: {"backend": "pallas", "block_m": 32, "block_n": 8,
                "block_k": 32, "block_kw": 1},
        packed: {"backend": "pallas", "block_m": 64, "block_n": 16,
                 "block_k": 32, "block_kw": 2, "packed": True},
    })
    assert cache.get(plain)["block_m"] == 32
    assert cache.get(packed)["packed"] is True


def test_apply_entry_round_trips_packed_flag():
    cfg = MVUConfig(in_features=64, out_features=32, mode="binary")
    entry = {"backend": "pallas", "block_m": 64, "block_n": 16,
             "block_k": 32, "block_kw": 2, "packed": True}
    tuned = autotune.apply_entry(cfg, entry)
    assert tuned.packed is True
    assert tuned.blocks == KernelBlocks(block_m=64, block_n=16, block_k=32,
                                        block_kw=2)
    # an unpacked (legacy) entry must not flip the flag on
    plain = autotune.apply_entry(cfg, {**entry, "packed": False})
    assert plain.packed is False


# ----------------------------------------------------- build integration
def _mlp_graph(dims=(24, 16, 8), bits=2, seed=3):
    rng = np.random.default_rng(seed)
    g = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(dims) - 2:
            g.append(Node("quant_act", f"act{i}", {"bits": bits,
                                                   "act_scale": 1.0}))
    return g


def _x(dims=(24,), bits=2, batch=9, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**bits, (batch, *dims)), jnp.int32)


@pytest.mark.parametrize("mode", ["binary", "standard"])
def test_pack_always_build_bit_exact_and_reported(mode):
    wb = 1 if mode == "binary" else 2
    dims = (96, 64, 8)  # whole-word K: the byte ratio hits the ideal
    packed_acc = build(_mlp_graph(dims), target="engine", mode=mode,
                       weight_bits=wb, act_bits=2, tune="off", pack="always")
    plain_acc = build(_mlp_graph(dims), target="engine", mode=mode,
                      weight_bits=wb, act_bits=2, tune="off", pack="never")
    x = _x((96,))
    np.testing.assert_array_equal(np.asarray(packed_acc(x)),
                                  np.asarray(plain_acc(x)))
    nodes = packed_acc.report.nodes
    assert nodes and all(n.packed for n in nodes)
    for n in nodes:
        assert 0 < n.weight_bytes < n.canonical_weight_bytes
        want_ratio = 8.0 if mode == "binary" else 4.0
        assert n.canonical_weight_bytes / n.weight_bytes == want_ratio
    assert all(not n.packed for n in plain_acc.report.nodes)


def test_pack_never_ignores_cached_packed_entry():
    """pack="never" must not apply a committed packed-datapath schedule --
    the storage rewrite it needs is policy-forbidden."""
    fin = lowering.finalize(lowering.lower_to_mvu(
        _mlp_graph((16, 8)), mode="binary", weight_bits=1, act_bits=2))
    mvu_nodes = [n for n in fin if n.op == "mvu"]
    assert mvu_nodes
    key = autotune.node_key(mvu_nodes[0].attrs["config"], epilogue="scale")
    cache = autotune.ScheduleCache({key: {
        "backend": "pallas", "block_m": 32, "block_n": 8, "block_k": 16,
        "block_kw": 1, "packed": True}})
    tuned = autotune.tune_graph(fin, cache=cache, mode="cache",
                                allow_packed=False)
    cfgs = [n.attrs["config"] for n in tuned if n.op == "mvu"]
    assert all(not c.packed and c.blocks is None for c in cfgs)
    # the same entry applies when packing is allowed
    tuned = autotune.tune_graph(fin, cache=cache, mode="cache",
                                allow_packed=True)
    assert any(n.attrs["config"].packed for n in tuned if n.op == "mvu")


def test_tune_node_selects_packed_with_stub_timer():
    """With a timer that always reports the challenger 3x faster, the
    winning entry is a bit-exact packed candidate (the packed axis is in
    the searched space, not bolted on after)."""
    fin = lowering.finalize(lowering.lower_to_mvu(
        _mlp_graph((64, 16)), mode="binary", weight_bits=1, act_bits=2))
    node = next(n for n in fin if n.op == "mvu")

    def fast_challenger(base_fn, fn, x, reps=3):
        return 1.0, 1.0 / 3.0, 3.0

    entry = autotune.tune_node(node, (64,), timer=fast_challenger,
                               sample_m=16, max_measure=16)
    assert entry.get("packed") is True
    assert entry["speedup"] == pytest.approx(3.0)
    # and the policy switch removes packed candidates from the search
    entry = autotune.tune_node(node, (64,), timer=fast_challenger,
                               sample_m=16, max_measure=16,
                               allow_packed=False)
    assert not entry.get("packed")


def test_tune_graph_skips_already_packed_nodes():
    """A node that already carries a tuned packed schedule (apply_entry ran
    in a prior pass) is passed through untouched -- re-tuning it under the
    |packed key would duplicate cache entries on every downstream pass."""
    fin = lowering.finalize(lowering.lower_to_mvu(
        _mlp_graph((16, 8)), mode="binary", weight_bits=1, act_bits=2))
    out: Graph = Graph()
    for n in fin:
        if n.op == "mvu":
            cfg = autotune.apply_entry(n.attrs["config"], {
                "backend": "pallas", "block_m": 32, "block_n": 8,
                "block_k": 16, "block_kw": 1, "packed": True})
            n = Node(n.op, n.name, {**n.attrs, "config": cfg}, n.params,
                     inputs=n.inputs)
        out.append(n)
    cache = autotune.ScheduleCache()

    def no_timer(*a, **kw):
        raise AssertionError("already-tuned packed node must not re-measure")

    tuned = autotune.tune_graph(out, cache=cache, mode="auto", timer=no_timer)
    assert len(cache) == 0
    assert [n.attrs["config"].packed for n in tuned if n.op == "mvu"] == [True]
