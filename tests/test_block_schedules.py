"""Property test: ANY legal block schedule is bit-exact with the oracle.

The autotuner's contract is that tile schedules are pure performance knobs:
whatever (block_m, block_n, block_k / block_kw) the search picks -- and
whatever the tuner of the future picks -- the kernel output must equal
``kernels/ref.py`` exactly, across all three weight codings, both epilogue
forms, and shapes that divide none of the tile dims.  Hypothesis sweeps
the schedule space the same way ``folding.block_candidates`` enumerates it
(nightly CI installs hypothesis; the tier-1 run skips)."""

import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.folding import divisors
from repro.kernels import ops, packing, ref


@st.composite
def _schedule_case(draw):
    mode = draw(st.sampled_from(["xnor", "binary", "standard"]))
    m = draw(st.integers(1, 24))
    n = draw(st.integers(1, 48))
    k = draw(st.integers(1, 96))
    # blocks drawn the way the tuner enumerates them: layer divisors clamped
    # to the TPU minima, plus off-divisor sizes that force padding
    bm = draw(st.sampled_from([8, 32, 128]))
    bn = draw(st.sampled_from(sorted({max(8, d) for d in divisors(n)} | {128})))
    if mode == "xnor":
        n_words = -(-k // packing.WORD_BITS)
        bk = draw(st.sampled_from(sorted(set(divisors(n_words)) | {8})))
    else:
        bk = draw(st.sampled_from(
            sorted({max(8, d) for d in divisors(k)} | {128})))
    epilogue = draw(st.sampled_from(["raw", "thresh"]))
    n_thresh = draw(st.integers(1, 7)) if epilogue == "thresh" else 0
    seed = draw(st.integers(0, 2**16))
    return mode, m, n, k, bm, bn, bk, n_thresh, seed


@settings(max_examples=30, deadline=None)
@given(_schedule_case())
def test_any_legal_schedule_is_bit_exact(case):
    mode, m, n, k, bm, bn, bk, n_thresh, seed = case
    rng = np.random.default_rng(seed)
    t = None
    if n_thresh:
        t = jnp.asarray(np.sort(
            rng.integers(-200, 200, (n, n_thresh)).astype(np.int32), axis=1))

    if mode == "xnor":
        ab = rng.integers(0, 2, (m, k)).astype(np.int32)
        wb = rng.integers(0, 2, (n, k)).astype(np.int32)
        a = packing.pack_bits(jnp.asarray(ab))
        w = packing.pack_bits(jnp.asarray(wb))
        want = ref.mvu_xnor_ref(a, w, k, t)
        got = ops.mvu(a, w, "xnor", k_bits=k, thresholds=t,
                      block_m=bm, block_n=bn, block_kw=bk)
    elif mode == "binary":
        a = jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(0, 2, (n, k)), jnp.int8)
        want = ref.mvu_binary_ref(a, w, t)
        got = ops.mvu(a, w, "binary", thresholds=t,
                      block_m=bm, block_n=bn, block_k=bk)
    else:
        a = jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(-8, 8, (n, k)), jnp.int8)
        want = ref.mvu_int_ref(a, w, t)
        got = ops.mvu(a, w, "standard", thresholds=t,
                      block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
