"""repro.serving tests: bounded admission (validation + backpressure),
continuous batcher (bit-exactness, bucket accounting, SLO-aware flush
policy), replica pool dispatch (single + multi device), metrics snapshots,
and the lower-is-better branch of the CI regression gate."""

import importlib.util
import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dataflow, lowering
from repro.core.autotune import ScheduleCache, cycle_time_key
from repro.core.engine import FusedEngine
from repro.core.ir import Node
from repro.serving import (
    AdmissionQueue,
    ContinuousBatcher,
    InputSpec,
    QueueFull,
    ReplicaPool,
    ServingMetrics,
    calibrate_cycle_time,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mlp_graph(dims=(24, 16, 8), bits=2, seed=3):
    rng = np.random.default_rng(seed)
    g = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(dims) - 2:
            g.append(Node("batchnorm", f"bn{i}", {}, {
                "gamma": jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32)),
                "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
                "mean": jnp.asarray(rng.normal(0, 1, n).astype(np.float32)),
                "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
            }))
            g.append(Node("quant_act", f"act{i}", {"bits": bits, "act_scale": 1.0}))
    return lowering.finalize(
        lowering.lower_to_mvu(g, mode="standard", weight_bits=4, act_bits=bits))


def _engine(**kw):
    return FusedEngine(_mlp_graph(), **kw)


def _samples(n, k=24, bits=2, seed=0):
    return np.random.default_rng(seed).integers(0, 2**bits, (n, k)).astype(np.int32)


# ---------------------------------------------------------------- admission
def test_input_spec_validates_shape_and_dtype_at_admission():
    engine = _engine()
    spec = InputSpec.from_graph(engine.graph)
    assert spec.shape == (24,) and spec.bits == 2
    q = AdmissionQueue(spec)
    with pytest.raises(ValueError, match="input spec"):
        q.admit(np.zeros(25, np.int32))
    with pytest.raises(ValueError, match="integer"):
        q.admit(np.zeros(24, np.float32))
    with pytest.raises(ValueError, match="input spec"):
        q.admit_batch(np.zeros((3, 23), np.int32))
    assert q.depth == 0
    q.admit(np.zeros(24, np.int32))
    assert q.depth == 1
    # non-canonical integer dtypes are converted, not rejected: the jit
    # cache must stay at one executable per bucket under any traffic
    q.admit(np.zeros(24, np.int64))
    q.admit_batch(np.zeros((2, 24), np.int8))
    _, xs = q.pop(4)
    assert xs.dtype == np.int32


def test_queue_reject_policy_backpressure():
    q = AdmissionQueue(InputSpec((4,), 2), capacity=4)
    q.admit_batch(np.zeros((4, 4), np.int32))
    with pytest.raises(QueueFull, match="full"):
        q.admit(np.zeros(4, np.int32))
    assert q.depth == 4  # the rejected arrival left no trace
    with pytest.raises(ValueError, match="capacity"):
        q.admit_batch(np.zeros((9, 4), np.int32))  # can never fit


def test_queue_shed_policy_drops_oldest():
    q = AdmissionQueue(InputSpec((4,), 2), capacity=4, policy="shed")
    first = q.admit_batch(np.arange(16, dtype=np.int32).reshape(4, 4))
    extra = q.admit_batch(np.zeros((2, 4), np.int32))
    assert q.depth == 4
    assert [e.rid for e in q.drain_shed()] == first[:2]  # oldest made room
    entries, xs = q.pop(4)
    assert [e.rid for e in entries] == first[2:] + extra
    np.testing.assert_array_equal(xs[:2], np.arange(16).reshape(4, 4)[2:])


def test_batcher_resolves_shed_requests_so_waiters_terminate():
    """A shed rid must resolve as a CompletedRequest with out=None -- the
    documented pop_result/poll wait loop has to terminate, not spin."""
    engine = _engine()
    batcher = ContinuousBatcher(engine, batch_buckets=(1, 4),
                                queue_capacity=4, policy="shed")
    xs = _samples(6)
    victims = [batcher.submit(xs[i]) for i in range(4)]
    survivor_batch = batcher.submit_batch(xs[4:])  # sheds the two oldest
    r = batcher.pop_result(victims[0])
    assert r is not None and r.shed and r.out is None
    assert batcher.shed == victims[:2]
    assert batcher.metrics.counters["shed"] == 2
    batcher.drain()
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(victims[2:] + survivor_batch, start=2):
        np.testing.assert_array_equal(batcher.results[rid].out, want[i])


def test_queue_batch_enqueue_is_one_block_without_copies():
    """submit_batch must enqueue ONE block sharing the caller's buffer while
    rids stay per-sample (the legacy server looped Python-per-sample)."""
    q = AdmissionQueue(InputSpec((4,), 2), capacity=64)
    xs = _samples(6, k=4)
    rids = q.admit_batch(xs)
    assert rids == list(range(6))  # one rid per sample
    assert len(q._blocks) == 1 and np.shares_memory(q._blocks[0].xs, xs)
    # partial pops slice the block (views), preserving FIFO rid order
    entries, head = q.pop(4)
    assert [e.rid for e in entries] == [0, 1, 2, 3]
    assert np.shares_memory(head, xs)
    assert [e.rid for e in q.pop(10)[0]] == [4, 5]


def test_queue_deadlines_and_fifo_slack():
    q = AdmissionQueue(InputSpec((4,), 2), default_slo_s=0.5)
    q.admit(np.zeros(4, np.int32), now=1.0)
    q.admit(np.zeros(4, np.int32), deadline=1.2, now=1.1)
    assert q.oldest_deadline() == 1.5  # FIFO head's deadline
    assert q.min_deadline() == 1.2  # the urgent later arrival drives slack
    q.pop(1)
    assert q.oldest_deadline() == q.min_deadline() == 1.2
    q.pop(1)
    assert q.oldest_deadline() == q.min_deadline() == math.inf


# ------------------------------------------------------------------ batcher
def test_batcher_bit_exact_with_direct_engine():
    engine = _engine()
    batcher = ContinuousBatcher(engine, batch_buckets=(1, 4, 8))
    xs = _samples(13)
    rids = [batcher.submit(xs[i]) for i in range(5)]
    rids += batcher.submit_batch(xs[5:])
    batcher.drain()
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(batcher.pop_result(rid).out, want[i])
    assert batcher.outstanding == 0


def test_batcher_bucket_accounting_matches_legacy_semantics():
    """11 requests over (1,4,8) buckets: one full 8-launch plus a 3-group
    padded to 4 -- the same split/pad arithmetic the legacy server had."""
    engine = _engine()
    batcher = ContinuousBatcher(engine, batch_buckets=(1, 4, 8))
    batcher.submit_batch(_samples(11))
    batcher.drain()
    c = batcher.metrics.counters
    assert c["flushes"] == 2 and c["padded_samples"] == 1
    assert c["dispatched_samples"] == 12 and c["completed"] == 11
    with pytest.raises(ValueError, match="largest bucket"):
        batcher.bucket_for(9)


def test_slo_slack_triggers_flush_with_fake_clock():
    """Deadline-slack flushing, isolated from the idle-greedy rule: no
    launch while slack exceeds the bucket's flush budget, launch the moment
    it shrinks to one engine flush budget."""
    engine = _engine()
    batcher = ContinuousBatcher(
        engine, batch_buckets=(1, 4), greedy_when_idle=False,
        interval_s=0.010, safety=1.0)
    assert batcher.budgets[1] == pytest.approx(0.010 * engine.plan(1).n_micro)
    x = _samples(1)[0]
    batcher.submit(x, deadline=1.0, now=0.0)
    batcher.poll(now=0.5)  # slack 0.5 >> budget: keep batching
    assert batcher.metrics.counters["flushes"] == 0
    batcher.poll(now=0.995)  # slack 5ms <= 10ms budget: must leave now
    assert batcher.metrics.counters["flushes"] == 1
    batcher.drain()
    np.testing.assert_array_equal(
        batcher.results[0].out, np.asarray(engine(jnp.asarray(x[None])))[0])


def test_urgent_later_arrival_triggers_deadline_flush():
    """A tighter per-request deadline behind a no-deadline FIFO head must
    still trigger the slack flush (min_deadline, not the head's)."""
    engine = _engine()
    batcher = ContinuousBatcher(
        engine, batch_buckets=(1, 4), greedy_when_idle=False,
        interval_s=0.010, safety=1.0, slo_s=None)
    xs = _samples(2)
    batcher.submit(xs[0], now=0.0)  # deadline inf (no default SLO)
    batcher.submit(xs[1], deadline=1.0, now=0.1)  # urgent override
    batcher.poll(now=0.5)
    assert batcher.metrics.counters["flushes"] == 0
    batcher.poll(now=0.995)  # urgent slack <= budget: whole backlog ships
    assert batcher.metrics.counters["flushes"] == 1
    assert batcher.queue.depth == 0


def test_engine_server_shim_survives_backlogs_beyond_result_capacity():
    """Regression: the shim's unbounded-backlog contract must extend to the
    result store -- a giant flush must not evict its own oldest results
    before popping them (AttributeError on r.t_submit)."""
    import warnings

    from repro.launch.serve import EngineServer

    engine = _engine()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        server = EngineServer(engine, batch_buckets=(1, 4, 8))
    # functional proof at a test-sized capacity: an 11-sample backlog with
    # room for only one max bucket (8) of results works because flush
    # resolves+pops each launch before the next (one launch never exceeds
    # the max bucket, the per-cycle floor of the result store)
    server._batcher.result_capacity = 8
    rids = server.submit_batch(_samples(11))
    done = {r.rid: r for r in server.flush()}
    assert sorted(done) == rids == list(range(11))
    want = np.asarray(engine(jnp.asarray(_samples(11))))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(done[rid].out, want[i])


def test_result_store_is_bounded():
    engine = _engine()
    batcher = ContinuousBatcher(engine, batch_buckets=(1, 4),
                                result_capacity=6)
    rids = batcher.submit_batch(_samples(10))
    batcher.drain()
    assert len(batcher.results) == 6  # oldest four evicted, memory flat
    assert [r for r in rids if r in batcher.results] == rids[4:]


def test_full_bucket_flushes_even_with_slack():
    engine = _engine()
    batcher = ContinuousBatcher(
        engine, batch_buckets=(1, 4), greedy_when_idle=False,
        interval_s=10.0, slo_s=None)  # no deadline pressure at all
    batcher.submit_batch(_samples(4), now=0.0)
    batcher.poll(now=0.0)
    assert batcher.metrics.counters["flushes"] == 1  # full burst ships


def test_greedy_idle_flush_ships_partial_buckets():
    engine = _engine()
    batcher = ContinuousBatcher(engine, batch_buckets=(1, 8), interval_s=10.0)
    batcher.submit(_samples(1)[0])
    batcher.poll()  # pipeline idle: waiting buys nothing
    assert batcher.metrics.counters["flushes"] == 1


# ------------------------------------------------- schedule -> seconds bridge
def test_calibrated_cycle_time_feeds_interval_seconds():
    engine = _engine()
    cache = ScheduleCache()
    entry = calibrate_cycle_time(engine, batch=8, reps=1, cache=cache)
    assert entry["s_per_cycle"] > 0
    assert cache.get(cycle_time_key()) == entry
    s = dataflow.interval_seconds(engine.schedule, cache=cache)
    assert s == pytest.approx(
        engine.schedule.steady_state_interval * entry["s_per_cycle"])
    # no measurement in the cache: the nominal clock converts the cycles
    nominal = dataflow.interval_seconds(engine.schedule, cache=ScheduleCache())
    assert nominal == pytest.approx(
        engine.schedule.steady_state_interval / dataflow.DEFAULT_CLOCK_HZ)


# --------------------------------------------------------------------- pool
def test_pool_single_device_dispatch_resolves_bit_exact():
    engine = _engine()
    pool = ReplicaPool(engine)
    q = AdmissionQueue(InputSpec.from_graph(engine.graph))
    q.admit_batch(_samples(8))
    entries, xs = q.pop(8)
    pending = pool.dispatch(xs, entries)
    assert pool.total_inflight == 1 and not pool.idle
    ys = pending.resolve()
    assert pool.idle
    np.testing.assert_array_equal(ys, np.asarray(engine(jnp.asarray(xs))))
    assert pool.load() == {0: 1}


def test_pool_spreads_load_across_replicas_multidevice():
    """4 host devices: four max-bucket launches land one per replica
    (least-loaded), results bit-exact with the single-device engine."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import lowering
        from repro.core.engine import FusedEngine
        from repro.core.ir import Node
        from repro.serving import ContinuousBatcher

        rng = np.random.default_rng(0)
        dims, bits = (24, 16, 8), 2
        g = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
        for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
            w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
            g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        fin = lowering.finalize(
            lowering.lower_to_mvu(g, mode="standard", weight_bits=4, act_bits=bits))
        engine = FusedEngine(fin)
        assert len(jax.local_devices()) == 4

        batcher = ContinuousBatcher(engine, batch_buckets=(32,))
        assert len(batcher.pool) == 4
        xs = rng.integers(0, 4, (128, 24)).astype(np.int32)
        rids = batcher.submit_batch(xs)
        batcher.flush_all()   # 4 x 32 launches, dispatched before resolving
        assert sorted(batcher.pool.load().values()) == [1, 1, 1, 1]
        batcher.drain()
        want = np.asarray(engine(jnp.asarray(xs)))
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(batcher.results[rid].out, want[i])
        print("OK")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "OK" in proc.stdout


# ------------------------------------------------------------------ metrics
def test_metrics_snapshot_percentiles_and_padding():
    m = ServingMetrics()
    for ms in range(1, 101):
        m.observe_latency(ms / 1e3, now=ms / 10.0)
    m.count("padded_samples", 25)
    m.count("dispatched_samples", 100)
    snap = m.snapshot()
    assert snap["completed"] == 100
    assert snap["p50_ms"] == pytest.approx(50.5, rel=0.05)
    assert snap["p99_ms"] == pytest.approx(99.01, rel=0.05)
    assert snap["padding_overhead"] == pytest.approx(0.25)
    assert snap["samples_per_s"] == pytest.approx(100 / 9.9)


# ------------------------------------------------------- CI regression gate
def _gate():
    spec = importlib.util.spec_from_file_location(
        "check_bench_regression",
        os.path.join(REPO, "scripts", "check_bench_regression.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_regression_gate_handles_lower_is_better_records():
    gate = _gate()
    base = {"bit_exact": True, "speedup": 1.05, "min_speedup": 1.0,
            "lower_is_better": ["p99_vs_server"],
            "p99_vs_server": 0.6, "max_p99_vs_server": 1.0}
    ok = {"bit_exact": True, "speedup": 1.1, "p99_vs_server": 0.5}
    assert gate.check_record("r", base, ok,
                             max_regression=0.2, min_speedup=1.0) == []
    # fresh p99 above the relative ceiling fails
    bad = {**ok, "p99_vs_server": 0.8}
    errs = gate.check_record("r", base, bad,
                             max_regression=0.2, min_speedup=1.0)
    assert len(errs) == 1 and "p99_vs_server" in errs[0]
    # a committed baseline that breaks its own absolute claim fails
    broken = {**base, "p99_vs_server": 1.3}
    errs = gate.check_record("r", broken, {**ok, "p99_vs_server": 1.3},
                             max_regression=0.2, min_speedup=1.0)
    assert any("ceiling" in e for e in errs)
    # the metric must exist on both sides
    errs = gate.check_record("r", base, {"bit_exact": True, "speedup": 1.1},
                             max_regression=0.2, min_speedup=1.0)
    assert any("missing" in e for e in errs)


def test_regression_gate_flags_baseline_missing_gated_keys():
    """A fresh record gating on keys the committed baseline lacks (a grown
    benchmark with a stale baseline) must fail with a clear message, not a
    KeyError or a silently ungated metric."""
    gate = _gate()
    fresh = {"bit_exact": True, "speedup": 1.2,
             "lower_is_better": ["p99_vs_server"], "p99_vs_server": 0.5}
    # baseline predates the latency metric AND the speedup claim
    base = {"bit_exact": True}
    errs = gate.check_record("r", base, fresh,
                             max_regression=0.2, min_speedup=1.0)
    assert len(errs) == 1
    assert "lacks gated key" in errs[0]
    assert "p99_vs_server" in errs[0] and "speedup" in errs[0]
    assert "regenerate" in errs[0]
    # a fully-populated baseline stays clean
    ok_base = {"bit_exact": True, "speedup": 1.1, "min_speedup": 1.0,
               "lower_is_better": ["p99_vs_server"], "p99_vs_server": 0.6,
               "max_p99_vs_server": 1.0}
    assert gate.check_record("r", ok_base, fresh,
                             max_regression=0.2, min_speedup=1.0) == []
