"""Property test for the AdmissionQueue accounting invariant: under any
interleaving of admits / pops / sheds, every admitted rid leaves the queue
exactly once (popped, shed, or still pending) and the depth never exceeds
capacity.  A seeded randomized version always runs; the hypothesis version
(nightly CI installs hypothesis) additionally shrinks counterexamples."""

import numpy as np
import pytest

from repro.serving import AdmissionQueue, InputSpec, QueueFull

SPEC = InputSpec((4,), 2)


def _apply(q, op, arg, admitted, popped, shed):
    """One queue operation; returns nothing, mutates the ledgers."""
    if op == "admit":
        try:
            rid = q.admit(np.full(4, arg % 4, np.int32),
                          tier="best_effort" if arg % 3 == 0 else "gold")
            admitted.append(rid)
        except QueueFull:
            pass
    elif op == "admit_batch":
        n = 1 + arg % 5
        try:
            admitted.extend(q.admit_batch(np.zeros((n, 4), np.int32)))
        except (QueueFull, ValueError):
            pass
    elif op == "pop":
        entries, xs = q.pop(1 + arg % 7)
        assert len(entries) == len(xs)
        popped.extend(e.rid for e in entries)
    elif op == "shed_tier":
        q.shed_tier("best_effort")
    elif op == "drain_shed":
        shed.extend(e.rid for e in q.drain_shed())


def _check(q, admitted, popped, shed):
    shed = shed + [e.rid for e in q.drain_shed()]
    pending = q.pending_rids()
    # every admitted rid is in exactly one ledger, no rid invented
    everything = popped + shed + pending
    assert sorted(everything) == sorted(set(everything)), "rid seen twice"
    assert sorted(everything) == sorted(admitted), "rid lost or invented"
    assert q.depth == len(pending)  # depth is the pending count
    assert 0 <= q.depth <= q.capacity


OPS = ("admit", "admit_batch", "pop", "shed_tier", "drain_shed")


def _run_trace(policy, capacity, trace):
    q = AdmissionQueue(SPEC, capacity=capacity, policy=policy)
    admitted, popped, shed = [], [], []
    for op_idx, arg in trace:
        _apply(q, OPS[op_idx % len(OPS)], arg, admitted, popped, shed)
        assert q.depth <= q.capacity
    _check(q, admitted, popped, shed)


@pytest.mark.parametrize("policy", ["reject", "shed"])
def test_queue_exactly_once_accounting_randomized(policy):
    rng = np.random.default_rng(1234 if policy == "reject" else 4321)
    for _ in range(200):
        capacity = int(rng.integers(1, 12))
        trace = [(int(rng.integers(0, 64)), int(rng.integers(0, 64)))
                 for _ in range(int(rng.integers(1, 60)))]
        _run_trace(policy, capacity, trace)


def test_queue_exactly_once_accounting_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(
        policy=st.sampled_from(["reject", "shed"]),
        capacity=st.integers(min_value=1, max_value=12),
        trace=st.lists(st.tuples(st.integers(0, 63), st.integers(0, 63)),
                       min_size=1, max_size=60),
    )
    def prop(policy, capacity, trace):
        _run_trace(policy, capacity, trace)

    prop()
