import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import folding as f


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 512), st.integers(1, 2048), st.integers(1, 64))
def test_cycle_model_conservation(n, k, pixels):
    """cycles * PE * SIMD == MACs when folds divide exactly (II=1 invariant)."""
    fold = f.choose_folding(n, k)
    fold.validate(n, k)
    cycles = fold.cycles(n, k, pixels)
    assert cycles * fold.pe * fold.simd == n * k * pixels


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 512), st.integers(1, 2048))
def test_choose_folding_meets_target(n, k):
    full = f.Folding(min(128, max(d for d in f.divisors(n) if d <= 128)),
                     min(128, max(d for d in f.divisors(k) if d <= 128)))
    target = full.cycles(n, k) * 4
    fold = f.choose_folding(n, k, target_cycles=target)
    fold.validate(n, k)
    assert fold.cycles(n, k) <= max(target, full.cycles(n, k))


def test_weight_mem_depth_eq2():
    # paper Eq. 2 with Kd=4, Ic=64, Oc=64, SIMD=32, PE=32
    k = 4 * 4 * 64
    n = 64
    fold = f.Folding(32, 32)
    assert f.weight_mem_depth(n, k, fold) == (k * n) // (32 * 32)
    assert f.input_buffer_depth(k, fold) == k // 32


def test_balance_pipeline_rate_matches():
    # NID MLP shapes (Table 6): (OFM, K, pixels)
    layers = [(64, 600, 1), (64, 64, 1), (64, 64, 1), (1, 64, 1)]
    folds = f.balance_pipeline(layers, max_pe=64, max_simd=64)
    cycles = [fd.cycles(n, k, px) for fd, (n, k, px) in zip(folds, layers)]
    slowest = max(cycles)
    # every stage is within the bottleneck's interval (balanced pipeline)
    assert all(c <= slowest for c in cycles)
    # and the bottleneck cannot be improved with legal folds under the caps
    full = [
        f.Folding(max(d for d in f.divisors(n) if d <= 64),
                  max(d for d in f.divisors(k) if d <= 64)).cycles(n, k, px)
        for n, k, px in layers
    ]
    assert slowest == max(full)


def test_illegal_folding_raises():
    with pytest.raises(ValueError):
        f.Folding(3, 2).validate(64, 64)
    with pytest.raises(ValueError):
        f.Folding(2, 7).validate(64, 64)


def test_to_tpu_blocks_xnor_words():
    blocks = f.to_tpu_blocks(f.Folding(64, 64), "xnor")
    assert blocks["block_kw"] == 2  # 64 synapses = 2 packed words
    blocks = f.to_tpu_blocks(f.Folding(64, 64), "standard")
    assert blocks["block_k"] == 64 and blocks["block_n"] == 64


def test_block_candidates_contains_heuristic_and_clamps():
    n, k = 24, 96
    cands = f.block_candidates(n, k, "standard")
    heur = f.to_tpu_blocks(f.choose_folding(n, k), "standard")
    assert heur in cands
    assert all(c["block_n"] >= 8 and c["block_k"] >= 8 for c in cands)
    xc = f.block_candidates(24, 96, "xnor")
    assert all("block_kw" in c and c["block_kw"] >= 1 for c in xc)
