"""Per-kernel allclose (exact integer) checks against the ref.py oracles.

Sweeps shapes (including non-multiples of every tile dim), all three SIMD
datapaths, both epilogues, and odd block shapes — interpret mode on CPU.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, packing, ref

SHAPES = [
    (1, 1, 32),      # degenerate
    (4, 64, 64),     # PE/SIMD=small paper regime
    (33, 65, 127),   # nothing divides anything
    (128, 128, 256), # aligned
    (65, 130, 600),  # NID layer-0-like K
]
BLOCKS = [(32, 32, 64), (128, 128, 128)]


def _rand(shape, lo, hi, seed, dtype=np.int8):
    return np.random.default_rng(seed).integers(lo, hi, shape).astype(dtype)


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("bm,bn,bk", BLOCKS)
def test_standard_matches_oracle(m, n, k, bm, bn, bk):
    a = _rand((m, k), -8, 8, 1)
    w = _rand((n, k), -8, 8, 2)
    want = np.asarray(ref.mvu_int_ref(jnp.asarray(a), jnp.asarray(w)))
    got = ops.mvu(jnp.asarray(a), jnp.asarray(w), "standard",
                  block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("m,n,k", SHAPES)
def test_binary_matches_oracle(m, n, k):
    a = _rand((m, k), -8, 8, 3)
    wb = _rand((n, k), 0, 2, 4)
    want = np.asarray(ref.mvu_binary_ref(jnp.asarray(a), jnp.asarray(wb)))
    got = ops.mvu(jnp.asarray(a), jnp.asarray(wb), "binary",
                  block_m=32, block_n=32, block_k=64)
    np.testing.assert_array_equal(np.asarray(got), want)
    # exact bipolar semantics
    manual = a.astype(np.int64) @ (2 * wb.astype(np.int64) - 1).T
    np.testing.assert_array_equal(want, manual)


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("bkw", [1, 4, 8])
def test_xnor_matches_oracle(m, n, k, bkw):
    ab = _rand((m, k), 0, 2, 5, np.int32)
    wb = _rand((n, k), 0, 2, 6, np.int32)
    ap = packing.pack_bits(jnp.asarray(ab))
    wp = packing.pack_bits(jnp.asarray(wb))
    want = np.asarray(ref.mvu_xnor_ref(ap, wp, k))
    got = ops.mvu(ap, wp, "xnor", k_bits=k, block_m=32, block_n=32, block_kw=bkw)
    np.testing.assert_array_equal(np.asarray(got), want)
    manual = (2 * ab - 1) @ (2 * wb - 1).T
    np.testing.assert_array_equal(want, manual)


@pytest.mark.parametrize("mode", ["standard", "binary", "xnor"])
@pytest.mark.parametrize("n_thresh", [1, 3, 15])
def test_threshold_epilogue(mode, n_thresh):
    m, n, k = 17, 29, 96
    if mode == "xnor":
        ab = _rand((m, k), 0, 2, 7, np.int32)
        wb = _rand((n, k), 0, 2, 8, np.int32)
        a = packing.pack_bits(jnp.asarray(ab))
        w = packing.pack_bits(jnp.asarray(wb))
        acc = (2 * ab - 1) @ (2 * wb - 1).T
    elif mode == "binary":
        a_ = _rand((m, k), -8, 8, 9)
        wb = _rand((n, k), 0, 2, 10)
        a, w = jnp.asarray(a_), jnp.asarray(wb)
        acc = a_.astype(np.int64) @ (2 * wb.astype(np.int64) - 1).T
    else:
        a_ = _rand((m, k), -8, 8, 11)
        w_ = _rand((n, k), -8, 8, 12)
        a, w = jnp.asarray(a_), jnp.asarray(w_)
        acc = a_.astype(np.int64) @ w_.astype(np.int64).T
    t = np.sort(_rand((n, n_thresh), -300, 300, 13, np.int32), axis=1)
    want = (acc[..., None] >= t[None]).sum(-1)
    got = ops.mvu(a, w, mode, k_bits=k, thresholds=jnp.asarray(t),
                  block_m=32, block_n=32, block_k=32, block_kw=2)
    np.testing.assert_array_equal(np.asarray(got), want)
    assert np.asarray(got).max() <= n_thresh and np.asarray(got).min() >= 0


@pytest.mark.parametrize("mode", ["standard", "binary"])
def test_scale_epilogue(mode):
    m, n, k = 19, 23, 80
    a_ = _rand((m, k), -8, 8, 14)
    if mode == "binary":
        w_ = _rand((n, k), 0, 2, 15)
        acc = a_.astype(np.int64) @ (2 * w_.astype(np.int64) - 1).T
    else:
        w_ = _rand((n, k), -8, 8, 15)
        acc = a_.astype(np.int64) @ w_.astype(np.int64).T
    s = np.random.default_rng(16).uniform(0.01, 2.0, (n,)).astype(np.float32)
    got = ops.mvu(jnp.asarray(a_), jnp.asarray(w_), mode,
                  out_scale=jnp.asarray(s), block_m=32, block_n=32, block_k=32)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), acc * s[None], rtol=1e-6)


def test_xla_backend_agrees_with_pallas():
    m, n, k = 40, 50, 160
    a = _rand((m, k), -8, 8, 17)
    w = _rand((n, k), -8, 8, 18)
    via_xla = ops.mvu(jnp.asarray(a), jnp.asarray(w), "standard", backend="xla")
    via_pl = ops.mvu(jnp.asarray(a), jnp.asarray(w), "standard", backend="pallas",
                     block_m=32, block_n=32, block_k=32)
    np.testing.assert_array_equal(np.asarray(via_xla), np.asarray(via_pl))


def test_xnor_mxu_variant_agrees():
    m, n, k = 30, 40, 222
    ab = _rand((m, k), 0, 2, 19, np.int32)
    wb = _rand((n, k), 0, 2, 20, np.int32)
    ap = packing.pack_bits(jnp.asarray(ab))
    wp = packing.pack_bits(jnp.asarray(wb))
    want = np.asarray(ref.mvu_xnor_ref(ap, wp, k))
    got = ops.xnor_mxu(ap, wp, k)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_accumulator_width_no_overflow():
    """int8 x int8 over K=8192 stays within int32 (FINN wide-accumulator claim)."""
    m, n, k = 8, 8, 8192
    a = np.full((m, k), 7, np.int8)
    w = np.full((n, k), 7, np.int8)
    got = ops.mvu(jnp.asarray(a), jnp.asarray(w), "standard",
                  block_m=8, block_n=8, block_k=256)
    assert int(np.asarray(got)[0, 0]) == 49 * k
