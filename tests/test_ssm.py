import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import ssm


def _naive_recurrence(x, dt, a_log, b_mat, c_mat):
    a = -np.exp(np.asarray(a_log))
    xn, dtn, bn, cn = map(np.asarray, (x, dt, b_mat, c_mat))
    B, S, H, P = xn.shape
    G, N = bn.shape[2], bn.shape[3]
    rep = H // G
    state = np.zeros((B, H, P, N))
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        da = np.exp(dtn[:, t] * a[None])
        bh = np.repeat(bn[:, t], rep, axis=1)
        ch = np.repeat(cn[:, t], rep, axis=1)
        state = state * da[..., None, None] + (
            dtn[:, t][..., None, None] * xn[:, t][..., None] * bh[:, :, None, :]
        )
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, ch)
    return ys, state


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]),
       st.integers(5, 40), st.sampled_from([1, 2]))
def test_ssd_chunked_equals_recurrence(seed, chunk, s, groups):
    rng = np.random.default_rng(seed)
    B, H, P, N = 2, 4, 8, 8
    x = jnp.asarray(rng.normal(size=(B, s, H, P)).astype(np.float32))
    dt = jax.nn.softplus(jnp.asarray(rng.normal(size=(B, s, H)).astype(np.float32)))
    a_log = jnp.asarray(rng.uniform(0, 1, H).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(B, s, groups, N)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(B, s, groups, N)).astype(np.float32))
    y, fs = ssm.ssd_chunked(x, dt, a_log, bm, cm, chunk=chunk)
    y_ref, s_ref = _naive_recurrence(x, dt, a_log, bm, cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), s_ref, rtol=1e-4, atol=1e-4)


def test_ssd_init_state_continuation():
    rng = np.random.default_rng(0)
    B, S, H, P, G, N = 1, 24, 2, 4, 1, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jax.nn.softplus(jnp.asarray(rng.normal(size=(B, S, H)).astype(np.float32)))
    a_log = jnp.asarray(rng.uniform(0, 1, H).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32))
    y_full, s_full = ssm.ssd_chunked(x, dt, a_log, bm, cm, chunk=8)
    y1, s1 = ssm.ssd_chunked(x[:, :10], dt[:, :10], a_log, bm[:, :10], cm[:, :10], chunk=8)
    y2, s2 = ssm.ssd_chunked(x[:, 10:], dt[:, 10:], a_log, bm[:, 10:], cm[:, 10:],
                             chunk=8, init_state=s1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=1e-4, atol=1e-4)


def test_ssm_forward_vs_decode_steps():
    """full-seq ssm_forward == prefill conv/state + per-token decode."""
    from repro.configs import get_reduced

    cfg = get_reduced("mamba2-780m").replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    p = ssm.ssm_init(key, cfg, jnp.float32)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.2

    y_full = ssm.ssm_forward(p, cfg, x, chunk=4)

    cache = ssm.init_ssm_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y, cache = ssm.ssm_decode_step(p, cfg, x[:, t : t + 1], cache)
        ys.append(y)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                               rtol=5e-3, atol=5e-3)


def test_causal_conv_is_causal():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 10, 3)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    b = jnp.zeros((3,))
    y1 = ssm._causal_conv(x, w, b)
    x2 = x.at[:, 7:].set(99.0)  # perturb the future
    y2 = ssm._causal_conv(x2, w, b)
    np.testing.assert_allclose(np.asarray(y1[:, :7]), np.asarray(y2[:, :7]), rtol=1e-5)
