"""End-to-end behaviour tests: train + crash/restart equivalence, the NID
use case, the data pipeline, and the fault-tolerance manager."""

import itertools
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp


def _tiny_model():
    from repro.configs import get_reduced
    from repro.models.model import build

    cfg = get_reduced("yi-9b").replace(dtype="float32", remat=False)
    return build(cfg), cfg


def _batches(cfg, n, b=4, s=32, seed=0):
    rng = np.random.default_rng(seed)
    return [
        {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)), jnp.int32)}
        for _ in range(n)
    ]


def test_train_crash_resume_equivalence():
    """Training interrupted at step 4 and resumed from checkpoint reaches the
    exact same loss trajectory as the uninterrupted run."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import train_loop
    from repro.optim import adamw

    model, cfg = _tiny_model()
    mesh = make_host_mesh((1, 1))
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=8)
    data = _batches(cfg, 20)

    with tempfile.TemporaryDirectory() as d1:
        _, _, full_hist = train_loop(
            model, mesh, steps=8, batch_iter=itertools.cycle(data),
            opt_cfg=opt, ckpt_dir=d1, ckpt_every=100, log_every=100)

    with tempfile.TemporaryDirectory() as d2:
        # run to step 4 with checkpoint cadence 2, then "crash"
        train_loop(model, mesh, steps=4, batch_iter=itertools.cycle(data),
                   opt_cfg=opt, ckpt_dir=d2, ckpt_every=2, log_every=100)
        # restart: resumes from step 4, continues with the same data order
        resumed_iter = itertools.cycle(data)
        for _ in range(4):  # advance the stream to where the crash happened
            next(resumed_iter)
        _, _, resumed_hist = train_loop(
            model, mesh, steps=8, batch_iter=resumed_iter,
            opt_cfg=opt, ckpt_dir=d2, ckpt_every=100, log_every=100)

    np.testing.assert_allclose(resumed_hist, full_hist[4:], rtol=1e-4, atol=1e-5)


def test_nid_end_to_end():
    from benchmarks.nid_mlp import accuracy_check

    out = accuracy_check(steps=200)
    assert out["float_acc"] > 0.95
    assert out["mvu_int_acc"] > 0.95
    # Table 7: bottleneck stage interval 12 cycles (layer 0: NF1 x SF12)
    assert out["pipeline_interval_cycles"] == 12
    assert out["bottleneck"] == "fc0.mvu"


def test_synthetic_lm_structure_learnable():
    from repro.data.pipeline import SyntheticLM

    data = SyntheticLM(64, 32, 8, seed=3, jump_prob=0.0)
    b = next(iter(data))
    data.close()
    assert b["tokens"].shape == (8, 33)
    # with jump_prob=0 the stream is exactly tok[t+1] = perm[tok[t]]
    toks = b["tokens"]
    assert (data.perm[toks[:, :-1]] == toks[:, 1:]).all()


def test_checkpoint_manager_and_watchdog():
    from repro.checkpoint import ckpt
    from repro.distributed.fault_tolerance import CheckpointManager, StepWatchdog

    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, every=2, keep=2, use_async=True)
        for step in range(1, 7):
            mgr.maybe_save(step, tree)
        mgr.wait()
        assert ckpt.available_steps(d) == [4, 6]  # keep=2
        step, restored = mgr.resume_latest(jax.eval_shape(lambda: tree))
        assert step == 6
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))

    wd = StepWatchdog(straggler_factor=50.0)
    for _ in range(10):
        with wd:
            pass
    assert wd.stragglers == 0 and wd.median >= 0


def test_atomic_save_never_leaves_partial():
    from repro.checkpoint import ckpt

    tree = {"w": jnp.zeros((8, 8))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        # a .tmp dir from a crashed save must not be listed
        os.makedirs(os.path.join(d, "step_00000002.tmp"))
        assert ckpt.available_steps(d) == [1]


def test_dryrun_helpers():
    """Pure helpers of the dry-run harness (import after jax init so the
    XLA_FLAGS side effect cannot change this process's device count)."""
    jax.devices()  # lock in single-device config first
    from repro.launch import dryrun
    from repro.launch.shapes import all_cells_with_skips

    cells = all_cells_with_skips()
    assert len(cells) == 40
    skips = [c for c in cells if c[2]]
    assert len(skips) == 7  # 7 long_500k skips for full-attention archs
    assert all(s == "long_500k" for _, s, _ in skips)

    hlo = """
HloModule m
%region_body.1 (a: bf16[8,16]) -> bf16[8,16] {
  %x = bf16[8,16]{1,0} all-reduce(bf16[8,16] %a), replica_groups={}
  ROOT %y = bf16[8,16]{1,0} add(%x, %x)
}
ENTRY %main (p: bf16[8,16]) -> bf16[8,16] {
  %w = bf16[8,16]{1,0} while(bf16[8,16] %p), body=%region_body.1, condition=%c
  %g = bf16[32,16]{1,0} all-gather(bf16[8,16] %w), dimensions={0}
  ROOT %r = bf16[8,16]{1,0} slice(%g)
}
"""
    out = dryrun.parse_collective_bytes(hlo, scan_trips=10)
    assert out["all-reduce"] == 8 * 16 * 2 * 10  # body scaled by trips
    assert out["all-gather"] == 32 * 16 * 2
    # total applies the 2x ring factor to all-reduce
    assert out["total_bytes"] == 2 * out["all-reduce"] + out["all-gather"]

    from repro.configs import get_config

    cfg = get_config("jamba-1.5-large-398b")
    v1 = dryrun.shallow_variant(cfg, 1)
    assert v1.num_layers == cfg.attn_period and v1.scan_unroll
    assert dryrun.scan_trip_count(cfg) == 9


def test_param_count_model_flops_sane():
    from repro.configs import ARCH_IDS, get_config

    # spot-check advertised sizes (within 20%)
    expect = {"yi-9b": 8.8e9, "command-r-plus-104b": 104e9,
              "qwen3-moe-235b-a22b": 235e9, "mamba2-780m": 0.78e9,
              "jamba-1.5-large-398b": 398e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count
        assert abs(got - n) / n < 0.35, (arch, got, n)
    # active < total for MoE
    for arch in ("granite-moe-3b-a800m", "qwen3-moe-235b-a22b", "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        assert cfg.active_param_count < cfg.param_count
