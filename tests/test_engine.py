"""Fused streaming engine tests: the jit-compiled engine must be bit-exact
with the eager ``dataflow.execute`` interpreter on MLP and conv (SWU) graphs
across all three MVU modes, with bn/quant epilogues fused away."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dataflow, lowering
from repro.core.engine import FusedEngine
from repro.core.ir import Graph, Node

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mlp_graph(rng, dims, bits, *, signed_gamma=True) -> Graph:
    g: Graph = [Node("input", "in", {"shape": (dims[0],), "bits": bits})]
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        w = rng.normal(0, 0.5, (n, k)).astype(np.float32)
        g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
        if i < len(dims) - 2:
            lo = -1.5 if signed_gamma else 0.5
            g.append(Node("batchnorm", f"bn{i}", {}, {
                "gamma": jnp.asarray(rng.uniform(lo, 1.5, n).astype(np.float32)),
                "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
                "mean": jnp.asarray(rng.normal(0, 2, n).astype(np.float32)),
                "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
            }))
            g.append(Node("quant_act", f"act{i}", {"bits": bits, "act_scale": 1.0}))
    return g


def _finalized(g, mode, bits):
    lowered = lowering.lower_to_mvu(g, mode=mode, weight_bits=4, act_bits=bits)
    return lowering.finalize(lowered)


@pytest.mark.parametrize("mode,bits", [("standard", 2), ("binary", 2), ("xnor", 1)])
def test_fused_engine_matches_interpreter_mlp(mode, bits):
    """Engine output == unfused interpreter output, all three datapaths
    (negative BN gammas included: flipped rows exercise weight negation in
    every weight coding)."""
    rng = np.random.default_rng(7)
    dims = [64, 32, 16, 8]
    fin = _finalized(_mlp_graph(rng, dims, bits), mode, bits)
    x = jnp.asarray(rng.integers(0, 2**bits, (13, dims[0])), jnp.int32)

    want = np.asarray(dataflow.execute(fin, x))
    engine = FusedEngine(fin)
    got = np.asarray(engine(x))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("mode,bits", [("standard", 2), ("binary", 2), ("xnor", 1)])
def test_epilogue_fusion_removes_bn_quant_nodes(mode, bits):
    rng = np.random.default_rng(3)
    fin = _finalized(_mlp_graph(rng, [24, 16, 8], bits), mode, bits)
    assert any(n.op in ("batchnorm", "quant_act") for n in fin)

    engine = FusedEngine(fin)
    ops_left = [n.op for n in engine.graph]
    assert "batchnorm" not in ops_left and "quant_act" not in ops_left
    mvus = [n for n in engine.graph if n.op == "mvu"]
    # hidden MVUs carry fused thresholds; the head keeps its raw epilogue
    assert all(m.params["mvu"].thresholds is not None for m in mvus[:-1])
    assert all(m.attrs.get("fused") for m in mvus[:-1])
    assert mvus[-1].params["mvu"].thresholds is None


@pytest.mark.parametrize("mode", ["standard", "binary"])
def test_fused_engine_matches_interpreter_conv(mode):
    """Conv (SWU-lowered) graph: engine == interpreter, epilogues fused."""
    bits = 2
    rng = np.random.default_rng(11)
    g: Graph = [Node("input", "in", {"shape": (8, 8, 3), "bits": bits})]
    w = rng.normal(0, 0.5, (3, 3, 3, 6)).astype(np.float32)
    g.append(Node("conv", "c0", {"kernel": 3, "stride": 1, "pad": 0},
                  {"w": jnp.asarray(w)}))
    n = 6
    g.append(Node("batchnorm", "bn0", {}, {
        "gamma": jnp.asarray(rng.uniform(-1.5, 1.5, n).astype(np.float32)),
        "beta": jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32)),
        "mean": jnp.asarray(rng.normal(0, 1, n).astype(np.float32)),
        "var": jnp.asarray(rng.uniform(0.5, 2, n).astype(np.float32)),
    }))
    g.append(Node("quant_act", "act0", {"bits": bits, "act_scale": 1.0}))
    fin = _finalized(g, mode, bits)
    x = jnp.asarray(rng.integers(0, 2**bits, (3, 8, 8, 3)), jnp.int32)

    want = np.asarray(dataflow.execute(fin, x))
    engine = FusedEngine(fin)
    got = np.asarray(engine(x))
    np.testing.assert_array_equal(got, want)
    # the swu+mvu pair collapses into the line-buffer conv kernel
    assert [node.op for node in engine.graph] == ["input", "conv_mvu"]


def test_microbatch_streaming_invariance():
    """Any microbatch split (including ragged last chunk) gives the same
    result as a single full-batch pass."""
    bits = 2
    rng = np.random.default_rng(5)
    fin = _finalized(_mlp_graph(rng, [32, 16, 8], bits), "standard", bits)
    x = jnp.asarray(rng.integers(0, 2**bits, (11, 32)), jnp.int32)
    base = np.asarray(FusedEngine(fin, microbatches=1)(x))
    for n_micro in (2, 3, 5, 11):
        got = np.asarray(FusedEngine(fin, microbatches=n_micro)(x))
        np.testing.assert_array_equal(got, base)


def test_stream_plan_from_schedule():
    bits = 2
    rng = np.random.default_rng(9)
    fin = _finalized(_mlp_graph(rng, [64, 32, 16, 8], bits), "standard", bits)
    engine = FusedEngine(fin)
    sched = engine.schedule
    plan = engine.plan(64)
    assert plan.interval_cycles == sched.steady_state_interval
    assert plan.fifo_bound == max(2, min(s.fifo_depth for s in sched.stages))
    # microbatch = the bottleneck stage's resident M tile (block_m): one
    # producer burst per microbatch, so 64 samples fit one burst ...
    tile = min(n.attrs["config"].block_m for n in engine.graph if n.op == "mvu")
    assert plan.n_micro == 1 and plan.microbatch == 64
    # ... and larger batches decompose into ceil(batch / tile) bursts.
    big = engine.plan(5 * tile + 1)
    assert big.n_micro == 6
    assert big.n_micro * big.microbatch >= 5 * tile + 1
    assert FusedEngine(fin).plan(1).n_micro == 1


def test_engine_server_coalesces_and_matches_direct():
    from repro.launch.serve import EngineServer

    bits = 2
    rng = np.random.default_rng(13)
    fin = _finalized(_mlp_graph(rng, [24, 16, 8], bits), "standard", bits)
    engine = FusedEngine(fin)
    server = EngineServer(engine, batch_buckets=(1, 4, 8))

    xs = rng.integers(0, 2**bits, (11, 24)).astype(np.int32)
    rids = [server.submit(x) for x in xs]
    done = {r.rid: r for r in server.flush()}
    assert sorted(done) == rids and not server._pending

    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(done[rid].out, want[i])
    # 11 requests over (1,4,8) buckets: one 8-chunk + one 4-bucket pad
    assert server.stats["flushes"] == 2
    assert server.stats["padded_samples"] == 1


def test_engine_server_splits_oversized_submissions():
    """Regression: a backlog larger than the biggest bucket must split across
    max-size bucket launches (not land in a non-existent bigger bucket)."""
    from repro.launch.serve import EngineServer

    bits = 2
    rng = np.random.default_rng(17)
    fin = _finalized(_mlp_graph(rng, [24, 16, 8], bits), "standard", bits)
    engine = FusedEngine(fin)
    server = EngineServer(engine, batch_buckets=(1, 4, 8))

    with pytest.raises(ValueError):
        server._bucket_for(9)  # no bucket holds 9 samples

    xs = rng.integers(0, 2**bits, (19, 24)).astype(np.int32)
    rids = server.submit_batch(xs)
    done = {r.rid: r for r in server.flush()}
    assert sorted(done) == rids and not server._pending
    # 19 = 8 + 8 + 3: two max-size launches, the tail padded up to 4
    assert server.stats["flushes"] == 3
    assert server.stats["padded_samples"] == 1
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(done[rid].out, want[i])


def test_engine_server_validates_requests_at_submit():
    """Regression: a sample whose shape/dtype disagrees with the engine
    graph's input spec used to die inside np.stack at flush time with a
    cryptic error; it must fail at submit with a clear ValueError."""
    from repro.launch.serve import EngineServer

    bits = 2
    rng = np.random.default_rng(23)
    fin = _finalized(_mlp_graph(rng, [24, 16, 8], bits), "standard", bits)
    server = EngineServer(FusedEngine(fin), batch_buckets=(1, 4, 8))

    with pytest.raises(ValueError, match="input spec"):
        server.submit(np.zeros(25, np.int32))  # wrong feature width
    with pytest.raises(ValueError, match="integer"):
        server.submit(np.zeros(24, np.float32))  # wrong dtype
    with pytest.raises(ValueError, match="input spec"):
        server.submit_batch(np.zeros((3, 23), np.int32))
    # nothing leaked into the queue; a well-formed request still works
    assert not server._pending and server.stats["requests"] == 0
    rid = server.submit(np.zeros(24, np.int32))
    done = server.flush()
    assert [r.rid for r in done] == [rid] and done[0].out is not None


def test_engine_server_submit_batch_enqueues_one_block():
    """Regression: submit_batch looped Python-per-sample over the batch;
    it must enqueue one shared-buffer block while rids stay per-sample."""
    from repro.launch.serve import EngineServer

    bits = 2
    rng = np.random.default_rng(29)
    fin = _finalized(_mlp_graph(rng, [24, 16, 8], bits), "standard", bits)
    engine = FusedEngine(fin)
    server = EngineServer(engine, batch_buckets=(1, 4, 8))

    xs = rng.integers(0, 2**bits, (6, 24)).astype(np.int32)
    rids = server.submit_batch(xs)
    assert rids == list(range(6))  # one rid per sample
    blocks = server._batcher.queue._blocks
    assert len(blocks) == 1 and np.shares_memory(blocks[0].xs, xs)
    done = {r.rid: r for r in server.flush()}
    want = np.asarray(engine(jnp.asarray(xs)))
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(done[rid].out, want[i])


def test_engine_pipeline_multidevice_matches_single():
    """as_pipeline on a 4-stage host mesh == single-device fused engine
    (subprocess so XLA_FLAGS never leaks into this pytest process)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import lowering
        from repro.core.engine import FusedEngine
        from repro.core.ir import Node

        rng = np.random.default_rng(0)
        d, L, bits = 32, 4, 2
        g = [Node("input", "in", {"shape": (d,), "bits": bits})]
        for i in range(L):
            w = rng.normal(0, 0.5, (d, d)).astype(np.float32)
            g.append(Node("linear", f"fc{i}", {}, {"w": jnp.asarray(w)}))
            g.append(Node("batchnorm", f"bn{i}", {}, {
                "gamma": jnp.asarray(rng.uniform(0.5, 1.5, d).astype(np.float32)),
                "beta": jnp.asarray(rng.uniform(-0.5, 0.5, d).astype(np.float32)),
                "mean": jnp.asarray(rng.normal(0, 1, d).astype(np.float32)),
                "var": jnp.asarray(rng.uniform(0.5, 2, d).astype(np.float32)),
            }))
            g.append(Node("quant_act", f"act{i}", {"bits": bits, "act_scale": 1.0}))
        fin = lowering.finalize(
            lowering.lower_to_mvu(g, mode="standard", weight_bits=4, act_bits=bits))
        eng = FusedEngine(fin)
        x = jnp.asarray(rng.integers(0, 2**bits, (8, 4, d)), jnp.int32)
        run = eng.as_pipeline(jax.make_mesh((4,), ("stage",)))
        got = np.asarray(run(x))
        want = np.asarray(eng(x.reshape(32, d))).reshape(8, 4, d)
        assert np.array_equal(got, want)
        print("OK")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "OK" in proc.stdout
